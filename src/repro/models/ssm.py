"""Mamba2 (SSD — state-space duality) layer, chunked for long sequences.

The chunked SSD algorithm follows the Mamba2 paper: within a chunk the
recurrence is computed in its dual quadratic-attention form (MXU-friendly
matmuls); across chunks a ``lax.scan`` carries the (H, P, N) state.  All
per-chunk work happens inside the scan body so peak memory is
O(chunk^2 * H), never O(S^2).

Decode is the O(1) recurrent update — this is why the SSM/hybrid archs run
the 500k-context shape (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of, rmsnorm, silu
from repro.sharding.ctx import shard_hint


def d_inner_of(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def n_ssm_heads(cfg) -> int:
    return d_inner_of(cfg) // cfg.ssm.head_dim


def conv_dim_of(cfg) -> int:
    return d_inner_of(cfg) + 2 * cfg.ssm.d_state


def init_mamba(cfg, key):
    ssm = cfg.ssm
    dt = dtype_of(cfg)
    d = cfg.d_model
    di = d_inner_of(cfg)
    H = n_ssm_heads(cfg)
    N = ssm.d_state
    ks = jax.random.split(key, 4)
    return {
        # order: [z di | x di | B N | C N | dt H]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), dt),
        "conv_w": dense_init(ks[1], (ssm.conv_width, di + 2 * N), dt, scale=0.5),
        "conv_b": jnp.zeros((di + 2 * N,), dt),
        "a_log": jnp.zeros((H,), jnp.float32),     # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dt),
        "out_proj": dense_init(ks[2], (di, d), dt),
    }


def _split_in_proj(cfg, zxbcdt):
    di = d_inner_of(cfg)
    N = cfg.ssm.d_state
    H = n_ssm_heads(cfg)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC, w, b, width):
    """Depthwise causal conv via shifted adds.  xBC: (B, S, Cd); w: (W, Cd)."""
    out = xBC * w[width - 1]
    for i in range(1, width):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[width - 1 - i]
    return silu(out + b)


def ssd_chunked(x, dt, a_neg, B, C, chunk: int):
    """Chunked SSD scan.

    x: (b, S, H, P) — *not* yet multiplied by dt;
    dt: (b, S, H) positive; a_neg: (H,) negative; B, C: (b, S, N).
    Returns y: (b, S, H, P) fp32 and final state (b, H, P, N).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(b, nc, L, H, P).astype(jnp.float32)
    dtc = dt.reshape(b, nc, L, H).astype(jnp.float32)
    Bc = B.reshape(b, nc, L, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, L, N).astype(jnp.float32)

    def chunk_step(state, inp):
        xk, dtk, Bk, Ck = inp                     # (b,L,H,P),(b,L,H),(b,L,N)
        dA = dtk * a_neg                          # (b,L,H) negative
        cs = jnp.cumsum(dA, axis=1)               # (b,L,H)
        # intra-chunk (dual quadratic form)
        seg = cs[:, :, None, :] - cs[:, None, :, :]          # (b,L,L,H)
        tri = jnp.tril(jnp.ones((L, L), bool))
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        att = jnp.einsum("bln,bmn->blm", Ck, Bk)             # (b,L,L)
        xdt = xk * dtk[..., None]                            # (b,L,H,P)
        y_diag = jnp.einsum("blm,blmh,bmhp->blhp", att, Lmat, xdt)
        # contribution of incoming state
        state_decay = jnp.exp(cs)                            # (b,L,H)
        y_off = jnp.einsum("bln,bhpn,blh->blhp", Ck, state, state_decay)
        # update state
        decay_states = jnp.exp(cs[:, -1:, :] - cs)           # (b,L,H)
        new_state = jnp.einsum("bln,blh,blhp->bhpn", Bk, decay_states * dtk, xk)
        chunk_decay = jnp.exp(cs[:, -1, :])                  # (b,H)
        state = state * chunk_decay[:, :, None, None] + new_state
        return state, y_diag + y_off

    state0 = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    state, ys = jax.lax.scan(chunk_step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * L, H, P)
    return y[:, :S], state


def mamba_sublayer(cfg, p, x, *, return_state: bool = False):
    """Full Mamba2 block: in_proj -> conv -> SSD -> gate -> out_proj.

    x: (B, S, d).  Returns (y, (conv_state, ssm_state)) if return_state.
    """
    ssm = cfg.ssm
    H, P, N = n_ssm_heads(cfg), ssm.head_dim, ssm.d_state
    di = d_inner_of(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)
    xBC_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], ssm.conv_width)
    xs = xBC_conv[..., :di]
    Bmat = xBC_conv[..., di:di + N]
    Cmat = xBC_conv[..., di + N:]
    Bsz, S = x.shape[:2]
    xh = xs.reshape(Bsz, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["a_log"])
    xh = shard_hint(xh, "ssm_heads")
    y, state = ssd_chunked(xh, dt, a_neg, Bmat, Cmat, ssm.chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = rmsnorm(y * silu(z), p["norm_scale"])
    out = y @ p["out_proj"]
    if return_state:
        w = ssm.conv_width
        conv_state = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))[:, S:S + w - 1]
        if S >= w - 1:
            conv_state = xBC[:, S - (w - 1):]
        return out, (conv_state, state)
    return out


def mamba_decode_sublayer(cfg, p, x, conv_state, ssm_state):
    """One-token recurrent update.  x: (B, 1, d).
    conv_state: (B, W-1, conv_dim); ssm_state: (B, H, P, N) fp32."""
    ssm = cfg.ssm
    H, P, N = n_ssm_heads(cfg), ssm.head_dim, ssm.d_state
    di = d_inner_of(cfg)
    W = ssm.conv_width
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)
    xBC_t = xBC[:, 0]                                   # (B, conv_dim)
    # conv: window = [conv_state, x_t]
    win = jnp.concatenate([conv_state, xBC_t[:, None]], axis=1)   # (B,W,Cd)
    conv_out = silu(jnp.einsum("bwc,wc->bc", win, p["conv_w"]) + p["conv_b"])
    new_conv_state = win[:, 1:]
    xs = conv_out[:, :di]
    Bmat = conv_out[:, di:di + N].astype(jnp.float32)
    Cmat = conv_out[:, di + N:].astype(jnp.float32)
    xh = xs.reshape(-1, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a_neg = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt * a_neg)                            # (B,H)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, Bmat, dt)
    ssm_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, Cmat)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rmsnorm(y * silu(z), p["norm_scale"])
    return y @ p["out_proj"], new_conv_state, ssm_state
