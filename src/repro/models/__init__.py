from .model import (decode_step, forward, group_layout, init_cache,
                    init_params)
from .common import count_params, tree_bytes

__all__ = ["decode_step", "forward", "group_layout", "init_cache",
           "init_params", "count_params", "tree_bytes"]
