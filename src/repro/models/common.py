"""Shared model primitives: norms, RoPE, activations, init helpers.

Everything is pure-functional JAX (no flax): params are nested dicts of
jnp arrays; layer stacks are stacked along axis 0 for ``lax.scan``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * (1.0 + scale.astype(jnp.float32)) if scale.ndim else x
    return x.astype(dt)


def layernorm(x: jax.Array, scale: jax.Array | None, bias: jax.Array | None,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(cfg, p: Params | None, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"] if p else None)
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"] if p else None, p["bias"] if p else None)
    if cfg.norm == "nonparam_ln":  # OLMo: LN without learned affine
        return layernorm(x, None, None)
    raise ValueError(cfg.norm)


def init_norm(cfg, key) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((cfg.d_model,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype_of(cfg)),
                "bias": jnp.zeros((cfg.d_model,), dtype_of(cfg))}
    return {}  # nonparam_ln


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (traced jnp — no giant HLO
    constants)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000 ** (2 * dim / d_model))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_at(pos, d_model: int) -> jax.Array:
    """Sinusoidal embedding at a dynamic scalar position -> (d_model,)."""
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000 ** (2 * dim / d_model))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return x * jax.nn.sigmoid(x)


ACTS = {"swiglu": silu, "geglu": gelu, "gelu": gelu}


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Normal init scaled by fan-in (abstract-safe under eval_shape)."""
    fan_in = shape[0]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


def count_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def tree_bytes(params) -> int:
    return sum(int(np.prod(p.shape)) * p.dtype.itemsize
               for p in jax.tree_util.tree_leaves(params))


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
