"""Mixture-of-Experts with capacity-based scatter dispatch.

Dispatch is *per batch row* (tokens of one sequence are load-balanced into
the experts independently of other rows) which (a) keeps the scatter
indices local to the ``data``-sharded batch dim under GSPMD and (b) bounds
the dispatch buffers at (B, E, C, d) with C = ceil(S*k/E * capacity_factor).
Expert weights are stacked on a leading E dim and sharded over the ``model``
axis (expert parallelism); the CCPG analogy is direct — the (E - k) inactive
experts per token never materialize activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ACTS, dense_init, dtype_of
from repro.sharding.ctx import shard_hint


def init_moe(cfg, key):
    m = cfg.moe
    dt = dtype_of(cfg)
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32, scale=d ** -0.5),
        "w_gate": dense_init(ks[1], (E, d, f), dt),
        "w_up": dense_init(ks[2], (E, d, f), dt),
        "w_down": dense_init(ks[3], (E, f, d), dt),
    }
    if m.n_shared_experts:
        S = m.n_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], (S, d, f), dt),
            "w_up": dense_init(ks2[1], (S, d, f), dt),
            "w_down": dense_init(ks2[2], (S, f, d), dt),
        }
    return p


def _capacity(S: int, E: int, k: int, cf: float) -> int:
    return max(k, int(-(-S * k * cf // E)))


DENSE_TOKEN_THRESHOLD = 32   # below this, dispatch overhead > dense compute


def moe_sublayer(cfg, p, x):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    C = _capacity(S, E, k, m.capacity_factor)
    act = ACTS[cfg.mlp]

    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(logits, k)               # (B,S,k)
    gates = jax.nn.softmax(gate_vals, axis=-1)              # renorm over top-k

    # --- load-balancing aux loss (Switch): E * sum_e f_e * p_e ------------
    sel_onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (B,S,k,E)
    frac_routed = sel_onehot.sum(2).mean(axis=(0, 1))       # (E,)
    frac_prob = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_routed * frac_prob) / k

    if B * S <= DENSE_TOKEN_THRESHOLD:
        # tiny-token path (single-token decode): computing ALL experts
        # densely is a few GFLOPs while capacity dispatch costs a
        # scatter/gather + all-to-all per layer (110 MB/layer observed on
        # the mixtral long_500k dry-run).  Combine with the top-k gate
        # mask so numerics match the dispatch path exactly (no capacity
        # drops possible at these sizes).
        gate_full = (sel_onehot * gates[..., None]).sum(2)  # (B,S,E)
        h = act(jnp.einsum("bsd,edf->bsef", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,edf->bsef", x, p["w_up"])
        y = jnp.einsum("bsef,efd,bse->bsd", h, p["w_down"],
                       gate_full.astype(h.dtype))
        if m.n_shared_experts:
            sp_ = p["shared"]
            hs = act(jnp.einsum("bsd,edf->bsef", x, sp_["w_gate"]))
            hs = hs * jnp.einsum("bsd,edf->bsef", x, sp_["w_up"])
            y = y + jnp.einsum("bsef,efd->bsd", hs, sp_["w_down"])
        return y.astype(x.dtype), aux

    def dispatch_row(x_row, idx_row, gates_row):
        # x_row (S,d); idx_row (S,k); gates_row (S,k)
        flat_e = idx_row.reshape(-1)                        # (S*k,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1   # (S*k,)
        within = pos < C
        x_rep = jnp.repeat(x_row, k, axis=0)                # (S*k, d)
        x_masked = jnp.where(within[:, None], x_rep, 0)
        buf = jnp.zeros((E, C, d), x_row.dtype)
        buf = buf.at[flat_e, pos].add(x_masked, mode="drop")
        return buf, (flat_e, pos, within)

    buf, (flat_e, pos, within) = jax.vmap(dispatch_row)(x, idx, gates)
    buf = shard_hint(buf, "moe_buffer")                     # (B,E,C,d)

    # --- expert FFN (batched over E; EP-sharded on E) ---------------------
    h = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = shard_hint(h, "moe_ffn")
    y_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])    # (B,E,C,d)

    def combine_row(y_b, flat_e_row, pos_row, within_row, gates_row):
        got = y_b.at[flat_e_row, pos_row].get(mode="fill", fill_value=0)
        got = got * (gates_row.reshape(-1, 1) * within_row[:, None]).astype(got.dtype)
        return got.reshape(S, k, d).sum(axis=1)

    y = jax.vmap(combine_row)(y_buf, flat_e, pos, within, gates)

    if m.n_shared_experts:
        sp = p["shared"]
        hs = act(jnp.einsum("bsd,edf->bsef", x, sp["w_gate"]))
        hs = hs * jnp.einsum("bsd,edf->bsef", x, sp["w_up"])
        y = y + jnp.einsum("bsef,efd->bsd", hs, sp["w_down"])

    return y.astype(x.dtype), aux
