"""Feed-forward sublayers: SwiGLU / GeGLU / plain GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ACTS, dense_init, dtype_of
from repro.sharding.ctx import shard_hint


def init_mlp(cfg, key, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, d_ff), dt),
            "w_up": dense_init(ks[1], (d, d_ff), dt),
            "w_down": dense_init(ks[2], (d_ff, d), dt),
        }
    return {  # plain 2-matrix MLP (whisper)
        "w_up": dense_init(ks[0], (d, d_ff), dt),
        "w_down": dense_init(ks[1], (d_ff, d), dt),
        "b_up": jnp.zeros((d_ff,), dt),
        "b_down": jnp.zeros((d,), dt),
    }


def mlp_sublayer(cfg, p, x):
    act = ACTS[cfg.mlp]
    if cfg.mlp in ("swiglu", "geglu"):
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
        h = shard_hint(h, "act_ffn")
        return h @ p["w_down"]
    h = act(x @ p["w_up"] + p["b_up"])
    h = shard_hint(h, "act_ffn")
    return h @ p["w_down"] + p["b_down"]
