"""Attention: GQA/MQA/MHA with chunked-flash (train/prefill) and cached decode.

Design notes (PICNIC adaptation, see DESIGN.md §3):
  * train/prefill use a blockwise online-softmax ("flash") implementation --
    ``lax.scan`` over KV chunks nested in a scan over Q chunks, so the S x S
    score matrix is never materialized.  This mirrors the paper's
    FlashAttention two-level nested loop on the IPCN mesh.
  * decode computes q against the full KV cache.  When the cache is
    sequence-sharded over the ``model`` mesh axis (the PICNIC
    distributed-scratchpad scheme) the softmax reduction becomes an
    in-network (ICI) reduction.  ``decode_attention_partial`` exposes the
    partial-softmax form used by the shard_map path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from .common import apply_rope, dense_init, dtype_of
from repro.sharding import ctx as shctx
from repro.sharding.ctx import shard_hint
from repro.sharding.shmap import shard_map

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(cfg, key):
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], (d, cfg.q_dim), dt),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), dt),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), dt),
        "wo": dense_init(ks[3], (cfg.q_dim, d), dt),
    }
    return p


def qkv_project(cfg, p, x):
    """x: (B, S, d) -> q: (B, S, Hq, D), k/v: (B, S, Hkv, D)."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise flash attention (pure jnp; the Pallas TPU kernel lives in
# repro.kernels.flash_attention and is numerically checked against this).
# ---------------------------------------------------------------------------

def _chunk_mask(qpos, kpos, causal: bool, window: Optional[int]):
    """(qc, kc) boolean validity mask for a (q-chunk, kv-chunk) pair."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    q_offset: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    kv_len: Optional[jax.Array] = None,
                    prefix_len: int = 0):
    """Blockwise attention with online softmax.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill=0;
    decode-with-history > 0).  ``kv_len``: optional dynamic valid KV length.
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    # pad to multiples
    if nq * q_chunk != Sq:
        q = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    if nk * kv_chunk != Skv:
        k = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Skv), (0, 0), (0, 0)))

    qb = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kb = k.reshape(B, nk, kv_chunk, Hkv, D)
    vb = v.reshape(B, nk, kv_chunk, Hkv, D)

    kv_valid = jnp.asarray(Skv if kv_len is None else kv_len)

    def q_step(_, qi):
        qc = qb[:, qi]                           # (B, qc, Hkv, G, D)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            kc = kb[:, ki]                       # (B, kc, Hkv, D)
            vc = vb[:, ki]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            valid = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                cm = qpos[:, None] >= kpos[None, :]
                if prefix_len:  # prefix-LM: the prefix is fully visible
                    cm |= (kpos < prefix_len)[None, :]
                valid &= cm
            if window is not None:
                valid &= (qpos[:, None] - kpos[None, :]) < window
            valid &= (kpos < kv_valid)[None, :]
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)                      # (B,Hkv,G,qc)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            l_cur = jnp.sum(p, axis=-1)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + l_cur
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)         # (B,Hkv,G,qc,D)
        out = jnp.moveaxis(out, 3, 1)                        # (B,qc,Hkv,G,D)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))     # (nq,B,qc,Hkv,G,D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, Hq, D)
    return out[:, :Sq]


def full_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                   kv_len=None, prefix_len=0):
    """Reference quadratic attention (small shapes / oracle)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qb = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    valid = jnp.ones((Sq, Skv), bool)
    if causal:
        cm = qpos[:, None] >= kpos[None, :]
        if prefix_len:
            cm |= (kpos < prefix_len)[None, :]
        valid &= cm
    if window is not None:
        valid &= (qpos[:, None] - kpos[None, :]) < window
    if kv_len is not None:
        valid &= (kpos < kv_len)[None, :]
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Sequence-parallel (shard_map) attention — train/prefill
#
# With activations sequence-sharded over the "model" axis, a plain GSPMD
# lowering of the chunked flash loop REPLICATES every chunk's compute on
# all model-axis devices (the scan serializes over the sharded dim).  The
# shard_map form keeps each device on its own Q range and all-gathers the
# (GQA-small) K/V — ring-attention-lite, and the PICNIC analogue of
# broadcasting K/V stripes from the distributed scratchpads.
# ---------------------------------------------------------------------------

def sp_flash_attention(q, k, v, *, mesh, dp_axes, seq_axes=("model",),
                       causal=True, window=None, prefix_len=0,
                       q_chunk=512, kv_chunk=512):
    """q, k, v: (B, S, H, D) with S sharded over seq_axes and B over
    dp_axes.  Returns (B, S, Hq, D) with the same sharding."""
    B, S, Hq, D = q.shape
    n_seq = 1
    for a in seq_axes:
        n_seq *= mesh.shape[a]
    S_local = S // n_seq
    bspec = dp_axes if B % _axes_size(mesh, dp_axes) == 0 else None

    def body(ql, kl, vl):
        kf = kl
        vf = vl
        for a in reversed(seq_axes):
            kf = jax.lax.all_gather(kf, a, axis=1, tiled=True)
            vf = jax.lax.all_gather(vf, a, axis=1, tiled=True)
        idx = jnp.int32(0)
        mult = 1
        for a in reversed(seq_axes):
            idx = idx + jax.lax.axis_index(a) * mult
            mult *= mesh.shape[a]
        q_offset = idx * S_local
        return flash_attention(ql, kf, vf, causal=causal, window=window,
                               prefix_len=prefix_len, q_offset=q_offset,
                               q_chunk=min(q_chunk, S_local),
                               kv_chunk=kv_chunk)

    spec = P(bspec, seq_axes, None, None)
    return shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


def _axes_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def picnic_decode_attention(q, k_new, v_new, k_cache, v_cache, cache_len, *,
                            mesh, dp_axes, seq_axes=("model",), window=None):
    """PICNIC distributed-scratchpad decode: the KV cache stays sequence-
    sharded; the new token's K/V is appended by the OWNING shard only (the
    paper's cyclic scratchpad write), each shard computes local partial
    flash-softmax terms, and the combine is a psum over the seq axes — the
    in-network reduction of paper §III.  Wire traffic per step is
    O(B*H*D) instead of O(cache).

    Returns (out (B,1,Hq,D), new_k_cache, new_v_cache)."""
    B, _, Hq, D = q.shape
    S = k_cache.shape[1]
    n_seq = _axes_size(mesh, seq_axes)
    S_local = S // n_seq
    bspec = dp_axes if B % _axes_size(mesh, dp_axes) == 0 else None
    qspec = P(bspec, None, None, None)
    cspec = P(bspec, seq_axes, None, None)

    def body(ql, knl, vnl, kl, vl):
        idx = jnp.int32(0)
        mult = 1
        for a in reversed(seq_axes):
            idx = idx + jax.lax.axis_index(a) * mult
            mult *= mesh.shape[a]
        base = idx * S_local
        # --- local append (only the owning shard's write survives) -------
        gpos = cache_len - 1
        li = jnp.clip(gpos - base, 0, S_local - 1)
        owns = (gpos >= base) & (gpos < base + S_local)

        def append(buf, new):
            cur = jax.lax.dynamic_slice(
                buf, (0, li, 0, 0), (buf.shape[0], 1) + buf.shape[2:])
            upd = jnp.where(owns, new.astype(buf.dtype), cur)
            return jax.lax.dynamic_update_slice(buf, upd, (0, li, 0, 0))

        kl = append(kl, knl)
        vl = append(vl, vnl)
        # --- local partial attention -------------------------------------
        kpos = base + jnp.arange(S_local)
        valid = kpos[None, :] < cache_len
        if window is not None:
            valid &= kpos[None, :] >= cache_len - window
        valid = jnp.broadcast_to(valid, (ql.shape[0], S_local))
        o, m, l = decode_attention_partial(ql[:, 0], kl, vl, valid)
        # --- in-network reduction (hierarchical over the seq axes) -------
        for a in seq_axes:
            M = jax.lax.pmax(m, a)
            scale = jnp.exp(m - M)
            o = jax.lax.psum(o * scale[..., None], a)
            l = jax.lax.psum(l * scale, a)
            m = M
        out = o / jnp.maximum(l[..., None], 1e-30)
        return out[:, None].astype(ql.dtype), kl, vl

    return shard_map(
        body, mesh=mesh, in_specs=(qspec, qspec, qspec, cspec, cspec),
        out_specs=(qspec, cspec, cspec), check_vma=False)(
        q, k_new, v_new, k_cache, v_cache)


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention_partial(q, k, v, valid):
    """Local partial flash-softmax terms for distributed (seq-sharded) KV.

    q: (B, Hq, D); k, v: (B, S_local, Hkv, D); valid: (B, S_local) bool.
    Returns (o, m, l): o = sum_j exp(s_j - m) v_j (fp32), m = local max,
    l = local denominator.  Combine across shards with:
      M = max_i m_i;  out = sum_i o_i * exp(m_i - M) / sum_i l_i * exp(m_i - M)
    — the PICNIC in-network reduction.
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    G = Hq // Hkv
    qb = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qb, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(jnp.float32),
                   v.astype(jnp.float32))
    return o, m, l


def combine_partials(o, m, l, axis_name: str):
    """psum/pmax combine of partial softmax terms over a mesh axis."""
    M = jax.lax.pmax(m, axis_name)
    scale = jnp.exp(m - M)
    num = jax.lax.psum(o * scale[..., None], axis_name)
    den = jax.lax.psum(l * scale, axis_name)
    return num / jnp.maximum(den[..., None], 1e-30)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None):
    """q: (B, 1, Hq, D) vs cache (B, S, Hkv, D); positions >= cache_len masked.

    Pure jnp: under jit+GSPMD a seq-sharded cache turns the reduction into
    ICI collectives automatically (baseline path).
    """
    B, _, Hq, D = q.shape
    S = k_cache.shape[1]
    kpos = jnp.arange(S)
    valid = kpos[None, :] < cache_len                          # (1 or B, S)
    if window is not None:
        valid = valid & (kpos[None, :] >= cache_len - window)
    valid = jnp.broadcast_to(valid, (B, S))
    o, m, l = decode_attention_partial(q[:, 0], k_cache, v_cache, valid)
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention sublayer (projections + rope + attention + output)
# ---------------------------------------------------------------------------

def attn_sublayer(cfg, p, x, *, positions, causal=True, impl="flash",
                  window=None, kv_len=None, prefix_len=0):
    """Bidirectional-prefix support: positions < prefix_len attend fully
    (PaliGemma image prefix); the rest is causal."""
    q, k, v = qkv_project(cfg, p, x)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    ctx = shctx.current()
    if ctx is not None and ctx.opt("sp_attention") and impl == "flash":
        seq_axes = tuple(ctx.opt("seq_axes", ("model",)))
        S = q.shape[1]
        n_seq = _axes_size(ctx.mesh, seq_axes)
        if S % n_seq == 0 and n_seq > 1:
            out = sp_flash_attention(
                q, k, v, mesh=ctx.mesh,
                dp_axes=tuple(ctx.opt("dp_axes", ("data",))),
                seq_axes=seq_axes, causal=causal, window=window,
                prefix_len=prefix_len)
            B, S = x.shape[:2]
            out = out.reshape(B, S, cfg.q_dim)
            return out @ p["wo"], (k, v)
    q = shard_hint(q, "act_heads")
    k = shard_hint(k, "act_kv_heads")
    fn = flash_attention if impl == "flash" else full_attention
    out = fn(q, k, v, causal=causal, window=window, kv_len=kv_len,
             prefix_len=prefix_len)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.q_dim)
    return out @ p["wo"], (k, v)


def attn_decode_sublayer(cfg, p, x, cache_k, cache_v, cache_len, *,
                         window=None):
    """One-token decode: x (B, 1, d). Cache is written at cache_len - 1
    (the caller appends the new K/V before calling) — here we take the
    already-updated cache."""
    q, k, v = qkv_project(cfg, p, x)
    pos = jnp.asarray(cache_len - 1)[None]
    if cfg.use_rope:
        q = apply_rope(q, pos[None, :], cfg.rope_theta)
        k = apply_rope(k, pos[None, :], cfg.rope_theta)
    B = x.shape[0]
    ctx = shctx.current()
    if ctx is not None and ctx.opt("picnic_decode"):
        seq_axes = tuple(ctx.opt("seq_axes", ("model",)))
        n_seq = _axes_size(ctx.mesh, seq_axes)
        if cache_k.shape[1] % n_seq == 0 and n_seq > 1:
            out, cache_k, cache_v = picnic_decode_attention(
                q, k, v, cache_k, cache_v, cache_len, mesh=ctx.mesh,
                dp_axes=tuple(ctx.opt("dp_axes", ("data",))),
                seq_axes=seq_axes, window=window)
            out = out.reshape(B, 1, cfg.q_dim)
            return out @ p["wo"], cache_k, cache_v
    # baseline (GSPMD) path: append then attend
    idx = cache_len - 1
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, idx, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, idx, 0, 0))
    cache_k = shard_hint(cache_k, "kv_cache")
    cache_v = shard_hint(cache_v, "kv_cache")
    out = decode_attention(q, cache_k, cache_v, cache_len, window=window)
    out = out.reshape(B, 1, cfg.q_dim)
    return out @ p["wo"], cache_k, cache_v
