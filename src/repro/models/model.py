"""Model assembly: layer blocks, scan-based stacks, train/prefill/decode.

Every architecture is expressed as a scan over homogeneous *layer groups*:

  dense/vlm : group = [attn+mlp]                      x n_layers
  moe       : group = [attn+moe]                      x n_layers      (mixtral)
              group = [attn+mlp, attn+moe]            x n_layers/2    (llama4)
  ssm       : group = [mamba]                         x n_layers
  hybrid    : group = [mamba x attn_every, shared-attn] x n_layers/attn_every
              (the shared attention block re-uses ONE param set -- zamba2)
  audio     : encoder scan + decoder scan (self+cross attention)

Group params are stacked on a leading axis so the layer stack is a single
``lax.scan`` -- essential both for compile time at 60+ layers and for the
PICNIC/CCPG analogy: only the active group's gathered weights are live.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import mlp as M
from . import moe as X
from . import ssm as S
from .common import apply_norm, dense_init, dtype_of, init_norm, sinusoidal_positions
from repro.sharding.ctx import shard_hint


# ---------------------------------------------------------------------------
# Group structure
# ---------------------------------------------------------------------------

def group_layout(cfg) -> Tuple[Tuple[str, ...], int]:
    """Returns (block kinds within a group, number of groups)."""
    if cfg.family in ("dense", "vlm"):
        return ("dense",), cfg.n_layers
    if cfg.family == "moe":
        if cfg.moe_every == 1:
            return ("moe",), cfg.n_layers
        kinds = tuple(["dense"] * (cfg.moe_every - 1) + ["moe"])
        return kinds, cfg.n_layers // cfg.moe_every
    if cfg.family == "ssm":
        return ("mamba",), cfg.n_layers
    if cfg.family == "hybrid":
        return tuple(["mamba"] * cfg.attn_every + ["shared_attn"]), \
            cfg.n_layers // cfg.attn_every
    if cfg.family == "audio":
        return ("dec",), cfg.n_layers
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Per-block init
# ---------------------------------------------------------------------------

def _init_block(cfg, kind: str, key):
    ks = jax.random.split(key, 6)
    if kind == "dense":
        return {"ln1": init_norm(cfg, ks[0]), "attn": A.init_attention(cfg, ks[1]),
                "ln2": init_norm(cfg, ks[2]), "mlp": M.init_mlp(cfg, ks[3])}
    if kind == "moe":
        return {"ln1": init_norm(cfg, ks[0]), "attn": A.init_attention(cfg, ks[1]),
                "ln2": init_norm(cfg, ks[2]), "moe": X.init_moe(cfg, ks[3])}
    if kind == "mamba":
        return {"ln1": init_norm(cfg, ks[0]), "mamba": S.init_mamba(cfg, ks[1])}
    if kind == "enc":
        return {"ln1": init_norm(cfg, ks[0]), "attn": A.init_attention(cfg, ks[1]),
                "ln2": init_norm(cfg, ks[2]), "mlp": M.init_mlp(cfg, ks[3])}
    if kind == "dec":
        return {"ln1": init_norm(cfg, ks[0]), "attn": A.init_attention(cfg, ks[1]),
                "lnx": init_norm(cfg, ks[2]), "cross": A.init_attention(cfg, ks[3]),
                "ln2": init_norm(cfg, ks[4]), "mlp": M.init_mlp(cfg, ks[5])}
    raise ValueError(kind)


def init_params(cfg, key) -> Dict[str, Any]:
    dt = dtype_of(cfg)
    kinds, n_groups = group_layout(cfg)
    k_emb, k_head, k_fn, k_layers, k_shared, k_enc = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "final_norm": init_norm(cfg, k_fn),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), dt)

    def init_group(gkey):
        gks = jax.random.split(gkey, len(kinds))
        return {f"b{i}_{kind}": _init_block(cfg, kind, gks[i])
                for i, kind in enumerate(kinds) if kind != "shared_attn"}

    gkeys = jax.random.split(k_layers, n_groups)
    params["layers"] = jax.vmap(init_group)(gkeys)

    if cfg.family == "hybrid":
        params["shared_attn"] = _init_block(cfg, "dense", k_shared)

    if cfg.is_encoder_decoder:
        eks = jax.random.split(k_enc, cfg.n_encoder_layers + 1)
        params["encoder"] = {
            "layers": jax.vmap(lambda kk: _init_block(cfg, "enc", kk))(
                jnp.stack(eks[:-1])),
            "final_norm": init_norm(cfg, eks[-1]),
        }
    return params


# ---------------------------------------------------------------------------
# Forward blocks (train / prefill)
# ---------------------------------------------------------------------------

class FwdCtx(NamedTuple):
    positions: jax.Array
    causal: bool = True
    impl: str = "flash"            # "flash" | "full"
    prefix_len: int = 0
    encoder_out: Optional[jax.Array] = None
    collect_cache: bool = False
    kv_max: int = 0                # cache allocation length (>= S)


def _block_forward(cfg, kind, p, x, ctx: FwdCtx):
    """Returns (x, cache_entry, aux)."""
    cache = {}
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "enc", "dec", "shared_attn"):
        h = apply_norm(cfg, p.get("ln1"), x)
        attn_out, (k, v) = A.attn_sublayer(
            cfg, p["attn"], h, positions=ctx.positions,
            causal=ctx.causal and kind != "enc",
            impl=ctx.impl, window=cfg.sliding_window,
            prefix_len=ctx.prefix_len)
        x = x + attn_out
        if ctx.collect_cache:
            cache = {"k": _alloc_cache(k, ctx.kv_max),
                     "v": _alloc_cache(v, ctx.kv_max)}
        if kind == "dec":
            h = apply_norm(cfg, p["lnx"], x)
            enc = ctx.encoder_out
            q, _, _ = A.qkv_project(cfg, p["cross"], h)
            ek = (enc @ p["cross"]["wk"]).reshape(
                enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim)
            ev = (enc @ p["cross"]["wv"]).reshape(
                enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim)
            co = A.full_attention(q, ek, ev, causal=False)
            x = x + co.reshape(*h.shape[:2], cfg.q_dim) @ p["cross"]["wo"]
            if ctx.collect_cache:
                cache["cross_k"], cache["cross_v"] = ek, ev
        h = apply_norm(cfg, p["ln2"], x)
        if kind == "moe":
            y, aux = X.moe_sublayer(cfg, p["moe"], h)
        else:
            y = M.mlp_sublayer(cfg, p["mlp"], h)
        x = x + y
        return x, cache, aux
    if kind == "mamba":
        h = apply_norm(cfg, p["ln1"], x)
        if ctx.collect_cache:
            y, (conv_s, ssm_s) = S.mamba_sublayer(cfg, p["mamba"], h,
                                                  return_state=True)
            cache = {"conv": conv_s, "ssm": ssm_s}
        else:
            y = S.mamba_sublayer(cfg, p["mamba"], h)
        return x + y, cache, aux
    raise ValueError(kind)


def _alloc_cache(kv, kv_max):
    """Place prefill K/V into a kv_max-length buffer."""
    B, Skv, H, D = kv.shape
    if kv_max <= Skv:
        return kv
    buf = jnp.zeros((B, kv_max, H, D), kv.dtype)
    return jax.lax.dynamic_update_slice(buf, kv, (0, 0, 0, 0))


def _scan_groups(cfg, params, x, ctx: FwdCtx, remat: bool):
    kinds, n_groups = group_layout(cfg)
    shared = params.get("shared_attn")

    def group_body(x, gp):
        caches = {}
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(kinds):
            if kind == "shared_attn":
                x, c, a = _block_forward(cfg, "shared_attn", shared, x, ctx)
                key = f"b{i}_shared"
            else:
                x, c, a = _block_forward(cfg, kind, gp[f"b{i}_{kind}"], x, ctx)
                key = f"b{i}_{kind}"
            if ctx.collect_cache:
                caches[key] = c
            aux = aux + a
        x = shard_hint(x, "act_btd")
        return x, (caches, aux)

    body = jax.checkpoint(group_body) if remat else group_body
    x, (caches, auxs) = jax.lax.scan(body, x, params["layers"])
    return x, caches, jnp.sum(auxs)


def forward(cfg, params, tokens, *, prefix_embeds=None, encoder_embeds=None,
            collect_cache=False, kv_max=0, impl=None):
    """tokens: (B, S) int32 -> logits (B, S, V).

    prefix_embeds: (B, n_prefix, d) precomputed patch embeddings (vlm stub).
    encoder_embeds: (B, enc_seq, d) precomputed frame embeddings (audio stub).
    Returns (logits, aux, cache|None).
    """
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
        S = x.shape[1]
    x = shard_hint(x, "act_btd")

    encoder_out = None
    if cfg.is_encoder_decoder:
        assert encoder_embeds is not None
        e = encoder_embeds.astype(x.dtype)
        e = e + sinusoidal_positions(e.shape[1], cfg.d_model).astype(e.dtype)[None]
        ectx = FwdCtx(positions=jnp.arange(e.shape[1]), causal=False,
                      impl="flash" if e.shape[1] > 2048 else "full")
        enc_p = params["encoder"]

        def enc_body(h, lp):
            h, _, _ = _block_forward(cfg, "enc", lp, h, ectx)
            return h, None
        body = jax.checkpoint(enc_body) if cfg.remat else enc_body
        e, _ = jax.lax.scan(body, e, enc_p["layers"])
        encoder_out = apply_norm(cfg, enc_p["final_norm"], e)
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]

    if impl is None:
        impl = "full" if (S <= 1024 or prefix_len) else "flash"
    ctx = FwdCtx(positions=jnp.arange(S), causal=True, impl=impl,
                 prefix_len=prefix_len, encoder_out=encoder_out,
                 collect_cache=collect_cache, kv_max=max(kv_max, S))
    x, caches, aux = _scan_groups(cfg, params, x, ctx, cfg.remat)
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = shard_hint(logits, "logits")
    if prefix_len:
        logits = logits[:, prefix_len:]
    return logits, aux, (caches if collect_cache else None)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    """Zero cache pytree (shapes only matter for the dry-run)."""
    kinds, n_groups = group_layout(cfg)
    dt = dtype_of(cfg)
    cache = {}
    for i, kind in enumerate(kinds):
        if kind in ("dense", "moe", "dec", "shared_attn"):
            key = f"b{i}_{kind}" if kind != "shared_attn" else f"b{i}_shared"
            c = {"k": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), dt),
                 "v": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), dt)}
            if kind == "dec":
                c["cross_k"] = jnp.zeros((n_groups, batch, cfg.encoder_seq,
                                          cfg.n_kv_heads, cfg.head_dim), dt)
                c["cross_v"] = jnp.zeros_like(c["cross_k"])
            cache[key] = c
        elif kind == "mamba":
            cache[f"b{i}_{kind}"] = {
                "conv": jnp.zeros((n_groups, batch, cfg.ssm.conv_width - 1,
                                   S.conv_dim_of(cfg)), dt),
                "ssm": jnp.zeros((n_groups, batch, S.n_ssm_heads(cfg),
                                  cfg.ssm.head_dim, cfg.ssm.d_state),
                                 jnp.float32),
            }
    return cache


def _block_decode(cfg, kind, p, x, c, cache_len):
    if kind in ("dense", "moe", "dec", "shared_attn"):
        h = apply_norm(cfg, p.get("ln1"), x)
        attn_out, ck, cv = A.attn_decode_sublayer(
            cfg, p["attn"], h, c["k"], c["v"], cache_len,
            window=cfg.sliding_window)
        x = x + attn_out
        newc = {"k": ck, "v": cv}
        if kind == "dec":
            h = apply_norm(cfg, p["lnx"], x)
            q, _, _ = A.qkv_project(cfg, p["cross"], h)
            co = A.full_attention(q, c["cross_k"], c["cross_v"], causal=False)
            x = x + co.reshape(x.shape[0], 1, cfg.q_dim) @ p["cross"]["wo"]
            newc["cross_k"], newc["cross_v"] = c["cross_k"], c["cross_v"]
        h = apply_norm(cfg, p["ln2"], x)
        if kind == "moe":
            y, _ = X.moe_sublayer(cfg, p["moe"], h)
        else:
            y = M.mlp_sublayer(cfg, p["mlp"], h)
        return x + y, newc
    if kind == "mamba":
        h = apply_norm(cfg, p["ln1"], x)
        y, conv_s, ssm_s = S.mamba_decode_sublayer(cfg, p["mamba"], h,
                                                   c["conv"], c["ssm"])
        return x + y, {"conv": conv_s, "ssm": ssm_s}
    raise ValueError(kind)


def decode_step(cfg, params, token, cache, cache_len):
    """token: (B, 1) int32; cache_len: scalar (tokens valid AFTER this step).
    Returns (logits (B,1,V), new_cache)."""
    kinds, _ = group_layout(cfg)
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.is_encoder_decoder:  # whisper: absolute sinusoidal positions
        from .common import sinusoidal_at
        x = x + sinusoidal_at(cache_len - 1, cfg.d_model).astype(x.dtype)[None, None]
    shared = params.get("shared_attn")

    def body(x, xs):
        gp, gc = xs
        newc = {}
        for i, kind in enumerate(kinds):
            if kind == "shared_attn":
                key = f"b{i}_shared"
                x, nc = _block_decode(cfg, "shared_attn", shared, x, gc[key],
                                      cache_len)
            else:
                key = f"b{i}_{kind}"
                x, nc = _block_decode(cfg, kind, gp[key], x, gc[key], cache_len)
            newc[key] = nc
        return x, newc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, new_cache
