"""JAX version-portability helpers.

The repo targets a span of JAX versions (0.4.37 → current):

* ``shard_map`` moved from ``jax.experimental.shard_map.shard_map`` to
  ``jax.shard_map``, renaming ``check_rep`` → ``check_vma`` and replacing
  the partial-manual ``auto={automatic axes}`` kwarg with
  ``axis_names={manual axes}`` (complementary sets over the mesh axes).
* ``Compiled.cost_analysis()`` returned ``[dict]`` (one dict per program)
  on older JAX and returns a plain ``dict`` on newer JAX.

This module resolves both seams once; call sites import from here (or the
higher-level :mod:`repro.sharding.shmap`) and never touch ``jax.*``
directly for these APIs.
"""
from __future__ import annotations

import inspect
import os
from typing import Any, Callable, Dict, FrozenSet, Mapping, Optional


def force_host_devices(n: int) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS,
    preserving whatever other flags are already set.  An existing
    device-count flag wins (the caller opted out).  Must run before JAX
    initializes — import this module, not jax, first."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (flags + " " if flags else "") + \
        f"--xla_force_host_platform_device_count={n}"


def resolve_shard_map() -> Callable:
    """The native shard_map entry point, wherever this JAX puts it."""
    import jax
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as legacy
    return legacy


def shard_map_param_names(fn: Optional[Callable] = None) -> FrozenSet[str]:
    """Keyword names accepted by the native shard_map (drives translation)."""
    fn = fn or resolve_shard_map()
    try:
        return frozenset(inspect.signature(fn).parameters)
    except (TypeError, ValueError):  # C-accelerated / exotic wrappers
        return frozenset({"mesh", "in_specs", "out_specs", "check_rep",
                          "auto"})


def translate_shard_map_kwargs(param_names: FrozenSet[str],
                               mesh_axis_names,
                               *,
                               check_vma: Optional[bool] = None,
                               check_rep: Optional[bool] = None,
                               axis_names=None,
                               auto=None) -> Dict[str, Any]:
    """Map the caller's (either-era) kwargs onto what this JAX accepts.

    ``check_vma`` ⇄ ``check_rep`` are the same boolean under two names.
    ``axis_names`` (the MANUAL axes, new API) and ``auto`` (the AUTOMATIC
    axes, old API) are complementary subsets of the mesh axes; omitting
    both means fully manual (the shared default).
    """
    if check_vma is not None and check_rep is not None \
            and check_vma != check_rep:
        raise ValueError("check_vma and check_rep are aliases; got "
                         f"conflicting values {check_vma} != {check_rep}")
    if axis_names is not None and auto is not None:
        both = frozenset(axis_names) | frozenset(auto)
        if frozenset(axis_names) & frozenset(auto) or \
                both != frozenset(mesh_axis_names):
            raise ValueError(
                "axis_names (manual) and auto (automatic) must partition "
                f"the mesh axes {tuple(mesh_axis_names)}; got "
                f"axis_names={axis_names} auto={auto}")

    kw: Dict[str, Any] = {}
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        if "check_vma" in param_names:
            kw["check_vma"] = check
        elif "check_rep" in param_names:
            kw["check_rep"] = check

    manual = None
    if axis_names is not None:
        manual = frozenset(axis_names)
    elif auto is not None:
        manual = frozenset(mesh_axis_names) - frozenset(auto)
    if manual is not None and manual != frozenset(mesh_axis_names):
        if "axis_names" in param_names:
            kw["axis_names"] = manual
        elif "auto" in param_names:
            kw["auto"] = frozenset(mesh_axis_names) - manual
        else:
            raise NotImplementedError(
                "this JAX's shard_map supports neither axis_names nor auto; "
                "partial-manual shard_map is unavailable")
    return kw


def cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version.

    Older JAX returns ``[dict]`` (one per program; ours are single-program),
    newer JAX returns ``dict``, and some backends return ``None``.  Indexing
    the old list with a string key is the seed-era
    ``TypeError: list indices must be integers or slices, not str``.
    """
    c = compiled.cost_analysis()
    if c is None:
        return {}
    if isinstance(c, (list, tuple)):
        if not c:
            return {}
        merged: Dict[str, float] = {}
        for prog in c:
            if isinstance(prog, Mapping):
                for k, v in prog.items():
                    merged[k] = merged.get(k, 0.0) + float(v)
        return merged
    return dict(c)
