"""End-to-end training driver.

Runs a real training loop on whatever devices exist (CPU smoke -> TPU pod;
the sharding specs are the same ones the dry-run validates at 512 chips).
Fault tolerance: periodic async checkpoints, restart-from-latest, optional
injected failures to exercise the supervisor.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --seq-len 256 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 30 --simulate-failures 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config, get_smoke_config
from repro.data import PackedStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.runtime import (RestartPolicy, StragglerDetector, WorkerFailure)
from repro.sharding import ShardingCtx, use_sharding
from repro.sharding import specs as sp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failures", type=int, default=0,
                    help="inject N worker failures to exercise restart")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    rules = sp.activation_rules(cfg, mesh, "train")
    ctx = ShardingCtx(mesh, rules)

    train_step = make_train_step(cfg, base_lr=args.lr, warmup=10,
                                 total_steps=args.steps)

    def wrapped(params, opt_state, batch):
        with use_sharding(ctx):
            return train_step(params, opt_state, batch)

    step_fn = jax.jit(wrapped, donate_argnums=(0, 1))

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    n_params = models.count_params(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={len(jax.devices())}")

    stream = PackedStream(cfg.vocab_size, args.seq_len, seed=args.seed)
    ckpt = AsyncCheckpointer(args.ckpt_dir)
    policy = RestartPolicy()
    detector = StragglerDetector(n_workers=1)

    start = 0
    if latest_step(args.ckpt_dir) is not None:
        (params, opt_state), extras = restore(
            args.ckpt_dir, (params, opt_state))
        start = extras.get("step", 0)
        stream.restore(extras["data_state"]) if "data_state" in extras else None
        print(f"restored from checkpoint at step {start}")

    failures_left = args.simulate_failures
    step = start
    losses = []
    while step < args.steps:
        batch_np = stream.next_batch(args.batch)
        if cfg.n_prefix_tokens:
            batch_np["prefix_embeds"] = np.zeros(
                (args.batch, cfg.n_prefix_tokens, cfg.d_model), np.float32)
        if cfg.is_encoder_decoder:
            batch_np["encoder_embeds"] = np.random.default_rng(step).normal(
                size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        try:
            if failures_left and step == start + 5:
                failures_left -= 1
                raise WorkerFailure(0, "(injected)")
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
        except WorkerFailure:
            now = time.time()
            policy.record_failure(now)
            if not policy.should_restart(now):
                raise
            ckpt.wait()
            ls = latest_step(args.ckpt_dir)
            if ls is not None:
                (params, opt_state), extras = restore(
                    args.ckpt_dir, jax.tree_util.tree_map(np.asarray,
                                                          (params, opt_state)))
                step = extras.get("step", 0)
                if "data_state" in extras:
                    stream.restore(extras["data_state"])
                print(f"[ft] restarted from step {step}")
            else:
                params, opt_state = init_train_state(
                    cfg, jax.random.PRNGKey(args.seed))
                step = 0
                print("[ft] no checkpoint; restarted from scratch")
            continue
        detector.record(0, time.time() - t0)
        step += 1
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"dt {time.time()-t0:.2f}s")
        if step % args.save_every == 0:
            ckpt.save(step, (params, opt_state),
                      {"step": step, "data_state": stream.snapshot()})
    ckpt.wait()
    assert losses and losses[-1] < losses[0], \
        f"loss did not improve: {losses[0]:.3f} -> {losses[-1]:.3f}"
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return losses


if __name__ == "__main__":
    main()
