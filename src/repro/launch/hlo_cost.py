"""Trip-count-aware cost analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so every
``lax.scan``-based model (all of ours — layer stacks, flash-attention
chunk loops) is undercounted by the trip count (verified experimentally:
a 10-iteration scan reports ~1/10 the flops of its unrolled twin; see
EXPERIMENTS.md §Dry-run).  This module re-derives

  * FLOPs           — from dot ops (2 * prod(out) * contracted dim)
  * HBM bytes       — operand + output bytes of top-level ops
  * collective bytes — per op type, with ring-model wire bytes

by parsing the HLO module text, building a per-computation symbol table of
shapes, and recursively multiplying ``while`` bodies by their trip counts
(parsed from the loop-condition comparison constant).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}\s\/]+?))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONSTANT_VAL = re.compile(r"constant\((\d+)\)")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call", "custom-call", "bitcast-convert",
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    operands: List[str]
    rest: str

    def out_bytes(self) -> int:
        return _shape_bytes(self.shape_str)

    def out_elems(self) -> int:
        n = 0
        for m in _SHAPE.finditer(self.shape_str):
            k = 1
            for d in m.group(2).split(","):
                if d:
                    k *= int(d)
            n += k
        return n


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)
    constants: Dict[str, int] = field(default_factory=dict)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, shape_str, op, args, rest = im.groups()
        inst = Instr(name, shape_str.strip(), op,
                     _OPERAND.findall(args), rest)
        cur.instrs.append(inst)
        cur.shapes[name] = inst.shape_str
        if op == "constant":
            cm = _CONSTANT_VAL.search(line)
            if cm:
                cur.constants[name] = int(cm.group(1))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Loop condition is `compare(counter, constant), direction=LT` — take
    the largest integer constant as the trip count (scan counters start
    at 0)."""
    best = 1
    for inst in cond.instrs:
        if inst.op == "compare":
            for o in inst.operands:
                if o in cond.constants:
                    best = max(best, cond.constants[o])
    if best == 1:
        # fall back: any constant in the condition
        for v in cond.constants.values():
            best = max(best, v)
    return max(best, 1)


def _dot_flops(comp: Computation, inst: Instr) -> float:
    out_elems = inst.out_elems()
    cm = _CONTRACT.search(inst.rest)
    contracted = 1
    if cm and inst.operands:
        lhs_shape = comp.shapes.get(inst.operands[0], "")
        dims = _shape_dims(lhs_shape)
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(dims):
                contracted *= dims[int(idx)]
    return 2.0 * out_elems * contracted


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def add_coll(self, op: str, logical: float, wire: float, count: float):
        d = self.coll.setdefault(op, {"count": 0.0, "bytes": 0.0,
                                      "wire_bytes": 0.0})
        d["count"] += count
        d["bytes"] += logical
        d["wire_bytes"] += wire

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.bytes * k)
        for op, d in self.coll.items():
            c.coll[op] = {kk: v * k for kk, v in d.items()}
        return c

    def merge(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for op, d in other.coll.items():
            self.add_coll(op, d["bytes"], d["wire_bytes"], d["count"])

    @property
    def wire_bytes(self) -> float:
        return sum(d["wire_bytes"] for d in self.coll.values())


def _collective_cost(inst: Instr, comp: Computation, total_devices: int,
                     cost: Cost):
    op = inst.op.replace("-start", "")
    out_b = inst.out_bytes()
    g = total_devices
    gm = _GROUPS_IOTA.search(inst.rest)
    if gm:
        g = int(gm.group(2))
    else:
        gm2 = _GROUPS_EXPL.search(inst.rest)
        if gm2:
            g = len(gm2.group(1).split(","))
    g = max(g, 1)
    ring = (g - 1) / g
    if op == "all-gather":
        cost.add_coll(op, out_b, out_b * ring, 1)
    elif op == "all-reduce":
        cost.add_coll(op, out_b, 2 * out_b * ring, 1)
    elif op == "reduce-scatter":
        cost.add_coll(op, out_b * g, out_b * g * ring, 1)
    elif op == "all-to-all":
        cost.add_coll(op, out_b, out_b * ring, 1)
    elif op == "collective-permute":
        cost.add_coll(op, out_b, out_b, 1)


def _called_comps(inst: Instr) -> List[str]:
    """computations referenced via calls=/body=/condition=/to_apply=..."""
    out = []
    for key in ("calls=", "body=", "condition=", "to_apply=",
                "branch_computations={"):
        i = inst.rest.find(key)
        if i < 0:
            continue
        seg = inst.rest[i + len(key):]
        out.extend(_OPERAND.findall(seg.split(")")[0].split("}")[0]))
    return out


def computation_cost(comps: Dict[str, Computation], name: str,
                     total_devices: int,
                     memo: Dict[str, Cost]) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    cost = Cost()
    if comp is None:
        memo[name] = cost
        return cost
    memo[name] = cost          # break cycles defensively
    for inst in comp.instrs:
        op = inst.op
        if op == "while":
            refs = _called_comps(inst)
            bm = re.search(r"body=%?([\w\.\-]+)", inst.rest)
            cm = re.search(r"condition=%?([\w\.\-]+)", inst.rest)
            body = bm.group(1) if bm else (refs[0] if refs else None)
            cond = cm.group(1) if cm else None
            # XLA records the trip count explicitly in backend_config
            tm = _TRIP_CFG.search(inst.rest)
            if tm:
                trips = int(tm.group(1))
            else:
                trips = _trip_count(comps[cond]) if cond in comps else 1
            if body:
                inner = computation_cost(comps, body, total_devices, {})
                cost.merge(inner.scaled(trips))
            continue
        if op.startswith(tuple(COLLECTIVES)):
            _collective_cost(inst, comp, total_devices, cost)
            cost.bytes += inst.out_bytes()
            continue
        if op == "fusion":
            # flops of dots inside the fused computation, bytes at the
            # fusion boundary (that's what touches HBM)
            for sub in _called_comps(inst):
                subc = comps.get(sub)
                if subc:
                    for si in subc.instrs:
                        if si.op == "dot":
                            cost.flops += _dot_flops(subc, si)
            cost.bytes += inst.out_bytes()
            for o in inst.operands:
                cost.bytes += _shape_bytes(comp.shapes.get(o, ""))
            continue
        if op in ("conditional", "call"):
            for sub in _called_comps(inst):
                cost.merge(computation_cost(comps, sub, total_devices, {}))
            continue
        if op == "dot":
            cost.flops += _dot_flops(comp, inst)
            cost.bytes += inst.out_bytes()
            for o in inst.operands:
                cost.bytes += _shape_bytes(comp.shapes.get(o, ""))
            continue
        if op in SKIP_BYTES_OPS:
            continue
        # slicing ops touch only the slice, not the full operand buffer
        if op in ("dynamic-slice", "gather", "slice"):
            cost.bytes += 2 * inst.out_bytes()
            continue
        if op == "dynamic-update-slice":
            upd = inst.operands[1] if len(inst.operands) > 1 else None
            ub = _shape_bytes(comp.shapes.get(upd, "")) if upd else 0
            cost.bytes += 2 * ub
            continue
        if op == "scatter":
            upd = inst.operands[2] if len(inst.operands) > 2 else None
            ub = _shape_bytes(comp.shapes.get(upd, "")) if upd else 0
            cost.bytes += 2 * ub
            continue
        # generic data-moving op (copy, reshape, broadcast, reduce,
        # convert, ...)
        cost.bytes += inst.out_bytes()
        for o in inst.operands:
            cost.bytes += _shape_bytes(comp.shapes.get(o, ""))
    memo[name] = cost
    return cost


def analyze(hlo_text: str, total_devices: int) -> Cost:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        # take the computation with the most instructions as entry
        entry = max(comps, key=lambda n: len(comps[n].instrs)) if comps else ""
    return computation_cost(comps, entry, total_devices, {})
