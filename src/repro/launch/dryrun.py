from repro.compat import force_host_devices
force_host_devices(512)   # appended to any pre-set XLA_FLAGS

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. infers param/opt/cache/batch shardings (repro.sharding.specs),
  3. jits the step function with in_/out_shardings and
     ``.lower(**ShapeDtypeStructs).compile()`` — no device allocation,
  4. records memory_analysis / cost_analysis / per-collective wire bytes
     into artifacts/dryrun/<cell>.json for the roofline table.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape decode_32k --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import compat, models
from repro.configs import SHAPES, get_config, ASSIGNED_ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_cost
from repro.launch import input_specs as ispec
from repro.launch import roofline as rl
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim import make_optimizer
from repro.sharding import specs as sp
from repro.sharding.ctx import ShardingCtx, use_sharding

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def cell_applicable(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k-token decode is "
                       "quadratic/unbounded-KV; skipped per assignment "
                       "(see DESIGN.md §6)")
    return True, ""


def build_cell(cfg, shape, mesh, *, opt_variant: str = "baseline"):
    """Returns (jit_fn, abstract_args) for the cell."""
    import dataclasses as _dc
    long_ctx = shape.name == "long_500k"
    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]
    rules = sp.activation_rules(cfg, mesh, mode, long_context=long_ctx)
    options = {}
    if opt_variant.startswith("picnic"):
        options = {
            "sp_attention": mode in ("train", "prefill"),
            "picnic_decode": mode == "decode",
            "seq_axes": ("data", "model") if long_ctx else ("model",),
            "dp_axes": sp.dp_axes(mesh),
        }
    if "fsdp16" in opt_variant:
        # weights FSDP over "model" only (shorter all-gather spans, plain
        # DP grad sync over "data"); optimizer stays 256-way sharded
        cfg = _dc.replace(cfg, fsdp_axes=("model",))
    ctx = ShardingCtx(mesh, rules, options)

    pshapes = ispec.params_shapes(cfg)
    pspecs = sp.param_specs(cfg, pshapes, mesh, mode,
                            mlp_tp="mlptp" in opt_variant)

    if shape.kind == "train" and opt_variant == "pp":
        # GPipe pipeline parallelism over the pod axis (multi-pod only)
        from repro.launch import pipeline as pp
        assert "pod" in mesh.shape, "pp variant needs the multi-pod mesh"
        # NOTE: passing activation hints inside the partial-manual
        # shard_map trips an XLA CHECK ("Invalid binary instruction opcode
        # copy") at 512 devices — documented in EXPERIMENTS.md; the pp
        # variant therefore relies on GSPMD propagation from the jit
        # shardings alone.
        step = pp.make_pp_train_step(cfg, mesh, stage_axis="pod",
                                     n_micro=8, dp_axes=("data",))
        opt_init, _ = make_optimizer(cfg.optimizer)
        oshapes = jax.eval_shape(opt_init, pshapes)
        ospecs = sp.opt_state_specs(cfg, oshapes, None, mesh)
        ppspecs = pp._stage_param_specs(pshapes, "pod")
        tokens = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)
        fn = jax.jit(
            step,
            in_shardings=sp.to_named(
                (ppspecs, ospecs, sp.P(("data",))), mesh),
            out_shardings=sp.to_named((ppspecs, ospecs, None), mesh),
            donate_argnums=(0, 1))
        return fn, (pshapes, oshapes, tokens)

    if shape.kind == "train":
        step = make_train_step(cfg)
        opt_init, _ = make_optimizer(cfg.optimizer)
        oshapes = jax.eval_shape(opt_init, pshapes)
        ospecs = sp.opt_state_specs(cfg, oshapes, pspecs, mesh)
        batch = ispec.train_batch_specs(cfg, shape)
        bspecs = sp.batch_specs(cfg, batch, mesh)

        def wrapped(params, opt_state, b):
            with use_sharding(ctx):
                return step(params, opt_state, b)

        fn = jax.jit(
            wrapped,
            in_shardings=sp.to_named((pspecs, ospecs, bspecs), mesh),
            out_shardings=sp.to_named((pspecs, ospecs, None), mesh),
            donate_argnums=(0, 1))
        args = (pshapes, oshapes, batch)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, kv_max=shape.seq_len)
        batch = ispec.prefill_batch_specs(cfg, shape)
        bspecs = sp.batch_specs(cfg, batch, mesh)
        cshapes = jax.eval_shape(
            lambda: models.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = sp.cache_specs(cfg, cshapes, mesh, long_context=long_ctx)

        def wrapped(params, b):
            with use_sharding(ctx):
                return step(params, b)

        fn = jax.jit(
            wrapped,
            in_shardings=sp.to_named((pspecs, bspecs), mesh),
            out_shardings=sp.to_named((None, cspecs), mesh))
        args = (pshapes, batch)
    else:  # decode
        step = make_serve_step(cfg)
        token, cshapes, clen = ispec.decode_arg_specs(cfg, shape)
        cspecs = sp.cache_specs(cfg, cshapes, mesh, long_context=long_ctx)
        tspec = sp.batch_specs(cfg, token, mesh)

        def wrapped(params, cache, tok, cache_len):
            with use_sharding(ctx):
                return step(params, cache, tok, cache_len)

        fn = jax.jit(
            wrapped,
            in_shardings=sp.to_named(
                (pspecs, cspecs, tspec, sp.P()), mesh),
            out_shardings=sp.to_named((tspec, cspecs), mesh),
            donate_argnums=(1,))
        args = (pshapes, cshapes, token, clen)
    return fn, args


def run_cell(arch: str, shape_name: str, mesh_name: str,
             opt_variant: str = "baseline", save: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": mesh_name, "variant": opt_variant}
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(rec, save)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    nchips = mesh.devices.size
    t0 = time.time()
    try:
        fn, args = build_cell(cfg, shape, mesh, opt_variant=opt_variant)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compat.cost_analysis(compiled)   # list-vs-dict normalized
        hlo = compiled.as_text()
        # trip-count-aware accounting (xla cost_analysis counts while
        # bodies once — see hlo_cost.py + EXPERIMENTS.md §Dry-run)
        parsed = hlo_cost.analyze(hlo, nchips)
        colls = parsed.coll
        flops = parsed.flops
        wire = parsed.wire_bytes
        mode = {"train": "train", "prefill": "prefill",
                "decode": "decode"}[shape.kind]
        bytes_acc = rl.analytic_memory_bytes(
            cfg, shape, dict(mesh.shape), mode)
        terms = rl.roofline_terms(flops, bytes_acc, wire)
        mflops = rl.model_flops(cfg, shape) / nchips
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            nchips=nchips,
            memory=dict(
                argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
                output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
                temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
                code_bytes=int(getattr(mem, "generated_code_size_in_bytes", 0)),
                alias_bytes=int(getattr(mem, "alias_size_in_bytes", 0)),
            ),
            flops_per_chip=flops,
            bytes_per_chip=bytes_acc,
            hlo_bytes_upper=parsed.bytes,
            xla_cost_analysis=dict(
                flops=float(cost.get("flops", 0.0)),
                bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            ),
            collectives=colls,
            wire_bytes_per_chip=wire,
            roofline=terms,
            dominant=rl.dominant_term(terms),
            model_flops_per_chip=mflops,
            useful_flop_frac=(mflops / flops if flops else None),
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _save(rec, save)
    return rec


def _save(rec, save):
    if not save:
        return
    ART.mkdir(parents=True, exist_ok=True)
    name = f"{rec['cell']}" + (
        "" if rec.get("variant", "baseline") == "baseline"
        else f"__{rec['variant']}")
    with open(ART / f"{name}.json", "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    jax.config.update("jax_compilation_cache_dir", "/tmp/jaxcache")

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for arch, shape in cells:
            t0 = time.time()
            rec = run_cell(arch, shape, mesh_name, args.variant)
            dt = time.time() - t0
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_err += st == "error"
            dom = rec.get("dominant", "-")
            print(f"[{st:7s}] {rec['cell']:60s} {dt:7.1f}s dom={dom}",
                  flush=True)
            if st == "error":
                print("   ", rec["error"][:300], flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}", flush=True)


if __name__ == "__main__":
    main()
