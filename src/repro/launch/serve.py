"""Serving driver: continuous-batched prefill + decode (JAX execution).

A minimal production-shaped server loop: requests arrive with prompts,
are prefetched into the (distributed, sequence-sharded) KV cache, and the
decode step advances ALL active slots one token per iteration (continuous
batching with slot recycling).  Greedy sampling.

This module EXECUTES tokens on the host; the matching *capacity* question
(what batching + CCPG do to latency/throughput/tokens-per-J on PICNIC
hardware under multi-user traffic) is answered by the discrete-event
engine in ``repro.launch.serving_engine``, which shares this module's
admission semantics but prices iterations with the mapped cycle model.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --n-requests 4 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config, get_smoke_config
from repro.data import ByteTokenizer
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_serve_step
from repro.sharding import ShardingCtx, use_sharding
from repro.sharding import specs as sp


@dataclasses.dataclass
class Slot:
    request_id: Optional[int] = None
    prompt_len: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = True


class Server:
    def __init__(self, cfg, *, max_batch: int = 4, max_len: int = 512,
                 seed: int = 0):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = models.init_params(cfg, jax.random.PRNGKey(seed))
        mesh = make_host_mesh()
        rules = sp.activation_rules(cfg, mesh, "decode")
        self.ctx = ShardingCtx(mesh, rules)
        serve_step = make_serve_step(cfg)

        def wrapped(params, cache, tok, cache_len):
            with use_sharding(self.ctx):
                return serve_step(params, cache, tok, cache_len)

        self.step_fn = jax.jit(wrapped, donate_argnums=(1,))
        self.cache = models.init_cache(cfg, max_batch, max_len)
        self.slots = [Slot() for _ in range(max_batch)]
        self.cur_len = 0          # shared cache length (continuous batch)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)

    def admit(self, request_id: int, prompt: np.ndarray) -> bool:
        """Prefill a prompt into a free slot (per-slot prefill via the
        decode path keeps the cache layout uniform)."""
        free = [i for i, s in enumerate(self.slots) if s.done]
        if not free:
            return False
        i = free[0]
        self.slots[i] = Slot(request_id, len(prompt), [], False)
        # feed prompt tokens through decode steps for this slot
        for t in prompt:
            tok = self.tokens.at[i, 0].set(int(t))
            self.cur_len = max(self.cur_len + 1, len(prompt))
            nxt, self.cache = self.step_fn(
                self.params, self.cache, tok, jnp.int32(self.cur_len))
            self.tokens = self.tokens.at[i, 0].set(int(nxt[i, 0]))
        return True

    def decode_round(self):
        self.cur_len += 1
        nxt, self.cache = self.step_fn(self.params, self.cache,
                                       self.tokens, jnp.int32(self.cur_len))
        self.tokens = nxt
        for i, s in enumerate(self.slots):
            if not s.done:
                s.generated.append(int(nxt[i, 0]))

    def active(self) -> int:
        return sum(not s.done for s in self.slots)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    srv = Server(cfg, max_batch=args.n_requests, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.n_requests):
        prompt = rng.integers(2, cfg.vocab_size, size=8)
        srv.admit(rid, prompt)
    for _ in range(args.max_new):
        srv.decode_round()
    dt = time.time() - t0
    total_tokens = sum(len(s.generated) for s in srv.slots)
    print(f"served {args.n_requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on "
          f"{len(jax.devices())} CPU device(s))")
    for s in srv.slots:
        assert len(s.generated) == args.max_new
        assert all(0 <= t < cfg.vocab_size for t in s.generated)
    print("OK")


if __name__ == "__main__":
    main()
