"""Serving scheduler: request admission + continuous-batching policy.

Production-shaped layer above launch/serve.Server: requests arrive with
prompt lengths, max-new-token budgets and (optional) deadlines; the
scheduler decides, each engine iteration, whether to run a PREFILL (admit
a queued request into a free slot) or a DECODE round (advance all active
slots) — the classic prefill/decode interleaving trade-off:

  * decode-priority keeps inter-token latency (ITL) low for running
    streams but starves the queue (high TTFT);
  * prefill-priority floods new requests but stalls running streams.

Policy implemented: deficit-based interleave — prefills are admitted when
(a) a slot is free AND (b) either the decode deficit counter allows it or
an admission deadline is at risk.  Starvation-free in both directions
(property-tested in tests/test_scheduler.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from enum import Enum
from typing import Callable, Deque, Dict, List, Optional, Tuple


class EventKind(str, Enum):
    PREFILL = "prefill"
    DECODE = "decode"
    FINISH = "finish"
    REJECT = "reject"
    IDLE = "idle"        # used by launch/serving_engine (gap to next arrival)
    PREEMPT = "preempt"  # paged-KV watermark eviction (recompute-on-resume)
    HANDOFF = "handoff"  # fleet: resident KV imported from a prefill node


def deadline_at_risk(head: Optional["Request"], clock: float,
                     prefill_eta_s: float) -> bool:
    """Shared TTFT-deadline test: would admitting the queue head now,
    at the given prefill cost, still miss its deadline?  Used by both
    ContinuousBatchScheduler (fixed CostModel pricing) and
    launch/serving_engine (cycle-model pricing) so the admission
    semantics cannot drift apart."""
    if head is None or head.deadline_ttft is None:
        return False
    return clock + prefill_eta_s >= head.arrival + head.deadline_ttft


@dataclasses.dataclass(order=True)
class Request:
    arrival: float
    request_id: int = dataclasses.field(compare=False)
    prompt_len: int = dataclasses.field(compare=False, default=8)
    max_new: int = dataclasses.field(compare=False, default=32)
    deadline_ttft: Optional[float] = dataclasses.field(compare=False,
                                                       default=None)
    generated: int = dataclasses.field(compare=False, default=0)
    first_token_at: Optional[float] = dataclasses.field(compare=False,
                                                        default=None)
    finished_at: Optional[float] = dataclasses.field(compare=False,
                                                     default=None)


@dataclasses.dataclass
class CostModel:
    """Engine-iteration costs (seconds) — calibrate from the dry-run
    roofline: decode round = max(memory, collective) term; prefill =
    compute term scaled by prompt length."""
    decode_round_s: float = 0.010
    prefill_s_per_token: float = 0.0005
    prefill_fixed_s: float = 0.005

    @classmethod
    def from_simulator(cls, sim, cfg, *, context: int = 512,
                       prompt_len: int = 512) -> "CostModel":
        """Calibrate the abstract engine-iteration costs from the mapped
        PICNIC cycle model (core/simulator.PicnicSimulator), so this
        policy layer and launch/serving_engine agree on time.  The decode
        round is priced at ``context``; prefill is linearized by a secant
        through prompt lengths 1 and ``prompt_len``.  The cycle model's
        prefill has a quadratic attention term, so the secant is exact at
        the two fit points and UNDERESTIMATES longer prompts (~-15% at
        2x ``prompt_len``) — calibrate at your workload's prompt scale,
        especially if TTFT deadlines matter."""
        from repro.core.scheduling import allocate_chiplets
        alloc = allocate_chiplets(cfg, sim.tile)
        f = sim.tile.frequency_hz
        dec_cyc, _ = sim.cycle_model.token_decode_cycles(cfg, alloc, context)
        p1, _ = sim.cycle_model.prefill_cycles(cfg, alloc, 1)
        pn, _ = sim.cycle_model.prefill_cycles(cfg, alloc, prompt_len)
        per_tok = max(0.0, (pn - p1) / max(prompt_len - 1, 1) / f)
        return cls(decode_round_s=dec_cyc / f,
                   prefill_s_per_token=per_tok,
                   prefill_fixed_s=p1 / f)


@dataclasses.dataclass
class SchedulerConfig:
    max_slots: int = 8
    queue_limit: int = 64
    # deficit policy: one prefill is allowed per `decode_quantum` decode
    # rounds unless a TTFT deadline forces it
    decode_quantum: int = 4


class ContinuousBatchScheduler:
    def __init__(self, cfg: SchedulerConfig = SchedulerConfig(),
                 cost: CostModel = CostModel()):
        self.cfg = cfg
        self.cost = cost
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_slots
        self.clock = 0.0
        self.decode_credit = 0
        self.events: List[Tuple[float, EventKind, int]] = []
        self.rejected = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        if len(self.queue) >= self.cfg.queue_limit:
            self.rejected += 1
            self.events.append((self.clock, EventKind.REJECT,
                                req.request_id))
            return False
        self.queue.append(req)
        return True

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _deadline_at_risk(self) -> bool:
        head = self.queue[0] if self.queue else None
        eta = self.cost.prefill_fixed_s \
            + (head.prompt_len if head else 0) * self.cost.prefill_s_per_token
        return deadline_at_risk(head, self.clock, eta)

    # ------------------------------------------------------------------
    def step(self) -> EventKind:
        """One engine iteration; returns what was scheduled."""
        slot = self._free_slot()
        want_prefill = bool(self.queue) and slot is not None
        must_prefill = want_prefill and self._deadline_at_risk()
        may_prefill = want_prefill and (
            self.decode_credit >= self.cfg.decode_quantum
            or not self._any_active())

        if must_prefill or may_prefill:
            req = self.queue.popleft()
            dt = self.cost.prefill_fixed_s \
                + req.prompt_len * self.cost.prefill_s_per_token
            self.clock += dt
            req.first_token_at = self.clock
            self.slots[slot] = req
            self.decode_credit = 0
            self.events.append((self.clock, EventKind.PREFILL,
                                req.request_id))
            return EventKind.PREFILL

        if self._any_active():
            self.clock += self.cost.decode_round_s
            self.decode_credit += 1
            for i, s in enumerate(self.slots):
                if s is None:
                    continue
                s.generated += 1
                if s.generated >= s.max_new:
                    s.finished_at = self.clock
                    self.events.append((self.clock, EventKind.FINISH,
                                        s.request_id))
                    self.slots[i] = None
            return EventKind.DECODE

        # idle: jump the clock to the next arrival if any
        if self.queue:
            self.clock = max(self.clock, self.queue[0].arrival)
            return self.step()
        return EventKind.DECODE

    def _any_active(self) -> bool:
        return any(s is not None for s in self.slots)

    def run_until_drained(self, max_iters: int = 100000) -> Dict:
        it = 0
        while (self.queue or self._any_active()) and it < max_iters:
            self.step()
            it += 1
        return self.metrics()

    # ------------------------------------------------------------------
    def metrics(self) -> Dict:
        finished = [(t, rid) for t, k, rid in self.events
                    if k == EventKind.FINISH]
        prefills = {rid: t for t, k, rid in self.events
                    if k == EventKind.PREFILL}
        return {
            "finished": len(finished),
            "rejected": self.rejected,
            "clock_s": self.clock,
            "prefill_count": len(prefills),
            "events": len(self.events),
        }


def ttft_of(sched: ContinuousBatchScheduler,
            requests: List[Request]) -> Dict[int, float]:
    return {r.request_id: (r.first_token_at - r.arrival)
            for r in requests if r.first_token_at is not None}
