"""Launch layer: meshes, train/serve steps, dry-run costing, serving.

Submodules are imported lazily by callers (several pull in JAX at import
time); the analytic serving stack (``scheduler``, ``serving_engine``,
``sweep_engine``, ``fleet``) stays JAX-free so traffic simulations run
instantly on any host.

This package is also the PUBLIC serving API (ISSUE 9): one documented
facade over the three execution tiers, so examples and benchmarks stop
importing module internals —

  * configs   — :class:`ServingConfig` (per-node engine knobs),
    :class:`FleetConfig` (pool shape / router / handoff / autoscaling)
    and :class:`FaultConfig` (deterministic fault schedules: LinkFault
    degradation windows, NodeFault crash/recover, WakeFault CCPG wake
    failures), all keyword-only and versioned with
    ``to_dict()``/``from_dict()`` round-trip and unknown-key rejection
    (`repro.launch.config`);
  * traces    — :class:`Trace` with ``Trace.poisson(...)`` /
    ``Trace.replay(rows)`` classmethods (one arrival/deadline/prefix
    spec; the legacy ``poisson_trace``/``replay_trace`` functions
    delegate to them);
  * reports   — :class:`ServingReport` (per node, with optional
    ``node_id``/``pool`` attribution) and :class:`FleetReport`
    (cluster aggregate);
  * entry points —
      serve(cfg, trace, ...)   one engine, one trace  -> ServingReport
      sweep(cells)             vectorized cell grid   -> [SweepResult]
      fleet(cfg, trace, ...)   multi-node disaggregated cluster
                                                      -> FleetReport

All three construct from the same :class:`ServingConfig` schema.  The
facade functions import their engines lazily, keeping ``import
repro.launch`` cheap and JAX-free.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.launch.config import (FaultConfig, FleetConfig, LinkFault,
                                 NodeFault, ServingConfig, WakeFault)
from repro.launch.serving_engine import (ServingReport, Trace,
                                         TrackedRequest, poisson_trace,
                                         replay_trace)

__all__ = [
    "FaultConfig", "FleetConfig", "LinkFault", "NodeFault",
    "ServingConfig", "ServingReport", "Trace",
    "TrackedRequest", "WakeFault", "poisson_trace", "replay_trace",
    "serve", "sweep", "fleet",
]


def serve(cfg, trace: Sequence[TrackedRequest], *,
          config: Optional[ServingConfig] = None, sim=None
          ) -> ServingReport:
    """Run ``trace`` through one fresh :class:`ContinuousBatchingEngine`
    built from ``config`` (default :class:`ServingConfig`)."""
    from repro.launch.serving_engine import ContinuousBatchingEngine
    eng = ContinuousBatchingEngine(
        cfg, sim=sim,
        engine=config if config is not None else ServingConfig())
    return eng.run(trace)


def sweep(cells):
    """Run a grid of `sweep_engine.SweepCell`s through one vectorized
    lockstep pass; results in cell order, each byte-identical to a
    per-cell scalar engine run."""
    from repro.launch.sweep_engine import sweep_serve
    return sweep_serve(cells)


def fleet(cfg, trace: Sequence[TrackedRequest], *,
          config: Optional[FleetConfig] = None, sim=None):
    """Run ``trace`` through a multi-node prefill/decode fleet built
    from ``config`` (default :class:`FleetConfig`); returns a
    `launch.fleet_engine.FleetReport`."""
    from repro.launch.fleet_engine import FleetEngine
    return FleetEngine(cfg, config, sim=sim).run(trace)
