"""Launch layer: meshes, train/serve steps, dry-run costing, serving.

Submodules are imported lazily by callers (several pull in JAX at import
time); the analytic serving stack (``scheduler``, ``serving_engine``)
stays JAX-free so traffic simulations run instantly on any host.
"""
