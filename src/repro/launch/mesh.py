"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when multi_pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh — used by smoke tests
    and examples on CPU (1 device -> 1x1 mesh)."""
    n = len(jax.devices())
    data = n
    model = 1
    return jax.make_mesh((data, model), ("data", "model"))
