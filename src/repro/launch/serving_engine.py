"""Continuous-batching PICNIC serving engine (discrete-event, multi-user).

Unifies three layers that previously only worked one request at a time:

  * ``launch/serve.py``     — the JAX functional server (slot recycling),
  * ``launch/scheduler.py`` — the abstract admission policy with a FIXED
    per-iteration :class:`CostModel`,
  * ``core/simulator.py``   — the analytic single-stream PicnicSimulator,

into one engine whose iteration costs come from the *mapped* PICNIC cycle
model instead of constants:

  arrival trace (Poisson / replay)
    -> admission queue (bounded, rejects at queue_limit)
    -> iteration-level scheduler: deficit-based prefill/decode interleave
       (same starvation-free policy as launch/scheduler.py), per-request
       KV-context tracking, preemption-free decode
    -> batched decode cost path (CycleModel.batched_token_decode_cycles):
       weight-stationary CIM crossbar reads amortized across the batch,
       per-request KV-scratchpad and C2C activation traffic charged fully
    -> CCPG cluster residency: co-batched requests share the active
       cluster, wake residue charged once per iteration; idle gaps between
       arrivals drop to scratchpad-retention power
    -> TimelineIR (core/timeline.Timeline): every round appends typed
       events (ComputeSpan / C2CTransfer / ClusterWake / ClusterSleep /
       TokenEmit); time, span-integrated energy, occupancy and C2C bytes
       all come from that one integrator — `engine.timeline` exports a
       chrome://tracing JSON of the whole run
    -> ServingReport: p50/p99 TTFT + end-to-end latency, aggregate
       tokens/s, tokens/J, queue-depth timeline, batch occupancy.

With ``EngineConfig.kv_cache`` set (runtime/kv_cache.KVCacheConfig) the
engine is **capacity-aware**: KV lives in fixed-size blocks over the
finite chiplet-scratchpad budget with a DRAM-hub spill tier behind the
photonic link — admission checks free *blocks* (not just free slots),
spills/remote reads land on the timeline as ``C2CTransfer`` events plus
DRAM access energy, watermark pressure preempts the newest resident
(recompute-on-resume), and ``chunked_prefill_tokens`` spreads long
prompts over several iterations.  The default (``kv_cache=None``,
capacity unbounded) stays byte-identical to the pre-paging engine —
locked by tests/golden/timeline_golden.json.

Pure Python + numpy on top of ``repro.core`` — no JAX import.  The
iteration loop is the repo's FAST SIMULATION CORE (ISSUE 5): slot state
lives in structure-of-arrays form (a numpy admit-seq column whose argmax
picks preemption victims, parallel slot-ordered active index/request/id/
context-offset lists, a running resident-context sum, an O(1)
request-id -> slot map, and a deferred-finish heap on the capacity-
unbounded path), per-iteration cycle costs come from the memoized
`CycleModel` (one O(layers) walk per distinct batch shape, O(1) affine
lookups after), and every event lands in the columnar TimelineIR
recorder — all byte-identical to the reference object path
(`EngineConfig.columnar_timeline=False` + `CycleModel(memoize=False)`),
locked by tests/test_fastpath.py and measured by
benchmarks/microbench.py.

  PYTHONPATH=src python examples/serve_continuous.py
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from bisect import bisect_left
from collections import deque
from heapq import heappop, heappush
from typing import (Callable, Deque, Dict, Iterable, List, Optional,
                    Sequence, Tuple)

import numpy as np

from repro.core.ccpg import CCPGModel
from repro.core.energy import E_DRAM_ACCESS
from repro.core.interconnect import c2c_average_power
from repro.core.scheduling import ChipletAllocation, allocate_chiplets
from repro.core.simulator import PicnicSimulator
from repro.core.timeline import Timeline
from repro.launch.config import ServingConfig
from repro.launch.scheduler import EventKind, Request, deadline_at_risk
from repro.runtime.kv_cache import (BlockAllocator, KVCacheConfig,
                                    OutOfBlocks)


@dataclasses.dataclass(order=True)
class TrackedRequest(Request):
    """A scheduler Request plus the per-request KV-context the batched
    cycle model charges for (KV-scratchpad reads are per-request)."""
    context: int = dataclasses.field(compare=False, default=0)
    admit_seq: int = dataclasses.field(compare=False, default=-1)
    # prompt token ids (prefix sharing only): the allocator chain-hashes
    # these to find/index shareable prefix blocks.  None = this request
    # never shares (the simulator otherwise has no token identities).
    prompt_tokens: Optional[Tuple[int, ...]] = dataclasses.field(
        compare=False, default=None, repr=False)

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.arrival


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------

_TOKEN_STRIDE = 1 << 24     # id-space stride between synthetic vocab pools


class Trace(List[TrackedRequest]):
    """An arrival trace: a list of :class:`TrackedRequest` with the two
    construction recipes as classmethods — ``Trace.poisson(...)`` for
    open-loop synthetic arrivals and ``Trace.replay(rows)`` for recorded
    ones (the ISSUE 9 unified trace surface, re-exported from
    ``repro.launch``).  It subclasses ``list`` so every existing
    consumer (engines, sweeps, benches) takes it unchanged; the legacy
    ``poisson_trace`` / ``replay_trace`` module functions delegate here
    and return the same object."""

    @classmethod
    def poisson(cls, n_requests: int, rate_rps: float, *, seed: int = 0,
                prompt_len: int = 512, max_new: int = 64,
                prompt_jitter: float = 0.25,
                deadline_ttft: Optional[float] = None,
                prefix_len: int = 0, prefix_frac: float = 0.9,
                prefix_groups: int = 1) -> "Trace":
        """Open-loop Poisson arrivals at ``rate_rps`` requests/second,
        with prompt lengths jittered uniformly by +-``prompt_jitter``.
        Arrivals are monotone by construction (cumulative exponential
        gaps), so ``run()`` never has to re-sort this trace.

        With ``prefix_len > 0`` every request carries synthetic
        ``prompt_tokens``: a ``prefix_frac`` share of requests open with
        one of ``prefix_groups`` shared system prompts of ``prefix_len``
        tokens (positive ids, disjoint per group) followed by
        per-request unique tokens (negative ids, disjoint per request)
        — the prefix-heavy workload the sharing allocator deduplicates.
        ``prefix_len = 0`` (the default) draws nothing extra from the
        RNG, so default traces are byte-identical to the pre-sharing
        generator."""
        rng = np.random.default_rng(seed)
        t = 0.0
        out = cls()
        for i in range(n_requests):
            t += float(rng.exponential(1.0 / rate_rps))
            p = max(1, int(round(prompt_len
                                 * (1.0 + prompt_jitter
                                    * float(rng.uniform(-1.0, 1.0))))))
            tokens: Optional[Tuple[int, ...]] = None
            if prefix_len > 0:
                shares = float(rng.uniform()) < prefix_frac
                g = (int(rng.integers(prefix_groups))
                     if prefix_groups > 1 else 0)
                uniq = -(i * _TOKEN_STRIDE + 1)     # request-private pool
                if shares:
                    pre = min(prefix_len, p - 1)
                    tokens = (tuple(g * _TOKEN_STRIDE + 1 + j
                                    for j in range(pre))
                              + tuple(uniq - j for j in range(p - pre)))
                else:
                    tokens = tuple(uniq - j for j in range(p))
            out.append(TrackedRequest(arrival=t, request_id=i,
                                      prompt_len=p, max_new=max_new,
                                      deadline_ttft=deadline_ttft,
                                      prompt_tokens=tokens))
        return out

    @classmethod
    def replay(cls, rows: Iterable) -> "Trace":
        """Replay recorded arrivals.  ``rows`` are ``(arrival_s,
        prompt_len, max_new)`` or ``(arrival_s, prompt_len, max_new,
        deadline_ttft)`` tuples, or dicts with those keys
        (``deadline_ttft`` optional in both forms).  The returned trace
        is sorted by arrival ONCE here (stable, after request ids are
        assigned in row order) so every ``run()`` re-use skips the
        per-run re-sort."""
        out = cls()
        for i, row in enumerate(rows):
            if isinstance(row, dict):
                out.append(TrackedRequest(
                    arrival=float(row["arrival_s"]), request_id=i,
                    prompt_len=int(row["prompt_len"]),
                    max_new=int(row["max_new"]),
                    deadline_ttft=row.get("deadline_ttft")))
            else:
                arrival, prompt_len, max_new, *rest = row
                deadline = rest[0] if rest else None
                out.append(TrackedRequest(
                    arrival=float(arrival), request_id=i,
                    prompt_len=int(prompt_len), max_new=int(max_new),
                    deadline_ttft=(None if deadline is None
                                   else float(deadline))))
        out.sort()      # stable on arrival — same order `sorted()` gave
        return out


def poisson_trace(n_requests: int, rate_rps: float, **kw) -> Trace:
    """Legacy spelling of :meth:`Trace.poisson` (same signature)."""
    return Trace.poisson(n_requests, rate_rps, **kw)


def replay_trace(rows: Iterable) -> Trace:
    """Legacy spelling of :meth:`Trace.replay`."""
    return Trace.replay(rows)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

# legacy positional field order of the pre-ISSUE-9 EngineConfig — the
# shim maps positional construction through it
_LEGACY_ENGINE_FIELDS = tuple(
    f.name for f in dataclasses.fields(ServingConfig))


class EngineConfig(ServingConfig):
    """DEPRECATED alias of :class:`repro.launch.config.ServingConfig`.

    Same fields and defaults; still accepts the legacy positional form.
    Construction emits a ``DeprecationWarning`` (asserted by
    tests/test_serving_api.py) — new code should build the keyword-only,
    versioned ``ServingConfig`` instead."""

    def __init__(self, *args, **kw):
        warnings.warn(
            "EngineConfig is deprecated; construct repro.launch."
            "ServingConfig (keyword-only, to_dict/from_dict) instead",
            DeprecationWarning, stacklevel=2)
        if args:
            if len(args) > len(_LEGACY_ENGINE_FIELDS):
                raise TypeError(
                    f"EngineConfig takes at most "
                    f"{len(_LEGACY_ENGINE_FIELDS)} positional arguments")
            kw = {**dict(zip(_LEGACY_ENGINE_FIELDS, args)), **kw}
        super().__init__(**kw)


@dataclasses.dataclass
class KVCacheStats:
    """Paged-KV accounting for one run (``engine.kv_stats``).  Kept out
    of ServingReport so the report schema — and its golden byte-identity
    — is untouched when paging is off."""
    n_blocks: int
    dram_blocks: int
    block_tokens: int
    preemptions: int            # watermark/OOM evictions (recompute)
    spilled_blocks: int
    spilled_bytes: int          # scratchpad -> DRAM hub over the C2C link
    dram_read_bytes: int        # per-iteration remote KV reads
    recomputed_tokens: int      # prefill work re-done after preemption
    peak_blocks_used: int
    infeasible_rejects: int     # could never fit even an empty cache
    # -- prefix sharing / copy-on-write (zeroed when sharing is off) ----
    prefix_sharing: bool = False
    prefix_hits: int = 0        # whole blocks adopted from the index
    prefix_hit_tokens: int = 0  # prompt tokens never (re)computed
    prefix_hit_rate: float = 0.0   # hit tokens / total prompt tokens
    cow_forks: int = 0
    cow_copied_bytes: int = 0
    shared_blocks_now: int = 0  # blocks with >= 2 readers at run end
    shared_blocks_peak: int = 0

    def row(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServingReport:
    """Aggregate serving metrics over one trace."""
    n_requests: int
    finished: int
    rejected: int
    wall_s: float
    busy_s: float
    idle_s: float
    tokens_generated: int
    tokens_prefilled: int
    tokens_per_s: float
    energy_J: float
    tokens_per_J: float
    p50_latency_s: float
    p99_latency_s: float
    p50_ttft_s: float
    p99_ttft_s: float
    mean_batch_occupancy: float
    max_queue_depth: int
    queue_depth: List[Tuple[float, int]]   # (clock_s, waiting) timeline
    c2c_bytes_total: int
    ccpg: bool
    # fleet attribution (launch/fleet_engine.py): which node produced this
    # report and its pool role ("prefill" | "decode" | "combined").
    # Both stay None on single-node runs — and row() then omits them —
    # so every pre-fleet BENCH_*.json artifact and the regression gate
    # remain byte-identical.
    node_id: Optional[int] = None
    pool: Optional[str] = None

    def row(self) -> Dict:
        def _r(x: float, nd: int):
            # NaN percentiles (all requests rejected -> finished == 0)
            # become None so the row stays strict-JSON serializable
            # instead of emitting bare `NaN` tokens
            return None if math.isnan(x) else round(x, nd)
        out = {
            "requests": self.n_requests,
            "finished": self.finished,
            "rejected": self.rejected,
            "ccpg": self.ccpg,
            "tokens_per_s": _r(self.tokens_per_s, 1),
            "tokens_per_J": _r(self.tokens_per_J, 1),
            "p50_latency_s": _r(self.p50_latency_s, 4),
            "p99_latency_s": _r(self.p99_latency_s, 4),
            "p50_ttft_s": _r(self.p50_ttft_s, 4),
            "p99_ttft_s": _r(self.p99_ttft_s, 4),
            "mean_batch": _r(self.mean_batch_occupancy, 2),
            "max_queue_depth": self.max_queue_depth,
            "wall_s": _r(self.wall_s, 4),
        }
        if self.node_id is not None:
            out["node_id"] = self.node_id
            out["pool"] = self.pool
        return out

    def summary(self) -> str:
        lines = [
            f"ServingReport (ccpg={'on' if self.ccpg else 'off'})",
            f"  requests          {self.finished}/{self.n_requests} finished"
            f", {self.rejected} rejected",
            f"  wall clock        {self.wall_s:.3f} s "
            f"(busy {self.busy_s:.3f}, idle {self.idle_s:.3f})",
            f"  tokens            {self.tokens_generated} generated, "
            f"{self.tokens_prefilled} prefilled",
            f"  throughput        {self.tokens_per_s:.1f} tok/s (generated)",
            f"  efficiency        {self.tokens_per_J:.1f} tok/J "
            f"({self.energy_J:.3f} J total)",
            f"  latency p50/p99   {self.p50_latency_s * 1e3:.1f} / "
            f"{self.p99_latency_s * 1e3:.1f} ms",
            f"  TTFT    p50/p99   {self.p50_ttft_s * 1e3:.1f} / "
            f"{self.p99_ttft_s * 1e3:.1f} ms",
            f"  batch occupancy   {self.mean_batch_occupancy:.2f} "
            f"(max queue depth {self.max_queue_depth})",
        ]
        return "\n".join(lines)


class ContinuousBatchingEngine:
    """Iteration-level continuous batching over the PICNIC cycle model.

    Each engine iteration either PREFILLs one queued request into a free
    KV slot (deficit-gated, deadline-overridable — the policy from
    launch/scheduler.py) or runs one batched DECODE round advancing every
    resident request by one token.  Decode is preemption-free: an admitted
    request keeps its slot until it emits ``max_new`` tokens.
    """

    def __init__(self, cfg, sim: Optional[PicnicSimulator] = None,
                 engine: Optional[ServingConfig] = None,
                 alloc: Optional[ChipletAllocation] = None):
        self.cfg = cfg
        self.sim = sim if sim is not None else PicnicSimulator()
        self.engine = engine if engine is not None else ServingConfig()
        # fleet hook: called at every request-finish site with the
        # finished request; returning True transfers KV ownership to the
        # caller (the engine then skips its own `kv.free`).  Installed
        # once by FleetEngine on prefill nodes; survives reset() so a
        # re-run keeps its wiring.  None (the default) is checked with
        # `is not None` at each site, keeping the single-node float/event
        # sequence byte-identical.
        self.on_finish: Optional[Callable[[TrackedRequest], bool]] = None
        # `alloc` lets N engines of a sweep grid share one allocation
        # object (allocate_chiplets is deterministic, so sharing changes
        # id()-keyed memo hit rates, never results); default: private.
        self.alloc: ChipletAllocation = (
            alloc if alloc is not None
            else allocate_chiplets(cfg, self.sim.tile))
        ccpg_model: CCPGModel = self.sim.ccpg_model
        self._busy_power = ccpg_model.system_power(
            self.alloc.n_chiplets, ccpg=self.engine.ccpg)
        self._idle_power = ccpg_model.idle_power(
            self.alloc.n_chiplets, ccpg=self.engine.ccpg)
        # static mode folds the pre-wake residue into the iteration cost;
        # dynamic mode charges the full walk as ClusterWake events instead
        self._residue_ccpg = self.engine.ccpg and not self.engine.dynamic_ccpg
        self._dyn_wake = self.engine.ccpg and self.engine.dynamic_ccpg
        self._bandwidth_Bps = self.sim.link.bandwidth_Bps
        self._cm = self.sim.cycle_model
        self._decode_names: Dict[int, str] = {}   # b -> "decode:b{b}"
        self.reset()

    # ------------------------------------------------------------------
    def reset(self) -> None:
        e = self.engine
        # ALL time/energy accounting lives in the TimelineIR accumulator —
        # the engine appends per-round events and never charges privately
        self.timeline = Timeline(link=self.sim.link,
                                 columnar=e.columnar_timeline,
                                 aggregate_only=e.aggregate_timeline)
        self.queue: Deque[TrackedRequest] = deque()
        self.slots: List[Optional[TrackedRequest]] = [None] * e.max_batch
        # -- SoA mirrors of the slot table (the fast-path state): the
        # per-iteration decisions read these columns and running
        # aggregates instead of walking the request-object list.
        #   _seq_col              per-slot admit-seq column (victim pick =
        #                         one argmax; -1 encodes a free slot)
        #   _active_idx           sorted occupied-slot indices (the round's
        #                         iteration order, no occupancy scan)
        #   _ctx_sum              running sum of resident contexts (the
        #                         batched cycle model's only context input)
        #   _slot_of              O(1) request-id -> slot map replacing the
        #                         `next(i for i, s ...)` identity scans
        self._seq_col = np.full(e.max_batch, -1, dtype=np.int64)
        # _active_idx / _active_reqs / _active_rids / _active_ctx0 are
        # PARALLEL lists in slot order — the decode round reads them
        # directly instead of rebuilding per-round comprehensions over
        # `slots`.  _active_ctx0 holds each resident's context MINUS the
        # round counter at admission: every resident gains one context
        # token per round, so its exact current context is
        # ``ctx0 + _round_no`` at any time — no per-round writes needed
        # to hand the cycle-model fallback a real per-request list.
        self._active_idx: List[int] = []
        self._active_reqs: List[TrackedRequest] = []
        self._active_rids: List[int] = []
        self._active_ctx0: List[int] = []
        self._ctx_sum = 0
        self._slot_of: Dict[int, int] = {}
        # deferred-finish schedule (capacity-unbounded path only): decode
        # is preemption-free and every resident advances one token per
        # round, so a request admitted with `k` tokens to go finishes in
        # EXACTLY `k` rounds — (finish_round, slot) entries in a heap
        # replace the per-round per-resident countdown, and the request
        # object's generated/context are synced (to their exact final
        # values) at finish.  The paged path keeps per-round object
        # updates: preemption reads resident state mid-flight.
        self._round_no = 0
        self._finish_heap: List[Tuple[int, int]] = []
        # decode-cost affine snapshot per batch size (see CycleModel
        # .decode_affine): valid at overlap == 0, revalidated against the
        # model's calibration version every round, with the CCPG residue
        # and clock frequency snapshotted per run
        self._affine_by_b: Dict[int, tuple] = {}
        self._use_affine = e.overlap == 0.0
        self._residue_cyc = (self.sim.ccpg_model.wake_overhead_cycles(
            self.alloc) if self._residue_ccpg else 0)
        self._freq_hz = self.sim.tile.frequency_hz
        # cleared by run() when no request in the trace carries a TTFT
        # deadline (the at-risk test is then statically False); direct
        # step() drivers keep the full per-iteration check
        self._any_deadline = True
        self.decode_credit = 0
        self.rejected = 0
        self.events: List[Tuple[float, EventKind, int]] = []
        self.queue_depth: List[Tuple[float, int]] = []
        self._tokens_prefilled = 0
        # -- paged KV state (None/zeroed on the default infinite path) --
        self.kv: Optional[BlockAllocator] = (
            BlockAllocator(e.kv_cache, on_spill=self._on_kv_spill,
                           on_cow=self._on_kv_cow)
            if e.kv_cache is not None else None)
        self._prefix_on = (e.kv_cache is not None
                           and e.kv_cache.prefix_sharing)
        # chain-hash + probe memos: hashing a prompt is O(len/block) — do
        # it once per request, and re-probe the index only after it
        # changed (the admission check runs every iteration)
        self._chain_cache: Dict[int, List[int]] = {}
        self._probe_cache: Dict[int, Tuple[int, int]] = {}
        self._partial: Optional[List] = None   # [req, done, target, slot]
        self._admit_counter = 0
        self._kv_fetch_bytes = 0
        self._preemptions = 0
        self._recomputed_tokens = 0
        self._kv_rejected_infeasible = 0

    @property
    def clock(self) -> float:
        return self.timeline.now

    @property
    def kv_stats(self) -> Optional[KVCacheStats]:
        """Paged-KV accounting for the last run (None with paging off)."""
        if self.kv is None:
            return None
        c = self.kv.cfg
        kv = self.kv
        prompt_total = self._tokens_prefilled + kv.shared_tokens_saved
        return KVCacheStats(
            n_blocks=c.n_blocks, dram_blocks=c.dram_blocks,
            block_tokens=c.block_tokens, preemptions=self._preemptions,
            spilled_blocks=kv.spilled_blocks,
            spilled_bytes=kv.spilled_bytes,
            dram_read_bytes=self._kv_fetch_bytes,
            recomputed_tokens=self._recomputed_tokens,
            peak_blocks_used=kv.peak_used,
            infeasible_rejects=self._kv_rejected_infeasible,
            prefix_sharing=c.prefix_sharing,
            prefix_hits=kv.prefix_hits,
            prefix_hit_tokens=kv.shared_tokens_saved,
            prefix_hit_rate=(kv.shared_tokens_saved / prompt_total
                             if prompt_total else 0.0),
            cow_forks=kv.cow_forks,
            cow_copied_bytes=kv.cow_copied_bytes,
            shared_blocks_now=kv.n_shared_blocks,
            shared_blocks_peak=kv.peak_shared_blocks)

    # ------------------------------------------------------------------
    # SoA slot bookkeeping: `slots` (request objects) and the numpy
    # columns are updated together through these two helpers only.
    def _slot_occupy(self, i: int, req: TrackedRequest) -> None:
        self.slots[i] = req
        self._seq_col[i] = req.admit_seq
        pos = bisect_left(self._active_idx, i)
        self._active_idx.insert(pos, i)
        self._active_reqs.insert(pos, req)
        self._active_rids.insert(pos, req.request_id)
        self._active_ctx0.insert(pos, req.context - self._round_no)
        self._ctx_sum += req.context
        self._slot_of[req.request_id] = i

    def _slot_release(self, i: int) -> TrackedRequest:
        req = self.slots[i]
        self.slots[i] = None
        self._seq_col[i] = -1
        pos = bisect_left(self._active_idx, i)
        del self._active_idx[pos]
        del self._active_reqs[pos]
        del self._active_rids[pos]
        del self._active_ctx0[pos]
        self._ctx_sum -= req.context
        del self._slot_of[req.request_id]
        return req

    def _free_slot(self) -> Optional[int]:
        if len(self._active_idx) == len(self.slots):
            return None
        return self.slots.index(None)      # C-level scan: lowest free slot

    def _active(self) -> List[TrackedRequest]:
        return list(self._active_reqs)

    def _wake_walk(self) -> None:
        """Dynamic CCPG: the iteration's cluster walk pays the FULL wake
        latency as a real ClusterWake timeline event (visible in the
        Chrome trace; raises serving p99 — see EXPERIMENTS.md)."""
        if not (self.engine.ccpg and self.engine.dynamic_ccpg):
            return
        dt, cyc = self.sim.wake_seconds(self.alloc)
        if dt:
            self.timeline.wake(dt, power_W=self._busy_power, cycles=cyc)

    def _on_kv_spill(self, nbytes: int) -> None:
        """Allocator spill callback: the cold block rides the photonic
        link to the DRAM hub — a real C2CTransfer on the timeline (DMA
        concurrent with compute) plus DRAM access energy."""
        self.timeline.c2c(nbytes, phase="kv_spill",
                          dur_s=self.sim.kv_transfer_seconds(nbytes))

    def _on_kv_cow(self, nbytes: int) -> None:
        """Allocator copy-on-write callback: the matching head of a
        divergence block is copied pad-to-pad over the C2C fabric — a
        non-advancing DMA like kv_spill (phase "kv_cow"; no new
        TimelineIR event KIND, per the back-compat contract)."""
        self.timeline.c2c(nbytes, phase="kv_cow",
                          dur_s=self.sim.kv_transfer_seconds(nbytes))

    # -- prefix-sharing helpers (all no-ops unless prefix_sharing) ------
    def _prefix_hashes(self, req: TrackedRequest) -> Optional[List[int]]:
        if not self._prefix_on or req.prompt_tokens is None:
            return None
        h = self._chain_cache.get(req.request_id)
        if h is None:
            h = self._chain_cache[req.request_id] = \
                self.kv.chunk_hashes(req.prompt_tokens)
        return h

    def _probe_shared(self, req: TrackedRequest) -> int:
        """Blocks ``req`` would adopt if admitted now (admission credit),
        memoized on the allocator's index version."""
        hashes = self._prefix_hashes(req)
        if hashes is None:
            return 0
        ver = self.kv.index_version
        hit = self._probe_cache.get(req.request_id)
        if hit is not None and hit[0] == ver:
            return hit[1]
        n = self.kv.probe_prefix(req.prompt_tokens, hashes)
        self._probe_cache[req.request_id] = (ver, n)
        return n

    def _admit_arrivals(self, pending: Deque[TrackedRequest]) -> None:
        now = self.timeline.now
        while pending and pending[0].arrival <= now:
            req = pending.popleft()
            if self.kv is not None and not self.kv.feasible(
                    req.prompt_len + max(req.max_new, 1)):
                # could never fit, even with the whole cache to itself
                self.rejected += 1
                self._kv_rejected_infeasible += 1
                self.events.append((now, EventKind.REJECT,
                                    req.request_id))
                continue
            if len(self.queue) >= self.engine.queue_limit:
                self.rejected += 1
                self.events.append((now, EventKind.REJECT,
                                    req.request_id))
                continue
            self.queue.append(req)

    def _kv_can_admit(self) -> bool:
        """Admission checks free KV *blocks*, not just free slots: the
        queue head needs blocks for its (possibly recomputed) context
        plus its first new token, with watermark headroom for the
        residents' growth — except when nothing is resident, where the
        full cache is available by definition."""
        if self.kv is None or not self.queue:
            return True
        head = self.queue[0]
        need = head.prompt_len + head.generated + 1
        # (only reached with no chunked prefill in flight: step() keeps
        # the prefill pipeline for the partial and skips this check)
        reserve = self.kv.cfg.watermark_blocks if self._active_idx else 0
        return self.kv.can_admit(need, reserve=reserve,
                                 shared_blocks=self._probe_shared(head))

    def _prefill_eta_s(self) -> float:
        """Prefill latency the queue HEAD would pay if admitted now —
        the horizon the TTFT at-risk test compares against.  Shared with
        the sweep engine, which freezes it per cruise (the head, and
        hence the estimate, cannot change between scalar events)."""
        head = self.queue[0]
        dt, _ = self.sim.prefill_seconds(
            self.cfg, self.alloc, head.prompt_len + head.generated,
            ccpg=self._residue_ccpg)
        if self.engine.ccpg and self.engine.dynamic_ccpg:
            dt += self.sim.wake_seconds(self.alloc)[0]
        return dt

    def _deadline_at_risk(self) -> bool:
        head = self.queue[0] if self.queue else None
        if head is None or head.deadline_ttft is None:
            # deadline-free heads short-circuit BEFORE pricing the
            # prefill: `deadline_at_risk` would discard it anyway, and
            # this check runs on every admission-eligible iteration
            return False
        return deadline_at_risk(head, self.clock, self._prefill_eta_s())

    # ------------------------------------------------------------------
    def _prefill(self, slot: int) -> None:
        if self._partial is None:
            req = self.queue.popleft()
            # recompute-on-resume: a preempted request re-prefills its
            # prompt PLUS everything it had already generated
            target = req.prompt_len + req.generated
            # prefix sharing: adopt indexed blocks (+ COW fork) FIRST —
            # the adopted tokens need no prefill compute, only the
            # unshared suffix is priced below.  shared == 0 whenever
            # sharing is off, keeping every expression byte-identical.
            shared = 0
            hashes = self._prefix_hashes(req)
            if hashes is not None:
                shared = self.kv.adopt_prefix(
                    req.request_id, req.prompt_tokens, hashes)
            if req.generated:
                self._recomputed_tokens += target - shared
            chunk_cap = self.engine.chunked_prefill_tokens
            if chunk_cap and target - shared > chunk_cap:
                self._partial = [req, shared, target, slot]
            else:
                # monolithic path — the default-config fast path; with
                # paging off its float sequence is byte-identical to the
                # pre-paging engine (timeline golden).  A shared prefix
                # turns it into one suffix "chunk" at context `shared`
                # (prefill_chunk_cycles(n, 0) == prefill_cycles(n), so
                # the two calls agree exactly at shared == 0).
                if shared:
                    dt, c2c = self.sim.prefill_chunk_seconds(
                        self.cfg, self.alloc, target - shared, shared,
                        ccpg=self._residue_ccpg)
                else:
                    dt, c2c = self.sim.prefill_seconds(
                        self.cfg, self.alloc, target,
                        ccpg=self._residue_ccpg)
                self._wake_walk()
                t0 = self.timeline.now
                self.timeline.compute(
                    dt, kind="prefill", power_W=self._busy_power,
                    batch=len(self._active_idx) + 1,
                    name=f"prefill:r{req.request_id}")
                if c2c:
                    # burst rides under the compute wave: anchor at start
                    self.timeline.c2c(c2c, phase="prefill", t0=t0,
                                      dur_s=c2c / self.sim.link.bandwidth_Bps)
                self._tokens_prefilled += target - shared
                self._finish_prefill(req, slot)
                return
        # chunked continuation: one chunk per engine iteration
        req, done, target, slot = self._partial
        chunk = min(self.engine.chunked_prefill_tokens, target - done)
        dt, c2c = self.sim.prefill_chunk_seconds(
            self.cfg, self.alloc, chunk, done, ccpg=self._residue_ccpg)
        self._wake_walk()
        t0 = self.timeline.now
        self.timeline.compute(dt, kind="prefill", power_W=self._busy_power,
                              batch=len(self._active_idx) + 1,
                              name=f"prefill:r{req.request_id}@{done}")
        if c2c:
            self.timeline.c2c(c2c, phase="prefill", t0=t0,
                              dur_s=c2c / self.sim.link.bandwidth_Bps)
        self._tokens_prefilled += chunk
        done += chunk
        if self.kv is not None:
            self._kv_ensure(req, done)
        self.decode_credit = 0
        if done >= target:
            self._partial = None
            self._finish_prefill(req, slot)
        else:
            self._partial = [req, done, target, slot]
            self.events.append((self.clock, EventKind.PREFILL,
                                req.request_id))

    def _finish_prefill(self, req: TrackedRequest, slot: int) -> None:
        """Post-prefill bookkeeping, shared by the monolithic and chunked
        paths.  A fresh prefill emits the request's first output token
        (unless max_new == 0, prefill-only scoring); a resumed one ends
        its recompute by producing the next token."""
        if req.first_token_at is None:
            req.first_token_at = self.clock
            req.generated = min(1, req.max_new)
            new_tokens = req.generated
        else:
            req.generated += 1
            new_tokens = 1
        req.context = req.prompt_len + req.generated
        if self.kv is not None:
            self._kv_ensure(req, max(req.context, 1))
            hashes = self._prefix_hashes(req)
            if hashes is not None:
                # the prompt's blocks now hold final KV — publish them
                self.kv.register_prefix(req.request_id,
                                        req.prompt_tokens, hashes)
        if new_tokens:
            self.timeline.token(new_tokens, request_id=req.request_id)
        self.events.append((self.clock, EventKind.PREFILL, req.request_id))
        if req.generated >= req.max_new:
            req.finished_at = self.clock
            self.events.append((self.clock, EventKind.FINISH,
                                req.request_id))
            handed = (self.on_finish is not None
                      and bool(self.on_finish(req)))
            if self.kv is not None and not handed:
                self.kv.free(req.request_id)
        else:
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self._slot_occupy(slot, req)
            if self.kv is None:
                heappush(self._finish_heap,
                         (self._round_no + req.max_new - req.generated,
                          slot))
        self.decode_credit = 0

    # -- paged-KV round bookkeeping ------------------------------------
    def _kv_ensure(self, req: TrackedRequest, n_tokens: int) -> None:
        """Grow the request's block table, preempting (other) residents
        when both tiers are exhausted."""
        while True:
            try:
                self.kv.ensure(req.request_id, n_tokens)
                return
            except OutOfBlocks:
                if not self._preempt_one(exclude=req.request_id):
                    raise RuntimeError(
                        "paged KV cache cannot hold the running set; "
                        "raise n_blocks/dram_blocks or lower max_batch")

    def _preempt_one(self, exclude: int = -1) -> bool:
        """Evict the most-recently-admitted resident (vLLM recompute
        policy): free its blocks, return it to the queue FRONT; its KV is
        recomputed at re-prefill.  The victim is argmax over the SoA
        admit-seq column (unoccupied slots carry -1), and `_slot_of`
        resolves the excluded request in O(1) — no object-identity scan.
        """
        seqs = self._seq_col
        excl_slot = self._slot_of.get(exclude, -1)
        if excl_slot >= 0:
            seqs = seqs.copy()
            seqs[excl_slot] = -1
        idx = int(seqs.argmax())
        if seqs[idx] >= 0:
            victim = self._slot_release(idx)
            self.kv.free(victim.request_id)
            self._preemptions += 1
            self.queue.appendleft(victim)
            self.events.append((self.clock, EventKind.PREEMPT,
                                victim.request_id))
            return True
        # last resort: abort an in-flight chunked prefill.  The partial
        # holds KV blocks but lives outside self.slots, so without this
        # a lone growing resident could exhaust the cache with no victim
        # available and crash a feasible run; its chunks are recomputed
        # when it is re-admitted.
        if self._partial is not None \
                and self._partial[0].request_id != exclude:
            req, done = self._partial[0], self._partial[1]
            self._partial = None
            if req.request_id in self.kv.tables:
                self.kv.free(req.request_id)
            # the discarded chunks are prefill work that will be re-done
            # on re-admission (the resume path only counts requests that
            # had already generated tokens)
            self._recomputed_tokens += done
            self._preemptions += 1
            self.queue.appendleft(req)
            self.events.append((self.clock, EventKind.PREEMPT,
                                req.request_id))
            return True
        return False

    def _kv_prepare_round(self) -> None:
        """Before a decode round: watermark-based preemption, then grow
        every resident's block table by the token this round appends."""
        cfg = self.kv.cfg
        while True:
            active = self._active()
            if not active:
                return
            needed = sum(
                cfg.blocks_for(r.context + 1)
                - len(self.kv.tables[r.request_id].blocks)
                for r in active)
            if needed == 0:
                return
            if (self.kv.free_total()
                    >= max(needed, cfg.watermark_blocks)
                    or len(active) <= 1):
                break
            self._preempt_one()
        # batched fast path: the whole round's growth fits the scratch
        # free list — one allocator pass, identical pops (same block ids
        # to the same tables) to the sequential ensure() loop below
        if self.kv.grow_round([(r.request_id, r.context + 1)
                               for r in self._active()]):
            return
        for r in self._active():
            self._kv_ensure(r, r.context + 1)

    def _decode_round(self) -> None:
        if self.kv is not None:
            self._kv_prepare_round()
        if not self._active_idx:  # everything was preempted back to the queue
            return
        b = len(self._active_idx)
        # the cycle model only needs (batch, sum of contexts) — both are
        # running SoA aggregates.  At overlap == 0 the memoized affine
        # decomposition is inlined as plain arithmetic (bit-identical to
        # the decode_iteration_seconds chain, which remains the fallback
        # for overlap > 0 / memoization off / non-affine subclasses).
        aff = self._affine_by_b.get(b) if self._use_affine else None
        cm = self._cm
        if aff is None or aff[5] != cm._cal_ver:
            aff = cm.decode_affine(self.cfg, self.alloc, b) \
                if self._use_affine else None
            if aff is not None:
                self._affine_by_b[b] = aff
        if aff is not None:
            base, n_attn, c2c, cpp, alpha, _ = aff
            cyc = base + n_attn * int(cpp * self._ctx_sum)
            cyc = int(cyc * alpha)
            dt = (cyc + self._residue_cyc) / self._freq_hz
        else:
            # real per-request contexts for the fallback (a CycleModel
            # subclass may legitimately iterate them): every resident
            # gains one token per round, so ctx0 + round counter is the
            # exact current value — no per-round bookkeeping needed
            rn = self._round_no
            contexts = [c + rn for c in self._active_ctx0]
            dt, c2c = self.sim.decode_iteration_seconds(
                self.cfg, self.alloc, contexts,
                ccpg=self._residue_ccpg, overlap=self.engine.overlap)
        if self._dyn_wake:
            self._wake_walk()
        tl = self.timeline
        name = self._decode_names.get(b)
        if name is None:
            name = self._decode_names[b] = f"decode:b{b}"
        t0 = tl.now
        tl.compute(dt, kind="decode", power_W=self._busy_power,
                   batch=b, name=name)
        if c2c:
            tl.c2c(c2c, phase="decode", t0=t0,
                   dur_s=c2c / self._bandwidth_Bps)
        if self.kv is not None:
            # DRAM-resident context is re-read over the photonic link
            # every iteration: an EXPOSED remote-memory stall (advancing
            # C2C) — the cost Sangam/Photonic-Fabric price for the tier
            fetch = sum(self.kv.dram_tokens(self.slots[i].request_id)
                        for i in self._active_idx) \
                * self.kv.cfg.bytes_per_token
            if fetch:
                # the chiplets keep burning busy power while stalled
                self.timeline.c2c(fetch, phase="kv_fetch",
                                  dur_s=self.sim.kv_transfer_seconds(fetch),
                                  advance=True, power_W=self._busy_power)
                self._kv_fetch_bytes += fetch
        self.decode_credit += 1
        clock = tl.now
        events = self.events
        events.append((clock, EventKind.DECODE, -1))
        # batched timeline append: one TokenEmit per resident, C-level
        # column extends (stream-identical to per-request token() calls)
        tl.token_each(self._active_rids)
        self._ctx_sum += b                  # every resident grew by one
        rn = self._round_no = self._round_no + 1
        kv = self.kv
        if kv is None:
            # deferred finish: pop exactly the residents whose countdown
            # elapsed this round (slot-ordered ties match the old loop)
            # and sync their objects to the exact final values
            heap = self._finish_heap
            while heap and heap[0][0] <= rn:
                i = heappop(heap)[1]
                req = self.slots[i]
                req.generated = req.max_new
                req.context = req.prompt_len + req.max_new
                req.finished_at = clock
                events.append((clock, EventKind.FINISH, req.request_id))
                self._slot_release(i)
                if self.on_finish is not None:
                    self.on_finish(req)  # no KV to hand on this path
            return
        # paged path: preemption can interrupt any resident mid-decode,
        # so per-round object state must stay exact
        act_list = list(self._active_idx)   # copies: releases mutate them
        residents = list(self._active_reqs)
        for i, req in zip(act_list, residents):
            gen = req.generated = req.generated + 1
            req.context += 1
            if gen >= req.max_new:
                req.finished_at = clock
                events.append((clock, EventKind.FINISH, req.request_id))
                self._slot_release(i)
                if self.on_finish is not None and self.on_finish(req):
                    continue        # KV ownership handed to the fleet
                kv.free(req.request_id)

    def step(self, pending: Deque[TrackedRequest]) -> EventKind:
        """One engine iteration; returns what was scheduled."""
        now = self.timeline.now
        if pending and pending[0].arrival <= now:
            self._admit_arrivals(pending)
        self.queue_depth.append((now, len(self.queue)))

        if self._partial is not None:
            # an in-flight chunked prefill owns the prefill pipeline (and
            # its reserved slot); new admissions wait behind it.  Its
            # chunks obey the SAME deficit gating as fresh prefills —
            # that is what stops a long prompt monopolizing iterations
            slot = self._partial[3]
            want_prefill = True
            must_prefill = False
        else:
            slot = self._free_slot()
            want_prefill = (bool(self.queue) and slot is not None
                            and (self.kv is None or self._kv_can_admit()))
            must_prefill = (want_prefill and self._any_deadline
                            and self._deadline_at_risk())
        may_prefill = want_prefill and (
            self.decode_credit >= self.engine.decode_quantum
            or not self._active_idx)
        if must_prefill or may_prefill:
            self._prefill(slot)
            return EventKind.PREFILL
        if self._active_idx:
            self._decode_round()
            return EventKind.DECODE
        if pending:
            # idle gap until the next arrival: CCPG lets every cluster
            # sleep (scratchpad retention only); without it the chiplets
            # burn active power waiting
            gap = max(0.0, pending[0].arrival - self.clock)
            self.timeline.sleep(gap, power_W=self._idle_power)
            self.events.append((self.clock, EventKind.IDLE, -1))
            return EventKind.IDLE
        return EventKind.IDLE

    # ------------------------------------------------------------------
    def import_request(self, req: TrackedRequest, *, nbytes: int = 0,
                       transfer_s: float = 0.0, phase: str = "kv_handoff",
                       retransmit_bytes: int = 0,
                       retransmit_s: float = 0.0) -> bool:
        """Admit a request whose prefill (and first token) ran on
        ANOTHER engine, arriving with resident KV over the fabric — the
        decode-side half of the fleet's prefill->decode handoff.

        Occupies a slot directly (no prefill compute here); with paging
        on, a fresh LOCAL block table covering ``req.context`` tokens is
        allocated (`BlockAllocator.import_table` — block ids never
        travel between allocators, only the footprint does).  The KV
        payload lands on this node's timeline as a non-advancing
        ``C2CTransfer`` (phase ``"kv_handoff"``): the fleet already
        folded the transfer latency into the request's arrival time, so
        the event prices bytes/energy, not time.  Returns False with
        state untouched when no slot is free or the blocks don't fit —
        the caller re-queues (never drops).

        Fault injection (launch/config.FaultConfig) rides the same
        import: ``phase="kv_recompute"`` marks a handoff whose KV was
        recomputed after a node crash, and ``retransmit_bytes`` prices
        the FEC-overflow overhead of a degraded link window as a second
        ``C2CTransfer(phase="retransmit", source="fault")`` on the same
        link — both default off, keeping the zero-fault event stream
        byte-identical."""
        slot = self._free_slot()
        if slot is None:
            return False
        if self.kv is not None:
            reserve = (self.kv.cfg.watermark_blocks
                       if self._active_idx else 0)
            if not self.kv.can_admit(req.context + 1, reserve=reserve):
                return False
            try:
                self.kv.import_table(req.request_id, req.context)
            except OutOfBlocks:
                # fragmented growth raced the headroom check: roll back
                if req.request_id in self.kv.tables:
                    self.kv.free(req.request_id)
                return False
        if nbytes:
            self.timeline.c2c(nbytes, phase=phase, source="fleet",
                              dur_s=transfer_s)
        if retransmit_bytes:
            self.timeline.c2c(retransmit_bytes, phase="retransmit",
                              source="fault", dur_s=retransmit_s)
        req.admit_seq = self._admit_counter
        self._admit_counter += 1
        self._slot_occupy(slot, req)
        if self.kv is None:
            heappush(self._finish_heap,
                     (self._round_no + req.max_new - req.generated, slot))
        self.events.append((self.clock, EventKind.HANDOFF,
                            req.request_id))
        return True

    def drop_inflight(self) -> List[TrackedRequest]:
        """Crash semantics for the fleet's fault layer: every in-flight
        request — queued, resident mid-decode, or mid-chunked-prefill —
        is dropped and returned; their KV block tables (lost with the
        node) are freed.  The timeline is deliberately untouched: a dead
        node emits nothing, and the recovery costs (recompute prefills,
        re-routed handoffs) land on the survivors' timelines."""
        dropped: List[TrackedRequest] = list(self.queue)
        self.queue.clear()
        for i in list(self._active_idx):
            req = self._slot_release(i)
            dropped.append(req)
            if self.kv is not None and req.request_id in self.kv.tables:
                self.kv.free(req.request_id)
        if self._partial is not None:
            req = self._partial[0]
            dropped.append(req)
            if self.kv is not None and req.request_id in self.kv.tables:
                self.kv.free(req.request_id)
            self._partial = None
        self._finish_heap.clear()
        self.decode_credit = 0
        return dropped

    # ------------------------------------------------------------------
    def _prepare_run(self, trace: Sequence[TrackedRequest]
                     ) -> Deque[TrackedRequest]:
        """Reset the engine and the trace's mutable per-run state, verify
        arrival order, and hand back the pending deque — factored out of
        :meth:`run` so the sweep engine can drive the step loop itself."""
        self.reset()
        for r in trace:
            # re-running a trace must be idempotent: the resume/recompute
            # paths branch on this mutable state, so leftovers from an
            # earlier run would masquerade as preempted residents
            r.generated = 0
            r.context = 0
            r.first_token_at = None
            r.finished_at = None
            r.admit_seq = -1
        # poisson_trace / replay_trace hand back arrival-sorted traces;
        # verify monotonicity in one O(n) pass and only re-sort (stable,
        # same order the old per-run `sorted(trace)` produced) when a
        # hand-built trace violates it
        arr = list(trace)
        prev = -math.inf
        for r in arr:
            if r.arrival < prev:
                arr.sort()
                break
            prev = r.arrival
        self._any_deadline = any(r.deadline_ttft is not None for r in arr)
        return deque(arr)

    def run(self, trace: Sequence[TrackedRequest]) -> ServingReport:
        pending = self._prepare_run(trace)
        it = 0
        while (pending or self.queue or self._active_idx
               or self._partial is not None):
            it += 1
            if it > self.engine.max_iters:
                raise RuntimeError("serving engine exceeded max_iters")
            self.step(pending)
        return self._report(list(trace))

    # ------------------------------------------------------------------
    def _report_inputs(self, requests: List[TrackedRequest]):
        """Report fields minus the four percentile columns, plus the raw
        ``(lat, ttft)`` arrays — the sweep engine defers and BATCHES the
        ``np.percentile`` calls across cells (row-identical to per-cell
        calls), everything else is cheap scalar arithmetic."""
        tl = self.timeline
        done = [r for r in requests if r.finished_at is not None]
        # NaN, not 0.0, when nothing finished: an all-rejected run must
        # not look like a zero-latency one in the benchmark rows
        nothing = np.array([np.nan])
        lat = np.array([r.latency for r in done]) if done else nothing
        ttft = np.array([r.ttft for r in done]) if done else nothing
        wall = max(tl.now, 1e-12)
        # C2C energy: average power at the delivered byte rate over the
        # whole wall clock (bursty traffic, duty-cycled laser bias)
        c2c_power = c2c_average_power(tl.c2c_bytes / wall, self.sim.link)
        energy = tl.energy_J + c2c_power * wall
        dram_bytes = ((self.kv.spilled_bytes if self.kv is not None else 0)
                      + self._kv_fetch_bytes)
        if dram_bytes:
            # KV spilled to / re-read from the DRAM hub pays the off-chip
            # access energy on top of the link transport charged above
            # (the hub's static power rides in via CCPGModel's
            # include_dram_hub path); guarded so the paging-off default
            # keeps its float sequence byte-identical
            energy += dram_bytes * 8 * E_DRAM_ACCESS
        fields = dict(
            n_requests=len(requests),
            finished=len(done),
            rejected=self.rejected,
            wall_s=wall,
            busy_s=tl.busy_s,
            idle_s=tl.idle_s,
            tokens_generated=tl.tokens,
            tokens_prefilled=self._tokens_prefilled,
            tokens_per_s=tl.tokens / wall,
            energy_J=energy,
            tokens_per_J=tl.tokens / max(energy, 1e-12),
            mean_batch_occupancy=(tl.occupancy_s
                                  / max(tl.busy_s, 1e-12)),
            max_queue_depth=max((d for _, d in self.queue_depth),
                                default=0),
            queue_depth=self.queue_depth,
            c2c_bytes_total=tl.c2c_bytes,
            ccpg=self.engine.ccpg,
        )
        return fields, lat, ttft

    def _report(self, requests: List[TrackedRequest]) -> ServingReport:
        """Everything here is DERIVED from the timeline integrator: wall
        clock, busy/idle split, span-integrated chip energy, C2C bytes,
        token counts, batch occupancy."""
        fields, lat, ttft = self._report_inputs(requests)
        return ServingReport(
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            p50_ttft_s=float(np.percentile(ttft, 50)),
            p99_ttft_s=float(np.percentile(ttft, 99)),
            **fields,
        )


def serve_trace(cfg, trace: Sequence[TrackedRequest], *,
                max_batch: int = 8, ccpg: bool = False,
                sim: Optional[PicnicSimulator] = None,
                **engine_kw) -> ServingReport:
    """One-call convenience wrapper: run ``trace`` through a fresh engine."""
    eng = ContinuousBatchingEngine(
        cfg, sim=sim,
        engine=ServingConfig(max_batch=max_batch, ccpg=ccpg, **engine_kw))
    return eng.run(trace)
