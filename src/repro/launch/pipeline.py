"""GPipe-style pipeline parallelism over a mesh axis (PP).

The PICNIC analogy is direct: the paper maps layers to chiplet clusters and
activations flow cluster -> cluster over the photonic C2C links; here layer
GROUPS map to pipeline stages on a mesh axis (the `pod` axis of the
production mesh) and activations flow stage -> stage over ICI via
`lax.ppermute`.

Implementation: shard_map over the stage axis; the stacked layer params are
sharded on their leading (group) dim so each stage holds `G / n_stages`
groups; a GPipe schedule runs `n_micro + n_stages - 1` slots; autodiff
through shard_map/ppermute gives the backward pipeline for free (the
transpose of a ppermute is the reverse ppermute).

Restrictions: homogeneous-group archs (dense / moe / ssm families),
n_groups % n_stages == 0, tied or untied embeddings (embed/head replicated
across stages; only stage 0 embeds and only the last stage computes the
loss, psum'd out).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.sharding.shmap import shard_map
from repro.models.model import FwdCtx, _scan_groups, group_layout
from repro.models.common import apply_norm
from repro.launch.steps import cross_entropy
from repro.optim import clip_by_global_norm, linear_warmup_cosine, make_optimizer


def _stage_param_specs(params_shapes, stage_axis: str):
    """Layer stacks sharded on the leading group dim over the stage axis;
    embed/head/final_norm replicated (consumed at the pipeline ends)."""
    def spec_of(path, leaf):
        ps = jax.tree_util.keystr(path)
        if "layers" in ps and len(leaf.shape) >= 1:
            return P(stage_axis)
        return P()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    return treedef.unflatten([spec_of(p, l) for p, l in flat])


def pp_forward(cfg, params, tokens, *, mesh, stage_axis: str = "pod",
               n_micro: int = 4, dp_axes=("data",), act_rules=None,
               partial_manual: bool = False):
    """Pipelined forward -> mean CE loss (computed on the last stage,
    psum-broadcast).  tokens: (B, S) with labels derived by shift.

    partial_manual=True keeps only the stage axis manual so GSPMD can
    data/sequence-parallelize each stage's compute over the automatic
    axes.  It is numerically verified at 8 devices
    (tests/test_distributed.py) but trips an XLA CHECK ("Invalid binary
    instruction opcode copy") when compiled at 512 devices — tracked in
    EXPERIMENTS.md; the default is the all-manual schedule."""
    from repro.sharding.ctx import ShardingCtx, use_sharding

    n_stages = mesh.shape[stage_axis]
    kinds, n_groups = group_layout(cfg)
    assert n_groups % n_stages == 0, (n_groups, n_stages)
    B, S = tokens.shape
    assert B % n_micro == 0

    pspecs = _stage_param_specs(jax.eval_shape(lambda: params), stage_axis)
    if partial_manual:
        tok_spec = P()   # batch sharding over the AUTO data axis via jit
    else:
        bspec = dp_axes if B % _axsz(mesh, dp_axes) == 0 else None
        tok_spec = P(bspec, None)

    hint_ctx = ShardingCtx(mesh, act_rules) \
        if (act_rules and partial_manual) else None

    def body(params_local, toks_local):
        stage = jax.lax.axis_index(stage_axis)
        Bm = toks_local.shape[0] // n_micro      # (auto axes: logical size)
        micro = toks_local.reshape(n_micro, Bm, S)
        ctx = FwdCtx(positions=jnp.arange(S), causal=True,
                     impl="full" if S <= 1024 else "flash")

        def run_stage(x):
            sub = {"layers": params_local["layers"]}
            if "shared_attn" in params_local:
                sub["shared_attn"] = params_local["shared_attn"]
            with use_sharding(hint_ctx):
                y, _, aux = _scan_groups(cfg, sub, x, ctx, cfg.remat)
            return y, aux

        d = cfg.d_model
        state = jnp.zeros((Bm, S, d), jnp.dtype(cfg.dtype))
        outs0 = jnp.zeros((n_micro, Bm, S, d), jnp.dtype(cfg.dtype))
        aux_sum = jnp.zeros((), jnp.float32)
        n_slots = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def slot(carry, t):
            state, outs, aux_sum = carry
            # receive activation from the previous stage
            recv = jax.lax.ppermute(state, stage_axis, perm)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            toks_t = jax.lax.dynamic_index_in_dim(micro, mb_idx, 0,
                                                  keepdims=False)
            embedded = jnp.take(params_local["embed"], toks_t, axis=0)
            x_in = jnp.where(stage == 0, embedded, recv)
            y, aux = run_stage(x_in)
            # stash the last stage's finished microbatch output
            valid = (t >= n_stages - 1) & (t - (n_stages - 1) < n_micro)
            out_mb = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, out_mb, 0,
                                               keepdims=False)
            upd = jnp.where(valid, y, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, out_mb, 0)
            # aux (MoE balance) accrues on every stage that processed a
            # real microbatch this slot
            did_work = (t >= stage) & (t - stage < n_micro)
            aux_sum = aux_sum + jnp.where(did_work, aux, 0.0)
            return (y, outs, aux_sum), None

        (state, outs, aux_sum), _ = jax.lax.scan(
            slot, (state, outs0, aux_sum), jnp.arange(n_slots))
        # loss ONCE over all collected outputs (only the last stage's
        # buffer is real; other stages' contribution is masked out)
        h = apply_norm(cfg, params_local["final_norm"],
                       outs.reshape(n_micro * Bm, S, d))
        head = params_local["embed"].T if cfg.tie_embeddings \
            else params_local["lm_head"]
        logits = h @ head
        labels = jnp.roll(micro.reshape(n_micro * Bm, S), -1, axis=1)
        ce = cross_entropy(logits, labels)
        is_last = (stage == n_stages - 1).astype(jnp.float32)
        loss_sum = ce * is_last * n_micro
        # only the last stage holds the loss; share across stages
        loss = jax.lax.psum(loss_sum, stage_axis) / n_micro
        aux = jax.lax.psum(aux_sum, stage_axis) / n_micro
        if not partial_manual:
            # all axes manual: average the per-data-shard CE means
            for a in dp_axes:
                loss = jax.lax.pmean(loss, a)
                aux = jax.lax.pmean(aux, a)
        return loss, aux

    kw = {}
    if partial_manual:
        kw["axis_names"] = frozenset({stage_axis})
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, tok_spec),
        out_specs=(P(), P()),
        check_vma=False, **kw)
    return fn(params, tokens)


def _axsz(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def strip_axis(rules: Dict[str, P], axis: str) -> Dict[str, P]:
    """Remove a (now-manual) mesh axis from activation hint rules."""
    out = {}
    for k, spec in rules.items():
        entries = []
        for e in spec:
            if e is None:
                entries.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != axis)
                entries.append(kept if kept else None)
            else:
                entries.append(None if e == axis else e)
        out[k] = P(*entries)
    return out


def make_pp_train_step(cfg, mesh, *, stage_axis="pod", n_micro=4,
                       dp_axes=("data",), base_lr=3e-4, warmup=100,
                       total_steps=10000, act_rules=None):
    """Pipeline-parallel training step (GPipe schedule, grads via autodiff
    through the shard_map)."""
    _, opt_update = make_optimizer(cfg.optimizer)
    if act_rules is not None:
        act_rules = strip_axis(act_rules, stage_axis)

    def loss_fn(params, tokens):
        loss, aux = pp_forward(cfg, params, tokens, mesh=mesh,
                               stage_axis=stage_axis, n_micro=n_micro,
                               dp_axes=dp_axes, act_rules=act_rules)
        return loss + 0.01 * aux, (loss, aux)

    def train_step(params, opt_state, tokens):
        (tot, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, tokens)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = linear_warmup_cosine(opt_state["step"].astype(jnp.float32),
                                  base_lr=base_lr, warmup_steps=warmup,
                                  total_steps=total_steps)
        params, opt_state = opt_update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "aux": aux,
                                   "grad_norm": gnorm}
    return train_step


def pp_shardings(cfg, params, mesh, stage_axis="pod"):
    pspecs = _stage_param_specs(jax.eval_shape(lambda: params), stage_axis)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
