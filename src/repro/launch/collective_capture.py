"""Measure photonic-link traffic from compiled HLO collectives.

Closes the ROADMAP loop "cost collectives from measured HLO wire bytes
instead of analytic formulas": the TP×SP×PP prefill/decode cells are
lowered and compiled on a forced-host-device mesh (no device allocation —
the same mechanism as ``dryrun.py``), ``hlo_cost.analyze`` extracts the
per-collective ring-model wire bytes from the SPMD-partitioned module
text, and the totals are packaged as a
:class:`repro.core.interconnect.MeasuredTraffic` that
``PicnicSimulator.run(..., measured_c2c=...)`` consumes as the photonic
C2C traffic term.  The default simulator path stays analytic, so the
calibrated Table II numbers are untouched (measured traffic is opt-in).

Methodology follows Photonic Fabric (arXiv:2507.14000) and LEAP's
balanced-dataflow accounting (arXiv:2509.14781): drive the interconnect
model with the traffic the compiled program actually emits.

CLI (runs in its own process so the host device count can be forced):

  PYTHONPATH=src python -m repro.launch.collective_capture \
      --arch llama3.2-1b --mesh 1x8 --seq 512 --batch 1 --variant picnic
"""
import argparse
import json
import math
import os
import re
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

from repro.compat import force_host_devices
from repro.core.interconnect import MeasuredTraffic

# NOTE: importing this module never touches XLA_FLAGS / jax device state
# (the repo convention, see launch/mesh.py).  The forced host device count
# is applied by main() (CLI), by capture_in_subprocess (child env), or by
# the caller (examples/collective_sweep.py) — always before jax loads.

_DEF_AXES = {2: ("data", "model"), 3: ("pod", "data", "model")}


def parse_mesh(spec: str):
    """"1x8" -> data×model; "2x2x2" -> pod×data×model (sizes per axis)."""
    sizes = tuple(int(s) for s in spec.lower().split("x"))
    if len(sizes) not in _DEF_AXES:
        raise ValueError(f"mesh spec {spec!r}: want 2 (data x model) or "
                         "3 (pod x data x model) factors")
    return sizes, _DEF_AXES[len(sizes)]


def capture_cell(arch: str, *, mode: str = "decode", seq_len: int = 512,
                 batch: int = 1, mesh: str = "1x8",
                 variant: str = "picnic", smoke: bool = False) -> Dict:
    """Lower + compile one (arch, mode, mesh) cell and return a record with
    the per-collective measured wire bytes.

    ``mode``: "decode" (one sharded decode step against a ``seq_len``
    cache), "prefill" (prompt of ``seq_len``), or "train".  ``variant`` is
    a ``dryrun.build_cell`` opt_variant ("picnic" turns on the shard_map
    SP attention / partial-softmax decode paths; "pp" is the GPipe cell
    and needs a 3-factor mesh).  ``smoke`` uses the CPU-sized config.
    """
    import jax
    from repro.configs import ShapeSpec, get_config, get_smoke_config
    from repro.launch import dryrun, hlo_cost
    from repro import compat

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    sizes, axes = parse_mesh(mesh)
    m = jax.make_mesh(sizes, axes)
    nchips = m.devices.size
    shape = ShapeSpec(f"{mode}_{seq_len}", seq_len, batch, mode)

    t0 = time.time()
    fn, args = dryrun.build_cell(cfg, shape, m, opt_variant=variant)
    compiled = fn.lower(*args).compile()
    t_compile = time.time() - t0
    parsed = hlo_cost.analyze(compiled.as_text(), nchips)
    xla = compat.cost_analysis(compiled)

    wire_per_chip = parsed.wire_bytes
    return {
        "arch": arch, "mode": mode, "seq_len": seq_len, "batch": batch,
        "mesh": dict(zip(axes, sizes)), "nchips": nchips,
        "variant": variant, "smoke": smoke,
        "compile_s": round(t_compile, 2),
        "collectives": parsed.coll,              # per chip, per step
        "wire_bytes_per_chip": wire_per_chip,
        "wire_bytes_total": wire_per_chip * nchips,
        "flops_per_chip": parsed.flops,
        "xla_flops": float(xla.get("flops", 0.0)),
    }


def to_measured_traffic(prefill_rec: Optional[Dict],
                        decode_rec: Dict) -> MeasuredTraffic:
    """Capture records -> the simulator's photonic traffic term.

    Totals are normalized PER REQUEST (divide by the captured batch) so
    they compose with the simulator's single-stream (b=1) Table II walk:
    decode bytes are per generated token, prefill bytes per prompt.
    """
    dec_per_tok = decode_rec["wire_bytes_total"] / max(decode_rec["batch"], 1)
    pre = 0.0
    if prefill_rec is not None:
        pre = prefill_rec["wire_bytes_total"] / max(prefill_rec["batch"], 1)
    return MeasuredTraffic(
        prefill_bytes=pre,
        decode_bytes_per_token=dec_per_tok,
        per_collective=decode_rec["collectives"],
        n_devices=decode_rec["nchips"],
        source=f"hlo:{decode_rec['mesh']}")


def capture_in_subprocess(arch: str, *, modes: Sequence[str] = ("prefill",
                                                               "decode"),
                          seq_len: int = 512, batch: int = 1,
                          mesh: str = "1x8", variant: str = "picnic",
                          smoke: bool = False, devices: Optional[int] = None,
                          timeout: int = 1200) -> List[Dict]:
    """Run the capture CLI in a fresh process (the forced host device count
    must be set before JAX initializes, which an already-running process —
    e.g. ``benchmarks/run.py`` — cannot do for itself).  ``devices``
    defaults to exactly what the mesh spec needs."""
    if devices is None:
        devices = math.prod(parse_mesh(mesh)[0])
    env = dict(os.environ)
    # inherit the user's XLA flags; only the device-count flag is ours
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                      env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (inherited + " " if inherited else "") + \
        f"--xla_force_host_platform_device_count={devices}"
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.collective_capture",
           "--arch", arch, "--modes", ",".join(modes),
           "--seq", str(seq_len), "--batch", str(batch),
           "--mesh", mesh, "--variant", variant, "--json"]
    if smoke:
        cmd.append("--smoke")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"collective capture failed:\n{r.stderr[-3000:]}")
    return json.loads(r.stdout)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--modes", default="prefill,decode",
                    help="comma list of prefill|decode|train")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--mesh", default="1x8",
                    help='"DxM" (data x model) or "PxDxM" (pod first)')
    ap.add_argument("--variant", default="picnic")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable records on stdout (logs -> "
                         "stderr)")
    args = ap.parse_args()

    # before capture_cell's jax import; an env-set count wins
    force_host_devices(math.prod(parse_mesh(args.mesh)[0]))

    recs = []
    for mode in args.modes.split(","):
        rec = capture_cell(args.arch, mode=mode.strip(), seq_len=args.seq,
                           batch=args.batch, mesh=args.mesh,
                           variant=args.variant, smoke=args.smoke)
        recs.append(rec)
        log = sys.stderr if args.json else sys.stdout
        print(f"[{rec['mode']:7s}] {rec['arch']} mesh={rec['mesh']} "
              f"compile={rec['compile_s']}s wire/chip="
              f"{rec['wire_bytes_per_chip']:.3e}B", file=log, flush=True)
        for op, d in sorted(rec["collectives"].items()):
            print(f"    {op:20s} count={int(d['count']):6d} "
                  f"wire={d['wire_bytes']:.3e}B", file=log, flush=True)
    if args.json:
        print(json.dumps(recs))


if __name__ == "__main__":
    main()
