"""Step functions: train_step / prefill_step / serve_step (decode).

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the ones `train.py` / `serve.py` drive for real.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import models
from repro.optim import (clip_by_global_norm, linear_warmup_cosine,
                         make_optimizer)


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE.  fp32 logsumexp; works with vocab-sharded logits."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def make_loss_fn(cfg, *, weight_noise_std: float = 0.0):
    """weight_noise_std > 0 enables the paper's noise-resilient training
    (§IV / [13]): multiplicative Gaussian noise on the weights during the
    forward pass models RRAM conductance relaxation, so the trained model
    tolerates the analog non-idealities the CIM macro exhibits."""
    def loss_fn(params, batch, noise_key=None):
        p = params
        if weight_noise_std > 0.0 and noise_key is not None:
            leaves, treedef = jax.tree_util.tree_flatten(params)
            keys = jax.random.split(noise_key, len(leaves))
            leaves = [
                (l * (1 + weight_noise_std
                      * jax.random.normal(k, l.shape, jnp.float32)
                      ).astype(l.dtype))
                if jnp.issubdtype(l.dtype, jnp.floating) and l.ndim >= 2
                else l
                for l, k in zip(leaves, keys)]
            p = treedef.unflatten(leaves)
        logits, aux, _ = models.forward(
            cfg, p, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            encoder_embeds=batch.get("encoder_embeds"))
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg, *, base_lr=3e-4, warmup=100, total_steps=10000,
                    max_grad_norm=1.0, weight_noise_std: float = 0.0):
    loss_fn = make_loss_fn(cfg, weight_noise_std=weight_noise_std)
    _, opt_update = make_optimizer(cfg.optimizer)

    def train_step(params, opt_state, batch):
        noise_key = None
        if weight_noise_std > 0.0:
            noise_key = jax.random.fold_in(jax.random.PRNGKey(17),
                                           opt_state["step"])
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, noise_key)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = linear_warmup_cosine(opt_state["step"].astype(jnp.float32),
                                  base_lr=base_lr, warmup_steps=warmup,
                                  total_steps=total_steps)
        params, opt_state = opt_update(params, grads, opt_state, lr=lr)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, *, kv_max: int):
    def prefill_step(params, batch):
        logits, _, cache = models.forward(
            cfg, params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            encoder_embeds=batch.get("encoder_embeds"),
            collect_cache=True, kv_max=kv_max)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return prefill_step


def make_serve_step(cfg):
    """One decode step: append token, attend over the (distributed) cache,
    greedy-sample the next token."""
    def serve_step(params, cache, token, cache_len):
        logits, cache = models.decode_step(cfg, params, token, cache,
                                           cache_len)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step


def init_train_state(cfg, key):
    params = models.init_params(cfg, key)
    opt_init, _ = make_optimizer(cfg.optimizer)
    return params, opt_init(params)
