"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e-like, per assignment):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI               : ~50 GB/s per link; ring collectives use 2 links
                      effectively (bidirectional ring) -> 100 GB/s wire BW.

Terms (seconds, per step, per chip — cost_analysis of an SPMD-partitioned
module is already per-partition):
  compute    = HLO_FLOPs / peak
  memory     = HLO_bytes_accessed / hbm_bw
  collective = wire_bytes / ici_bw
where wire_bytes follows the standard ring model per collective op:
  all-gather      (g-1)/g * out_bytes
  reduce-scatter  (g-1)/g * in_bytes
  all-reduce      2 (g-1)/g * in_bytes
  all-to-all      (g-1)/g * in_bytes
  collective-permute  in_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9
ICI_WIRE_BW = 2 * ICI_LINK_BW   # bidirectional ring

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\(|\w).*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, total_devices: int) -> Dict[str, Dict]:
    """Sum logical + ring-model wire bytes per collective type from
    (partitioned) HLO text.  Shapes in the partitioned module are
    per-device, so byte counts are per-chip."""
    out: Dict[str, Dict] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shape, op = m.group(1), m.group(2)
        # operand types are not inlined in this HLO dialect; derive traffic
        # from the (per-device) OUTPUT shape + the ring model.
        out_bytes = _shape_bytes(out_shape)
        g = total_devices
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gm2 = _GROUPS_EXPL_RE.search(line)
            if gm2:
                g = len(gm2.group(1).split(","))
        g = max(g, 1)
        ring = (g - 1) / g
        if op == "all-gather":          # out = g x in
            wire = out_bytes * ring
            logical = out_bytes
        elif op == "all-reduce":        # out = in
            wire = 2 * out_bytes * ring
            logical = out_bytes
        elif op == "reduce-scatter":    # in = g x out
            wire = out_bytes * g * ring
            logical = out_bytes * g
        elif op == "all-to-all":        # in = out
            wire = out_bytes * ring
            logical = out_bytes
        else:  # collective-permute
            wire = out_bytes
            logical = out_bytes
        d = out.setdefault(op, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += logical
        d["wire_bytes"] += wire
    return out


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float):
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": wire_bytes / ICI_WIRE_BW,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


# ---------------------------------------------------------------------------
# Analytic HBM traffic (per chip, per step)
#
# The HLO-parsed byte count is structurally inflated on the CPU backend
# (bf16->f32 converts, CPU fusion boundaries that a TPU would fuse away),
# so the memory roofline term uses this first-principles model; the parsed
# number is recorded alongside as an upper bound.
# ---------------------------------------------------------------------------

def analytic_memory_bytes(cfg, shape, mesh_shape: dict, mode: str) -> float:
    """Per-chip HBM bytes touched per step."""
    nchips = 1
    for v in mesh_shape.values():
        nchips *= v
    model_div = mesh_shape.get("model", 1)
    N = cfg.n_params(include_embeddings=True)
    P = 2.0 * N                              # bf16 weight bytes
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    tokens_local = B * S / nchips

    if mode == "train":
        # params: fwd + remat-fwd + bwd weight reads (gathered per layer,
        # each chip streams the full gathered weights from HBM per pass)
        w = 3.0 * P
        # grads (bf16 w+r) + AdamW moments (fp32 r+w each), ZeRO-sharded
        opt = (4.0 * N + 16.0 * N) / nchips if cfg.optimizer == "adamw" \
            else (4.0 * N) / nchips
        # activations: ~12 tensor r/w per layer per token (remat keeps the
        # working set at one layer)
        layers = cfg.n_layers + (cfg.n_encoder_layers if cfg.is_encoder_decoder else 0)
        act = 12.0 * layers * tokens_local * d * 2.0
        logits = 6.0 * tokens_local * cfg.vocab_size  # fp32 r/w + bf16
        return w + opt + act + logits
    if mode == "prefill":
        w = 1.0 * P
        layers = cfg.n_layers + (cfg.n_encoder_layers if cfg.is_encoder_decoder else 0)
        act = 8.0 * layers * tokens_local * d * 2.0
        cache = 2.0 * _cache_bytes(cfg, B, S) / nchips
        return w + act + cache
    # decode: every (TP-sharded) weight shard is read once per token;
    # the KV cache shard is read (+appended) once.
    if cfg.moe:
        # only the routed experts' weights are streamed from HBM per token
        # (with batch > experts all experts are usually hit; keep the
        # active-param bound, which is what a well-scheduled kernel reads)
        w = 2.0 * cfg.active_params(True) / max(model_div, 1)
    else:
        w = P / max(model_div, 1)
    cache = _cache_bytes(cfg, B, S) / nchips
    act = 30.0 * cfg.n_layers * (B / max(nchips / model_div, 1)) * d * 2.0
    return w + cache + act


def _cache_bytes(cfg, B: int, S: int) -> float:
    """Global KV/state cache bytes."""
    kinds_attn = 0
    if cfg.family in ("dense", "moe", "vlm"):
        kinds_attn = cfg.n_layers
    elif cfg.family == "hybrid":
        kinds_attn = cfg.n_layers // max(cfg.attn_every, 1)
    elif cfg.family == "audio":
        kinds_attn = cfg.n_layers
    kv = 2.0 * kinds_attn * B * S * cfg.kv_dim * 2.0
    if cfg.is_encoder_decoder:
        kv += 2.0 * cfg.n_layers * B * cfg.encoder_seq * cfg.kv_dim * 2.0
    ssm = 0.0
    if cfg.ssm is not None:
        n_ssm = cfg.n_layers if cfg.family == "ssm" else \
            cfg.n_layers - cfg.n_layers // max(cfg.attn_every, 1) \
            if cfg.family == "hybrid" else 0
        di = cfg.ssm.expand * cfg.d_model
        H = di // cfg.ssm.head_dim
        ssm = n_ssm * B * (H * cfg.ssm.head_dim * cfg.ssm.d_state * 4.0
                           + (cfg.ssm.conv_width - 1) * (di + 2 * cfg.ssm.d_state) * 2.0)
    return kv + ssm


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (global, whole step)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """Useful-math FLOPs for the step: 6·N·D train / 2·N·D inference
    (N = active non-embedding params + lm head), plus exact attention terms.
    """
    B, S = shape.global_batch, shape.seq_len
    N = cfg.active_params(include_embeddings=False)
    head = cfg.d_model * cfg.vocab_size          # logits matmul params
    n_attn_layers = _attn_layer_count(cfg)
    if shape.kind == "train":
        tokens = B * S
        mm = 6.0 * (N + head) * tokens
        attn = 3 * 2 * 2 * B * n_attn_layers * cfg.q_dim * _causal_pairs(cfg, S)
        return mm + attn
    if shape.kind == "prefill":
        tokens = B * S
        mm = 2.0 * (N + head) * tokens
        attn = 2 * 2 * B * n_attn_layers * cfg.q_dim * _causal_pairs(cfg, S)
        if cfg.is_encoder_decoder:
            mm += 2.0 * N * B * cfg.encoder_seq   # encoder pass (approx)
        return mm + attn
    # decode: one token against an S-long cache
    mm = 2.0 * (N + head) * B
    kv_span = S if cfg.sliding_window is None else min(S, cfg.sliding_window)
    attn = 2 * 2 * B * n_attn_layers * cfg.q_dim * kv_span
    return mm + attn


def _attn_layer_count(cfg) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.is_encoder_decoder:
        return cfg.n_layers + cfg.n_encoder_layers
    return cfg.n_layers


def _causal_pairs(cfg, S: int) -> float:
    if cfg.sliding_window is not None and S > cfg.sliding_window:
        w = cfg.sliding_window
        return w * (w + 1) / 2 + (S - w) * w
    return S * (S + 1) / 2
