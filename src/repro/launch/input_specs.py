"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the abstract args for the step function
of the shape's kind:
  train   -> batch {tokens, labels [, prefix_embeds | encoder_embeds]}
  prefill -> batch {tokens [, ...stubs]}
  decode  -> (token, cache, cache_len)
Modality frontends are STUBS per the assignment: the vlm/audio entries get
precomputed patch/frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ShapeSpec

SDS = jax.ShapeDtypeStruct


def _stub_inputs(cfg, batch: int):
    extra = {}
    if cfg.n_prefix_tokens:
        extra["prefix_embeds"] = SDS((batch, cfg.n_prefix_tokens, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        extra["encoder_embeds"] = SDS((batch, cfg.encoder_seq, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
    return extra


def train_batch_specs(cfg, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((B, S), jnp.int32),
             "labels": SDS((B, S), jnp.int32)}
    batch.update(_stub_inputs(cfg, B))
    return batch


def prefill_batch_specs(cfg, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((B, S), jnp.int32)}
    batch.update(_stub_inputs(cfg, B))
    return batch


def decode_arg_specs(cfg, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: models.init_cache(cfg, B, S))
    token = SDS((B, 1), jnp.int32)
    cache_len = SDS((), jnp.int32)
    return token, cache, cache_len


def params_shapes(cfg):
    return jax.eval_shape(
        lambda: models.init_params(cfg, jax.random.PRNGKey(0)))
