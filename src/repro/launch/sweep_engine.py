"""Vectorized sweep engine: advance a GRID of serving cells in lockstep.

Every headline result in this repro is a *sweep* — Table II is a model x
context grid, the paged bench a ctx x rate x tier grid, the prefix bench
a sharing on/off pair — and the roadmap's fleet studies need thousands
of cells.  Running `ContinuousBatchingEngine` once per cell re-pays the
per-cell costs (simulator construction, `decode_affine` walks, prefill
pricing) and executes the pure-decode majority of every cell one Python
iteration at a time.  This module lifts the PR-5 SoA serving loop one
dimension higher: the unit of execution is the grid.

How a cell executes
-------------------

Each cell still owns a real `ContinuousBatchingEngine` (aggregate-only
TimelineIR recorder) — admission, prefill, chunked prefill, preemption,
prefix adoption, finishes and idle gaps all run the engine's own scalar
code, byte-for-byte.  What gets vectorized is the regime that dominates
wall clock: *cruise*, an uninterrupted streak of pure decode rounds.
On entering cruise the cell's round state is snapshotted into cell-major
numpy arrays (batch size, context sum, affine cost coefficients, KV
fetch bytes, busy power, ...) plus three exact countdowns:

  * ``exitA``  — rounds until a scalar event (a resident finishing, or
    the deficit counter reaching ``decode_quantum`` while a prefill is
    admissible) forces the cell back to the scalar step loop;
  * ``growA``  — rounds until some resident crosses a KV block boundary
    (paged cells only);
  * ``arrA``   — wall-clock time of the next pending arrival.

One lockstep iteration then advances EVERY cruising cell by a decode
BURST — up to its own safe horizon of rounds, folded into one
``np.add.accumulate`` (`SweepAggregates.decode_burst`, a strict
sequential left fold; `decode_round` is the one-round reference it is
tested against) — performing per lane exactly the scalar engine's
arithmetic — same truncations, same float64 adds in the same order — so
each cell's `ServingReport` and `kv_stats` are byte-identical to running
the scalar fast engine cell by cell (tests/test_sweep_engine.py).

KV block-table growth is too frequent to leave cruise for (a block
boundary every ``block_tokens / batch`` rounds): those rounds run
*semi-scalar* — the cell's objects and timeline row are synced, the
engine's own ``_kv_prepare_round`` runs verbatim (spills, preemption,
copy-on-write all land on the real timeline), and the cell stays in the
same vectorized round, mirroring the scalar ``_decode_round`` = prepare
+ round sequence.

Cells grouped by ``(simulator, model config)`` share one
`ChipletAllocation` and one `core.scheduling.DecodeCostSurface`, so the
O(layers) cycle-model walks are paid once per distinct batch shape per
GROUP instead of once per cell, and a calibration mutation on the shared
model (``cycle_model.alpha = ...``) invalidates every cell of every
sweep at once through the surface's version stamp.

Feature coverage and graceful degradation
-----------------------------------------

Chunked prefill, paged KV, preemption and COW prefix sharing are fully
supported on the vectorized path.  Cells using features the batched
round cannot price — ``overlap > 0``, ``dynamic_ccpg``, TTFT deadlines
in the trace, or a non-affine `CycleModel` (subclass or memoization
off) — degrade gracefully to a per-cell scalar run, logged with the
reason and flagged in their `SweepResult.fallback`.

Sweep-mode report caveats (documented contract): per-cell reports and
``kv_stats`` are byte-identical to the scalar engine, including
``max_queue_depth``; the `ServingReport.queue_depth` *samples* and the
engine's per-round ``(clock, DECODE, -1)`` event markers are only
recorded on scalar iterations (all other events — PREFILL / FINISH /
PREEMPT / REJECT / IDLE — are complete and exactly timestamped).

  PYTHONPATH=src python -m benchmarks.run sweep
"""
from __future__ import annotations

import copy
import dataclasses
import logging
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduling import (ChipletAllocation, DecodeCostSurface,
                                   allocate_chiplets)
from repro.core.simulator import PicnicSimulator
from repro.core.timeline import SweepAggregates
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         EngineConfig, KVCacheStats,
                                         ServingReport, TrackedRequest)

log = logging.getLogger(__name__)

_BIG = 1 << 60          # "no exit scheduled" countdown sentinel
_H_CAP = 512            # max decode rounds folded into one burst


@dataclasses.dataclass
class SweepCell:
    """One point of a sweep grid.

    Cells passing the SAME ``sim`` object (and model ``cfg``) share its
    memoized cycle model, one chiplet allocation and one batched decode
    cost surface — the big amortization win over per-cell engines.
    ``sim=None`` cells all share one default `PicnicSimulator`.
    """
    key: str
    cfg: object
    trace: Sequence[TrackedRequest]
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    sim: Optional[PicnicSimulator] = None


@dataclasses.dataclass
class SweepResult:
    key: str
    report: ServingReport
    kv_stats: Optional[KVCacheStats]
    # None = vectorized path; else the reason this cell ran scalar
    fallback: Optional[str] = None


class _Group:
    """Cells sharing (simulator, model config): one allocation, one
    batched decode cost surface sized to the group's largest batch."""

    __slots__ = ("sim", "cfg", "alloc", "surface", "max_batch")

    def __init__(self, sim: PicnicSimulator, cfg):
        self.sim = sim
        self.cfg = cfg
        self.alloc: ChipletAllocation = allocate_chiplets(cfg, sim.tile)
        self.surface: Optional[DecodeCostSurface] = None
        self.max_batch = 0


class _CellState:
    """Per-cell runtime bookkeeping around the cell's scalar engine."""

    __slots__ = ("pos", "i", "cell", "group", "eng", "requests", "pending",
                 "in_cruise", "done", "iters", "qmax", "report", "kv")

    def __init__(self, pos: int, i: int, cell: SweepCell, group: _Group,
                 eng: ContinuousBatchingEngine,
                 requests: List[TrackedRequest]):
        self.pos = pos          # index into the caller's cell list
        self.i = i              # lane in the cell-major arrays
        self.cell = cell
        self.group = group
        self.eng = eng
        self.requests = requests
        self.pending = None     # set by run() via _prepare_run
        self.in_cruise = False
        self.done = False
        self.iters = 0          # scalar steps + vector rounds (max_iters)
        self.qmax = 0           # queue depth seen at cruise preemptions
        self.report: Optional[ServingReport] = None
        self.kv: Optional[KVCacheStats] = None


def _fallback_reason(cell: SweepCell) -> Optional[str]:
    e = cell.engine
    if e.overlap != 0.0:
        return "overlap>0 (C2C hiding prices per-request)"
    if e.ccpg and e.dynamic_ccpg:
        return "dynamic_ccpg (per-round ClusterWake walk)"
    if any(r.deadline_ttft is not None for r in cell.trace):
        return "ttft_deadline (per-round at-risk check)"
    return None


class SweepEngine:
    """Run a grid of serving cells in one vectorized lockstep pass.

    Single-shot: construct with the cells, call :meth:`run` once.
    Results come back in cell order, each byte-identical to
    ``ContinuousBatchingEngine(...).run(trace)`` for that cell.
    """

    def __init__(self, cells: Sequence[SweepCell]):
        self.cells = list(cells)
        self._default_sim: Optional[PicnicSimulator] = None
        self._groups: Dict[Tuple[int, int], _Group] = {}
        self._states: List[_CellState] = []
        self._fallbacks: List[Tuple[int, SweepCell, _Group, str]] = []

        vec: List[Tuple[int, SweepCell, _Group]] = []
        for pos, cell in enumerate(self.cells):
            sim = cell.sim
            if sim is None:
                if self._default_sim is None:
                    self._default_sim = PicnicSimulator()
                sim = self._default_sim
            gkey = (id(sim), id(cell.cfg))
            group = self._groups.get(gkey)
            if group is None:
                group = self._groups[gkey] = _Group(sim, cell.cfg)
            reason = _fallback_reason(cell)
            if reason is not None:
                self._fallbacks.append((pos, cell, group, reason))
                continue
            group.max_batch = max(group.max_batch, cell.engine.max_batch)
            vec.append((pos, cell, group))

        # batched cost surfaces, one per group that has vectorized cells;
        # a surface with no affine lane (memoization off / non-affine
        # subclass) demotes the whole group to the scalar fallback
        for group in self._groups.values():
            if group.max_batch:
                group.surface = DecodeCostSurface(
                    group.sim.cycle_model, group.cfg, group.alloc,
                    group.max_batch)
        kept: List[Tuple[int, SweepCell, _Group]] = []
        for pos, cell, group in vec:
            if not group.surface.affine[1:].any():
                self._fallbacks.append(
                    (pos, cell, group,
                     "non-affine decode cost (memoize off or subclass)"))
            else:
                kept.append((pos, cell, group))

        n = len(kept)
        for i, (pos, cell, group) in enumerate(kept):
            eng = ContinuousBatchingEngine(
                cell.cfg, sim=group.sim,
                engine=dataclasses.replace(cell.engine,
                                           aggregate_timeline=True),
                alloc=group.alloc)
            # engines mutate per-request state, and grid builders often
            # reuse one trace object across cells — copy defensively
            requests = [copy.copy(r) for r in cell.trace]
            self._states.append(_CellState(pos, i, cell, group, eng,
                                           requests))

        # -- cell-major lockstep state (one lane per vectorized cell) --
        self.agg = SweepAggregates(n)
        self._cruise = np.zeros(n, dtype=bool)
        self.bA = np.zeros(n, dtype=np.int64)       # resident batch size
        self.ctxA = np.zeros(n, dtype=np.int64)     # running context sum
        self.baseA = np.zeros(n, dtype=np.int64)    # affine base cycles
        self.nattnA = np.zeros(n, dtype=np.int64)   # attention multiplier
        self.c2cA = np.zeros(n, dtype=np.int64)     # decode burst bytes
        self.fA = np.zeros(n, dtype=np.int64)       # frozen kv fetch bytes
        self.cppA = np.zeros(n)                     # ctx_cycles_per_pos
        self.alphaA = np.zeros(n)                   # CIM speedup factor
        self.residA = np.zeros(n, dtype=np.int64)   # CCPG wake residue cyc
        self.freqA = np.zeros(n)                    # tile clock Hz
        self.powA = np.zeros(n)                     # busy power W
        self.bwA = np.zeros(n)                      # C2C bandwidth B/s
        self.pendA = np.zeros(n, dtype=np.int64)    # rounds since sync
        self.exitA = np.zeros(n, dtype=np.int64)    # rounds to scalar event
        self.growA = np.zeros(n, dtype=np.int64)    # rounds to KV growth
        self.arrA = np.full(n, math.inf)            # next pending arrival
        for st in self._states:
            eng = st.eng
            self.residA[st.i] = eng._residue_cyc
            self.freqA[st.i] = eng._freq_hz
            self.powA[st.i] = eng._busy_power
            self.bwA[st.i] = eng._bandwidth_Bps

    # ------------------------------------------------------------------
    def run(self) -> List[SweepResult]:
        results: List[Optional[SweepResult]] = [None] * len(self.cells)

        for pos, cell, group, reason in self._fallbacks:
            log.info("sweep cell %r: scalar fallback (%s)", cell.key,
                     reason)
            eng = ContinuousBatchingEngine(cell.cfg, sim=group.sim,
                                           engine=cell.engine,
                                           alloc=group.alloc)
            rep = eng.run([copy.copy(r) for r in cell.trace])
            results[pos] = SweepResult(cell.key, rep, eng.kv_stats,
                                       fallback=reason)

        for st in self._states:
            st.pending = st.eng._prepare_run(st.requests)

        agg = self.agg
        while True:
            # phase A: scalar service — every non-cruising cell steps its
            # own engine until it finishes or the next step would be a
            # vectorizable decode round
            for st in self._states:
                if not st.done and not st.in_cruise:
                    self._scalar_service(st)
            idx = np.nonzero(self._cruise)[0]
            if idx.size == 0:
                break           # phase A leaves every cell done or cruising

            self._check_surfaces()

            # phase B.1: cruise exits — a scheduled scalar event (finish /
            # admissible prefill) or a pending arrival is due this round
            lm = (self.exitA[idx] < 1) | (self.arrA[idx] <= agg.now[idx])
            if lm.any():
                for i in idx[lm]:
                    self._leave_cruise(self._states[int(i)])
                idx = idx[~lm]
                if idx.size == 0:
                    continue

            # phase B.2: KV growth rounds — run the engine's own round
            # prep semi-scalar; the cell stays in this vector round
            gm = self.growA[idx] < 1
            if gm.any():
                drop = [int(i) for i in idx[gm]
                        if not self._growth_prep(self._states[int(i)])]
                if drop:
                    idx = idx[~np.isin(idx, drop)]
                    if idx.size == 0:
                        continue

            # phase B.3: a decode BURST for every cruising cell — each
            # lane advances up to its own safe horizon (rounds until its
            # next scalar event or KV growth, capped) in one sequential
            # fold.  Round j of the burst prices the scalar engine's
            # exact arithmetic at the context it would see then:
            #   cyc = int((base + n_attn * int(cpp*(ctx + (j-1)*b))) * alpha)
            #   dt  = (cyc + residue) / freq
            # A cell that just ran growth prep may have exitA == 0 (the
            # prep flipped want-prefill on), but its round was committed
            # before the prep — clip forces the single committed round.
            h0 = np.minimum(self.exitA[idx], self.growA[idx])
            np.clip(h0, 1, _H_CAP, out=h0)
            J = np.arange(int(h0.max()), dtype=np.int64)[:, None]
            b = self.bA[idx]
            ctx = self.ctxA[idx] + J * b
            cyc = self.baseA[idx] + self.nattnA[idx] * (
                self.cppA[idx] * ctx).astype(np.int64)
            cyc = (cyc * self.alphaA[idx]).astype(np.int64)
            dt = (cyc + self.residA[idx]) / self.freqA[idx]
            burst = self.c2cA[idx]
            fetch = self.fA[idx]
            bw = self.bwA[idx]
            h = agg.decode_burst(idx, h0, dt, self.powA[idx], b,
                                 burst, burst / bw, fetch, fetch / bw,
                                 self.arrA[idx])
            self.ctxA[idx] += b * h
            self.pendA[idx] += h
            self.exitA[idx] -= h
            self.growA[idx] -= h

        for st in self._states:
            results[st.pos] = SweepResult(st.cell.key, st.report, st.kv)
        return results

    # ------------------------------------------------------------------
    # scalar service and cruise transitions
    def _scalar_service(self, st: _CellState) -> None:
        eng, pending = st.eng, st.pending
        max_iters = eng.engine.max_iters
        while True:
            if not (pending or eng.queue or eng._active_idx
                    or eng._partial is not None):
                self._finalize(st)
                return
            if self._enterable(st):
                self._enter_cruise(st)
                return
            st.iters += 1
            if st.iters > max_iters:
                raise RuntimeError("sweep cell exceeded max_iters")
            eng.step(pending)

    def _enterable(self, st: _CellState) -> bool:
        """Would the engine's next step be a decode round the vector path
        can price (affine batch size) and complete (no finish)?"""
        eng = st.eng
        if not eng._active_idx:
            return False
        if st.pending and st.pending[0].arrival <= eng.timeline.now:
            return False
        if not st.group.surface.affine[len(eng._active_idx)]:
            return False
        fin, pre = self._budgets(eng)
        return fin >= 1 and pre >= 1

    def _enter_cruise(self, st: _CellState) -> None:
        i, eng = st.i, st.eng
        self._snap_cost(st, len(eng._active_idx))
        self.ctxA[i] = eng._ctx_sum
        self.fA[i] = self._fetch_bytes(eng)
        fin, pre = self._budgets(eng)
        self.exitA[i] = min(fin, pre)
        self.growA[i] = self._grow_budget(eng)
        self.arrA[i] = (st.pending[0].arrival if st.pending else math.inf)
        self.pendA[i] = 0
        self.agg.sync_in(i, eng.timeline)
        st.in_cruise = True
        self._cruise[i] = True

    def _leave_cruise(self, st: _CellState) -> None:
        self._sync_objects(st)
        self.agg.sync_out(st.i, st.eng.timeline)
        st.in_cruise = False
        self._cruise[st.i] = False

    def _sync_objects(self, st: _CellState) -> None:
        """Replay the pending vector rounds onto the engine's object
        state: every resident gained one token per round, the round/
        credit counters advanced, and the (frozen) per-round DRAM fetch
        accrued — exactly what the scalar rounds would have written."""
        p = int(self.pendA[st.i])
        if not p:
            return
        eng = st.eng
        for r in eng._active_reqs:
            r.generated += p
            r.context += p
        eng._ctx_sum = int(self.ctxA[st.i])
        eng._round_no += p
        eng.decode_credit += p
        f = int(self.fA[st.i])
        if f:
            eng._kv_fetch_bytes += p * f
        st.iters += p
        self.pendA[st.i] = 0

    def _growth_prep(self, st: _CellState) -> bool:
        """A resident crosses a KV block boundary this round: sync the
        cell and run the engine's own ``_kv_prepare_round`` (growth,
        watermark preemption, spill/COW timeline charges) exactly as the
        scalar ``_decode_round`` would before pricing the round.  The
        cell keeps its place in the current vector round; returns False
        only when the post-prep batch size has no affine cost lane, in
        which case the committed round ran scalar instead."""
        i, eng = st.i, st.eng
        self._sync_objects(st)
        self.agg.sync_out(i, eng.timeline)
        eng._kv_prepare_round()
        q = len(eng.queue)      # preemption appendlefts victims: track
        if q > st.qmax:         # the depth the scalar engine would have
            st.qmax = q         # sampled on its next step
        self.agg.sync_in(i, eng.timeline)
        b = len(eng._active_idx)
        if not st.group.surface.affine[b]:
            eng._decode_round()     # re-entry prep is a no-op (needed==0)
            self.agg.sync_in(i, eng.timeline)
            st.in_cruise = False
            self._cruise[i] = False
            st.iters += 1
            return False
        self._snap_cost(st, b)
        self.ctxA[i] = eng._ctx_sum
        self.fA[i] = self._fetch_bytes(eng)
        fin, pre = self._budgets(eng)
        self.exitA[i] = min(fin, pre)
        self.growA[i] = self._grow_budget(eng)
        return True

    def _finalize(self, st: _CellState) -> None:
        eng = st.eng
        rep = eng._report(st.requests)
        # queue-depth maxima reached during cruise (growth preemptions)
        # were tracked out-of-band; everything else in the report comes
        # from the synced timeline aggregates
        if st.qmax > rep.max_queue_depth:
            rep.max_queue_depth = st.qmax
        st.report = rep
        st.kv = eng.kv_stats
        st.done = True

    # ------------------------------------------------------------------
    # snapshots and countdowns
    def _snap_cost(self, st: _CellState, b: int) -> None:
        surf = st.group.surface
        i = st.i
        self.bA[i] = b
        self.baseA[i] = surf.base[b]
        self.nattnA[i] = surf.n_attn[b]
        self.c2cA[i] = surf.c2c_bytes[b]
        self.cppA[i] = surf.cpp
        self.alphaA[i] = surf.alpha

    @staticmethod
    def _budgets(eng: ContinuousBatchingEngine) -> Tuple[int, int]:
        """(finish, prefill) budgets: how many decode rounds INCLUDING
        the next one can run before that scalar event fires."""
        if eng.kv is None:
            heap = eng._finish_heap
            fin = (heap[0][0] - eng._round_no - 1) if heap else _BIG
        else:
            fin = min(r.max_new - r.generated
                      for r in eng._active_reqs) - 1
        if eng._partial is not None:
            want = True
        elif not eng.queue or eng._free_slot() is None:
            want = False
        else:
            want = eng.kv is None or eng._kv_can_admit()
        pre = (eng.engine.decode_quantum - eng.decode_credit
               if want else _BIG)
        return fin, pre

    @staticmethod
    def _grow_budget(eng: ContinuousBatchingEngine) -> int:
        """Rounds until some resident's next token no longer fits its
        block table (capacity is exact: growth fires when context
        reaches ``len(blocks) * block_tokens``)."""
        kv = eng.kv
        if kv is None:
            return _BIG
        bt = kv.cfg.block_tokens
        tables = kv.tables
        return min(len(tables[r.request_id].blocks) * bt - r.context
                   for r in eng._active_reqs)

    @staticmethod
    def _fetch_bytes(eng: ContinuousBatchingEngine) -> int:
        """Per-round DRAM-resident KV fetch — frozen between growth/
        scalar events (block tables only change there)."""
        kv = eng.kv
        if kv is None:
            return 0
        return sum(kv.dram_tokens(eng.slots[j].request_id)
                   for j in eng._active_idx) * kv.cfg.bytes_per_token

    def _check_surfaces(self) -> None:
        """Mid-run calibration guard, mirroring the scalar engine's
        per-round ``aff[5] != cm._cal_ver`` check: a mutated model
        rebuilds the group surface and re-snapshots every cruising
        cell's cost lanes before the next vector round."""
        refreshed = False
        for group in self._groups.values():
            if group.surface is not None and group.surface.refresh():
                refreshed = True
        if refreshed:
            for st in self._states:
                if st.in_cruise:
                    self._snap_cost(st, int(self.bA[st.i]))


def sweep_serve(cells: Sequence[SweepCell]) -> List[SweepResult]:
    """Run a grid of serving cells through one vectorized lockstep pass;
    results in cell order, each byte-identical to a per-cell scalar
    `ContinuousBatchingEngine` run."""
    return SweepEngine(cells).run()
