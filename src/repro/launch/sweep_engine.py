"""Vectorized sweep engine: advance a GRID of serving cells in lockstep.

Every headline result in this repro is a *sweep* — Table II is a model x
context grid, the paged bench a ctx x rate x tier grid, the prefix bench
a sharing on/off pair — and the roadmap's fleet studies need thousands
of cells.  Running `ContinuousBatchingEngine` once per cell re-pays the
per-cell costs (simulator construction, `decode_affine` walks, prefill
pricing) and executes the pure-decode majority of every cell one Python
iteration at a time.  This module lifts the PR-5 SoA serving loop one
dimension higher: the unit of execution is the grid.

How a cell executes
-------------------

Each cell still owns a real `ContinuousBatchingEngine` (aggregate-only
TimelineIR recorder) — admission, monolithic prefill, preemption, prefix
adoption, finishes and idle gaps all run the engine's own scalar code,
byte-for-byte.  What gets vectorized is the regimes that dominate wall
clock, the *cruises*:

  * **decode cruise** — an uninterrupted streak of pure decode rounds.
    On entry the cell's round state is snapshotted into cell-major numpy
    arrays (batch size, context sum, split-cost coefficients, KV fetch
    bytes, busy power, ...) plus exact countdowns: ``exitA`` (rounds to
    a scalar event: a resident finishing, or the deficit counter
    reaching ``decode_quantum`` while a prefill is admissible),
    ``growA`` (rounds to a KV block boundary, paged cells only) and
    ``arrA`` (wall-clock time of the next pending arrival).
  * **prefill cruise** — a lone chunked prefill streaming full-cap
    chunks with no residents and no due arrival: the guaranteed
    non-finishing chunks fold the same way, priced by the cost surface's
    closed-form prefill lane.

One lockstep iteration advances EVERY cruising cell by a BURST — up to
its own safe horizon of rounds/chunks, folded into one
``np.add.accumulate`` (`SweepAggregates.decode_burst` /
`prefill_burst`, strict sequential left folds; `decode_round` is the
one-round reference they are tested against) — performing per lane
exactly the scalar engine's arithmetic — same truncations, same float64
adds in the same order — so each cell's `ServingReport` and `kv_stats`
are byte-identical to running the scalar fast engine cell by cell
(tests/test_sweep_engine.py).

KV block-table growth is too frequent to leave cruise for (a block
boundary every ``block_tokens / batch`` rounds): those rounds run
*semi-scalar* — the cell's objects and timeline row are synced, the
engine's own ``_kv_prepare_round`` runs verbatim (spills, preemption,
copy-on-write all land on the real timeline, with a batched
`BlockAllocator.grow_round` fast path), and the cell stays in the same
vectorized round, mirroring the scalar ``_decode_round`` = prepare +
round sequence.

Cells grouped by ``(simulator, model config)`` share one
`ChipletAllocation` and one `core.scheduling.DecodeCostSurface`, so the
O(layers) cycle-model walks are paid once per distinct batch shape per
GROUP instead of once per cell, and a calibration mutation on the shared
model (``cycle_model.alpha = ...``) invalidates every cell of every
sweep at once through the surface's version stamp.

Feature coverage and graceful degradation
-----------------------------------------

Chunked prefill, paged KV, preemption, COW prefix sharing,
``overlap > 0`` (C2C hiding priced via the split-cost lane:
``int((base + n_attn*int(cpp*ctx) + (1-ov)*c2c_cyc) * alpha)``),
``dynamic_ccpg`` (the per-round `ClusterWake` walk folded into the
burst as wake columns) and TTFT deadlines (a vectorized at-risk horizon
check truncating the burst exactly where the scalar engine would flip
to a must-prefill) are all fully supported on the vectorized path.
The only remaining scalar fallback is a non-affine `CycleModel`
(subclass or memoization off), logged once per run with the cell count
(per-cell detail at DEBUG) and flagged in `SweepResult.fallback`.

Sweep-mode report caveats (documented contract): per-cell reports and
``kv_stats`` are byte-identical to the scalar engine, including
``max_queue_depth``; the `ServingReport.queue_depth` *samples*, the
engine's per-round ``(clock, DECODE, -1)`` event markers, and the
mid-chunk ``PREFILL`` progress markers of chunks folded into a prefill
cruise are only recorded on scalar iterations (all other events —
PREFILL boundaries / FINISH / PREEMPT / REJECT / IDLE — are complete
and exactly timestamped).

`SweepEngine` is single-shot: a second :meth:`run` raises.  The wall
clock spent on the vector path vs the scalar fallback path is split
into ``vector_wall_s`` / ``fallback_wall_s`` for the benchmarks.

  PYTHONPATH=src python -m benchmarks.run sweep
"""
from __future__ import annotations

import copy
import dataclasses
import logging
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduling import (ChipletAllocation, DecodeCostSurface,
                                   allocate_chiplets)
from repro.core.simulator import PicnicSimulator
from repro.core.timeline import SweepAggregates
from repro.launch.config import FaultConfig, FleetConfig, ServingConfig
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         KVCacheStats, ServingReport,
                                         TrackedRequest)

log = logging.getLogger(__name__)

_BIG = 1 << 60          # "no exit scheduled" countdown sentinel
_H_CAP = 512            # max decode rounds / prefill chunks per burst


@dataclasses.dataclass
class SweepCell:
    """One point of a sweep grid.

    Cells passing the SAME ``sim`` object (and model ``cfg``) share its
    memoized cycle model, one chiplet allocation and one batched decode
    cost surface — the big amortization win over per-cell engines.
    ``sim=None`` cells all share one default `PicnicSimulator`.
    """
    key: str
    cfg: object
    trace: Sequence[TrackedRequest]
    engine: ServingConfig = dataclasses.field(
        default_factory=ServingConfig)
    sim: Optional[PicnicSimulator] = None
    # an ACTIVE fault schedule demotes the cell to the scalar fallback
    # path (flagged in SweepResult.fallback): crash/recovery re-routing
    # is inherently event-driven and runs through a 1-node combined
    # FleetEngine instead of the lockstep burst fold.  An inert
    # FaultConfig (no faults declared) stays on the vector path.
    fault: Optional[FaultConfig] = None


@dataclasses.dataclass
class SweepResult:
    key: str
    report: ServingReport
    kv_stats: Optional[KVCacheStats]
    # None = vectorized path; else the reason this cell ran scalar
    fallback: Optional[str] = None


class _Group:
    """Cells sharing (simulator, model config): one allocation, one
    batched decode cost surface sized to the group's largest batch."""

    __slots__ = ("sim", "cfg", "alloc", "surface", "max_batch")

    def __init__(self, sim: PicnicSimulator, cfg):
        self.sim = sim
        self.cfg = cfg
        self.alloc: ChipletAllocation = allocate_chiplets(cfg, sim.tile)
        self.surface: Optional[DecodeCostSurface] = None
        self.max_batch = 0


class _CellState:
    """Per-cell runtime bookkeeping around the cell's scalar engine."""

    __slots__ = ("pos", "i", "cell", "group", "eng", "requests", "pending",
                 "in_cruise", "done", "iters", "qmax", "report", "kv",
                 "_fin", "_pre", "_eta", "_adl", "_pfK",
                 "_fields", "_lat", "_ttft")

    def __init__(self, pos: int, i: int, cell: SweepCell, group: _Group,
                 eng: ContinuousBatchingEngine,
                 requests: List[TrackedRequest]):
        self.pos = pos          # index into the caller's cell list
        self.i = i              # lane in the cell-major arrays
        self.cell = cell
        self.group = group
        self.eng = eng
        self.requests = requests
        self.pending = None     # set by run() via _prepare_run
        self.in_cruise = False
        self.done = False
        self.iters = 0          # scalar steps + vector rounds (max_iters)
        self.qmax = 0           # queue depth seen at cruise preemptions
        self.report: Optional[ServingReport] = None
        self.kv: Optional[KVCacheStats] = None
        # stashed by _enterable / _pf_enterable for the batched entry
        self._fin = self._pre = self._pfK = 0
        self._eta, self._adl = 0.0, math.inf
        # deferred report inputs (percentiles batched across cells)
        self._fields = None
        self._lat = self._ttft = None


class SweepEngine:
    """Run a grid of serving cells in one vectorized lockstep pass.

    Single-shot: construct with the cells, call :meth:`run` once.
    Results come back in cell order, each byte-identical to
    ``ContinuousBatchingEngine(...).run(trace)`` for that cell.
    """

    def __init__(self, cells: Sequence[SweepCell]):
        self.cells = list(cells)
        self._default_sim: Optional[PicnicSimulator] = None
        self._groups: Dict[Tuple[int, int], _Group] = {}
        self._states: List[_CellState] = []
        self._fallbacks: List[Tuple[int, SweepCell, _Group, str]] = []
        self._ran = False
        # wall-clock split + per-reason counts, filled by run() for the
        # benchmark summary lines
        self.vector_wall_s = 0.0
        self.fallback_wall_s = 0.0
        self.fallback_counts: Dict[str, int] = {}

        vec: List[Tuple[int, SweepCell, _Group]] = []
        for pos, cell in enumerate(self.cells):
            sim = cell.sim
            if sim is None:
                if self._default_sim is None:
                    self._default_sim = PicnicSimulator()
                sim = self._default_sim
            gkey = (id(sim), id(cell.cfg))
            group = self._groups.get(gkey)
            if group is None:
                group = self._groups[gkey] = _Group(sim, cell.cfg)
            group.max_batch = max(group.max_batch, cell.engine.max_batch)
            if cell.fault is not None and cell.fault.active():
                self._fallbacks.append(
                    (pos, cell, group,
                     "fault injection (1-node fleet fallback)"))
            else:
                vec.append((pos, cell, group))

        # batched cost surfaces, one per group; a surface with no affine
        # lane (memoization off / non-affine subclass) demotes the whole
        # group to the scalar fallback
        for group in self._groups.values():
            if group.max_batch:
                group.surface = DecodeCostSurface(
                    group.sim.cycle_model, group.cfg, group.alloc,
                    group.max_batch)
        kept: List[Tuple[int, SweepCell, _Group]] = []
        for pos, cell, group in vec:
            if not group.surface.affine[1:].any():
                self._fallbacks.append(
                    (pos, cell, group,
                     "non-affine decode cost (memoize off or subclass)"))
            else:
                kept.append((pos, cell, group))

        n = len(kept)
        for i, (pos, cell, group) in enumerate(kept):
            eng = ContinuousBatchingEngine(
                cell.cfg, sim=group.sim,
                engine=dataclasses.replace(cell.engine,
                                           aggregate_timeline=True),
                alloc=group.alloc)
            # engines mutate per-request state, and grid builders often
            # reuse one trace object across cells — copy defensively
            requests = [copy.copy(r) for r in cell.trace]
            self._states.append(_CellState(pos, i, cell, group, eng,
                                           requests))

        # -- cell-major lockstep state (one lane per vectorized cell) --
        self.agg = SweepAggregates(n)
        self._cruise = np.zeros(n, dtype=bool)
        self._pfA = np.zeros(n, dtype=bool)         # lane cruises prefill
        self.bA = np.zeros(n, dtype=np.int64)       # resident batch size
        self.ctxA = np.zeros(n, dtype=np.int64)     # running context sum
        self.baseA = np.zeros(n, dtype=np.int64)    # compute base cycles
        self.nattnA = np.zeros(n, dtype=np.int64)   # attention multiplier
        self.c2cA = np.zeros(n, dtype=np.int64)     # decode burst bytes
        self.ovA = np.zeros(n)                      # (1-overlap)*c2c_cyc
        self.fA = np.zeros(n, dtype=np.int64)       # frozen kv fetch bytes
        self.cppA = np.zeros(n)                     # ctx_cycles_per_pos
        self.alphaA = np.zeros(n)                   # CIM speedup factor
        self.residA = np.zeros(n, dtype=np.int64)   # CCPG wake residue cyc
        self.freqA = np.zeros(n)                    # tile clock Hz
        self.powA = np.zeros(n)                     # busy power W
        self.bwA = np.zeros(n)                      # C2C bandwidth B/s
        self.wdtA = np.zeros(n)                     # dynamic wake dt/round
        self.wcycA = np.zeros(n, dtype=np.int64)    # dynamic wake cycles
        self.etaA = np.zeros(n)                     # TTFT at-risk horizon
        self.adlA = np.full(n, math.inf)            # arrival + deadline
        self.capA = np.zeros(n, dtype=np.int64)     # prefill chunk cap
        self.doneA = np.zeros(n, dtype=np.int64)    # prefilled so far
        self.pfc2cA = np.zeros(n, dtype=np.int64)   # prefill chunk bytes
        self.pendA = np.zeros(n, dtype=np.int64)    # rounds since sync
        self.exitA = np.zeros(n, dtype=np.int64)    # rounds to scalar event
        self.growA = np.zeros(n, dtype=np.int64)    # rounds to KV growth
        self.arrA = np.full(n, math.inf)            # next pending arrival
        for st in self._states:
            eng = st.eng
            self.residA[st.i] = eng._residue_cyc
            self.freqA[st.i] = eng._freq_hz
            self.powA[st.i] = eng._busy_power
            self.bwA[st.i] = eng._bandwidth_Bps
            if eng._dyn_wake:
                # per-cell constant: the scalar engine replays the same
                # ClusterWake walk before every round/chunk
                wdt, wcyc = eng.sim.wake_seconds(eng.alloc)
                self.wdtA[st.i] = wdt
                self.wcycA[st.i] = wcyc

    # ------------------------------------------------------------------
    def run(self) -> List[SweepResult]:
        if self._ran:
            raise RuntimeError("SweepEngine is single-shot")
        self._ran = True
        results: List[Optional[SweepResult]] = [None] * len(self.cells)

        for _, cell, _, reason in self._fallbacks:
            self.fallback_counts[reason] = \
                self.fallback_counts.get(reason, 0) + 1
        for reason, cnt in self.fallback_counts.items():
            log.info("sweep: %d cell(s) on the scalar fallback path (%s)",
                     cnt, reason)
        t0 = time.perf_counter()
        for pos, cell, group, reason in self._fallbacks:
            log.debug("sweep cell %r: scalar fallback (%s)", cell.key,
                      reason)
            if cell.fault is not None and cell.fault.active():
                # fault cell: run it as a degenerate 1-node combined
                # fleet so the crash/recovery machinery applies; the
                # node's own ServingReport is the cell result
                from repro.launch.fleet_engine import FleetEngine
                fcfg = FleetConfig(n_prefill=1, n_decode=0,
                                   handoff=False, engine=cell.engine,
                                   fault=cell.fault)
                feng = FleetEngine(cell.cfg, fcfg, sim=group.sim)
                frep = feng.run([copy.copy(r) for r in cell.trace])
                results[pos] = SweepResult(
                    cell.key, frep.node_reports[0],
                    feng.nodes[0].eng.kv_stats, fallback=reason)
                continue
            eng = ContinuousBatchingEngine(cell.cfg, sim=group.sim,
                                           engine=cell.engine,
                                           alloc=group.alloc)
            rep = eng.run([copy.copy(r) for r in cell.trace])
            results[pos] = SweepResult(cell.key, rep, eng.kv_stats,
                                       fallback=reason)
        self.fallback_wall_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        for st in self._states:
            st.pending = st.eng._prepare_run(st.requests)

        agg = self.agg
        while True:
            # phase A: scalar service — every non-cruising cell steps its
            # own engine until it finishes or the next step would be a
            # vectorizable decode round / prefill chunk streak; entries
            # are collected and snapshotted as batched column writes
            enter_dec: List[_CellState] = []
            enter_pf: List[_CellState] = []
            for st in self._states:
                if not st.done and not st.in_cruise:
                    self._scalar_service(st, enter_dec, enter_pf)
            if enter_dec:
                self._enter_cruise_many(enter_dec)
            if enter_pf:
                self._enter_pf_cruise_many(enter_pf)
            idx = np.nonzero(self._cruise)[0]
            if idx.size == 0:
                break           # phase A leaves every cell done or cruising

            self._check_surfaces()

            # phase B.1: cruise exits — a scheduled scalar event (finish /
            # admissible prefill), a pending arrival due this round, or
            # the queue head's TTFT deadline now at risk (must-prefill)
            now = agg.now[idx]
            lm = ((self.exitA[idx] < 1) | (self.arrA[idx] <= now)
                  | (now + self.etaA[idx] >= self.adlA[idx]))
            if lm.any():
                self._leave_cruise_many(
                    [self._states[int(i)] for i in idx[lm]])
                idx = idx[~lm]
                if idx.size == 0:
                    continue

            # phase B.2: KV growth rounds — run the engine's own round
            # prep semi-scalar; the cell stays in this vector round
            gm = self.growA[idx] < 1
            if gm.any():
                drop = [int(i) for i in idx[gm]
                        if not self._growth_prep(self._states[int(i)])]
                if drop:
                    idx = idx[~np.isin(idx, drop)]
                    if idx.size == 0:
                        continue

            # phase B.3: one BURST per cruising cell — each lane advances
            # up to its own safe horizon in one sequential fold.
            pf_lanes = self._pfA[idx]
            dec = idx[~pf_lanes]
            pf = idx[pf_lanes]
            if dec.size:
                self._decode_bursts(dec)
            if pf.size:
                self._prefill_bursts(pf)

        self._emit_reports()
        for st in self._states:
            results[st.pos] = SweepResult(st.cell.key, st.report, st.kv)
        self.vector_wall_s = time.perf_counter() - t0
        return results

    # ------------------------------------------------------------------
    # vector bursts
    def _decode_bursts(self, dec: np.ndarray) -> None:
        """Decode burst for lanes ``dec``.  Round j of the burst prices
        the scalar engine's exact arithmetic at the context it would see:
            cyc = int((base + n_attn*int(cpp*ctx_j) + ov_c2c) * alpha)
            dt  = (cyc + residue) / freq
        with ``ov_c2c = (1-overlap)*c2c_cyc`` (== c2c_cyc at overlap 0 —
        the int fold and the float add agree exactly below 2**53).  A
        cell that just ran growth prep may have exitA == 0 (the prep
        flipped want-prefill on), but its round was committed before the
        prep — clip forces the single committed round."""
        agg = self.agg
        h0 = np.minimum(self.exitA[dec], self.growA[dec])
        np.clip(h0, 1, _H_CAP, out=h0)
        J = np.arange(int(h0.max()), dtype=np.int64)[:, None]
        b = self.bA[dec]
        ctx = self.ctxA[dec] + J * b
        cyc = self.baseA[dec] + self.nattnA[dec] * (
            self.cppA[dec] * ctx).astype(np.int64)
        cyc = ((cyc + self.ovA[dec]) * self.alphaA[dec]).astype(np.int64)
        dt = (cyc + self.residA[dec]) / self.freqA[dec]
        burst = self.c2cA[dec]
        fetch = self.fA[dec]
        bw = self.bwA[dec]
        wdt = self.wdtA[dec]
        risk = bool(np.isfinite(self.adlA[dec]).any())
        h = agg.decode_burst(
            dec, h0, dt, self.powA[dec], b,
            burst, burst / bw, fetch, fetch / bw, self.arrA[dec],
            wake_dt=wdt if wdt.any() else None,
            wake_cyc=self.wcycA[dec],
            risk_eta=self.etaA[dec] if risk else None,
            risk_bound=self.adlA[dec] if risk else None)
        self.ctxA[dec] += b * h
        self.pendA[dec] += h
        self.exitA[dec] -= h
        self.growA[dec] -= h

    def _prefill_bursts(self, pf: np.ndarray) -> None:
        """Prefill-chunk burst for lanes ``pf``: chunk j covers tokens
        [done + j*cap, done + (j+1)*cap), priced by the group surface's
        closed-form prefill lane (bit-identical to the model walk)."""
        agg = self.agg
        h0 = np.clip(self.exitA[pf], 1, _H_CAP)
        H = int(h0.max())
        J = np.arange(H, dtype=np.int64)[:, None]
        cap = self.capA[pf]
        before = self.doneA[pf] + J * cap
        cyc = self._pf_cycles(pf, cap, before)
        dt = (cyc + self.residA[pf]) / self.freqA[pf]
        bb = self.pfc2cA[pf]
        wdt = self.wdtA[pf]
        h = agg.prefill_burst(
            pf, h0, dt, self.powA[pf], bb, bb / self.bwA[pf],
            self.arrA[pf],
            wake_dt=wdt if wdt.any() else None,
            wake_cyc=self.wcycA[pf])
        self.doneA[pf] += cap * h
        self.pendA[pf] += h
        self.exitA[pf] -= h

    def _pf_cycles(self, pf: np.ndarray, cap: np.ndarray,
                   before: np.ndarray) -> np.ndarray:
        """Closed-form prefill chunk cycles, per cost-surface group."""
        cyc = np.empty(before.shape, dtype=np.int64)
        buckets: Dict[int, List[int]] = {}
        groups: Dict[int, _Group] = {}
        for k, lane in enumerate(pf.tolist()):
            g = self._states[lane].group
            buckets.setdefault(id(g), []).append(k)
            groups[id(g)] = g
        for gid, ks in buckets.items():
            k = np.asarray(ks)
            c, _ = groups[gid].surface._prefill_closed_form(
                cap[k], before[:, k])
            cyc[:, k] = c
        return cyc

    # ------------------------------------------------------------------
    # scalar service and cruise transitions
    def _scalar_service(self, st: _CellState,
                        enter_dec: List[_CellState],
                        enter_pf: List[_CellState]) -> None:
        eng, pending = st.eng, st.pending
        max_iters = eng.engine.max_iters
        while True:
            if not (pending or eng.queue or eng._active_idx
                    or eng._partial is not None):
                self._finalize(st)
                return
            if self._enterable(st):
                enter_dec.append(st)
                return
            if self._pf_enterable(st):
                enter_pf.append(st)
                return
            st.iters += 1
            if st.iters > max_iters:
                raise RuntimeError("sweep cell exceeded max_iters")
            eng.step(pending)

    def _enterable(self, st: _CellState) -> bool:
        """Would the engine's next step be a decode round the vector path
        can price (affine batch size) and complete (no finish, no
        must-prefill)?  Stashes the budgets and the TTFT at-risk horizon
        for the batched cruise entry."""
        eng = st.eng
        if not eng._active_idx:
            return False
        if st.pending and st.pending[0].arrival <= eng.timeline.now:
            return False
        if not st.group.surface.affine[len(eng._active_idx)]:
            return False
        fin, pre, want = self._budgets(eng)
        if fin < 1 or pre < 1:
            return False
        eta, adl = self._risk_horizon(eng, want)
        if eng.timeline.now + eta >= adl:
            return False        # next step is a must-prefill
        st._fin, st._pre = fin, pre
        st._eta, st._adl = eta, adl
        return True

    def _pf_enterable(self, st: _CellState) -> bool:
        """Would the engine's next steps be a streak of full-cap,
        non-finishing prefill chunks the vector path can price?  A lone
        partial (no residents) with paging off streams chunks with no
        other engine effect; the finishing chunk always runs scalar."""
        eng = st.eng
        if eng._partial is None or eng._active_idx or eng.kv is not None:
            return False
        if st.pending and st.pending[0].arrival <= eng.timeline.now:
            return False
        if not st.group.surface.prefill_closed:
            return False
        done, target = eng._partial[1], eng._partial[2]
        k = (target - done - 1) // eng.engine.chunked_prefill_tokens
        if k < 2:
            return False
        st._pfK = k
        return True

    def _enter_cruise_many(self, sts: List[_CellState]) -> None:
        bs = []
        bases = []
        natts = []
        c2cs = []
        ovs = []
        cpps = []
        alphas = []
        ctxs = []
        fss = []
        exits = []
        grows = []
        arrs = []
        etas = []
        adls = []
        for st in sts:
            eng = st.eng
            surf = st.group.surface
            b = len(eng._active_idx)
            bs.append(b)
            bases.append(surf.base_compute[b])
            natts.append(surf.n_attn[b])
            c2cs.append(surf.c2c_bytes[b])
            ovs.append((1.0 - eng.engine.overlap) * int(surf.c2c_cyc[b]))
            cpps.append(surf.cpp)
            alphas.append(surf.alpha)
            ctxs.append(eng._ctx_sum)
            fss.append(self._fetch_bytes(eng))
            exits.append(min(st._fin, st._pre))
            grows.append(self._grow_budget(eng))
            arrs.append(st.pending[0].arrival if st.pending else math.inf)
            etas.append(st._eta)
            adls.append(st._adl)
            st.in_cruise = True
        ii = np.fromiter((st.i for st in sts), np.int64, len(sts))
        self.bA[ii] = bs
        self.baseA[ii] = bases
        self.nattnA[ii] = natts
        self.c2cA[ii] = c2cs
        self.ovA[ii] = ovs
        self.cppA[ii] = cpps
        self.alphaA[ii] = alphas
        self.ctxA[ii] = ctxs
        self.fA[ii] = fss
        self.exitA[ii] = exits
        self.growA[ii] = grows
        self.arrA[ii] = arrs
        self.etaA[ii] = etas
        self.adlA[ii] = adls
        self.pendA[ii] = 0
        self._pfA[ii] = False
        self._cruise[ii] = True
        self.agg.sync_in_many(ii, [st.eng.timeline for st in sts])

    def _enter_pf_cruise_many(self, sts: List[_CellState]) -> None:
        caps = []
        dones = []
        pfc = []
        exits = []
        arrs = []
        for st in sts:
            eng = st.eng
            cap = eng.engine.chunked_prefill_tokens
            caps.append(cap)
            dones.append(eng._partial[1])
            pfc.append(cap * st.group.surface._pf_c2cb)
            exits.append(st._pfK)
            arrs.append(st.pending[0].arrival if st.pending else math.inf)
            st.in_cruise = True
        ii = np.fromiter((st.i for st in sts), np.int64, len(sts))
        self.capA[ii] = caps
        self.doneA[ii] = dones
        self.pfc2cA[ii] = pfc
        self.exitA[ii] = exits
        self.growA[ii] = _BIG
        self.arrA[ii] = arrs
        self.etaA[ii] = 0.0
        self.adlA[ii] = math.inf
        self.pendA[ii] = 0
        self._pfA[ii] = True
        self._cruise[ii] = True
        self.agg.sync_in_many(ii, [st.eng.timeline for st in sts])

    def _leave_cruise_many(self, sts: List[_CellState]) -> None:
        for st in sts:
            self._sync_objects(st)
            st.in_cruise = False
        ii = np.fromiter((st.i for st in sts), np.int64, len(sts))
        self.agg.sync_out_many(ii, [st.eng.timeline for st in sts])
        self._cruise[ii] = False
        self._pfA[ii] = False

    def _sync_objects(self, st: _CellState) -> None:
        """Replay the pending vector rounds onto the engine's object
        state — exactly what the scalar rounds/chunks would have
        written.  Decode: every resident gained one token per round, the
        round/credit counters advanced, and the (frozen) per-round DRAM
        fetch accrued.  Prefill: the partial absorbed ``p`` full chunks
        and each chunk reset the decode deficit."""
        p = int(self.pendA[st.i])
        if not p:
            return
        eng = st.eng
        if self._pfA[st.i]:
            eng._partial[1] = int(self.doneA[st.i])
            eng._tokens_prefilled += p * int(self.capA[st.i])
            eng.decode_credit = 0
            st.iters += p
            self.pendA[st.i] = 0
            return
        for r in eng._active_reqs:
            r.generated += p
            r.context += p
        eng._ctx_sum = int(self.ctxA[st.i])
        eng._round_no += p
        eng.decode_credit += p
        f = int(self.fA[st.i])
        if f:
            eng._kv_fetch_bytes += p * f
        st.iters += p
        self.pendA[st.i] = 0

    def _growth_prep(self, st: _CellState) -> bool:
        """A resident crosses a KV block boundary this round: sync the
        cell and run the engine's own ``_kv_prepare_round`` (growth,
        watermark preemption, spill/COW timeline charges) exactly as the
        scalar ``_decode_round`` would before pricing the round.  The
        cell keeps its place in the current vector round; returns False
        when the post-prep state cannot cruise on (no affine cost lane
        for the new batch size, or the post-prep queue head — preemption
        can change it — is now TTFT at-risk), in which case the
        committed round ran scalar instead."""
        i, eng = st.i, st.eng
        self._sync_objects(st)
        self.agg.sync_out(i, eng.timeline)
        eng._kv_prepare_round()
        q = len(eng.queue)      # preemption appendlefts victims: track
        if q > st.qmax:         # the depth the scalar engine would have
            st.qmax = q         # sampled on its next step
        self.agg.sync_in(i, eng.timeline)
        b = len(eng._active_idx)
        if st.group.surface.affine[b]:
            fin, pre, want = self._budgets(eng)
            eta, adl = self._risk_horizon(eng, want)
            if eng.timeline.now + eta < adl:
                self._snap_cost(st, b)
                self.ctxA[i] = eng._ctx_sum
                self.fA[i] = self._fetch_bytes(eng)
                self.exitA[i] = min(fin, pre)
                self.growA[i] = self._grow_budget(eng)
                self.etaA[i] = eta
                self.adlA[i] = adl
                return True
        eng._decode_round()     # re-entry prep is a no-op (needed==0)
        self.agg.sync_in(i, eng.timeline)
        st.in_cruise = False
        self._cruise[i] = False
        st.iters += 1
        return False

    def _finalize(self, st: _CellState) -> None:
        eng = st.eng
        fields, lat, ttft = eng._report_inputs(st.requests)
        # queue-depth maxima reached during cruise (growth preemptions)
        # were tracked out-of-band; everything else in the report comes
        # from the synced timeline aggregates.  The percentile columns
        # are deferred: _emit_reports batches them across cells.
        if st.qmax > fields["max_queue_depth"]:
            fields["max_queue_depth"] = st.qmax
        st._fields = fields
        st._lat = lat
        st._ttft = ttft
        st.kv = eng.kv_stats
        st.done = True

    def _emit_reports(self) -> None:
        """Build every cell's `ServingReport`, batching the four
        ``np.percentile`` calls across cells with equal finished counts
        (row k of a batched axis-1 percentile is bit-identical to the
        per-cell call on that row)."""
        by_len: Dict[int, List[_CellState]] = {}
        for st in self._states:
            by_len.setdefault(st._lat.size, []).append(st)
        for sts in by_len.values():
            lat = np.stack([st._lat for st in sts])
            ttft = np.stack([st._ttft for st in sts])
            p50l = np.percentile(lat, 50, axis=1)
            p99l = np.percentile(lat, 99, axis=1)
            p50t = np.percentile(ttft, 50, axis=1)
            p99t = np.percentile(ttft, 99, axis=1)
            for k, st in enumerate(sts):
                st.report = ServingReport(
                    p50_latency_s=float(p50l[k]),
                    p99_latency_s=float(p99l[k]),
                    p50_ttft_s=float(p50t[k]),
                    p99_ttft_s=float(p99t[k]),
                    **st._fields)

    # ------------------------------------------------------------------
    # snapshots and countdowns
    def _snap_cost(self, st: _CellState, b: int) -> None:
        surf = st.group.surface
        i = st.i
        self.bA[i] = b
        self.baseA[i] = surf.base_compute[b]
        self.nattnA[i] = surf.n_attn[b]
        self.c2cA[i] = surf.c2c_bytes[b]
        self.ovA[i] = (1.0 - st.eng.engine.overlap) * int(surf.c2c_cyc[b])
        self.cppA[i] = surf.cpp
        self.alphaA[i] = surf.alpha

    @staticmethod
    def _budgets(eng: ContinuousBatchingEngine) -> Tuple[int, int, bool]:
        """(finish, prefill, want) budgets: how many decode rounds
        INCLUDING the next one can run before that scalar event fires,
        plus whether the engine currently wants a prefill at all (the
        TTFT at-risk check is only armed when it does)."""
        if eng.kv is None:
            heap = eng._finish_heap
            fin = (heap[0][0] - eng._round_no - 1) if heap else _BIG
        else:
            fin = min(r.max_new - r.generated
                      for r in eng._active_reqs) - 1
        if eng._partial is not None:
            want = True
        elif not eng.queue or eng._free_slot() is None:
            want = False
        else:
            want = eng.kv is None or eng._kv_can_admit()
        pre = (eng.engine.decode_quantum - eng.decode_credit
               if want else _BIG)
        return fin, pre, want

    @staticmethod
    def _risk_horizon(eng: ContinuousBatchingEngine,
                      want: bool) -> Tuple[float, float]:
        """(eta, bound) for the frozen TTFT at-risk check: the scalar
        engine flips to a must-prefill once ``clock + eta >= bound``.
        ``(0.0, inf)`` when the check cannot fire in the frozen cruise
        state (no admissible head, in-flight partial, or no deadline) —
        bit-neutral in the burst fold."""
        if not want or eng._partial is not None or not eng._any_deadline:
            return 0.0, math.inf
        head = eng.queue[0]
        if head.deadline_ttft is None:
            return 0.0, math.inf
        return (eng._prefill_eta_s(),
                head.arrival + head.deadline_ttft)

    @staticmethod
    def _grow_budget(eng: ContinuousBatchingEngine) -> int:
        """Rounds until some resident's next token no longer fits its
        block table (capacity is exact: growth fires when context
        reaches ``len(blocks) * block_tokens``)."""
        kv = eng.kv
        if kv is None:
            return _BIG
        bt = kv.cfg.block_tokens
        tables = kv.tables
        return min(len(tables[r.request_id].blocks) * bt - r.context
                   for r in eng._active_reqs)

    @staticmethod
    def _fetch_bytes(eng: ContinuousBatchingEngine) -> int:
        """Per-round DRAM-resident KV fetch — frozen between growth/
        scalar events (block tables only change there)."""
        kv = eng.kv
        if kv is None:
            return 0
        return kv.dram_tokens_total(
            eng.slots[j].request_id for j in eng._active_idx) \
            * kv.cfg.bytes_per_token

    def _check_surfaces(self) -> None:
        """Mid-run calibration guard, mirroring the scalar engine's
        per-round ``aff[5] != cm._cal_ver`` check: a mutated model
        rebuilds the group surface (decode AND prefill lanes) and
        re-snapshots every cruising cell's cost lanes — including the
        frozen TTFT horizon, which prices a prefill — before the next
        vector round."""
        refreshed = False
        for group in self._groups.values():
            if group.surface is not None and group.surface.refresh():
                refreshed = True
        if not refreshed:
            return
        for st in self._states:
            if not st.in_cruise:
                continue
            if self._pfA[st.i]:
                if not st.group.surface.prefill_closed:
                    self._leave_cruise_many([st])   # back to scalar chunks
                continue
            self._snap_cost(st, int(self.bA[st.i]))
            if np.isfinite(self.adlA[st.i]):
                self.etaA[st.i] = st.eng._prefill_eta_s()


def sweep_serve(cells: Sequence[SweepCell]) -> List[SweepResult]:
    """Run a grid of serving cells through one vectorized lockstep pass;
    results in cell order, each byte-identical to a per-cell scalar
    `ContinuousBatchingEngine` run."""
    return SweepEngine(cells).run()
