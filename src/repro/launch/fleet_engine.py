"""Disaggregated prefill/decode fleet over the photonic fabric.

The cluster layer above `launch/serving_engine` (ROADMAP item 1): N
PICNIC node instances — each one a full `ContinuousBatchingEngine` with
its own TimelineIR — split into dedicated PREFILL and DECODE pools, with
a global router in front and priced KV handoff between them:

  arrival trace
    -> ROUTER: SLO-aware admission (optional; rejects a request whose
       TTFT deadline is already unreachable on the least-loaded node),
       least-loaded prefill dispatch, bounded hold-don't-drop backlog
       when every prefill queue is full
    -> PREFILL node: runs prompt prefill + first token (a max_new<=1
       copy of the request), then exports the resident KV block set
       (`BlockAllocator.export_table`) through the engine's `on_finish`
       hook
    -> KV HANDOFF over the inter-node fabric: wire bytes from
       `core.interconnect.fleet_handoff_bytes` (analytic Table-II KV
       footprint by default, HLO-`MeasuredTraffic` resharding cost
       opt-in), latency = bytes / fabric bandwidth folded into the
       decode-side arrival, energy priced as a C2CTransfer
       (phase "kv_handoff") on the decode node's timeline
    -> DECODE node: `import_table` re-admits the context into a fresh
       local block table, the request decodes to completion in that
       node's continuous batch.  A full decode node re-queues the
       handoff (never drops); an empty-but-infeasible one re-routes it.
    -> CCPG autoscaling (optional): nodes beyond `min_awake` per pool
       start asleep; the router wakes one — paying the REAL ClusterWake
       cluster-walk latency on that node's timeline — when awake nodes
       saturate, and drained nodes go back to sleep.

``handoff=False`` degrades every node to a COMBINED (prefill+decode)
replica — plain data-parallel serving, the disaggregation baseline.  A
1-node combined fleet reproduces the bare engine's step sequence
EXACTLY (hex-identical timeline floats, events and report — locked by
tests/test_fleet.py): the fleet adds no timeline activity of its own on
that path.

Scheduling is conservative parallel discrete-event simulation: every
entity (router, node) exposes a *horizon* — the earliest simulated time
its next action can happen (a busy node: its clock; an idle node: its
next input's arrival; the router: the next undispatched arrival) — and
the fleet always steps the runnable entity with the minimum horizon,
router first on ties.  The minimum-horizon entity can never receive an
earlier input from the others, so the interleave is causally safe and
deterministic.

Fault injection (ISSUE 10): an optional, fully deterministic
``FleetConfig.fault`` schedule becomes a third DES entity.  Link
degradation windows price FEC/retransmit overhead on every handoff sent
inside them (``C2CTransfer(phase="retransmit")``); CCPG wake failures
cost a bounded `RestartPolicy` retry walk before the router falls back
to the awake pool; node crashes freeze a node's engine mid-flight (its
KV is lost) and the router — running `HeartbeatMonitor` on the DES
clock — only learns of the death after ``heartbeat_dead_s``, at which
point it drains the dead node's mailboxes: raw arrivals re-dispatch,
queued handoffs re-route, and partially-decoded residents
recompute-from-prompt on the prefill pool (prefix sharing adopts any
still-indexed prompt blocks, cutting the recompute bill).  Degraded
mode sheds deadline-infeasible work first — counted (``fault_shed``),
never silent.  With ``fault=None`` every code path above is skipped and
the fleet stays byte-identical to the zero-fault engine.

Pure Python + numpy like the engine underneath — no JAX import.

  PYTHONPATH=src python -c "from repro.launch import fleet; ..."
"""
from __future__ import annotations

import copy
import dataclasses
import json
import math
from bisect import insort
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interconnect import (c2c_transfer_time,
                                     fleet_handoff_bytes,
                                     retransmit_overhead_bytes)
from repro.core.scheduling import ChipletAllocation, allocate_chiplets
from repro.core.simulator import PicnicSimulator
from repro.core.timeline import merge_chrome_traces
from repro.launch.config import FleetConfig, ServingConfig
from repro.launch.scheduler import EventKind
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingReport, TrackedRequest)
from repro.runtime.fault_tolerance import (HeartbeatMonitor, RestartPolicy,
                                           WorkerState)
from repro.runtime.kv_cache import kv_bytes_per_token

PREFILL = "prefill"
DECODE = "decode"
COMBINED = "combined"


class _Node:
    """One PICNIC node of the fleet: an engine plus its fleet-side
    mailboxes (dispatched arrivals, queued handoffs) and pool state."""

    __slots__ = ("node_id", "pool", "eng", "pending", "handoffs",
                 "assigned", "asleep", "wakes", "requeued",
                 "outstanding_s", "_last_deferred_seq",
                 "crashed", "down", "fail_t", "wake_fails_left",
                 "wake_policy")

    def __init__(self, node_id: int, pool: str, cfg, sim, engine_cfg,
                 alloc):
        self.node_id = node_id
        self.pool = pool
        self.eng = ContinuousBatchingEngine(cfg, sim=sim,
                                            engine=engine_cfg,
                                            alloc=alloc)
        # arrivals the router has dispatched here (arrival-ordered; the
        # engine admits them itself, preserving its queue_limit/reject
        # semantics)
        self.pending: Deque[TrackedRequest] = deque()
        # (arrival_s, seq, request, nbytes, transfer_s, phase,
        # retransmit_bytes, retransmit_s) — handed-off requests in
        # fabric-arrival order (insort: wakes and re-routes can land
        # out of order)
        self.handoffs: List[Tuple] = []
        self.assigned: List[TrackedRequest] = []
        self.asleep = False
        self.wakes = 0
        self.requeued = 0
        self.outstanding_s = 0.0     # router's prefill-work estimate
        self._last_deferred_seq = -1
        # fault state: crashed = the node is frozen (ground truth);
        # down = the router has DETECTED the crash and excludes it
        self.crashed = False
        self.down = False
        self.fail_t = math.nan
        self.wake_fails_left = 0     # CCPG wake attempts that time out
        self.wake_policy: Optional[RestartPolicy] = None

    def reset(self) -> None:
        self.eng.reset()
        self.pending.clear()
        self.handoffs.clear()
        self.assigned = []
        self.asleep = False
        self.wakes = 0
        self.requeued = 0
        self.outstanding_s = 0.0
        self._last_deferred_seq = -1
        self.crashed = False
        self.down = False
        self.fail_t = math.nan
        self.wake_fails_left = 0
        self.wake_policy = None


@dataclasses.dataclass
class FleetReport:
    """Cluster-level aggregate over one trace, plus every node's own
    :class:`ServingReport` (carrying ``node_id``/``pool`` attribution
    whenever the fleet has more than one node)."""
    n_nodes: int
    n_prefill: int
    n_decode: int
    handoff: bool
    n_requests: int
    finished: int
    rejected: int
    wall_s: float
    tokens_generated: int
    tokens_per_s: float
    energy_J: float
    tokens_per_J: float
    p50_latency_s: float
    p99_latency_s: float
    p50_ttft_s: float
    p99_ttft_s: float
    handoffs: int
    handoff_bytes: int
    requeued_handoffs: int
    rerouted_handoffs: int
    wakes: int
    slo_rejected: int
    node_reports: List[ServingReport]
    # fault/degraded-mode metrics — populated only when the run had an
    # active FaultConfig (``availability is not None`` gates row()/
    # summary() emission, keeping zero-fault artifacts byte-identical)
    router_rejected: Optional[int] = None
    fault_shed: Optional[int] = None
    node_failures: Optional[int] = None
    node_recoveries: Optional[int] = None
    downtime_s: Optional[float] = None
    mttr_s: Optional[float] = None
    availability: Optional[float] = None
    goodput_tokens_per_s: Optional[float] = None
    recomputes: Optional[int] = None
    recompute_tokens: Optional[int] = None
    retransmit_bytes: Optional[int] = None
    wake_retries: Optional[int] = None
    wake_fallbacks: Optional[int] = None

    def row(self) -> Dict:
        def _r(x: float, nd: int):
            return None if math.isnan(x) else round(x, nd)
        row = {
            "nodes": self.n_nodes,
            "prefill_nodes": self.n_prefill,
            "decode_nodes": self.n_decode,
            "handoff": self.handoff,
            "requests": self.n_requests,
            "finished": self.finished,
            "rejected": self.rejected,
            "tokens_per_s": _r(self.tokens_per_s, 1),
            "tokens_per_J": _r(self.tokens_per_J, 1),
            "p50_latency_s": _r(self.p50_latency_s, 4),
            "p99_latency_s": _r(self.p99_latency_s, 4),
            "p50_ttft_s": _r(self.p50_ttft_s, 4),
            "p99_ttft_s": _r(self.p99_ttft_s, 4),
            "handoffs": self.handoffs,
            "handoff_MB": round(self.handoff_bytes / 1e6, 3),
            "requeued_handoffs": self.requeued_handoffs,
            "wakes": self.wakes,
            "slo_rejected": self.slo_rejected,
            "wall_s": _r(self.wall_s, 4),
        }
        if self.availability is not None:
            # reject attribution by cause + chaos headline metrics
            row.update({
                "router_rejected": self.router_rejected,
                "fault_shed": self.fault_shed,
                "node_failures": self.node_failures,
                "node_recoveries": self.node_recoveries,
                "availability": _r(self.availability, 6),
                "goodput_tokens_per_s": _r(self.goodput_tokens_per_s, 1),
                "mttr_s": _r(self.mttr_s, 4),
                "downtime_s": _r(self.downtime_s, 4),
                "recomputes": self.recomputes,
                "recompute_tokens": self.recompute_tokens,
                "retransmit_MB": round(self.retransmit_bytes / 1e6, 3),
                "wake_retries": self.wake_retries,
                "wake_fallbacks": self.wake_fallbacks,
            })
        return row

    def summary(self) -> str:
        shape = (f"{self.n_prefill}P+{self.n_decode}D"
                 if self.handoff else f"{self.n_nodes}x combined")
        lines = [
            f"FleetReport ({shape})",
            f"  requests          {self.finished}/{self.n_requests} "
            f"finished, {self.rejected} rejected "
            f"({self.slo_rejected} at the SLO gate)",
            f"  wall clock        {self.wall_s:.3f} s",
            f"  throughput        {self.tokens_per_s:.1f} tok/s",
            f"  efficiency        {self.tokens_per_J:.1f} tok/J "
            f"({self.energy_J:.3f} J total)",
            f"  latency p50/p99   {self.p50_latency_s * 1e3:.1f} / "
            f"{self.p99_latency_s * 1e3:.1f} ms",
            f"  TTFT    p50/p99   {self.p50_ttft_s * 1e3:.1f} / "
            f"{self.p99_ttft_s * 1e3:.1f} ms",
            f"  handoffs          {self.handoffs} "
            f"({self.handoff_bytes / 1e6:.2f} MB over the fabric, "
            f"{self.requeued_handoffs} re-queued, "
            f"{self.rerouted_handoffs} re-routed)",
            f"  node wakes        {self.wakes}",
        ]
        if self.availability is not None:
            mttr = (f"{self.mttr_s:.4f} s"
                    if self.mttr_s == self.mttr_s else "n/a")
            lines += [
                f"  fault model       {self.node_failures} failures / "
                f"{self.node_recoveries} recoveries, "
                f"availability {self.availability:.4f}, MTTR {mttr}",
                f"  degraded mode     {self.fault_shed} shed, "
                f"{self.router_rejected} router-rejected, "
                f"{self.recomputes} recomputes "
                f"({self.recompute_tokens} tokens), "
                f"{self.retransmit_bytes / 1e6:.2f} MB retransmitted, "
                f"{self.wake_retries} wake retries / "
                f"{self.wake_fallbacks} fallbacks",
                f"  goodput           "
                f"{self.goodput_tokens_per_s:.1f} tok/s",
            ]
        return "\n".join(lines)


class FleetEngine:
    """A fleet of :class:`ContinuousBatchingEngine` nodes behind one
    router — see the module docstring for the full data path."""

    def __init__(self, cfg, fleet: Optional[FleetConfig] = None, *,
                 sim: Optional[PicnicSimulator] = None):
        self.cfg = cfg
        self.fleet = fleet if fleet is not None else FleetConfig()
        self.sim = sim if sim is not None else PicnicSimulator()
        f = self.fleet
        if f.n_nodes < 1:
            raise ValueError("fleet needs at least one node")
        ecfg = f.engine
        # one chiplet allocation shared by every node (deterministic;
        # sharing also maximizes cycle-model memo hits across nodes)
        self._alloc: ChipletAllocation = allocate_chiplets(
            cfg, self.sim.tile)
        disagg = f.handoff and f.n_prefill > 0 and f.n_decode > 0
        pools = ([PREFILL] * f.n_prefill + [DECODE] * f.n_decode
                 if disagg else [COMBINED] * f.n_nodes)
        self.nodes = [_Node(i, pool, cfg, self.sim, ecfg, self._alloc)
                      for i, pool in enumerate(pools)]
        self._disagg = disagg
        self._residue_ccpg = ecfg.ccpg and not ecfg.dynamic_ccpg
        # handoff wire pricing: explicit knob > paged cache's own
        # footprint > analytic model-derived KV bytes/token
        if f.handoff_bytes_per_token is not None:
            self._bpt = int(f.handoff_bytes_per_token)
        elif ecfg.kv_cache is not None:
            self._bpt = int(ecfg.kv_cache.bytes_per_token)
        else:
            self._bpt = kv_bytes_per_token(cfg)
        for n in self.nodes:
            if n.pool == PREFILL:
                n.eng.on_finish = (
                    lambda req, node=n: self._on_prefill_done(node, req))
        # run-scoped state (rebuilt by run())
        self._records: Dict[int, Dict] = {}
        self._arrivals: Deque[TrackedRequest] = deque()
        self._backlog: Deque[TrackedRequest] = deque()
        self._handoff_seq = 0
        self.handoffs = 0
        self.handoff_bytes = 0
        self.requeued = 0
        self.rerouted = 0
        self.wakes = 0
        self.slo_rejected = 0
        self._fleet_rejected = 0
        # fault-injection state (run()-rebuilt; inert when fault=None)
        fc = f.fault
        self._fault_on = fc is not None and fc.active()
        if fc is not None:
            for nf in fc.nodes:
                if not 0 <= nf.node < len(self.nodes):
                    raise ValueError(
                        f"NodeFault.node {nf.node} outside fleet "
                        f"of {len(self.nodes)} nodes")
            for wf in fc.wakes:
                if not 0 <= wf.node < len(self.nodes):
                    raise ValueError(
                        f"WakeFault.node {wf.node} outside fleet "
                        f"of {len(self.nodes)} nodes")
        self._sched: List[Tuple[float, int, int]] = []
        self._sched_i = 0
        self._pending_detect: List[Tuple[float, int]] = []
        self._monitor: Optional[HeartbeatMonitor] = None
        self._des_now = 0.0
        self._mttr: List[float] = []
        self.router_rejected = 0
        self.fault_shed = 0
        self.node_failures = 0
        self.node_recoveries = 0
        self.recomputes = 0
        self.recompute_tokens = 0
        self.retransmit_bytes = 0
        self.wake_retries = 0
        self.wake_fallbacks = 0
        self.downtime_total = 0.0

    # -- horizons ------------------------------------------------------
    def _node_horizon(self, n: _Node) -> float:
        """Earliest simulated time node ``n``'s next step can happen:
        its clock while it holds work, else its next input's arrival
        (clamped to the clock), else +inf (not runnable).  Sleeping
        nodes only re-enter through a router wake.  A crashed node is
        frozen — it re-enters only through the recovery event."""
        if n.asleep or n.crashed:
            return math.inf
        e = n.eng
        if e.queue or e._active_idx or e._partial is not None:
            return e.clock
        t = math.inf
        if n.pending:
            t = n.pending[0].arrival
        if n.handoffs:
            h = n.handoffs[0][0]
            if h < t:
                t = h
        if t is math.inf:
            return math.inf
        return t if t > e.clock else e.clock

    # -- run -----------------------------------------------------------
    def run(self, trace: Sequence[TrackedRequest]) -> FleetReport:
        f = self.fleet
        for n in self.nodes:
            n.reset()
        # replicate ContinuousBatchingEngine._prepare_run for the whole
        # fleet: reset the trace's mutable per-run state, verify arrival
        # monotonicity (stable re-sort only when violated), share the
        # any-deadline flag with every node
        arr = list(trace)
        for r in arr:
            r.generated = 0
            r.context = 0
            r.first_token_at = None
            r.finished_at = None
            r.admit_seq = -1
        prev = -math.inf
        for r in arr:
            if r.arrival < prev:
                arr.sort()
                break
            prev = r.arrival
        any_deadline = any(r.deadline_ttft is not None for r in arr)
        for n in self.nodes:
            n.eng._any_deadline = any_deadline
        self._records = {}
        self._arrivals = deque(arr)
        self._backlog = deque()
        self._handoff_seq = 0
        self.handoffs = 0
        self.handoff_bytes = 0
        self.requeued = 0
        self.rerouted = 0
        self.wakes = 0
        self.slo_rejected = 0
        self._fleet_rejected = 0
        # rebuild the deterministic fault schedule for this run
        fc = f.fault
        self._fault_on = fc is not None and fc.active()
        self._sched = []
        self._sched_i = 0
        self._pending_detect = []
        self._monitor = None
        self._des_now = 0.0
        self._mttr = []
        self.router_rejected = 0
        self.fault_shed = 0
        self.node_failures = 0
        self.node_recoveries = 0
        self.recomputes = 0
        self.recompute_tokens = 0
        self.retransmit_bytes = 0
        self.wake_retries = 0
        self.wake_fallbacks = 0
        self.downtime_total = 0.0
        if self._fault_on:
            for wf in fc.wakes:
                n = self.nodes[wf.node]
                n.wake_fails_left = int(wf.failures)
                n.wake_policy = RestartPolicy(
                    base_backoff_s=fc.wake_backoff_base_s,
                    max_backoff_s=fc.wake_backoff_max_s)
            ev: List[Tuple[float, int, int]] = []
            for nf in fc.nodes:
                ev.append((nf.t_fail, 0, nf.node))
                if math.isfinite(nf.t_recover):
                    ev.append((nf.t_recover, 1, nf.node))
            ev.sort()
            self._sched = ev
            self._monitor = HeartbeatMonitor(
                len(self.nodes), suspect_s=fc.heartbeat_suspect_s,
                dead_s=fc.heartbeat_dead_s,
                clock=lambda: self._des_now)
        if f.autoscale:
            for pool in (PREFILL, DECODE, COMBINED):
                awake = 0
                for n in self.nodes:
                    if n.pool != pool:
                        continue
                    awake += 1
                    n.asleep = awake > max(f.min_awake, 0)
        # conservative-DES main loop: min-horizon entity steps next,
        # router (dispatch) before nodes on ties so a node never admits
        # at a timestamp the router still owes arrivals for
        it = 0
        while True:
            rh = (self._arrivals[0].arrival if self._arrivals
                  else math.inf)
            best: Optional[_Node] = None
            bh = math.inf
            for n in self.nodes:
                h = self._node_horizon(n)
                if h < bh:
                    bh = h
                    best = n
            # the fault schedule is a third DES entity; it steps first
            # on ties so crashes/detections/recoveries are visible to
            # the router step at the same timestamp (zero-fault: fh is
            # always +inf and this branch never runs)
            fh = self._fault_horizon()
            if fh < math.inf and fh <= rh and fh <= bh:
                self._fault_step()
                continue
            if rh <= bh:
                if best is None and rh is math.inf:
                    break
                if rh is not math.inf:
                    self._router_step()
                    continue
            it += 1
            if it > f.max_iters:
                raise RuntimeError("fleet exceeded max_iters")
            self._step_node(best)
        if self._backlog:
            if self._fault_on:
                # degraded mode: every live route is gone (e.g. the
                # whole prefill pool died without recovery).  Shed the
                # stranded work, counted — never silently dropped.
                while self._backlog:
                    req = self._backlog.popleft()
                    self._shed(self._records[req.request_id])
            else:               # unreachable: flush runs per node step
                raise RuntimeError("fleet backlog not drained")
        return self._report()

    # -- router --------------------------------------------------------
    def _router_step(self) -> None:
        """Dispatch every arrival at the next arrival timestamp (equal
        arrivals batch together, FIFO — matching one engine
        ``_admit_arrivals`` pass)."""
        t = self._arrivals[0].arrival
        while self._arrivals and self._arrivals[0].arrival <= t:
            req = self._arrivals.popleft()
            rec = {"req": req, "final": None, "rejected": False,
                   "eta": 0.0}
            self._records[req.request_id] = rec
            if self._disagg:
                rec["eta"] = self.sim.prefill_seconds(
                    self.cfg, self._alloc, req.prompt_len,
                    ccpg=self._residue_ccpg)[0]
                self._dispatch_prefill(req, t)
            else:
                self._dispatch_combined(req, t)

    @staticmethod
    def _pf_load(n: _Node) -> int:
        e = n.eng
        return (len(n.pending) + len(e.queue)
                + (1 if e._partial is not None else 0))

    @staticmethod
    def _dc_load(n: _Node) -> int:
        return len(n.eng._active_idx) + len(n.handoffs)

    def _dispatch_prefill(self, req: TrackedRequest, now: float) -> None:
        f = self.fleet
        rec = self._records[req.request_id]
        targets = [n for n in self.nodes
                   if n.pool == PREFILL and not n.down]
        awake = [n for n in targets if not n.asleep]
        if (f.slo_admission and req.deadline_ttft is not None
                and req.first_token_at is None):
            # the BEST case (least-loaded awake node, its whole queue
            # estimate ahead of us) already misses the deadline: reject
            # at the router instead of burning prefill on a dead request.
            # Recompute re-dispatches (first token already out) are
            # exempt — their SLO is already met or missed.
            wait = min((n.outstanding_s for n in awake), default=0.0)
            if now + wait + rec["eta"] >= req.arrival + req.deadline_ttft:
                rec["rejected"] = True
                rec["cause"] = "slo"
                self.slo_rejected += 1
                self._fleet_rejected += 1
                return
        limit = f.engine.queue_limit
        open_nodes = [n for n in awake if self._pf_load(n) < limit]
        if f.autoscale:
            asleep = [n for n in targets if n.asleep]
            if asleep and (
                    not open_nodes
                    or min(self._pf_load(n) for n in open_nodes)
                    >= f.scale_up_queue):
                n0 = asleep[0]
                if self._wake(n0, now):
                    open_nodes.append(n0)
        if not open_nodes:
            # every awake prefill queue is full: HOLD the request in the
            # router backlog (re-tried after every node step) instead of
            # dropping it; reject only past the router's own bound
            if len(self._backlog) >= f.queue_limit:
                rec["rejected"] = True
                rec["cause"] = "router"
                self.router_rejected += 1
                self._fleet_rejected += 1
            else:
                self._backlog.append(req)
            return
        node = min(open_nodes,
                   key=lambda n: (self._pf_load(n), n.node_id))
        self._send_prefill(node, req, rec)

    def _send_prefill(self, node: _Node, req: TrackedRequest,
                      rec: Dict) -> None:
        """Hand ``req`` to a prefill node as a max_new<=1 copy: the
        prefill engine emits the first token and finishes, which fires
        the handoff hook.  The ORIGINAL request object stays untouched
        until the decode copy is built from the prefill result."""
        pf = copy.copy(req)
        pf.max_new = min(1, req.max_new)
        rec["final"] = pf
        node.pending.append(pf)
        node.assigned.append(pf)
        node.outstanding_s += rec["eta"]

    def _dispatch_combined(self, req: TrackedRequest, now: float) -> None:
        f = self.fleet
        rec = self._records[req.request_id]
        targets = [n for n in self.nodes
                   if n.pool == COMBINED and not n.down]
        awake = [n for n in targets if not n.asleep]

        def load(n: _Node) -> int:
            return self._pf_load(n) + len(n.eng._active_idx)

        if f.autoscale:
            asleep = [n for n in targets if n.asleep]
            if asleep and (not awake
                           or min(load(n) for n in awake)
                           >= f.scale_up_queue):
                n0 = asleep[0]
                if self._wake(n0, now):
                    awake.append(n0)
        if not awake:           # min_awake == 0 edge: wake on demand
            for n0 in targets:
                if self._wake(n0, now, force=True):
                    awake = [n0]
                    break
        if not awake:
            # every combined node is detected-dead: shed, counted
            self._shed(rec)
            return
        node = min(awake, key=lambda n: (load(n), n.node_id))
        # combined nodes admit/reject through the ENGINE's own queue
        # bound — unconditional dispatch keeps the 1-node fleet
        # byte-identical to the bare engine
        rec["final"] = req
        node.pending.append(req)
        node.assigned.append(req)

    # -- prefill-finish hook / handoff ---------------------------------
    def _on_prefill_done(self, node: _Node, pf: TrackedRequest) -> bool:
        """`on_finish` hook on prefill nodes: export the finished
        prefill's KV, build the decode-side copy, and ship it over the
        fabric.  Returns True — KV ownership always leaves the prefill
        engine here (export_table already released the local blocks)."""
        rec = self._records[pf.request_id]
        node.outstanding_s = max(0.0, node.outstanding_s - rec["eta"])
        e = node.eng
        handoff = None
        if e.kv is not None and pf.request_id in e.kv.tables:
            handoff = e.kv.export_table(pf.request_id)
        orig = rec["req"]
        if pf.generated >= orig.max_new:
            # everything asked for is out — done at prefill.  Covers the
            # zero-fault max_new<=1 case (fresh pf generates exactly
            # min(1, max_new)) AND a recompute re-prefill whose resumed
            # generated count already reached the original budget.
            rec["final"] = pf
            return True
        f = self.fleet
        dc = copy.copy(orig)
        dc.generated = pf.generated
        dc.context = pf.context
        dc.first_token_at = pf.first_token_at
        dc.finished_at = None
        dc.admit_seq = -1
        if handoff is not None:
            nbytes = handoff.nbytes     # block-padded, what the wire sees
            if f.measured_handoff is not None:
                nbytes += int(f.measured_handoff.prefill_bytes)
        else:
            nbytes = fleet_handoff_bytes(dc.context, self._bpt,
                                         f.measured_handoff)
        transfer_s = c2c_transfer_time(nbytes, self.sim.link)
        # a fresh prefill hands off with generated <= 1; anything more
        # is a crash-recovery recompute shipping rebuilt KV
        phase = "kv_recompute" if pf.generated > 1 else "kv_handoff"
        extra = 0
        extra_s = 0.0
        if self._fault_on:
            frac = self._link_frac(e.clock)
            if frac > 0.0:
                extra = retransmit_overhead_bytes(nbytes, frac)
                extra_s = c2c_transfer_time(extra, self.sim.link)
                self.retransmit_bytes += extra
        rec["final"] = dc
        self.handoffs += 1
        self.handoff_bytes += nbytes
        t_arr = e.clock + transfer_s
        if extra:
            t_arr += extra_s
        self._dispatch_handoff(dc, nbytes, transfer_s, t_arr, e.clock,
                               phase=phase, extra=extra,
                               extra_s=extra_s)
        return True

    def _dispatch_handoff(self, dc: TrackedRequest, nbytes: int,
                          transfer_s: float, t_arr: float,
                          now: float, *, phase: str = "kv_handoff",
                          extra: int = 0, extra_s: float = 0.0) -> None:
        f = self.fleet
        targets = [n for n in self.nodes
                   if n.pool == DECODE and not n.down]
        awake = [n for n in targets if not n.asleep]
        if f.autoscale:
            asleep = [n for n in targets if n.asleep]
            if asleep and (not awake
                           or min(self._dc_load(n) for n in awake)
                           >= f.scale_up_queue):
                # scale-up rides the handoff: the wake starts NOW (at
                # the prefill finish), the KV lands at max(wake end,
                # fabric arrival) — ClusterWake precedes the kv_handoff
                # C2CTransfer on the woken node's timeline
                n0 = asleep[0]
                if self._wake(n0, now):
                    awake.append(n0)
        if not awake:
            # the first token is already out — never shed mid-flight
            # work for a transient wake failure, so keep retrying down
            # the pool (force=True exhausts each node's wake-fail
            # budget); shed only when the whole pool is detected-dead
            for n0 in targets:
                if self._wake(n0, now, force=True):
                    awake = [n0]
                    break
        if not awake:
            self._shed(self._records[dc.request_id])
            return
        node = min(awake, key=lambda n: (self._dc_load(n), n.node_id))
        self._enqueue_handoff(node, dc, nbytes, transfer_s, t_arr,
                              phase=phase, extra=extra, extra_s=extra_s)

    def _enqueue_handoff(self, node: _Node, dc: TrackedRequest,
                         nbytes: int, transfer_s: float,
                         t_arr: float, *, phase: str = "kv_handoff",
                         extra: int = 0, extra_s: float = 0.0) -> None:
        seq = self._handoff_seq
        self._handoff_seq += 1
        insort(node.handoffs,
               (t_arr, seq, dc, nbytes, transfer_s, phase, extra,
                extra_s))
        node.assigned.append(dc)

    def _reroute_handoff(self, dc: TrackedRequest, nbytes: int,
                         transfer_s: float, now: float,
                         exclude: _Node, *,
                         phase: str = "kv_handoff",
                         cause: str = "router") -> None:
        """The chosen decode node can never hold this context (empty
        and still over capacity, or detected dead): pay a second fabric
        hop to a node that can, or reject if none exists."""
        # identity-based removal: TrackedRequest.__eq__ compares arrival
        # only, so list.remove could drop a different equal-arrival copy
        for i, r in enumerate(exclude.assigned):
            if r is dc:
                del exclude.assigned[i]
                break
        feas = [n for n in self.nodes
                if n.pool == DECODE and n is not exclude
                and not n.down
                and (n.eng.kv is None
                     or n.eng.kv.feasible(dc.context + 1))]
        if not feas:
            rec = self._records[dc.request_id]
            rec["rejected"] = True
            rec["cause"] = cause
            if cause == "fault_shed":
                self.fault_shed += 1
            else:
                self.router_rejected += 1
            self._fleet_rejected += 1
            return
        node = min(feas, key=lambda n: (self._dc_load(n), n.node_id))
        if node.asleep:
            if not self._wake(node, now, force=True):
                rec = self._records[dc.request_id]
                rec["rejected"] = True
                rec["cause"] = "fault_shed"
                self.fault_shed += 1
                self._fleet_rejected += 1
                return
        # the second hop crosses the fabric NOW — re-price any link
        # degradation window covering the re-route time
        extra = 0
        extra_s = 0.0
        if self._fault_on:
            frac = self._link_frac(now)
            if frac > 0.0:
                extra = retransmit_overhead_bytes(nbytes, frac)
                extra_s = c2c_transfer_time(extra, self.sim.link)
                self.retransmit_bytes += extra
        t_arr = now + transfer_s
        if extra:
            t_arr += extra_s
        self.rerouted += 1
        self.handoff_bytes += nbytes
        self._enqueue_handoff(node, dc, nbytes, transfer_s, t_arr,
                              phase=phase, extra=extra,
                              extra_s=extra_s)

    # -- node stepping -------------------------------------------------
    def _step_node(self, node: _Node) -> None:
        if node.pool == DECODE:
            self._step_decode(node)
        else:
            node.eng.step(node.pending)
        if self._backlog:
            self._try_flush_backlog()
        if self.fleet.autoscale:
            self._maybe_sleep(node)

    def _step_decode(self, node: _Node) -> None:
        e = node.eng
        now = e.clock
        # import every handoff the fabric has delivered, in arrival
        # order; a full node keeps the head QUEUED (re-tried next step —
        # re-queue, never drop), an empty-but-infeasible one re-routes
        while node.handoffs and node.handoffs[0][0] <= now:
            t_a, seq, dc, nb, ts, ph, xb, xs = node.handoffs[0]
            if e.import_request(dc, nbytes=nb, transfer_s=ts, phase=ph,
                                retransmit_bytes=xb, retransmit_s=xs):
                node.handoffs.pop(0)
                continue
            if node._last_deferred_seq != seq:
                node._last_deferred_seq = seq
                node.requeued += 1
                self.requeued += 1
            if not e._active_idx:
                # nothing resident and it still doesn't fit: no future
                # free() can help — this node is permanently infeasible
                # for this context
                node.handoffs.pop(0)
                self._reroute_handoff(dc, nb, ts, now, exclude=node,
                                      phase=ph)
                continue
            break
        e.queue_depth.append((now, len(node.handoffs)))
        if e._active_idx:
            e._decode_round()
        elif node.handoffs:
            gap = max(0.0, node.handoffs[0][0] - e.clock)
            e.timeline.sleep(gap, power_W=e._idle_power)
            e.events.append((e.clock, EventKind.IDLE, -1))

    def _try_flush_backlog(self) -> None:
        limit = self.fleet.engine.queue_limit
        while self._backlog:
            open_nodes = [n for n in self.nodes
                          if n.pool == PREFILL and not n.asleep
                          and not n.down
                          and self._pf_load(n) < limit]
            if not open_nodes:
                return
            req = self._backlog.popleft()
            node = min(open_nodes,
                       key=lambda n: (self._pf_load(n), n.node_id))
            self._send_prefill(node, req, self._records[req.request_id])

    def _maybe_sleep(self, node: _Node) -> None:
        if node.asleep or node.crashed or node.down:
            return
        if self._node_horizon(node) is not math.inf:
            return
        awake = sum(1 for m in self.nodes
                    if m.pool == node.pool and not m.asleep)
        if awake > max(self.fleet.min_awake, 0):
            node.asleep = True

    def _wake(self, node: _Node, now: float,
              force: bool = False) -> bool:
        """Wake a sleeping node at simulated time ``now``: pad its
        timeline to the wake signal at retention power, then charge the
        REAL CCPG cluster-walk latency as a ClusterWake event.

        Fault mode: a node carrying injected `WakeFault` budget times
        out instead of waking.  The router retries with `RestartPolicy`
        exponential backoff — each failed attempt costs
        ``wake_timeout_s + backoff`` of wall time, padded onto the
        target's timeline at retention power.  With ``force=False`` the
        walk is bounded by ``wake_retries`` and returns False on
        exhaustion (caller falls back to the awake pool); ``force=True``
        keeps retrying until the (finite) fault budget drains — used
        when mid-flight work cannot be shed.  A crashed/detected-dead
        node never wakes.  Returns True iff the node is awake on exit.
        """
        if node.crashed or node.down:
            self.wake_fallbacks += 1
            return False
        e = node.eng
        if self._fault_on and node.wake_fails_left > 0:
            fc = self.fleet.fault
            pol = node.wake_policy
            budget = max(int(fc.wake_retries), 1)
            delay = 0.0
            attempts = 0
            while node.wake_fails_left > 0 and (force
                                                or attempts < budget):
                attempts += 1
                node.wake_fails_left -= 1
                backoff = pol.next_backoff(now + delay)
                pol.record_failure(now + delay)
                delay += fc.wake_timeout_s + backoff
            self.wake_retries += attempts
            if node.wake_fails_left > 0:
                # retry budget exhausted and the cluster still won't
                # come up: fall back to the awake pool
                self.wake_fallbacks += 1
                return False
            # the successful wake starts after the failed walk
            now = now + delay
        gap = now - e.clock
        if gap > 0:
            e.timeline.sleep(gap, power_W=e._idle_power)
            e.events.append((e.clock, EventKind.IDLE, -1))
        dt, cyc = self.sim.wake_seconds(self._alloc)
        if dt:
            e.timeline.wake(dt, power_W=e._busy_power, cycles=cyc,
                            cluster=node.node_id)
        node.asleep = False
        node.wakes += 1
        self.wakes += 1
        return True

    # -- fault injection -----------------------------------------------
    def _shed(self, rec: Dict) -> None:
        """Degraded-mode load shed: counted and attributed, never
        silent."""
        rec["rejected"] = True
        rec["cause"] = "fault_shed"
        self.fault_shed += 1
        self._fleet_rejected += 1

    def _link_frac(self, t: float) -> float:
        """Retransmit fraction of the worst LinkFault window covering
        simulated time ``t`` (0.0 outside every window)."""
        frac = 0.0
        for w in self.fleet.fault.links:
            if w.t_start <= t < w.t_end and w.retransmit_frac > frac:
                frac = w.retransmit_frac
        return frac

    def _fault_horizon(self) -> float:
        """Earliest pending fault-entity action: the next scheduled
        fail/recover event, or the next heartbeat detection deadline."""
        if not self._fault_on:
            return math.inf
        t = math.inf
        if self._sched_i < len(self._sched):
            t = self._sched[self._sched_i][0]
        if self._pending_detect and self._pending_detect[0][0] < t:
            t = self._pending_detect[0][0]
        return t

    def _fault_step(self) -> None:
        """Process exactly one fault-entity action at the fault
        horizon (schedule events before detections on ties, so a
        recovery landing exactly at its own detection deadline
        heartbeats first and the sweep stays clean)."""
        st = (self._sched[self._sched_i][0]
              if self._sched_i < len(self._sched) else math.inf)
        dt = (self._pending_detect[0][0]
              if self._pending_detect else math.inf)
        if st <= dt:
            t, kind, nid = self._sched[self._sched_i]
            self._sched_i += 1
            node = self.nodes[nid]
            if kind == 0:
                if not node.crashed:
                    self._fail_node(node, t)
            else:
                if node.crashed:
                    self._recover_node(node, t)
        else:
            self._detect(dt)
        # a recovery (or detection re-dispatch) may have re-opened
        # capacity for held work
        if self._backlog:
            self._try_flush_backlog()

    def _fail_node(self, node: _Node, t: float) -> None:
        """Crash ``node`` at simulated time ``t``: its engine freezes
        mid-flight (KV lost with it) and its last heartbeat lands at
        ``t`` — the router stays oblivious until the monitor's
        ``heartbeat_dead_s`` gap elapses, so pre-detection dispatches
        still pile onto the corpse (drained at detection)."""
        fc = self.fleet.fault
        node.crashed = True
        node.fail_t = t
        self.node_failures += 1
        e = node.eng
        e.timeline.node_fail(node.node_id, t0=max(t, e.clock))
        self._des_now = t
        self._monitor.heartbeat(node.node_id)
        self._pending_detect.append((t + fc.heartbeat_dead_s,
                                     node.node_id))

    def _recover_node(self, node: _Node, t: float) -> None:
        """The crashed node comes back at ``t``: pad the outage at zero
        power (it was dark), stamp the NodeRecover instant, revive its
        monitor slot and make it routable again.  Whatever it held when
        it died was already re-routed at detection (or, for an
        undetected blip, is still resident and simply resumes)."""
        e = node.eng
        down_for = t - node.fail_t
        self._mttr.append(down_for)
        self.downtime_total += down_for
        self.node_recoveries += 1
        gap = t - e.clock
        if gap > 0:
            e.timeline.sleep(gap, power_W=0.0)
            e.events.append((e.clock, EventKind.IDLE, -1))
        e.timeline.node_recover(node.node_id, downtime_s=down_for,
                                t0=max(t, e.clock))
        self._des_now = t
        self._monitor.revive(node.node_id)
        self._monitor.heartbeat(node.node_id)
        node.crashed = False
        node.down = False
        node.fail_t = math.nan
        node._last_deferred_seq = -1

    def _detect(self, td: float) -> None:
        """A heartbeat detection deadline fired: every live node
        heartbeats, the monitor sweeps on the DES clock, and any node
        whose gap crossed ``dead_s`` is marked down and drained.  The
        scheduled deadline itself is authoritative: it sits at exactly
        ``fail_t + dead_s``, where the sweep's ``now - last_heartbeat``
        subtraction can land one ULP short of ``dead_s`` — a due entry
        whose node is still crashed is dead by construction, whether or
        not the float comparison agrees."""
        due = []
        while self._pending_detect and self._pending_detect[0][0] <= td:
            due.append(self._pending_detect.pop(0)[1])
        self._des_now = td
        mon = self._monitor
        for n in self.nodes:
            if not n.crashed:
                mon.heartbeat(n.node_id)
        dead = set(mon.sweep())
        for nid in due:
            if self.nodes[nid].crashed and nid not in dead:
                mon.workers[nid].state = WorkerState.DEAD
                dead.add(nid)
        for nid in sorted(dead):
            node = self.nodes[nid]
            if node.crashed and not node.down:
                self._drain_failed(node, td)

    def _drain_failed(self, node: _Node, now: float) -> None:
        """The router finally KNOWS ``node`` is dead: drain everything
        parked on it and re-route the survivors.  Raw arrivals
        re-dispatch as-is; queued handoffs pay a second fabric hop;
        partially-decoded residents lost their KV with the node and
        recompute-from-prompt on the prefill pool.  Deadline-infeasible
        fresh work is shed (counted) when ``shed_infeasible`` is on;
        mid-decode work (first token out) is never shed here."""
        fc = self.fleet.fault
        node.down = True
        e = node.eng
        raw = list(node.pending)
        node.pending.clear()
        dropped = e.drop_inflight()
        hand = list(node.handoffs)
        node.handoffs.clear()
        lost = {id(x) for x in raw}
        lost.update(id(x) for x in dropped)
        lost.update(id(h[2]) for h in hand)
        node.assigned = [r for r in node.assigned
                         if id(r) not in lost]
        node.outstanding_s = 0.0

        def infeasible(req: TrackedRequest, eta: float) -> bool:
            return (fc.shed_infeasible
                    and req.deadline_ttft is not None
                    and req.first_token_at is None
                    and now + eta >= req.arrival + req.deadline_ttft)

        for x in raw + dropped:
            rec = self._records.get(x.request_id)
            if rec is None or rec["rejected"]:
                continue
            if node.pool == COMBINED:
                # combined victims re-enter whole; the destination
                # engine's recompute-on-resume rebuilds any lost decode
                # progress from the prompt
                if x.generated:
                    self.recomputes += 1
                    self.recompute_tokens += x.prompt_len + x.generated
                x.admit_seq = -1
                x.finished_at = None
                if infeasible(x, 0.0):
                    self._shed(rec)
                else:
                    self._dispatch_combined(x, now)
            elif x.first_token_at is None:
                # fresh prefill died before its first token: re-dispatch
                # the ORIGINAL request (the lost copy produced nothing)
                if infeasible(rec["req"], rec["eta"]):
                    self._shed(rec)
                else:
                    self._dispatch_prefill(rec["req"], now)
            else:
                self._dispatch_recompute(rec, x, now)
        for h in hand:
            self._reroute_handoff(h[2], h[3], h[4], now, exclude=node,
                                  phase=h[5], cause="fault_shed")

    def _dispatch_recompute(self, rec: Dict, x: TrackedRequest,
                            now: float) -> None:
        """A partially-decoded request lost its KV with a dead decode
        node: re-prefill prompt+generated on the prefill pool (prefix
        sharing adopts any still-indexed prompt blocks, cutting the
        bill), then hand the rebuilt KV back to a live decode node as a
        ``kv_recompute`` handoff and resume where it died."""
        self.recomputes += 1
        self.recompute_tokens += x.prompt_len + x.generated
        rc = copy.copy(x)
        rc.finished_at = None
        rc.admit_seq = -1
        rec["eta"] = self.sim.prefill_seconds(
            self.cfg, self._alloc, rc.prompt_len + rc.generated,
            ccpg=self._residue_ccpg)[0]
        rec["final"] = rc
        self._dispatch_prefill(rc, now)

    # -- reporting -----------------------------------------------------
    def _report(self) -> FleetReport:
        f = self.fleet
        wall = max(n.eng.timeline.now for n in self.nodes)
        for n in self.nodes:
            # pad every node to the cluster wall clock at its idle
            # power, so per-node energy covers the whole run.  The
            # 1-node gap is exactly 0.0 — no event, bare-engine
            # byte-identity preserved.  A still-crashed node pads dark.
            gap = wall - n.eng.timeline.now
            if gap > 0:
                n.eng.timeline.sleep(
                    gap,
                    power_W=0.0 if n.crashed else n.eng._idle_power)
        node_reports = [n.eng._report(n.assigned) for n in self.nodes]
        if len(self.nodes) > 1:
            for nr, n in zip(node_reports, self.nodes):
                nr.node_id = n.node_id
                nr.pool = n.pool
        lats: List[float] = []
        ttfts: List[float] = []
        finished = 0
        for rec in self._records.values():
            final = rec["final"]
            if final is None or final.finished_at is None:
                continue
            finished += 1
            arrival = rec["req"].arrival
            lats.append(final.finished_at - arrival)
            if final.first_token_at is not None:
                ttfts.append(final.first_token_at - arrival)
        nan = [np.nan]
        lat_a = np.array(lats) if lats else np.array(nan)
        ttft_a = np.array(ttfts) if ttfts else np.array(nan)
        tokens = sum(nr.tokens_generated for nr in node_reports)
        energy = sum(nr.energy_J for nr in node_reports)
        rejected = (sum(nr.rejected for nr in node_reports)
                    + self._fleet_rejected)
        wall = max(wall, 1e-12)
        fault_kw: Dict = {}
        if self._fault_on:
            # downtime accrues to the report wall for nodes that never
            # recovered; availability is node-time weighted
            downtime = self.downtime_total + sum(
                wall - n.fail_t for n in self.nodes if n.crashed)
            goodput_tokens = 0
            for rec in self._records.values():
                final = rec["final"]
                if final is not None and final.finished_at is not None:
                    goodput_tokens += final.generated
            fault_kw = dict(
                router_rejected=self.router_rejected,
                fault_shed=self.fault_shed,
                node_failures=self.node_failures,
                node_recoveries=self.node_recoveries,
                downtime_s=downtime,
                mttr_s=(sum(self._mttr) / len(self._mttr)
                        if self._mttr else float("nan")),
                availability=1.0 - downtime / (len(self.nodes) * wall),
                goodput_tokens_per_s=goodput_tokens / wall,
                recomputes=self.recomputes,
                recompute_tokens=self.recompute_tokens,
                retransmit_bytes=self.retransmit_bytes,
                wake_retries=self.wake_retries,
                wake_fallbacks=self.wake_fallbacks,
            )
        return FleetReport(
            n_nodes=len(self.nodes),
            n_prefill=f.n_prefill if self._disagg else 0,
            n_decode=f.n_decode if self._disagg else 0,
            handoff=self._disagg,
            n_requests=len(self._records),
            finished=finished,
            rejected=rejected,
            wall_s=wall,
            tokens_generated=tokens,
            tokens_per_s=tokens / wall,
            energy_J=energy,
            tokens_per_J=tokens / max(energy, 1e-12),
            p50_latency_s=float(np.percentile(lat_a, 50)),
            p99_latency_s=float(np.percentile(lat_a, 99)),
            p50_ttft_s=float(np.percentile(ttft_a, 50)),
            p99_ttft_s=float(np.percentile(ttft_a, 99)),
            handoffs=self.handoffs,
            handoff_bytes=self.handoff_bytes,
            requeued_handoffs=self.requeued,
            rerouted_handoffs=self.rerouted,
            wakes=self.wakes,
            slo_rejected=self.slo_rejected,
            node_reports=node_reports,
            **fault_kw,
        )

    def save_chrome_trace(self, path) -> None:
        """One merged chrome://tracing document, each node its own
        process (pid = node id, named ``node<i>:<pool>``)."""
        doc = merge_chrome_traces(
            [(f"node{n.node_id}:{n.pool}", n.eng.timeline)
             for n in self.nodes])
        with open(path, "w") as fh:
            json.dump(doc, fh)


def fleet_serve(cfg, trace: Sequence[TrackedRequest], *,
                fleet: Optional[FleetConfig] = None,
                sim: Optional[PicnicSimulator] = None) -> FleetReport:
    """One-call convenience wrapper: run ``trace`` through a fresh
    fleet (the `repro.launch.fleet()` facade lands here)."""
    return FleetEngine(cfg, fleet, sim=sim).run(trace)
