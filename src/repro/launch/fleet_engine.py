"""Disaggregated prefill/decode fleet over the photonic fabric.

The cluster layer above `launch/serving_engine` (ROADMAP item 1): N
PICNIC node instances — each one a full `ContinuousBatchingEngine` with
its own TimelineIR — split into dedicated PREFILL and DECODE pools, with
a global router in front and priced KV handoff between them:

  arrival trace
    -> ROUTER: SLO-aware admission (optional; rejects a request whose
       TTFT deadline is already unreachable on the least-loaded node),
       least-loaded prefill dispatch, bounded hold-don't-drop backlog
       when every prefill queue is full
    -> PREFILL node: runs prompt prefill + first token (a max_new<=1
       copy of the request), then exports the resident KV block set
       (`BlockAllocator.export_table`) through the engine's `on_finish`
       hook
    -> KV HANDOFF over the inter-node fabric: wire bytes from
       `core.interconnect.fleet_handoff_bytes` (analytic Table-II KV
       footprint by default, HLO-`MeasuredTraffic` resharding cost
       opt-in), latency = bytes / fabric bandwidth folded into the
       decode-side arrival, energy priced as a C2CTransfer
       (phase "kv_handoff") on the decode node's timeline
    -> DECODE node: `import_table` re-admits the context into a fresh
       local block table, the request decodes to completion in that
       node's continuous batch.  A full decode node re-queues the
       handoff (never drops); an empty-but-infeasible one re-routes it.
    -> CCPG autoscaling (optional): nodes beyond `min_awake` per pool
       start asleep; the router wakes one — paying the REAL ClusterWake
       cluster-walk latency on that node's timeline — when awake nodes
       saturate, and drained nodes go back to sleep.

``handoff=False`` degrades every node to a COMBINED (prefill+decode)
replica — plain data-parallel serving, the disaggregation baseline.  A
1-node combined fleet reproduces the bare engine's step sequence
EXACTLY (hex-identical timeline floats, events and report — locked by
tests/test_fleet.py): the fleet adds no timeline activity of its own on
that path.

Scheduling is conservative parallel discrete-event simulation: every
entity (router, node) exposes a *horizon* — the earliest simulated time
its next action can happen (a busy node: its clock; an idle node: its
next input's arrival; the router: the next undispatched arrival) — and
the fleet always steps the runnable entity with the minimum horizon,
router first on ties.  The minimum-horizon entity can never receive an
earlier input from the others, so the interleave is causally safe and
deterministic.

Pure Python + numpy like the engine underneath — no JAX import.

  PYTHONPATH=src python -c "from repro.launch import fleet; ..."
"""
from __future__ import annotations

import copy
import dataclasses
import json
import math
from bisect import insort
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interconnect import c2c_transfer_time, fleet_handoff_bytes
from repro.core.scheduling import ChipletAllocation, allocate_chiplets
from repro.core.simulator import PicnicSimulator
from repro.core.timeline import merge_chrome_traces
from repro.launch.config import FleetConfig, ServingConfig
from repro.launch.scheduler import EventKind
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingReport, TrackedRequest)
from repro.runtime.kv_cache import kv_bytes_per_token

PREFILL = "prefill"
DECODE = "decode"
COMBINED = "combined"


class _Node:
    """One PICNIC node of the fleet: an engine plus its fleet-side
    mailboxes (dispatched arrivals, queued handoffs) and pool state."""

    __slots__ = ("node_id", "pool", "eng", "pending", "handoffs",
                 "assigned", "asleep", "wakes", "requeued",
                 "outstanding_s", "_last_deferred_seq")

    def __init__(self, node_id: int, pool: str, cfg, sim, engine_cfg,
                 alloc):
        self.node_id = node_id
        self.pool = pool
        self.eng = ContinuousBatchingEngine(cfg, sim=sim,
                                            engine=engine_cfg,
                                            alloc=alloc)
        # arrivals the router has dispatched here (arrival-ordered; the
        # engine admits them itself, preserving its queue_limit/reject
        # semantics)
        self.pending: Deque[TrackedRequest] = deque()
        # (arrival_s, seq, request, nbytes, transfer_s) — handed-off
        # requests in fabric-arrival order (insort: wakes and re-routes
        # can land out of order)
        self.handoffs: List[Tuple] = []
        self.assigned: List[TrackedRequest] = []
        self.asleep = False
        self.wakes = 0
        self.requeued = 0
        self.outstanding_s = 0.0     # router's prefill-work estimate
        self._last_deferred_seq = -1

    def reset(self) -> None:
        self.eng.reset()
        self.pending.clear()
        self.handoffs.clear()
        self.assigned = []
        self.asleep = False
        self.wakes = 0
        self.requeued = 0
        self.outstanding_s = 0.0
        self._last_deferred_seq = -1


@dataclasses.dataclass
class FleetReport:
    """Cluster-level aggregate over one trace, plus every node's own
    :class:`ServingReport` (carrying ``node_id``/``pool`` attribution
    whenever the fleet has more than one node)."""
    n_nodes: int
    n_prefill: int
    n_decode: int
    handoff: bool
    n_requests: int
    finished: int
    rejected: int
    wall_s: float
    tokens_generated: int
    tokens_per_s: float
    energy_J: float
    tokens_per_J: float
    p50_latency_s: float
    p99_latency_s: float
    p50_ttft_s: float
    p99_ttft_s: float
    handoffs: int
    handoff_bytes: int
    requeued_handoffs: int
    rerouted_handoffs: int
    wakes: int
    slo_rejected: int
    node_reports: List[ServingReport]

    def row(self) -> Dict:
        def _r(x: float, nd: int):
            return None if math.isnan(x) else round(x, nd)
        return {
            "nodes": self.n_nodes,
            "prefill_nodes": self.n_prefill,
            "decode_nodes": self.n_decode,
            "handoff": self.handoff,
            "requests": self.n_requests,
            "finished": self.finished,
            "rejected": self.rejected,
            "tokens_per_s": _r(self.tokens_per_s, 1),
            "tokens_per_J": _r(self.tokens_per_J, 1),
            "p50_latency_s": _r(self.p50_latency_s, 4),
            "p99_latency_s": _r(self.p99_latency_s, 4),
            "p50_ttft_s": _r(self.p50_ttft_s, 4),
            "p99_ttft_s": _r(self.p99_ttft_s, 4),
            "handoffs": self.handoffs,
            "handoff_MB": round(self.handoff_bytes / 1e6, 3),
            "requeued_handoffs": self.requeued_handoffs,
            "wakes": self.wakes,
            "slo_rejected": self.slo_rejected,
            "wall_s": _r(self.wall_s, 4),
        }

    def summary(self) -> str:
        shape = (f"{self.n_prefill}P+{self.n_decode}D"
                 if self.handoff else f"{self.n_nodes}x combined")
        return "\n".join([
            f"FleetReport ({shape})",
            f"  requests          {self.finished}/{self.n_requests} "
            f"finished, {self.rejected} rejected "
            f"({self.slo_rejected} at the SLO gate)",
            f"  wall clock        {self.wall_s:.3f} s",
            f"  throughput        {self.tokens_per_s:.1f} tok/s",
            f"  efficiency        {self.tokens_per_J:.1f} tok/J "
            f"({self.energy_J:.3f} J total)",
            f"  latency p50/p99   {self.p50_latency_s * 1e3:.1f} / "
            f"{self.p99_latency_s * 1e3:.1f} ms",
            f"  TTFT    p50/p99   {self.p50_ttft_s * 1e3:.1f} / "
            f"{self.p99_ttft_s * 1e3:.1f} ms",
            f"  handoffs          {self.handoffs} "
            f"({self.handoff_bytes / 1e6:.2f} MB over the fabric, "
            f"{self.requeued_handoffs} re-queued, "
            f"{self.rerouted_handoffs} re-routed)",
            f"  node wakes        {self.wakes}",
        ])


class FleetEngine:
    """A fleet of :class:`ContinuousBatchingEngine` nodes behind one
    router — see the module docstring for the full data path."""

    def __init__(self, cfg, fleet: Optional[FleetConfig] = None, *,
                 sim: Optional[PicnicSimulator] = None):
        self.cfg = cfg
        self.fleet = fleet if fleet is not None else FleetConfig()
        self.sim = sim if sim is not None else PicnicSimulator()
        f = self.fleet
        if f.n_nodes < 1:
            raise ValueError("fleet needs at least one node")
        ecfg = f.engine
        # one chiplet allocation shared by every node (deterministic;
        # sharing also maximizes cycle-model memo hits across nodes)
        self._alloc: ChipletAllocation = allocate_chiplets(
            cfg, self.sim.tile)
        disagg = f.handoff and f.n_prefill > 0 and f.n_decode > 0
        pools = ([PREFILL] * f.n_prefill + [DECODE] * f.n_decode
                 if disagg else [COMBINED] * f.n_nodes)
        self.nodes = [_Node(i, pool, cfg, self.sim, ecfg, self._alloc)
                      for i, pool in enumerate(pools)]
        self._disagg = disagg
        self._residue_ccpg = ecfg.ccpg and not ecfg.dynamic_ccpg
        # handoff wire pricing: explicit knob > paged cache's own
        # footprint > analytic model-derived KV bytes/token
        if f.handoff_bytes_per_token is not None:
            self._bpt = int(f.handoff_bytes_per_token)
        elif ecfg.kv_cache is not None:
            self._bpt = int(ecfg.kv_cache.bytes_per_token)
        else:
            self._bpt = kv_bytes_per_token(cfg)
        for n in self.nodes:
            if n.pool == PREFILL:
                n.eng.on_finish = (
                    lambda req, node=n: self._on_prefill_done(node, req))
        # run-scoped state (rebuilt by run())
        self._records: Dict[int, Dict] = {}
        self._arrivals: Deque[TrackedRequest] = deque()
        self._backlog: Deque[TrackedRequest] = deque()
        self._handoff_seq = 0
        self.handoffs = 0
        self.handoff_bytes = 0
        self.requeued = 0
        self.rerouted = 0
        self.wakes = 0
        self.slo_rejected = 0
        self._fleet_rejected = 0

    # -- horizons ------------------------------------------------------
    def _node_horizon(self, n: _Node) -> float:
        """Earliest simulated time node ``n``'s next step can happen:
        its clock while it holds work, else its next input's arrival
        (clamped to the clock), else +inf (not runnable).  Sleeping
        nodes only re-enter through a router wake."""
        if n.asleep:
            return math.inf
        e = n.eng
        if e.queue or e._active_idx or e._partial is not None:
            return e.clock
        t = math.inf
        if n.pending:
            t = n.pending[0].arrival
        if n.handoffs:
            h = n.handoffs[0][0]
            if h < t:
                t = h
        if t is math.inf:
            return math.inf
        return t if t > e.clock else e.clock

    # -- run -----------------------------------------------------------
    def run(self, trace: Sequence[TrackedRequest]) -> FleetReport:
        f = self.fleet
        for n in self.nodes:
            n.reset()
        # replicate ContinuousBatchingEngine._prepare_run for the whole
        # fleet: reset the trace's mutable per-run state, verify arrival
        # monotonicity (stable re-sort only when violated), share the
        # any-deadline flag with every node
        arr = list(trace)
        for r in arr:
            r.generated = 0
            r.context = 0
            r.first_token_at = None
            r.finished_at = None
            r.admit_seq = -1
        prev = -math.inf
        for r in arr:
            if r.arrival < prev:
                arr.sort()
                break
            prev = r.arrival
        any_deadline = any(r.deadline_ttft is not None for r in arr)
        for n in self.nodes:
            n.eng._any_deadline = any_deadline
        self._records = {}
        self._arrivals = deque(arr)
        self._backlog = deque()
        self._handoff_seq = 0
        self.handoffs = 0
        self.handoff_bytes = 0
        self.requeued = 0
        self.rerouted = 0
        self.wakes = 0
        self.slo_rejected = 0
        self._fleet_rejected = 0
        if f.autoscale:
            for pool in (PREFILL, DECODE, COMBINED):
                awake = 0
                for n in self.nodes:
                    if n.pool != pool:
                        continue
                    awake += 1
                    n.asleep = awake > max(f.min_awake, 0)
        # conservative-DES main loop: min-horizon entity steps next,
        # router (dispatch) before nodes on ties so a node never admits
        # at a timestamp the router still owes arrivals for
        it = 0
        while True:
            rh = (self._arrivals[0].arrival if self._arrivals
                  else math.inf)
            best: Optional[_Node] = None
            bh = math.inf
            for n in self.nodes:
                h = self._node_horizon(n)
                if h < bh:
                    bh = h
                    best = n
            if rh <= bh:
                if best is None and rh is math.inf:
                    break
                if rh is not math.inf:
                    self._router_step()
                    continue
            it += 1
            if it > f.max_iters:
                raise RuntimeError("fleet exceeded max_iters")
            self._step_node(best)
        if self._backlog:       # unreachable: flush runs per node step
            raise RuntimeError("fleet backlog not drained")
        return self._report()

    # -- router --------------------------------------------------------
    def _router_step(self) -> None:
        """Dispatch every arrival at the next arrival timestamp (equal
        arrivals batch together, FIFO — matching one engine
        ``_admit_arrivals`` pass)."""
        t = self._arrivals[0].arrival
        while self._arrivals and self._arrivals[0].arrival <= t:
            req = self._arrivals.popleft()
            rec = {"req": req, "final": None, "rejected": False,
                   "eta": 0.0}
            self._records[req.request_id] = rec
            if self._disagg:
                rec["eta"] = self.sim.prefill_seconds(
                    self.cfg, self._alloc, req.prompt_len,
                    ccpg=self._residue_ccpg)[0]
                self._dispatch_prefill(req, t)
            else:
                self._dispatch_combined(req, t)

    @staticmethod
    def _pf_load(n: _Node) -> int:
        e = n.eng
        return (len(n.pending) + len(e.queue)
                + (1 if e._partial is not None else 0))

    @staticmethod
    def _dc_load(n: _Node) -> int:
        return len(n.eng._active_idx) + len(n.handoffs)

    def _dispatch_prefill(self, req: TrackedRequest, now: float) -> None:
        f = self.fleet
        rec = self._records[req.request_id]
        targets = [n for n in self.nodes if n.pool == PREFILL]
        awake = [n for n in targets if not n.asleep]
        if f.slo_admission and req.deadline_ttft is not None:
            # the BEST case (least-loaded awake node, its whole queue
            # estimate ahead of us) already misses the deadline: reject
            # at the router instead of burning prefill on a dead request
            wait = min((n.outstanding_s for n in awake), default=0.0)
            if now + wait + rec["eta"] >= req.arrival + req.deadline_ttft:
                rec["rejected"] = True
                self.slo_rejected += 1
                self._fleet_rejected += 1
                return
        limit = f.engine.queue_limit
        open_nodes = [n for n in awake if self._pf_load(n) < limit]
        if f.autoscale:
            asleep = [n for n in targets if n.asleep]
            if asleep and (
                    not open_nodes
                    or min(self._pf_load(n) for n in open_nodes)
                    >= f.scale_up_queue):
                n0 = asleep[0]
                self._wake(n0, now)
                open_nodes.append(n0)
        if not open_nodes:
            # every awake prefill queue is full: HOLD the request in the
            # router backlog (re-tried after every node step) instead of
            # dropping it; reject only past the router's own bound
            if len(self._backlog) >= f.queue_limit:
                rec["rejected"] = True
                self._fleet_rejected += 1
            else:
                self._backlog.append(req)
            return
        node = min(open_nodes,
                   key=lambda n: (self._pf_load(n), n.node_id))
        self._send_prefill(node, req, rec)

    def _send_prefill(self, node: _Node, req: TrackedRequest,
                      rec: Dict) -> None:
        """Hand ``req`` to a prefill node as a max_new<=1 copy: the
        prefill engine emits the first token and finishes, which fires
        the handoff hook.  The ORIGINAL request object stays untouched
        until the decode copy is built from the prefill result."""
        pf = copy.copy(req)
        pf.max_new = min(1, req.max_new)
        rec["final"] = pf
        node.pending.append(pf)
        node.assigned.append(pf)
        node.outstanding_s += rec["eta"]

    def _dispatch_combined(self, req: TrackedRequest, now: float) -> None:
        f = self.fleet
        rec = self._records[req.request_id]
        targets = [n for n in self.nodes if n.pool == COMBINED]
        awake = [n for n in targets if not n.asleep]

        def load(n: _Node) -> int:
            return self._pf_load(n) + len(n.eng._active_idx)

        if f.autoscale:
            asleep = [n for n in targets if n.asleep]
            if asleep and (not awake
                           or min(load(n) for n in awake)
                           >= f.scale_up_queue):
                n0 = asleep[0]
                self._wake(n0, now)
                awake.append(n0)
        if not awake:           # min_awake == 0 edge: wake on demand
            n0 = targets[0]
            self._wake(n0, now)
            awake = [n0]
        node = min(awake, key=lambda n: (load(n), n.node_id))
        # combined nodes admit/reject through the ENGINE's own queue
        # bound — unconditional dispatch keeps the 1-node fleet
        # byte-identical to the bare engine
        rec["final"] = req
        node.pending.append(req)
        node.assigned.append(req)

    # -- prefill-finish hook / handoff ---------------------------------
    def _on_prefill_done(self, node: _Node, pf: TrackedRequest) -> bool:
        """`on_finish` hook on prefill nodes: export the finished
        prefill's KV, build the decode-side copy, and ship it over the
        fabric.  Returns True — KV ownership always leaves the prefill
        engine here (export_table already released the local blocks)."""
        rec = self._records[pf.request_id]
        node.outstanding_s = max(0.0, node.outstanding_s - rec["eta"])
        e = node.eng
        handoff = None
        if e.kv is not None and pf.request_id in e.kv.tables:
            handoff = e.kv.export_table(pf.request_id)
        orig = rec["req"]
        if orig.max_new <= 1:
            # the first token was everything asked for — done at prefill
            rec["final"] = pf
            return True
        f = self.fleet
        dc = copy.copy(orig)
        dc.generated = pf.generated
        dc.context = pf.context
        dc.first_token_at = pf.first_token_at
        dc.finished_at = None
        dc.admit_seq = -1
        if handoff is not None:
            nbytes = handoff.nbytes     # block-padded, what the wire sees
            if f.measured_handoff is not None:
                nbytes += int(f.measured_handoff.prefill_bytes)
        else:
            nbytes = fleet_handoff_bytes(dc.context, self._bpt,
                                         f.measured_handoff)
        transfer_s = c2c_transfer_time(nbytes, self.sim.link)
        rec["final"] = dc
        self.handoffs += 1
        self.handoff_bytes += nbytes
        self._dispatch_handoff(dc, nbytes, transfer_s,
                               e.clock + transfer_s, e.clock)
        return True

    def _dispatch_handoff(self, dc: TrackedRequest, nbytes: int,
                          transfer_s: float, t_arr: float,
                          now: float) -> None:
        f = self.fleet
        targets = [n for n in self.nodes if n.pool == DECODE]
        awake = [n for n in targets if not n.asleep]
        if f.autoscale:
            asleep = [n for n in targets if n.asleep]
            if asleep and (not awake
                           or min(self._dc_load(n) for n in awake)
                           >= f.scale_up_queue):
                # scale-up rides the handoff: the wake starts NOW (at
                # the prefill finish), the KV lands at max(wake end,
                # fabric arrival) — ClusterWake precedes the kv_handoff
                # C2CTransfer on the woken node's timeline
                n0 = asleep[0]
                self._wake(n0, now)
                awake.append(n0)
        if not awake:
            n0 = targets[0]
            self._wake(n0, now)
            awake = [n0]
        node = min(awake, key=lambda n: (self._dc_load(n), n.node_id))
        self._enqueue_handoff(node, dc, nbytes, transfer_s, t_arr)

    def _enqueue_handoff(self, node: _Node, dc: TrackedRequest,
                         nbytes: int, transfer_s: float,
                         t_arr: float) -> None:
        seq = self._handoff_seq
        self._handoff_seq += 1
        insort(node.handoffs, (t_arr, seq, dc, nbytes, transfer_s))
        node.assigned.append(dc)

    def _reroute_handoff(self, dc: TrackedRequest, nbytes: int,
                         transfer_s: float, now: float,
                         exclude: _Node) -> None:
        """The chosen decode node can never hold this context (empty
        and still over capacity): pay a second fabric hop to a node
        that can, or reject if none exists."""
        # identity-based removal: TrackedRequest.__eq__ compares arrival
        # only, so list.remove could drop a different equal-arrival copy
        for i, r in enumerate(exclude.assigned):
            if r is dc:
                del exclude.assigned[i]
                break
        feas = [n for n in self.nodes
                if n.pool == DECODE and n is not exclude
                and (n.eng.kv is None
                     or n.eng.kv.feasible(dc.context + 1))]
        if not feas:
            rec = self._records[dc.request_id]
            rec["rejected"] = True
            self._fleet_rejected += 1
            return
        node = min(feas, key=lambda n: (self._dc_load(n), n.node_id))
        if node.asleep:
            self._wake(node, now)
        self.rerouted += 1
        self.handoff_bytes += nbytes
        self._enqueue_handoff(node, dc, nbytes, transfer_s,
                              now + transfer_s)

    # -- node stepping -------------------------------------------------
    def _step_node(self, node: _Node) -> None:
        if node.pool == DECODE:
            self._step_decode(node)
        else:
            node.eng.step(node.pending)
        if self._backlog:
            self._try_flush_backlog()
        if self.fleet.autoscale:
            self._maybe_sleep(node)

    def _step_decode(self, node: _Node) -> None:
        e = node.eng
        now = e.clock
        # import every handoff the fabric has delivered, in arrival
        # order; a full node keeps the head QUEUED (re-tried next step —
        # re-queue, never drop), an empty-but-infeasible one re-routes
        while node.handoffs and node.handoffs[0][0] <= now:
            t_a, seq, dc, nb, ts = node.handoffs[0]
            if e.import_request(dc, nbytes=nb, transfer_s=ts):
                node.handoffs.pop(0)
                continue
            if node._last_deferred_seq != seq:
                node._last_deferred_seq = seq
                node.requeued += 1
                self.requeued += 1
            if not e._active_idx:
                # nothing resident and it still doesn't fit: no future
                # free() can help — this node is permanently infeasible
                # for this context
                node.handoffs.pop(0)
                self._reroute_handoff(dc, nb, ts, now, exclude=node)
                continue
            break
        e.queue_depth.append((now, len(node.handoffs)))
        if e._active_idx:
            e._decode_round()
        elif node.handoffs:
            gap = max(0.0, node.handoffs[0][0] - e.clock)
            e.timeline.sleep(gap, power_W=e._idle_power)
            e.events.append((e.clock, EventKind.IDLE, -1))

    def _try_flush_backlog(self) -> None:
        limit = self.fleet.engine.queue_limit
        while self._backlog:
            open_nodes = [n for n in self.nodes
                          if n.pool == PREFILL and not n.asleep
                          and self._pf_load(n) < limit]
            if not open_nodes:
                return
            req = self._backlog.popleft()
            node = min(open_nodes,
                       key=lambda n: (self._pf_load(n), n.node_id))
            self._send_prefill(node, req, self._records[req.request_id])

    def _maybe_sleep(self, node: _Node) -> None:
        if node.asleep or self._node_horizon(node) is not math.inf:
            return
        awake = sum(1 for m in self.nodes
                    if m.pool == node.pool and not m.asleep)
        if awake > max(self.fleet.min_awake, 0):
            node.asleep = True

    def _wake(self, node: _Node, now: float) -> None:
        """Wake a sleeping node at simulated time ``now``: pad its
        timeline to the wake signal at retention power, then charge the
        REAL CCPG cluster-walk latency as a ClusterWake event."""
        e = node.eng
        gap = now - e.clock
        if gap > 0:
            e.timeline.sleep(gap, power_W=e._idle_power)
            e.events.append((e.clock, EventKind.IDLE, -1))
        dt, cyc = self.sim.wake_seconds(self._alloc)
        if dt:
            e.timeline.wake(dt, power_W=e._busy_power, cycles=cyc,
                            cluster=node.node_id)
        node.asleep = False
        node.wakes += 1
        self.wakes += 1

    # -- reporting -----------------------------------------------------
    def _report(self) -> FleetReport:
        f = self.fleet
        wall = max(n.eng.timeline.now for n in self.nodes)
        for n in self.nodes:
            # pad every node to the cluster wall clock at its idle
            # power, so per-node energy covers the whole run.  The
            # 1-node gap is exactly 0.0 — no event, bare-engine
            # byte-identity preserved.
            gap = wall - n.eng.timeline.now
            if gap > 0:
                n.eng.timeline.sleep(gap, power_W=n.eng._idle_power)
        node_reports = [n.eng._report(n.assigned) for n in self.nodes]
        if len(self.nodes) > 1:
            for nr, n in zip(node_reports, self.nodes):
                nr.node_id = n.node_id
                nr.pool = n.pool
        lats: List[float] = []
        ttfts: List[float] = []
        finished = 0
        for rec in self._records.values():
            final = rec["final"]
            if final is None or final.finished_at is None:
                continue
            finished += 1
            arrival = rec["req"].arrival
            lats.append(final.finished_at - arrival)
            if final.first_token_at is not None:
                ttfts.append(final.first_token_at - arrival)
        nan = [np.nan]
        lat_a = np.array(lats) if lats else np.array(nan)
        ttft_a = np.array(ttfts) if ttfts else np.array(nan)
        tokens = sum(nr.tokens_generated for nr in node_reports)
        energy = sum(nr.energy_J for nr in node_reports)
        rejected = (sum(nr.rejected for nr in node_reports)
                    + self._fleet_rejected)
        wall = max(wall, 1e-12)
        return FleetReport(
            n_nodes=len(self.nodes),
            n_prefill=f.n_prefill if self._disagg else 0,
            n_decode=f.n_decode if self._disagg else 0,
            handoff=self._disagg,
            n_requests=len(self._records),
            finished=finished,
            rejected=rejected,
            wall_s=wall,
            tokens_generated=tokens,
            tokens_per_s=tokens / wall,
            energy_J=energy,
            tokens_per_J=tokens / max(energy, 1e-12),
            p50_latency_s=float(np.percentile(lat_a, 50)),
            p99_latency_s=float(np.percentile(lat_a, 99)),
            p50_ttft_s=float(np.percentile(ttft_a, 50)),
            p99_ttft_s=float(np.percentile(ttft_a, 99)),
            handoffs=self.handoffs,
            handoff_bytes=self.handoff_bytes,
            requeued_handoffs=self.requeued,
            rerouted_handoffs=self.rerouted,
            wakes=self.wakes,
            slo_rejected=self.slo_rejected,
            node_reports=node_reports,
        )

    def save_chrome_trace(self, path) -> None:
        """One merged chrome://tracing document, each node its own
        process (pid = node id, named ``node<i>:<pool>``)."""
        doc = merge_chrome_traces(
            [(f"node{n.node_id}:{n.pool}", n.eng.timeline)
             for n in self.nodes])
        with open(path, "w") as fh:
            json.dump(doc, fh)


def fleet_serve(cfg, trace: Sequence[TrackedRequest], *,
                fleet: Optional[FleetConfig] = None,
                sim: Optional[PicnicSimulator] = None) -> FleetReport:
    """One-call convenience wrapper: run ``trace`` through a fresh
    fleet (the `repro.launch.fleet()` facade lands here)."""
    return FleetEngine(cfg, fleet, sim=sim).run(trace)
