"""The public serving-configuration schema (ISSUE 9 API redesign).

One keyword-only, versioned config family shared by every serving
entry point — `ContinuousBatchingEngine` (scalar), `SweepEngine`
(vectorized grids) and `FleetEngine` (multi-node disaggregation) all
construct from :class:`ServingConfig`; the fleet layer adds its knobs
in :class:`FleetConfig`, which *embeds* a ServingConfig per node
instead of duplicating its fields.

Schema contract (locked by tests/test_serving_api.py):

  * keyword-only construction — positional field order is not API;
  * ``to_dict()`` / ``from_dict()`` round-trip exactly, including the
    nested ``kv_cache`` (`runtime.kv_cache.KVCacheConfig`) and
    ``engine`` blocks;
  * ``from_dict`` REJECTS unknown keys (`ValueError` naming them) — a
    typo'd knob must fail loudly, not silently fall back to defaults;
  * every dict carries a ``schema`` stamp; ``from_dict`` refuses
    documents newer than it understands.

``repro.launch.serving_engine.EngineConfig`` remains as a deprecated
alias (same fields, accepts the legacy positional form) that warns on
construction — see the shim there.

Pure Python, JAX-free, like the rest of the analytic serving stack.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import ClassVar, Dict, Optional, Tuple

from repro.core.interconnect import MeasuredTraffic
from repro.runtime.kv_cache import KVCacheConfig


def _check_known_keys(cls, d: Dict) -> None:
    """Unknown-key rejection shared by every ``from_dict``."""
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} keys: {unknown} "
            f"(known: {sorted(known)})")


def _check_schema(cls, d: Dict) -> Dict:
    """Pop + validate the version stamp; returns a shallow copy of
    ``d`` without it."""
    d = dict(d)
    ver = d.pop("schema", 1)
    if not isinstance(ver, int) or ver < 1:
        raise ValueError(f"bad {cls.__name__} schema stamp: {ver!r}")
    if ver > cls.SCHEMA_VERSION:
        raise ValueError(
            f"{cls.__name__} document has schema {ver}, this build "
            f"understands <= {cls.SCHEMA_VERSION}")
    return d


@dataclasses.dataclass(kw_only=True)
class ServingConfig:
    """Per-engine serving knobs (one PICNIC node).

    The field set (and every default) is the former ``EngineConfig``
    — promoting it to a keyword-only, versioned schema is the ISSUE 9
    API consolidation; the semantics of each knob are unchanged and
    documented inline.
    """
    SCHEMA_VERSION: ClassVar[int] = 1

    max_batch: int = 8          # KV-cache slots = max co-resident requests
    queue_limit: int = 256      # admission queue bound (then reject)
    decode_quantum: int = 4     # decode rounds per allowed prefill
    ccpg: bool = False          # cluster power gating (paper §II-E)
    dynamic_ccpg: bool = False  # full ClusterWake latency per iteration
    #                             instead of the folded pre-wake residue
    overlap: float = 0.0        # fraction of decode C2C hidden by compute
    max_iters: int = 2_000_000  # safety valve for the event loop
    # -- paged KV cache (None = capacity unbounded, paging off; the
    #    default path stays byte-identical to timeline_golden.json) -----
    kv_cache: Optional[KVCacheConfig] = None
    # chunked prefill: prompts longer than this are prefilled in chunks
    # of at most this many tokens, one chunk per engine iteration, so a
    # long prompt cannot monopolize an iteration (0 = off)
    chunked_prefill_tokens: int = 0
    # columnar TimelineIR recording (the fast simulation core).  False
    # restores the one-dataclass-per-append reference recorder — both
    # are byte-identical (tests/test_fastpath.py); the toggle exists for
    # the equivalence tests and the microbench before/after measurement.
    columnar_timeline: bool = True
    # aggregate-only TimelineIR recording (the sweep-engine recorder):
    # running sums and counts only, NO event stream — reading
    # `timeline.events` / exporting a trace raises.  Every report-level
    # aggregate stays byte-identical to the other recorders (same float
    # adds in the same order); takes precedence over columnar_timeline.
    aggregate_timeline: bool = False

    def to_dict(self) -> Dict:
        d = {"schema": self.SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "kv_cache" and v is not None:
                v = dataclasses.asdict(v)
            d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "ServingConfig":
        d = _check_schema(cls, d)
        _check_known_keys(cls, d)
        kv = d.get("kv_cache")
        if isinstance(kv, dict):
            _check_known_keys(KVCacheConfig, kv)
            d["kv_cache"] = KVCacheConfig(**kv)
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class LinkFault:
    """One photonic link-degradation window.

    Thermal drift of the ring resonators raises the BER past the FEC
    budget for ``[t_start, t_end)``; every KV handoff sent during the
    window re-transmits ``retransmit_frac`` of its payload, priced on
    the timeline as ``C2CTransfer(phase="retransmit")`` riding the same
    link model as the payload itself.
    """
    t_start: float
    t_end: float
    retransmit_frac: float = 0.1


@dataclasses.dataclass(frozen=True)
class NodeFault:
    """One fleet-node crash/recover event: the node freezes at
    ``t_fail`` holding its in-flight KV (lost), and rejoins the fleet at
    ``t_recover`` (inf = never).  The router only learns of the death
    when the heartbeat gap crosses ``FaultConfig.heartbeat_dead_s``."""
    node: int
    t_fail: float
    t_recover: float = math.inf


@dataclasses.dataclass(frozen=True)
class WakeFault:
    """CCPG wake failures: the first ``failures`` ClusterWake attempts
    on this node time out (regulator settle never completes); each
    failed attempt costs ``FaultConfig.wake_timeout_s`` plus the
    RestartPolicy backoff before the router retries or falls back to
    the awake pool."""
    node: int
    failures: int = 1


@dataclasses.dataclass(kw_only=True)
class FaultConfig:
    """A reproducible fault schedule for the fleet (ISSUE 10).

    Declarative and fully deterministic: the schedule is data, not
    callbacks, so the same FaultConfig replayed against the same trace
    yields a hex-identical report and timeline.  ``seeded()`` draws a
    schedule from a seed for fault-rate sweeps.  An empty schedule is
    inert — the fleet takes the exact zero-fault code paths.
    """
    SCHEMA_VERSION: ClassVar[int] = 1

    seed: int = 0
    links: Tuple[LinkFault, ...] = ()
    nodes: Tuple[NodeFault, ...] = ()
    wakes: Tuple[WakeFault, ...] = ()
    # CCPG wake retry policy (RestartPolicy on the DES clock)
    wake_timeout_s: float = 2e-3
    wake_retries: int = 3
    wake_backoff_base_s: float = 1e-3
    wake_backoff_max_s: float = 16e-3
    # DES-clock HeartbeatMonitor thresholds: a crashed node keeps
    # receiving work until its heartbeat gap crosses heartbeat_dead_s
    # (bounded pile-up, drained and re-routed at detection)
    heartbeat_suspect_s: float = 5e-3
    heartbeat_dead_s: float = 20e-3
    # degraded-mode load shedding: when capacity has dropped, shed the
    # re-routed requests whose TTFT deadline is already infeasible
    # (counted as fault_shed, never silent) instead of recomputing them
    shed_infeasible: bool = True

    def active(self) -> bool:
        """Inert configs (no scheduled faults) take zero-fault paths."""
        return bool(self.links or self.nodes or self.wakes)

    @classmethod
    def seeded(cls, *, seed: int, n_nodes: int, horizon_s: float,
               link_windows: int = 0, node_crashes: int = 0,
               wake_faults: int = 0, recover: bool = True,
               **knobs) -> "FaultConfig":
        """Draw a reproducible schedule: same seed -> same faults."""
        rng = random.Random(seed)
        links = tuple(sorted(
            (LinkFault(t_start=(t0 := rng.uniform(0.05, 0.70) * horizon_s),
                       t_end=t0 + rng.uniform(0.05, 0.25) * horizon_s,
                       retransmit_frac=rng.uniform(0.05, 0.30))
             for _ in range(link_windows)),
            key=lambda w: (w.t_start, w.t_end)))
        crash_ids = sorted(rng.sample(range(n_nodes),
                                      min(node_crashes, n_nodes)))
        nodes = tuple(
            NodeFault(node=i,
                      t_fail=(tf := rng.uniform(0.10, 0.60) * horizon_s),
                      t_recover=(tf + rng.uniform(0.10, 0.30) * horizon_s
                                 if recover else math.inf))
            for i in crash_ids)
        wake_ids = sorted(rng.sample(range(n_nodes),
                                     min(wake_faults, n_nodes)))
        wakes = tuple(WakeFault(node=i, failures=1 + rng.randrange(2))
                      for i in wake_ids)
        return cls(seed=seed, links=links, nodes=nodes, wakes=wakes,
                   **knobs)

    def to_dict(self) -> Dict:
        d = {"schema": self.SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name in ("links", "nodes", "wakes"):
                v = [dataclasses.asdict(x) for x in v]
            d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultConfig":
        d = _check_schema(cls, d)
        _check_known_keys(cls, d)
        for key, sub in (("links", LinkFault), ("nodes", NodeFault),
                         ("wakes", WakeFault)):
            items = d.get(key)
            if items is not None:
                built = []
                for x in items:
                    if isinstance(x, dict):
                        _check_known_keys(sub, x)
                        x = sub(**x)
                    built.append(x)
                d[key] = tuple(built)
        return cls(**d)


@dataclasses.dataclass(kw_only=True)
class FleetConfig:
    """Fleet-level knobs for `launch.fleet_engine.FleetEngine`: pool shape,
    router policy, KV-handoff pricing and node autoscaling.  Every node
    runs one :class:`ServingConfig` (the ``engine`` block).

    Schema 2 adds the optional ``fault`` block (:class:`FaultConfig`);
    absent/None keeps the zero-fault fleet byte-identical to schema 1.
    """
    SCHEMA_VERSION: ClassVar[int] = 2

    # pool shape.  handoff=True splits the fleet into n_prefill
    # dedicated prefill nodes and n_decode decode nodes with priced KV
    # handoff between them; handoff=False runs n_prefill + n_decode
    # COMBINED nodes (plain data-parallel replication, the
    # disaggregation baseline) — node count is preserved either way so
    # ratio sweeps compare like for like.
    n_prefill: int = 1
    n_decode: int = 1
    handoff: bool = True
    # per-node engine schema (shared by every node of the fleet)
    engine: ServingConfig = dataclasses.field(default_factory=ServingConfig)
    # router backlog bound: requests held (NOT rejected) while every
    # awake prefill node's admission queue is full; beyond this the
    # router itself rejects
    queue_limit: int = 4096
    # SLO-aware admission: reject at the ROUTER when the estimated
    # queue-wait + prefill time of the least-loaded node already blows
    # the request's TTFT deadline (deadline-free requests never reject
    # here; off by default so the degenerate fleet stays bare-engine
    # identical)
    slo_admission: bool = False
    # CCPG-driven node autoscaling: nodes beyond min_awake (per pool)
    # start asleep; the router wakes one — paying the REAL ClusterWake
    # cluster-walk latency on that node's timeline — when every awake
    # node of the pool carries more than scale_up_queue outstanding
    # units of work; drained nodes above min_awake go back to sleep.
    autoscale: bool = False
    min_awake: int = 1
    scale_up_queue: int = 4
    # KV-handoff wire pricing: bytes/token of resident context moved
    # prefill -> decode over the fabric.  None derives the analytic
    # Table-II-style per-token KV footprint from the model config
    # (`runtime.kv_cache.kv_bytes_per_token`, or the paged cache's own
    # bytes_per_token when the engine block carries one).
    handoff_bytes_per_token: Optional[int] = None
    # opt-in measured pricing (launch/collective_capture.py): adds the
    # HLO-measured prefill collective wire bytes per handoff — the
    # resharding traffic of re-establishing the KV on the destination
    # node's chiplets, which the analytic footprint ignores.
    measured_handoff: Optional[MeasuredTraffic] = None
    max_iters: int = 8_000_000  # safety valve over ALL node steps
    # deterministic fault injection (ISSUE 10): link-degradation
    # windows, CCPG wake failures and node crash/recover events.  None
    # (or an inert FaultConfig) keeps every zero-fault code path.
    fault: Optional[FaultConfig] = None

    @property
    def n_nodes(self) -> int:
        return self.n_prefill + self.n_decode

    def to_dict(self) -> Dict:
        d = {"schema": self.SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "engine":
                v = v.to_dict()
            elif f.name == "measured_handoff" and v is not None:
                v = dataclasses.asdict(v)
            elif f.name == "fault" and v is not None:
                v = v.to_dict()
            d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "FleetConfig":
        d = _check_schema(cls, d)
        _check_known_keys(cls, d)
        eng = d.get("engine")
        if isinstance(eng, dict):
            d["engine"] = ServingConfig.from_dict(eng)
        mh = d.get("measured_handoff")
        if isinstance(mh, dict):
            _check_known_keys(MeasuredTraffic, mh)
            d["measured_handoff"] = MeasuredTraffic(**mh)
        fl = d.get("fault")
        if isinstance(fl, dict):
            d["fault"] = FaultConfig.from_dict(fl)
        return cls(**d)
