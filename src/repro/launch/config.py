"""The public serving-configuration schema (ISSUE 9 API redesign).

One keyword-only, versioned config family shared by every serving
entry point — `ContinuousBatchingEngine` (scalar), `SweepEngine`
(vectorized grids) and `FleetEngine` (multi-node disaggregation) all
construct from :class:`ServingConfig`; the fleet layer adds its knobs
in :class:`FleetConfig`, which *embeds* a ServingConfig per node
instead of duplicating its fields.

Schema contract (locked by tests/test_serving_api.py):

  * keyword-only construction — positional field order is not API;
  * ``to_dict()`` / ``from_dict()`` round-trip exactly, including the
    nested ``kv_cache`` (`runtime.kv_cache.KVCacheConfig`) and
    ``engine`` blocks;
  * ``from_dict`` REJECTS unknown keys (`ValueError` naming them) — a
    typo'd knob must fail loudly, not silently fall back to defaults;
  * every dict carries a ``schema`` stamp; ``from_dict`` refuses
    documents newer than it understands.

``repro.launch.serving_engine.EngineConfig`` remains as a deprecated
alias (same fields, accepts the legacy positional form) that warns on
construction — see the shim there.

Pure Python, JAX-free, like the rest of the analytic serving stack.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional

from repro.core.interconnect import MeasuredTraffic
from repro.runtime.kv_cache import KVCacheConfig


def _check_known_keys(cls, d: Dict) -> None:
    """Unknown-key rejection shared by every ``from_dict``."""
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} keys: {unknown} "
            f"(known: {sorted(known)})")


def _check_schema(cls, d: Dict) -> Dict:
    """Pop + validate the version stamp; returns a shallow copy of
    ``d`` without it."""
    d = dict(d)
    ver = d.pop("schema", 1)
    if not isinstance(ver, int) or ver < 1:
        raise ValueError(f"bad {cls.__name__} schema stamp: {ver!r}")
    if ver > cls.SCHEMA_VERSION:
        raise ValueError(
            f"{cls.__name__} document has schema {ver}, this build "
            f"understands <= {cls.SCHEMA_VERSION}")
    return d


@dataclasses.dataclass(kw_only=True)
class ServingConfig:
    """Per-engine serving knobs (one PICNIC node).

    The field set (and every default) is the former ``EngineConfig``
    — promoting it to a keyword-only, versioned schema is the ISSUE 9
    API consolidation; the semantics of each knob are unchanged and
    documented inline.
    """
    SCHEMA_VERSION: ClassVar[int] = 1

    max_batch: int = 8          # KV-cache slots = max co-resident requests
    queue_limit: int = 256      # admission queue bound (then reject)
    decode_quantum: int = 4     # decode rounds per allowed prefill
    ccpg: bool = False          # cluster power gating (paper §II-E)
    dynamic_ccpg: bool = False  # full ClusterWake latency per iteration
    #                             instead of the folded pre-wake residue
    overlap: float = 0.0        # fraction of decode C2C hidden by compute
    max_iters: int = 2_000_000  # safety valve for the event loop
    # -- paged KV cache (None = capacity unbounded, paging off; the
    #    default path stays byte-identical to timeline_golden.json) -----
    kv_cache: Optional[KVCacheConfig] = None
    # chunked prefill: prompts longer than this are prefilled in chunks
    # of at most this many tokens, one chunk per engine iteration, so a
    # long prompt cannot monopolize an iteration (0 = off)
    chunked_prefill_tokens: int = 0
    # columnar TimelineIR recording (the fast simulation core).  False
    # restores the one-dataclass-per-append reference recorder — both
    # are byte-identical (tests/test_fastpath.py); the toggle exists for
    # the equivalence tests and the microbench before/after measurement.
    columnar_timeline: bool = True
    # aggregate-only TimelineIR recording (the sweep-engine recorder):
    # running sums and counts only, NO event stream — reading
    # `timeline.events` / exporting a trace raises.  Every report-level
    # aggregate stays byte-identical to the other recorders (same float
    # adds in the same order); takes precedence over columnar_timeline.
    aggregate_timeline: bool = False

    def to_dict(self) -> Dict:
        d = {"schema": self.SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "kv_cache" and v is not None:
                v = dataclasses.asdict(v)
            d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "ServingConfig":
        d = _check_schema(cls, d)
        _check_known_keys(cls, d)
        kv = d.get("kv_cache")
        if isinstance(kv, dict):
            _check_known_keys(KVCacheConfig, kv)
            d["kv_cache"] = KVCacheConfig(**kv)
        return cls(**d)


@dataclasses.dataclass(kw_only=True)
class FleetConfig:
    """Fleet-level knobs for `launch.fleet_engine.FleetEngine`: pool shape,
    router policy, KV-handoff pricing and node autoscaling.  Every node
    runs one :class:`ServingConfig` (the ``engine`` block)."""
    SCHEMA_VERSION: ClassVar[int] = 1

    # pool shape.  handoff=True splits the fleet into n_prefill
    # dedicated prefill nodes and n_decode decode nodes with priced KV
    # handoff between them; handoff=False runs n_prefill + n_decode
    # COMBINED nodes (plain data-parallel replication, the
    # disaggregation baseline) — node count is preserved either way so
    # ratio sweeps compare like for like.
    n_prefill: int = 1
    n_decode: int = 1
    handoff: bool = True
    # per-node engine schema (shared by every node of the fleet)
    engine: ServingConfig = dataclasses.field(default_factory=ServingConfig)
    # router backlog bound: requests held (NOT rejected) while every
    # awake prefill node's admission queue is full; beyond this the
    # router itself rejects
    queue_limit: int = 4096
    # SLO-aware admission: reject at the ROUTER when the estimated
    # queue-wait + prefill time of the least-loaded node already blows
    # the request's TTFT deadline (deadline-free requests never reject
    # here; off by default so the degenerate fleet stays bare-engine
    # identical)
    slo_admission: bool = False
    # CCPG-driven node autoscaling: nodes beyond min_awake (per pool)
    # start asleep; the router wakes one — paying the REAL ClusterWake
    # cluster-walk latency on that node's timeline — when every awake
    # node of the pool carries more than scale_up_queue outstanding
    # units of work; drained nodes above min_awake go back to sleep.
    autoscale: bool = False
    min_awake: int = 1
    scale_up_queue: int = 4
    # KV-handoff wire pricing: bytes/token of resident context moved
    # prefill -> decode over the fabric.  None derives the analytic
    # Table-II-style per-token KV footprint from the model config
    # (`runtime.kv_cache.kv_bytes_per_token`, or the paged cache's own
    # bytes_per_token when the engine block carries one).
    handoff_bytes_per_token: Optional[int] = None
    # opt-in measured pricing (launch/collective_capture.py): adds the
    # HLO-measured prefill collective wire bytes per handoff — the
    # resharding traffic of re-establishing the KV on the destination
    # node's chiplets, which the analytic footprint ignores.
    measured_handoff: Optional[MeasuredTraffic] = None
    max_iters: int = 8_000_000  # safety valve over ALL node steps

    @property
    def n_nodes(self) -> int:
        return self.n_prefill + self.n_decode

    def to_dict(self) -> Dict:
        d = {"schema": self.SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "engine":
                v = v.to_dict()
            elif f.name == "measured_handoff" and v is not None:
                v = dataclasses.asdict(v)
            d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "FleetConfig":
        d = _check_schema(cls, d)
        _check_known_keys(cls, d)
        eng = d.get("engine")
        if isinstance(eng, dict):
            d["engine"] = ServingConfig.from_dict(eng)
        mh = d.get("measured_handoff")
        if isinstance(mh, dict):
            _check_known_keys(MeasuredTraffic, mh)
            d["measured_handoff"] = MeasuredTraffic(**mh)
        return cls(**d)
