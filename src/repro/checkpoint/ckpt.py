"""Checkpointing: atomic, manifest-driven pytree save/restore + recovery.

Layout per step:
  <dir>/step_000123/
    manifest.json    — step, tree structure, shapes/dtypes, extras
    arrays.npz       — flat leaves (host-gathered)
    .complete        — commit marker written LAST (atomicity: a crash
                       mid-write leaves no .complete and the checkpoint is
                       ignored by latest_step())

On a multi-host cluster each host writes its own shard file; this
single-host implementation keeps the same manifest/commit protocol so the
restart logic in runtime/fault_tolerance.py is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extras: Optional[Dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {}
    dtypes = []
    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)
        dtypes.append(str(a.dtype))
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            # npz cannot store ml_dtypes; persist the raw bits
            a = a.view(np.uint16)
        arrays[f"a{i}"] = a
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "names": names,
        "shapes": [list(np.shape(a)) for a in arrays.values()],
        "dtypes": dtypes,
        "extras": extras or {},
        "time": time.time(),
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    (tmp / ".complete").touch()
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    best = None
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / ".complete").exists():
            s = int(p.name.split("_")[1])
            best = s if best is None else max(best, s)
    return best


def restore(ckpt_dir: str | Path, tree_like: Any,
            step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like` (shape-checked)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:09d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    data = np.load(d / "arrays.npz")
    leaves = []
    for i in range(len(data.files)):
        a = data[f"a{i}"]
        want = manifest["dtypes"][i]
        if want == "bfloat16" and a.dtype == np.uint16:
            a = a.view(jnp.bfloat16.dtype)
        leaves.append(a)
    names, like_leaves, treedef = _flatten_with_names(tree_like)
    if names != manifest["names"]:
        raise ValueError("checkpoint tree structure mismatch: "
                         f"{set(names) ^ set(manifest['names'])}")
    out = []
    for leaf, like in zip(leaves, like_leaves):
        if hasattr(like, "dtype") and leaf.dtype != like.dtype:
            # jnp handles ml_dtypes (bfloat16) casts that numpy cannot
            leaf = np.asarray(jnp.asarray(leaf).astype(like.dtype))
        out.append(leaf)
    return treedef.unflatten(out), manifest["extras"]


def gc_old(ckpt_dir: str | Path, keep: int = 3):
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_")
                   and (p / ".complete").exists())
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:09d}", ignore_errors=True)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in-flight write;
    back-pressure if the previous write hasn't finished — the standard
    large-scale pattern)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extras: Optional[Dict] = None):
        self.wait()
        # device->host copy happens synchronously (consistent snapshot);
        # disk IO happens on the thread.
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            save(self.ckpt_dir, step, host_tree, extras)
            gc_old(self.ckpt_dir, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
