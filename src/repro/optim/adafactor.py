"""Adafactor (factored second moment, no momentum) — O(params/d) state.

Used for the 400B llama4 config where AdamW's 8 bytes/param of fp32 moments
cannot fit the per-chip HBM budget even fully sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {
        "v": jax.tree_util.tree_map(init, params,
                                    is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, *, lr, b2=0.999, eps=1e-30,
                     weight_decay=0.0, clip_threshold=1.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2t = 1.0 - t ** -0.8

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if _factored(p.shape):
            vr = beta2t * s["vr"] + (1 - beta2t) * jnp.mean(g2, axis=-1)
            vc = beta2t * s["vc"] + (1 - beta2t) * jnp.mean(g2, axis=-2)
            rfac = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None]
            u = g32 * jax.lax.rsqrt(rfac * vc[..., None, :] + eps)
            news = {"vr": vr, "vc": vc}
        else:
            v = beta2t * s["v"] + (1 - beta2t) * g2
            u = g32 * jax.lax.rsqrt(v + eps)
            news = {"v": v}
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        newp = p.astype(jnp.float32) - lr * u
        if weight_decay:
            newp -= lr * weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), news

    is_state_leaf = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = jax.tree_util.tree_leaves(state["v"], is_leaf=is_state_leaf)
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, {"v": new_v, "step": step}
