from .adamw import adamw_init, adamw_update
from .adafactor import adafactor_init, adafactor_update
from .schedule import cosine_schedule, linear_warmup_cosine
from .clip import global_norm, clip_by_global_norm

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "cosine_schedule", "linear_warmup_cosine", "global_norm",
           "clip_by_global_norm"]


def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)
