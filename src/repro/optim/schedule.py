"""LR schedules (pure functions of step)."""
import jax.numpy as jnp


def cosine_schedule(step, *, base_lr, total_steps, final_frac=0.1):
    frac = jnp.clip(step / total_steps, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return base_lr * (final_frac + (1 - final_frac) * cos)


def linear_warmup_cosine(step, *, base_lr, warmup_steps, total_steps,
                         final_frac=0.1):
    warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
    post = cosine_schedule(jnp.maximum(step - warmup_steps, 0),
                           base_lr=base_lr,
                           total_steps=jnp.maximum(total_steps - warmup_steps, 1),
                           final_frac=final_frac)
    return jnp.where(step < warmup_steps, warm, post)
