"""IPCN Instruction Set Architecture (paper §II-B.5, Fig 3(g)).

A unit-router instruction is a 30-bit vector:

    [29:23] rd_en      (7)  — FIFO read-enable, one bit per I/O port
                              (4 planar N/E/S/W + PE-in + 2 TSV)
    [22:19] mode_sel   (4)  — router operation mode (see Mode)
    [18:12] out_en     (7)  — output direction mask (unicast = one bit,
                              broadcast = several; paper supports both)
    [11:10] intxfer_en (2)  — internal movement between FIFOs <-> scratchpad
    [ 9: 0] sp_addr   (10)  — scratchpad row address (32 KB / 32 B rows)

The Network Program Memory stores per row: two commands (CMR) plus a
per-router selection + repeat count (CFR); each router executes CMD1, CMD2
or IDLEs (paper Fig 3(d)).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

PORTS = ("N", "E", "S", "W", "PE", "TSV_UP", "TSV_DN")
N_PORTS = len(PORTS)

RD_EN_BITS = 7
MODE_BITS = 4
OUT_EN_BITS = 7
INTXFER_BITS = 2
SP_ADDR_BITS = 10
TOTAL_BITS = RD_EN_BITS + MODE_BITS + OUT_EN_BITS + INTXFER_BITS + SP_ADDR_BITS
assert TOTAL_BITS == 30


class Mode(enum.IntEnum):
    IDLE = 0
    ROUTE = 1         # move packet from rd ports to out ports
    PSUM = 2          # partial summation of incoming operands
    DMAC = 3          # dynamic-dynamic multiply-accumulate (QK^T, PV)
    LINACT = 4        # linear activation on in-flight data
    SMAC_FIRE = 5     # trigger attached PE crossbar MVM
    SP_LOAD = 6       # scratchpad -> FIFO
    SP_STORE = 7      # FIFO -> scratchpad
    SOFTMAX_FEED = 8  # stream operands up the TSV to the SCU die
    SOFTMAX_DRAIN = 9
    C2C_TX = 10       # hand packet to the optical engine die (TSV down)
    C2C_RX = 11
    MACC_CLR = 12


@dataclass(frozen=True)
class Instr:
    rd_en: int = 0
    mode: Mode = Mode.IDLE
    out_en: int = 0
    intxfer_en: int = 0
    sp_addr: int = 0

    def encode(self) -> int:
        assert 0 <= self.rd_en < (1 << RD_EN_BITS)
        assert 0 <= int(self.mode) < (1 << MODE_BITS)
        assert 0 <= self.out_en < (1 << OUT_EN_BITS)
        assert 0 <= self.intxfer_en < (1 << INTXFER_BITS)
        assert 0 <= self.sp_addr < (1 << SP_ADDR_BITS)
        word = self.rd_en
        word = (word << MODE_BITS) | int(self.mode)
        word = (word << OUT_EN_BITS) | self.out_en
        word = (word << INTXFER_BITS) | self.intxfer_en
        word = (word << SP_ADDR_BITS) | self.sp_addr
        return word

    @staticmethod
    def decode(word: int) -> "Instr":
        assert 0 <= word < (1 << TOTAL_BITS)
        sp_addr = word & ((1 << SP_ADDR_BITS) - 1)
        word >>= SP_ADDR_BITS
        intxfer = word & ((1 << INTXFER_BITS) - 1)
        word >>= INTXFER_BITS
        out_en = word & ((1 << OUT_EN_BITS) - 1)
        word >>= OUT_EN_BITS
        mode = Mode(word & ((1 << MODE_BITS) - 1))
        word >>= MODE_BITS
        rd_en = word
        return Instr(rd_en=rd_en, mode=mode, out_en=out_en,
                     intxfer_en=intxfer, sp_addr=sp_addr)

    def hex(self) -> str:
        return f"{self.encode():08X}"


def port_mask(*names: str) -> int:
    m = 0
    for n in names:
        m |= 1 << PORTS.index(n)
    return m


def unicast(direction: str) -> int:
    return port_mask(direction)


def broadcast(*directions: str) -> int:
    return port_mask(*directions) if directions else (1 << N_PORTS) - 1
