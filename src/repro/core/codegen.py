"""IPCN program generation: mapped layer -> instruction stream -> NPM image.

This closes the paper's toolchain loop (§II-B.5): the API (ProgramBuilder)
and compiler (hex image) exist in program.py; this module is the *code
generator* that turns a spatial mapping (mapping.py) plus a temporal
schedule (scheduling.py) into the actual per-router instruction rows:

  decode-token program for an attention layer =
    1. broadcast x into the W_K|W_Q|W_V column bands (spanning tree)
    2. SMAC fire (crossbars compute k/q/v partial products)
    3. PSUM partial outputs up the tile columns
    4. store K/V rows into the cyclic scratchpad stripe (SP_STORE)
    5. flash inner loop: for each context block, SP_LOAD K stripe,
       DMAC q.k, stream scores up the TSV to the SCU (SOFTMAX_FEED),
       drain probabilities, DMAC p.v accumulate
    6. PSUM attention output into the W_O band, SMAC fire W_O
    7. C2C_TX the layer output to the next chiplet

The emitted program is executable by the cycle model (simulator) and its
row count is the program-memory footprint the NPM double-buffering must
sustain (checked in tests against Bank capacity / refill rate).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from .isa import Instr, Mode, PORTS, broadcast, port_mask, unicast
from .mapping import LayerMapping, Region
from .noc import Mesh2D
from .program import SEL_CMD1, SEL_CMD2, SEL_IDLE, ProgramBuilder
from .partition import ScratchpadPlan


@dataclass
class LayerProgram:
    builder: ProgramBuilder
    rows_per_token: int
    smac_fires: int
    sp_traffic_bytes: int
    c2c_bytes: int

    @property
    def npm_rows(self) -> int:
        return len(self.builder.rows)


def _region_router_ids(mesh: Mesh2D, region: Region) -> List[int]:
    return [mesh.rid(rc) for rc in region.routers]


def emit_attention_decode(mapping: LayerMapping, *, d_model: int,
                          kv_dim: int, context_blocks: int,
                          kv_plan: ScratchpadPlan,
                          block_tokens: int = 64) -> LayerProgram:
    """Generate the per-token decode program for one attention layer."""
    mesh = mapping.mesh
    pb = ProgramBuilder(mesh.n_routers)
    sp_bytes = 0

    wq = mapping.regions["W_Q"]
    wk = mapping.regions["W_K"]
    wv = mapping.regions["W_V"]
    wo = mapping.regions["W_O"]

    qkv_routers = set()
    for r in (wq, wk, wv):
        qkv_routers.update(_region_router_ids(mesh, r))
    wo_routers = set(_region_router_ids(mesh, wo))

    # --- 1. input broadcast into the QKV bands (eastward spanning tree) --
    bcast = Instr(mode=Mode.ROUTE, rd_en=port_mask("W"),
                  out_en=port_mask("E", "PE"))
    sel = {r: SEL_CMD1 for r in qkv_routers}
    rows_in = -(-d_model // mesh.cfg.link_bytes_per_cycle)
    pb.emit(bcast, None, sel, repeat=rows_in)

    # --- 2. crossbars fire --------------------------------------------------
    fire = Instr(mode=Mode.SMAC_FIRE)
    pb.emit(fire, None, {r: SEL_CMD1 for r in qkv_routers},
            repeat=8)  # bit-serial input bits

    # --- 3. partial-output reduction up tile columns ------------------------
    psum = Instr(mode=Mode.PSUM, rd_en=port_mask("S", "PE"),
                 out_en=unicast("N"))
    pb.emit(psum, None, {r: SEL_CMD1 for r in qkv_routers},
            repeat=max(wq.grid.grid[0], 1))

    # --- 4. append K/V rows into the cyclic scratchpad stripe ----------------
    store = Instr(mode=Mode.SP_STORE, rd_en=port_mask("N"),
                  sp_addr=0, intxfer_en=1)
    kv_routers = set(_region_router_ids(mesh, wk)) | \
        set(_region_router_ids(mesh, wv))
    pb.emit(store, None, {r: SEL_CMD1 for r in kv_routers}, repeat=1)
    sp_bytes += 2 * kv_dim

    # --- 5. flash inner loop over context blocks ----------------------------
    load = Instr(mode=Mode.SP_LOAD, sp_addr=0, intxfer_en=2,
                 out_en=unicast("PE"))
    dmac = Instr(mode=Mode.DMAC, rd_en=port_mask("PE", "N"),
                 out_en=port_mask("TSV_UP"))
    feed = Instr(mode=Mode.SOFTMAX_FEED, rd_en=port_mask("PE"),
                 out_en=port_mask("TSV_UP"))
    drain = Instr(mode=Mode.SOFTMAX_DRAIN, rd_en=port_mask("TSV_UP"),
                  out_en=unicast("PE"))
    pv = Instr(mode=Mode.DMAC, rd_en=port_mask("PE"), out_en=unicast("E"))
    kv_sel = {r: SEL_CMD1 for r in kv_routers}
    for _ in range(context_blocks):
        pb.emit(load, dmac, kv_sel, repeat=block_tokens)      # qk^T
        pb.emit(feed, None, kv_sel, repeat=block_tokens)      # scores -> SCU
        pb.emit(drain, pv, kv_sel, repeat=block_tokens)       # p -> p.v
        sp_bytes += block_tokens * kv_dim * 2

    # --- 6. attention output into W_O band, fire, reduce --------------------
    route_o = Instr(mode=Mode.ROUTE, rd_en=port_mask("W"),
                    out_en=port_mask("E", "PE"))
    pb.emit(route_o, None, {r: SEL_CMD1 for r in wo_routers},
            repeat=-(-wq.grid.shape[1] // mesh.cfg.link_bytes_per_cycle))
    pb.emit(fire, None, {r: SEL_CMD1 for r in wo_routers}, repeat=8)
    pb.emit(psum, None, {r: SEL_CMD1 for r in wo_routers},
            repeat=max(wo.grid.grid[0], 1))

    # --- 7. ship the layer output to the next chiplet ------------------------
    tx = Instr(mode=Mode.C2C_TX, rd_en=port_mask("N"),
               out_en=port_mask("TSV_DN"))
    edge = {mesh.rid((r, mesh.cfg.cols - 1)): SEL_CMD1
            for r in range(mesh.cfg.rows)}
    rows_out = -(-d_model // mesh.cfg.link_bytes_per_cycle)
    pb.emit(tx, None, edge, repeat=rows_out)

    return LayerProgram(builder=pb, rows_per_token=len(pb.rows),
                        smac_fires=2, sp_traffic_bytes=sp_bytes,
                        c2c_bytes=d_model)


def emit_ffn(mapping_regions: Dict[str, Region], mesh: Mesh2D,
             in_dim: int) -> LayerProgram:
    """FFN layer: broadcast -> fire -> reduce -> C2C."""
    pb = ProgramBuilder(mesh.n_routers)
    routers = set()
    for r in mapping_regions.values():
        routers.update(_region_router_ids(mesh, r))
    sel = {r: SEL_CMD1 for r in routers}
    pb.emit(Instr(mode=Mode.ROUTE, rd_en=port_mask("W"),
                  out_en=port_mask("E", "PE")), None, sel,
            repeat=-(-in_dim // mesh.cfg.link_bytes_per_cycle))
    pb.emit(Instr(mode=Mode.SMAC_FIRE), None, sel, repeat=8)
    pb.emit(Instr(mode=Mode.PSUM, rd_en=port_mask("S", "PE"),
                  out_en=unicast("N")), None, sel, repeat=4)
    edge = {mesh.rid((r, mesh.cfg.cols - 1)): SEL_CMD1
            for r in range(mesh.cfg.rows)}
    pb.emit(Instr(mode=Mode.C2C_TX, rd_en=port_mask("N"),
                  out_en=port_mask("TSV_DN")), None, edge, repeat=4)
    return LayerProgram(builder=pb, rows_per_token=len(pb.rows),
                        smac_fires=1, sp_traffic_bytes=0, c2c_bytes=in_dim)
