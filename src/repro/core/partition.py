"""Partitioning of static weights and dynamic tensors (paper §III-1).

Static matrices (W_Q/K/V/O, FFN) are tiled to the 256x256 PE crossbar
capacity along both row and column dimensions; dynamic tensors (Q/K/V/S)
are tiled to the 32 KB scratchpads.  Partitioning the weights induces the
collective pattern (input broadcast along rows of tiles, partial-output
reduction along columns of tiles) that `scheduling.py` turns into
spanning-tree traffic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class PEArraySpec:
    rows: int = 256
    cols: int = 256
    bits_per_cell: int = 8          # RRAM conductance levels (weight slice)
    weight_bits: int = 8            # one cell per weight at 8-bit inference

    @property
    def weights_per_array(self) -> int:
        return self.rows * self.cols


@dataclass(frozen=True)
class TileGrid:
    """A weight matrix partitioned into an r x c grid of PE arrays."""
    name: str
    shape: Tuple[int, int]          # logical (in_dim, out_dim)
    grid: Tuple[int, int]           # tiles along (rows, cols)
    pe: PEArraySpec

    @property
    def n_tiles(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def utilization(self) -> float:
        used = self.shape[0] * self.shape[1]
        return used / (self.n_tiles * self.pe.weights_per_array)

    def tile_shape(self, i: int, j: int) -> Tuple[int, int]:
        r = min(self.pe.rows, self.shape[0] - i * self.pe.rows)
        c = min(self.pe.cols, self.shape[1] - j * self.pe.cols)
        return (r, c)


def partition_matrix(name: str, in_dim: int, out_dim: int,
                     pe: PEArraySpec = PEArraySpec()) -> TileGrid:
    grid = (-(-in_dim // pe.rows), -(-out_dim // pe.cols))
    return TileGrid(name=name, shape=(in_dim, out_dim), grid=grid, pe=pe)


def attention_grids(d_model: int, q_dim: int, kv_dim: int,
                    pe: PEArraySpec = PEArraySpec()) -> List[TileGrid]:
    return [
        partition_matrix("W_Q", d_model, q_dim, pe),
        partition_matrix("W_K", d_model, kv_dim, pe),
        partition_matrix("W_V", d_model, kv_dim, pe),
        partition_matrix("W_O", q_dim, d_model, pe),
    ]


def ffn_grids(d_model: int, d_ff: int, gated: bool = True,
              pe: PEArraySpec = PEArraySpec()) -> List[TileGrid]:
    grids = [partition_matrix("W_gate", d_model, d_ff, pe),
             partition_matrix("W_up", d_model, d_ff, pe)]
    if not gated:
        grids = grids[:1]
    grids.append(partition_matrix("W_down", d_ff, d_model, pe))
    return grids


@dataclass(frozen=True)
class ScratchpadPlan:
    """Dynamic tensor striped across scratchpads (paper: cyclic KV store)."""
    name: str
    elem_bytes: int
    row_elems: int                  # elements per (token) row
    n_pads: int                     # scratchpads allocated
    pad_bytes: int = 32 * 1024

    @property
    def rows_capacity(self) -> int:
        """Token rows storable across the allocated pads."""
        per_pad = self.pad_bytes // (self.row_elems * self.elem_bytes)
        return per_pad * self.n_pads

    def pad_of_token(self, t: int) -> int:
        """Cyclic striping: token t lives in pad t mod n_pads — balanced
        utilization regardless of sequence length (paper §III 'KV cache')."""
        return t % self.n_pads


def plan_kv_cache(kv_dim: int, n_pads: int, elem_bytes: int = 1,
                  pad_bytes: int = 32 * 1024) -> ScratchpadPlan:
    return ScratchpadPlan("KV", elem_bytes, kv_dim, n_pads, pad_bytes)
