"""Chip-to-chip interconnect energy/latency: silicon photonics vs
electrical (paper §II-D, §IV-C, Fig 9/10).

The optical engine die carries a laser source, microring modulators,
switching elements and photodetectors; the model reduces this to an
energy-per-bit + static laser bias + serialization bandwidth, which is the
level the paper evaluates at (average C2C power for a traffic trace).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from .energy import E_DRAM_ACCESS, E_ELECTRICAL_C2C, E_OPTICAL_C2C


@dataclass(frozen=True)
class LinkSpec:
    kind: str                 # "optical" | "electrical"
    energy_per_bit: float     # J/bit
    bandwidth_Bps: float      # bytes/s
    static_watts: float = 0.0


# laser bias is shared across the pod's links (comb source) -> small
# per-link static allocation [15]
OPTICAL = LinkSpec("optical", E_OPTICAL_C2C, 64e9, static_watts=0.002)
ELECTRICAL = LinkSpec("electrical", E_ELECTRICAL_C2C, 16e9)


def c2c_average_power(bytes_per_second: float, link: LinkSpec,
                      duty: float | None = None) -> float:
    """Average power at a given traffic rate.  ``duty`` is the fraction of
    time the link is active; C2C traffic is bursty (Fig 10: <1% link
    utilization) and the laser/modulator bias is gated between bursts, so
    the static term is duty-cycled.  duty=None derives it from the rate."""
    if duty is None:
        duty = min(1.0, bytes_per_second / link.bandwidth_Bps)
    return bytes_per_second * 8 * link.energy_per_bit \
        + link.static_watts * duty


def c2c_transfer_time(payload_bytes: int, link: LinkSpec) -> float:
    return payload_bytes / link.bandwidth_Bps


def dram_access_power(bytes_per_second: float) -> float:
    return bytes_per_second * 8 * E_DRAM_ACCESS


def retransmit_overhead_bytes(payload_bytes: int,
                              retransmit_frac: float) -> int:
    """Extra wire bytes a degraded link re-sends for one transfer.

    When ring-resonator thermal drift pushes the BER past the FEC
    budget, a ``retransmit_frac`` fraction of the payload fails FEC and
    is re-transmitted (launch/config.LinkFault window).  The overhead
    rides the same :class:`LinkSpec` as the payload — priced on the
    timeline as ``C2CTransfer(phase="retransmit")`` with duration
    ``c2c_transfer_time(overhead, link)`` — so a degraded window slows
    *and* burns energy exactly in proportion to the traffic it carries.
    """
    if retransmit_frac <= 0.0:
        return 0
    return int(int(payload_bytes) * retransmit_frac)


def fleet_handoff_bytes(context_tokens: int, bytes_per_token: int,
                        measured: "Optional[MeasuredTraffic]" = None
                        ) -> int:
    """Wire bytes for ONE prefill -> decode KV handoff across the
    inter-node fabric (launch/fleet_engine.py).

    Analytic Table-II-style default: the KV footprint of the resident
    context (``context_tokens * bytes_per_token``).  With ``measured``
    (HLO-captured traffic, see :class:`MeasuredTraffic`) the sharded
    re-establishment cost is charged on top — re-admitting the KV on the
    destination node's chiplets replays the prefill's measured
    collective wire bytes, traffic the analytic footprint ignores."""
    nbytes = int(context_tokens) * int(bytes_per_token)
    if measured is not None:
        nbytes += int(measured.prefill_bytes)
    return nbytes


@dataclass(frozen=True)
class MeasuredTraffic:
    """Photonic-link traffic measured from compiled (SPMD-partitioned) HLO.

    Produced by ``launch/collective_capture.py``: the TP×SP×PP cells are
    lowered, ``hlo_cost.analyze`` extracts per-collective ring-model wire
    bytes, and the totals land here — the measured replacement for the
    cycle model's analytic layer-boundary C2C estimate (the same
    measured-traffic methodology as Photonic Fabric, arXiv:2507.14000).

    ``prefill_bytes``: total link bytes for one prefill of the prompt.
    ``decode_bytes_per_token``: total link bytes per generated token
    (one sharded decode step, normalized per request).
    ``per_collective``: op -> {count, bytes, wire_bytes} per chip per step,
    as reported by ``hlo_cost.Cost.coll`` — kept for reporting.
    """
    prefill_bytes: float
    decode_bytes_per_token: float
    per_collective: Mapping[str, Mapping[str, float]] = \
        field(default_factory=dict)
    n_devices: int = 1
    source: str = "hlo"


@dataclass
class TrafficTrace:
    """(t_start_s, duration_s, bytes) C2C burst events — Fig 10."""
    events: List[Tuple[float, float, int]]

    @classmethod
    def from_timeline(cls, timeline) -> "TrafficTrace":
        """Build the Fig-10 burst trace from a TimelineIR event stream
        (core/timeline.Timeline): every C2CTransfer event becomes one
        burst.  Duck-typed on ``nbytes`` to keep interconnect free of a
        timeline import."""
        events = [(e.t0, e.dur_s, e.nbytes) for e in timeline.events
                  if hasattr(e, "nbytes")]
        return cls(events)

    def average_power(self, link: LinkSpec, horizon_s: float) -> float:
        total_bits = sum(b for _, _, b in self.events) * 8
        return total_bits * link.energy_per_bit / horizon_s + link.static_watts

    def utilization(self, horizon_s: float) -> float:
        busy = sum(d for _, d, _ in self.events)
        return busy / horizon_s

    def binned(self, horizon_s: float, n_bins: int = 100) -> List[float]:
        """Average C2C bandwidth per bin (GB/s) — the Fig 10 timeline."""
        bins = [0.0] * n_bins
        dt = horizon_s / n_bins
        for t, d, b in self.events:
            i = min(int(t / dt), n_bins - 1)
            bins[i] += b
        return [b / dt / 1e9 for b in bins]
