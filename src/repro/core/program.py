"""Network Program Memory (NPM), assembler and "compiler" (paper §II-B.1-3).

NPM layout per the paper: banks B1 and B2, each holding rows of
  CMR  — two 30-bit commands (CMD1, CMD2)
  CFR  — per-router 2-bit command select (IDLE/CMD1/CMD2) + repeat count
plus a CSR bank.  A configuration co-processor refills the bank the
Network Main Controller is NOT currently draining (double buffering), so
the mesh never idles waiting for program words.

The Python "API + compiler" the paper describes (§II-B.5, toolchain) is
modeled by :class:`ProgramBuilder` (API) and :func:`compile_to_hex`
(compiler emitting the NPM hex image).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Sequence, Tuple

from .isa import Instr, Mode

SEL_IDLE, SEL_CMD1, SEL_CMD2 = 0, 1, 2


@dataclass
class NPMRow:
    cmd1: Instr
    cmd2: Instr
    select: Dict[int, int]        # router-id -> SEL_*  (absent -> IDLE)
    repeat: int = 1

    def hex_words(self, n_routers: int) -> List[str]:
        words = [self.cmd1.hex(), self.cmd2.hex(), f"{self.repeat:08X}"]
        # pack 2-bit selects, 16 per 32-bit word
        packed, cur, nbits = [], 0, 0
        for r in range(n_routers):
            cur |= (self.select.get(r, SEL_IDLE) & 0x3) << nbits
            nbits += 2
            if nbits == 32:
                packed.append(f"{cur:08X}")
                cur, nbits = 0, 0
        if nbits:
            packed.append(f"{cur:08X}")
        return words + packed


@dataclass
class Bank:
    rows: List[NPMRow] = field(default_factory=list)
    CAPACITY = 256                # rows per bank

    def full(self) -> bool:
        return len(self.rows) >= self.CAPACITY


class ProgramBuilder:
    """The user-facing API: emit rows; the builder splits the stream into
    alternating banks exactly as the co-processor would load them."""

    def __init__(self, n_routers: int):
        self.n_routers = n_routers
        self.rows: List[NPMRow] = []

    def emit(self, cmd1: Instr, cmd2: Instr | None = None,
             select: Dict[int, int] | None = None, repeat: int = 1):
        self.rows.append(NPMRow(cmd1, cmd2 or Instr(), select or {}, repeat))
        return self

    def all_do(self, cmd: Instr, repeat: int = 1):
        sel = {r: SEL_CMD1 for r in range(self.n_routers)}
        return self.emit(cmd, None, sel, repeat)

    def split_banks(self) -> List[Bank]:
        banks, cur = [], Bank()
        for row in self.rows:
            if cur.full():
                banks.append(cur)
                cur = Bank()
            cur.rows.append(row)
        banks.append(cur)
        return banks

    def total_cycles(self) -> int:
        return sum(r.repeat for r in self.rows)


def compile_to_hex(prog: ProgramBuilder) -> str:
    """The 'program compiler' producing the hex file loaded into the NPM."""
    lines = []
    for b_idx, bank in enumerate(prog.split_banks()):
        lines.append(f"@BANK{b_idx % 2 + 1}_{b_idx // 2:04X}")
        for row in bank.rows:
            lines.extend(row.hex_words(prog.n_routers))
    return "\n".join(lines) + "\n"


def parse_hex(text: str, n_routers: int) -> List[Tuple[str, List[str]]]:
    """Inverse of compile_to_hex for round-trip tests: returns
    (bank-label, words) sections."""
    sections: List[Tuple[str, List[str]]] = []
    cur: List[str] = []
    label = None
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("@"):
            if label is not None:
                sections.append((label, cur))
            label, cur = line[1:], []
        else:
            cur.append(line)
    if label is not None:
        sections.append((label, cur))
    return sections


class DoubleBufferedNPM:
    """Runtime model of B1/B2 interleaved configure/drain (paper §II-B.2).

    ``run()`` yields (cycle, row) while accounting for co-processor refill
    latency: if refilling a bank takes longer than draining the other, the
    NMC stalls — the model exposes those stall cycles (they should be ~0
    with the paper's sizing, which tests assert).
    """

    def __init__(self, banks: Sequence[Bank], refill_cycles_per_row: int = 2):
        self.banks = list(banks)
        self.refill_per_row = refill_cycles_per_row
        self.stall_cycles = 0

    def run(self) -> Iterator[Tuple[int, NPMRow]]:
        cycle = 0
        # bank 0 is pre-loaded at boot; refill of bank i+1 starts when
        # drain of bank i starts.
        refill_ready_at = 0
        for i, bank in enumerate(self.banks):
            if cycle < refill_ready_at:
                self.stall_cycles += refill_ready_at - cycle
                cycle = refill_ready_at
            if i + 1 < len(self.banks):
                refill_ready_at = cycle + \
                    self.refill_per_row * len(self.banks[i + 1].rows)
            for row in bank.rows:
                yield cycle, row
                cycle += row.repeat
