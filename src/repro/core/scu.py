"""Softmax Compute Unit (paper §II-C, Fig 4).

Exponential via EIGHT-segment piecewise-linear approximation; a 3-state
FSM: (1) stream inputs, compute exp into the indexed cache while a partial
adder accumulates the denominator; (2) reciprocal of the sum; (3) multiply
cached numerators by the reciprocal, streaming results out.  States 2/3
ping-pong for continuous output.

``pwl_exp`` here is the NUMERICAL REFERENCE shared with the Pallas kernel
(repro/kernels/pwl_softmax.py validates against this + jnp.exp).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

# 8 segments over [-8, 0] (softmax inputs are max-subtracted, so x <= 0).
N_SEGMENTS = 8
X_MIN, X_MAX = -8.0, 0.0
_edges = np.linspace(X_MIN, X_MAX, N_SEGMENTS + 1)


def _segment_coeffs() -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Least-max-error linear fit per segment: secant line through segment
    endpoints (max error interior, halved by midpoint offset)."""
    x0, x1 = _edges[:-1], _edges[1:]
    y0, y1 = np.exp(x0), np.exp(x1)
    slope = (y1 - y0) / (x1 - x0)
    # secant overestimates nowhere/underestimates: shift by half the max gap
    xm = (x0 + x1) / 2
    gap = np.exp(xm) - (y0 + slope * (xm - x0))
    intercept = y0 - slope * x0 + gap / 2
    return _edges.copy(), slope, intercept


SEG_EDGES, SEG_SLOPE, SEG_INTERCEPT = _segment_coeffs()


def pwl_exp(x: np.ndarray) -> np.ndarray:
    """8-segment PWL exp for x <= 0 (clamped below at X_MIN -> ~0)."""
    x = np.asarray(x, np.float32)
    xc = np.clip(x, X_MIN, X_MAX)
    idx = np.clip(((xc - X_MIN) / (X_MAX - X_MIN) * N_SEGMENTS).astype(int),
                  0, N_SEGMENTS - 1)
    y = SEG_SLOPE[idx] * xc + SEG_INTERCEPT[idx]
    return np.where(x < X_MIN, 0.0, y).astype(np.float32)


def pwl_softmax(x: np.ndarray, axis: int = -1) -> np.ndarray:
    m = np.max(x, axis=axis, keepdims=True)
    e = pwl_exp(np.asarray(x - m, np.float32))
    return e / np.maximum(e.sum(axis=axis, keepdims=True), 1e-30)


def max_pwl_exp_error() -> float:
    xs = np.linspace(X_MIN, X_MAX, 20001)
    return float(np.max(np.abs(pwl_exp(xs) - np.exp(xs))))


@dataclass
class SCUTiming:
    """Cycle model of the 3-state FSM."""
    pipeline_fill: int = 4          # exp PWL + adder latency
    recip_cycles: int = 12          # iterative reciprocal
    mult_cycles: int = 1

    def softmax_cycles(self, n: int) -> int:
        """One softmax over n streamed inputs, one element/cycle."""
        s1 = n + self.pipeline_fill          # stream + exp + accumulate
        s2 = self.recip_cycles               # reciprocal of denominator
        s3 = n * self.mult_cycles            # scale cached numerators
        return s1 + s2 + s3

    def throughput_softmax_cycles(self, n: int) -> int:
        """Steady state: states 2/3 overlap the next row's state 1."""
        return max(n + self.pipeline_fill, self.recip_cycles + n)


class SCUFsm:
    """Cycle-stepped behavioural model (for the unit test vs pwl_softmax)."""
    def __init__(self, timing: SCUTiming = SCUTiming()):
        self.timing = timing

    def run(self, row: np.ndarray) -> Tuple[np.ndarray, int]:
        row = np.asarray(row, np.float32)
        m = row.max()
        cache = pwl_exp(row - m)                 # state 1: indexed cache
        denom = cache.sum()                      # partial-sum adder
        recip = np.float32(1.0) / np.float32(denom)   # state 2
        out = cache * recip                      # state 3
        return out, self.timing.softmax_cycles(row.size)
