"""Chiplet Clustering and Power Gating — CCPG (paper §II-E, Fig 5).

Four adjacent compute-tile chiplets form a cluster.  During runtime only
ONE cluster is fully activated; every other cluster keeps only its
scratchpad modules powered (context-window / KV retention) while all other
macros sleep.  RRAM weights are unaffected (non-volatile).

The model exposes system power with/without CCPG and the wake-up overhead
that makes throughput "similar" rather than identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

from .energy import TileSpec
from .scheduling import ChipletAllocation

CLUSTER_SIZE = 4


@dataclass
class CCPGModel:
    tile: TileSpec = field(default_factory=TileSpec)
    wake_cycles: int = 1000          # cluster power-up (regulator settle)
    dram_hub_watts: float = 0.25     # DRAM hub + IO (external comms, §II)
    optical_base_watts: float = 0.05  # laser bias per active link
    # Table II excludes the DRAM hub (weights live in RRAM, embeddings are
    # streamed once); opt in when modelling the full package
    include_dram_hub: bool = False

    def system_power(self, n_chiplets: int, *, ccpg: bool) -> float:
        if not ccpg:
            p = n_chiplets * self.tile.tile_power_active
        else:
            n_sleep = max(0, n_chiplets - CLUSTER_SIZE)
            n_active = min(n_chiplets, CLUSTER_SIZE)
            p = (n_active * self.tile.tile_power_active
                 + n_sleep * self.tile.tile_power_sleep)
        if self.include_dram_hub:
            p += self.dram_hub_watts
        return p

    def power_saving_frac(self, n_chiplets: int) -> float:
        p0 = self.system_power(n_chiplets, ccpg=False)
        if p0 <= 0.0:
            return 0.0               # nothing to gate on an empty system
        p1 = self.system_power(n_chiplets, ccpg=True)
        return 1.0 - p1 / p0

    def wake_overhead_cycles(self, alloc: ChipletAllocation) -> int:
        """Per decode token: each cluster transition wakes the next cluster.
        Wake-up is overlapped with the previous cluster's tail compute
        (pre-wake one cluster ahead), leaving a small exposed residue.
        (Cheap arithmetic on purpose — the serving engine snapshots the
        residue once per run rather than calling this per iteration.)"""
        n_transitions = max(0, alloc.n_clusters - 1)
        exposed = max(0, self.wake_cycles - 2000)   # pre-wake hides ~2us
        return n_transitions * exposed + n_transitions * 16  # ctrl overhead

    def wake_latency_cycles(self, alloc: ChipletAllocation) -> int:
        """Dynamic mode: the FULL regulator-settle latency (`wake_cycles`)
        is exposed on every cluster transition — no pre-wake overlap.
        This is what the timeline layer emits as real `ClusterWake`
        events; the static path above keeps only the folded-in residue,
        which leaves `wake_cycles` dead state at its default value."""
        n_transitions = max(0, alloc.n_clusters - 1)
        return n_transitions * (self.wake_cycles + 16)  # settle + ctrl

    def wake_overhead_cycles_batched(self, alloc: ChipletAllocation,
                                     batch_size: int) -> int:
        """Cluster residency is shared by a co-scheduled batch: one engine
        iteration walks the cluster sequence ONCE (all requests ride the
        same activation wave through the active cluster), so the wake
        residue is charged per iteration — not per request.  This is the
        reason batching improves tokens/J *more* with CCPG than without."""
        if batch_size <= 0:
            return 0
        return self.wake_overhead_cycles(alloc)

    def idle_power(self, n_chiplets: int, *, ccpg: bool) -> float:
        """Power while NO request is in flight.  With CCPG every cluster
        sleeps (scratchpads retain KV; RRAM weights are non-volatile);
        without it the chiplets have no gating path and burn active power.
        """
        if ccpg:
            p = n_chiplets * self.tile.tile_power_sleep
            if self.include_dram_hub:
                p += self.dram_hub_watts   # the hub has no gating path
            return p
        return self.system_power(n_chiplets, ccpg=False)

    def scaling_table(self, chiplet_counts: List[int]) -> List[dict]:
        rows = []
        for n in chiplet_counts:
            rows.append({
                "chiplets": n,
                "power_no_ccpg_W": self.system_power(n, ccpg=False),
                "power_ccpg_W": self.system_power(n, ccpg=True),
                "saving_%": 100 * self.power_saving_frac(n),
            })
        return rows
