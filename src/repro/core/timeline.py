"""Unified event-timeline simulation core — TimelineIR.

Every cost producer in the repo appends typed events to ONE `Timeline`:

  * ``PicnicSimulator.run``            — analytic prefill/decode/C2C spans
    (or `MeasuredTraffic`-sourced C2C transfers),
  * ``ContinuousBatchingEngine``       — per-round prefill/decode spans,
    idle (`ClusterSleep`) gaps, per-token `TokenEmit`s,
  * ``CCPGModel`` (dynamic mode)       — real `ClusterWake` latency on
    cluster transitions instead of a folded-in residue constant.

Every consumer derives its numbers from the same event stream:
`InferenceResult` (cycle/byte sums), `ServingReport` (percentiles,
tok/s, tok/J via the span-integrated energy), and the Chrome-trace
exporter (`chrome://tracing` / Perfetto JSON).

Energy is INTEGRATED over spans — ``sum(duration * power)`` in append
order — instead of multiplying one average power by the wall clock.
The paper's CCPG and interconnect claims are time-resolved effects
(cluster wake-up, bursty C2C, idle retention) that average-power models
cannot show; see PAPERS.md on CIM power-gating surveys.

Cursor semantics: *advancing* appends (`compute` / `wake` / `sleep`)
move ``now`` and integrate energy; *concurrent* appends (`c2c` /
`token` / `sample`, or any append with ``advance=False``) annotate the
stream at a given instant without advancing time — C2C bursts overlap
compute, token emits are instantaneous.

Recording modes
---------------
``columnar=True`` (the default, the fast simulation core) stores each
event class as growable parallel columns of scalars — no per-event
Python object is built on the hot append path, and the existing
dataclass events are materialized **lazily** (and cached) only when a
consumer actually reads ``timeline.events`` (golden-file comparisons,
``TrafficTrace.from_timeline``).  ``columnar=False`` keeps the original
one-dataclass-per-append recorder; both modes run the same float
arithmetic in the same order, so they are byte-identical — locked by
tests/test_fastpath.py.

``aggregate_only=True`` goes one step further: NO event stream at all —
only the running per-(class, kind) sums and per-class counts that the
columnar recorder already maintains internally.  Same accumulator
arithmetic in the same append order (so every derived aggregate stays
bit-identical to the other modes), but reading ``events`` / ``column``
/ the trace exporters raises.  This is the sweep-engine recorder: a
grid of N cells keeps N aggregate-only timelines, mirrored into one
cell-major :class:`SweepAggregates` array block while the vectorized
round loop advances all cells at once.

Aggregate queries (`cycles()` / `span_seconds()` / `count()` /
`total_energy_J()`) read running per-(class, kind) sums maintained on
append — O(1) instead of an O(E) event scan — in ALL modes.
"""
from __future__ import annotations

import json
from collections import defaultdict
from dataclasses import dataclass
from typing import (Dict, Iterator, List, Optional, Sequence, Tuple, Type,
                    Union)

import numpy as np

from .interconnect import LinkSpec, OPTICAL, c2c_average_power


# ---------------------------------------------------------------------------
# Event taxonomy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ComputeSpan:
    """A busy span of the active cluster(s): one prefill, one (batched)
    decode iteration, or one sampled chunk of the analytic decode walk."""
    t0: float
    dur_s: float
    kind: str                # "prefill" | "decode"
    power_W: float = 0.0
    cycles: int = 0          # exact cycle count (ints sum losslessly)
    batch: int = 1           # co-scheduled requests riding this span
    name: str = ""


@dataclass(frozen=True)
class C2CTransfer:
    """Photonic/electrical chip-to-chip burst.  Concurrent with compute
    (the link runs under the compute wave unless `overlap` < 1 exposes
    part of it — the exposed part is inside the owning ComputeSpan)."""
    t0: float
    dur_s: float
    nbytes: int
    phase: str = ""          # "prefill" | "decode"
    source: str = "analytic"  # "analytic" | MeasuredTraffic.source


@dataclass(frozen=True)
class ClusterWake:
    """Exposed cluster power-up latency (CCPG).  Static mode folds the
    pre-wake residue into decode cycles; dynamic mode emits the full
    regulator-settle walk as real timeline latency."""
    t0: float
    dur_s: float
    cycles: int = 0
    cluster: int = -1        # -1: aggregate walk over all transitions


@dataclass(frozen=True)
class ClusterSleep:
    """Idle/retention span: scratchpads only (CCPG) or full active burn
    (no gating path).  ``advance=False`` appends mark background sleepers
    concurrent with compute (their power is already inside the span's
    aggregate) and carry no energy of their own."""
    t0: float
    dur_s: float
    power_W: float = 0.0


@dataclass(frozen=True)
class EnergySample:
    """Instantaneous power sample (W) — the Fig-8-style power trace.
    Emitted automatically at every advancing span start; contributes no
    energy (spans carry the integral)."""
    t0: float
    power_W: float


@dataclass(frozen=True)
class TokenEmit:
    """``n`` tokens produced at instant ``t0`` (request_id -1: aggregate
    analytic walk, otherwise the serving engine's per-request emits)."""
    t0: float
    n: int = 1
    request_id: int = -1


@dataclass(frozen=True)
class NodeFail:
    """A fleet node dies at instant ``t0`` holding its in-flight KV
    (fault injection, launch/config.FaultConfig).  Appended to the
    failing node's own timeline; the router's recovery actions
    (re-routes, recompute prefills, retransmits) land on the survivors'
    timelines as ordinary spans/transfers."""
    t0: float
    node: int = -1


@dataclass(frozen=True)
class NodeRecover:
    """The node rejoins the fleet at ``t0`` after ``downtime_s`` of
    being dead (its timeline is padded with a zero-power sleep over the
    gap — a dead node burns nothing)."""
    t0: float
    node: int = -1
    downtime_s: float = 0.0


Event = Union[ComputeSpan, C2CTransfer, ClusterWake, ClusterSleep,
              EnergySample, TokenEmit, NodeFail, NodeRecover]

# The core categories every full trace contains; the fault kinds only
# appear when fault injection is on, so they live in their own tuple
# (trace-completeness checks iterate EVENT_CATEGORIES).
EVENT_CATEGORIES: Tuple[Type, ...] = (
    ComputeSpan, C2CTransfer, ClusterWake, ClusterSleep, EnergySample,
    TokenEmit)
FAULT_EVENT_CATEGORIES: Tuple[Type, ...] = (NodeFail, NodeRecover)
ALL_EVENT_CATEGORIES: Tuple[Type, ...] = \
    EVENT_CATEGORIES + FAULT_EVENT_CATEGORIES

# columnar class ids, in ALL_EVENT_CATEGORIES order
_COMPUTE, _C2C, _WAKE, _SLEEP, _SAMPLE, _TOKEN, _FAIL, _RECOVER = range(8)


# ---------------------------------------------------------------------------
# Accumulator
# ---------------------------------------------------------------------------

class Timeline:
    """Append-only event stream with a time cursor and running integrals.

    The integrals (`energy_J`, `busy_s`, `idle_s`, `occupancy_s`) are
    accumulated in append order with one multiply-add per span, so a
    producer that previously charged ``energy += dt * power`` inline
    reproduces its floats bit-for-bit by appending the same spans in the
    same order.  The same holds for the per-(class, kind) cycle / span /
    count aggregates behind `cycles()` / `span_seconds()` / `count()`.
    """

    def __init__(self, link: LinkSpec = OPTICAL, *, columnar: bool = True,
                 aggregate_only: bool = False):
        self.link = link
        self.columnar = columnar
        self.aggregate_only = aggregate_only
        self.now = 0.0
        self.energy_J = 0.0        # span-integrated chip energy
        self.busy_s = 0.0
        self.idle_s = 0.0
        self.c2c_bytes = 0
        self.tokens = 0
        self.occupancy_s = 0.0     # integral of batch occupancy over busy
        # running aggregates behind the O(1) derived queries; float sums
        # run in append order, exactly as the old O(E) scans did
        self._cycles: Dict[Tuple[str, Optional[str]], int] = \
            defaultdict(int)
        self._span_s: Dict[Tuple[str, Optional[str]], float] = \
            defaultdict(float)
        if aggregate_only:
            self._counts = [0] * 8             # per-class append counts
        elif columnar:
            # per-class parallel columns + one global class-id sequence;
            # dataclass events are materialized lazily from these
            self._seq: List[int] = []
            self._cols: Tuple[Tuple[list, ...], ...] = tuple(
                tuple([] for _ in range(n))
                for n in (7, 5, 4, 3, 2, 3, 2, 3))
            self._mat: List[Event] = []        # lazy materialization cache
            self._cursors = [0] * 8            # per-class materialize pos
        else:
            self._events: List[Event] = []

    # -- advancing producers ------------------------------------------
    def compute(self, dur_s: float, *, kind: str, power_W: float = 0.0,
                cycles: int = 0, batch: int = 1, name: str = "") -> float:
        now = self.now
        if self.aggregate_only:
            cnt = self._counts
            cnt[_COMPUTE] += 1
            cnt[_SAMPLE] += 1
        elif self.columnar:
            seq = self._seq
            seq.append(_COMPUTE)
            c = self._cols[_COMPUTE]
            c[0].append(now)
            c[1].append(dur_s)
            c[2].append(kind)
            c[3].append(power_W)
            c[4].append(cycles)
            c[5].append(batch)
            c[6].append(name)
            seq.append(_SAMPLE)               # auto power sample (inline)
            c = self._cols[_SAMPLE]
            c[0].append(now)
            c[1].append(power_W)
        else:
            self._events.append(ComputeSpan(now, dur_s, kind, power_W,
                                            cycles, batch, name))
            self._events.append(EnergySample(now, power_W))
        span = self._span_s
        span["ComputeSpan", None] += dur_s
        span["ComputeSpan", kind] += dur_s
        if cycles:
            cyc = self._cycles
            cyc["ComputeSpan", None] += cycles
            cyc["ComputeSpan", kind] += cycles
        self.busy_s += dur_s
        self.energy_J += dur_s * power_W
        self.occupancy_s += dur_s * batch
        self.now = now + dur_s
        return self.now

    def wake(self, dur_s: float, *, power_W: float = 0.0, cycles: int = 0,
             cluster: int = -1) -> float:
        now = self.now
        if self.aggregate_only:
            cnt = self._counts
            cnt[_WAKE] += 1
            cnt[_SAMPLE] += 1
        elif self.columnar:
            seq = self._seq
            seq.append(_WAKE)
            c = self._cols[_WAKE]
            c[0].append(now)
            c[1].append(dur_s)
            c[2].append(cycles)
            c[3].append(cluster)
            seq.append(_SAMPLE)
            c = self._cols[_SAMPLE]
            c[0].append(now)
            c[1].append(power_W)
        else:
            self._events.append(ClusterWake(now, dur_s, cycles, cluster))
            self._events.append(EnergySample(now, power_W))
        self._span_s["ClusterWake", None] += dur_s
        if cycles:
            self._cycles["ClusterWake", None] += cycles
        self.busy_s += dur_s
        self.energy_J += dur_s * power_W
        self.now = now + dur_s
        return self.now

    def sleep(self, dur_s: float, *, power_W: float = 0.0,
              t0: Optional[float] = None, advance: bool = True) -> float:
        at = self.now if t0 is None else t0
        if self.aggregate_only:
            self._counts[_SLEEP] += 1
        elif self.columnar:
            self._seq.append(_SLEEP)
            c = self._cols[_SLEEP]
            c[0].append(at)
            c[1].append(dur_s)
            c[2].append(power_W)
        else:
            self._events.append(ClusterSleep(at, dur_s, power_W))
        self._span_s["ClusterSleep", None] += dur_s
        if advance:
            if self.aggregate_only:
                self._counts[_SAMPLE] += 1
            elif self.columnar:
                self._seq.append(_SAMPLE)
                c = self._cols[_SAMPLE]
                c[0].append(at)
                c[1].append(power_W)
            else:
                self._events.append(EnergySample(at, power_W))
            self.idle_s += dur_s
            self.energy_J += dur_s * power_W
            self.now += dur_s
        return self.now

    # -- concurrent annotations ---------------------------------------
    def c2c(self, nbytes: int, *, dur_s: float = 0.0, phase: str = "",
            t0: Optional[float] = None, source: str = "analytic",
            advance: bool = False, power_W: float = 0.0) -> None:
        """``advance=True`` serializes the burst (cursor moves past it) —
        the Fig-10 layer-boundary handoff view; the default treats it as
        concurrent with the surrounding compute (any exposed transfer
        time is already inside the owning ComputeSpan's cycles).
        ``power_W`` charges chip power over an *advancing* burst (the
        chiplets do not stop burning while stalled on a remote KV read);
        concurrent bursts carry no energy of their own."""
        nbytes = int(nbytes)
        at = self.now if t0 is None else t0
        if self.aggregate_only:
            self._counts[_C2C] += 1
        elif self.columnar:
            self._seq.append(_C2C)
            c = self._cols[_C2C]
            c[0].append(at)
            c[1].append(dur_s)
            c[2].append(nbytes)
            c[3].append(phase)
            c[4].append(source)
        else:
            self._events.append(C2CTransfer(at, dur_s, nbytes, phase,
                                            source))
        self._span_s["C2CTransfer", None] += dur_s
        self.c2c_bytes += nbytes
        if advance:
            if power_W:
                if self.aggregate_only:
                    self._counts[_SAMPLE] += 1
                elif self.columnar:
                    self._seq.append(_SAMPLE)
                    c = self._cols[_SAMPLE]
                    c[0].append(self.now)
                    c[1].append(power_W)
                else:
                    self._events.append(EnergySample(self.now, power_W))
                self.energy_J += dur_s * power_W
            self.busy_s += dur_s
            self.now += dur_s

    def token(self, n: int = 1, *, request_id: int = -1,
              t0: Optional[float] = None) -> None:
        n = int(n)
        at = self.now if t0 is None else t0
        if self.aggregate_only:
            self._counts[_TOKEN] += 1
        elif self.columnar:
            self._seq.append(_TOKEN)
            c = self._cols[_TOKEN]
            c[0].append(at)
            c[1].append(n)
            c[2].append(request_id)
        else:
            self._events.append(TokenEmit(at, n, request_id))
        self.tokens += n

    def token_each(self, request_ids: Sequence[int], *,
                   t0: Optional[float] = None) -> None:
        """Batched emit: ONE single-token `TokenEmit` per request id, all
        at the same instant — the serving engine's per-decode-round
        batch, appended with C-level column extends instead of one
        `token()` call per resident.  Event-stream equivalent to
        ``for rid in request_ids: token(1, request_id=rid)``."""
        b = len(request_ids)
        if not b:
            return
        at = self.now if t0 is None else t0
        if self.aggregate_only:
            self._counts[_TOKEN] += b
        elif self.columnar:
            self._seq.extend([_TOKEN] * b)
            c = self._cols[_TOKEN]
            c[0].extend([at] * b)
            c[1].extend([1] * b)
            c[2].extend(request_ids)
        else:
            self._events.extend(
                TokenEmit(at, 1, rid) for rid in request_ids)
        self.tokens += b

    def node_fail(self, node: int = -1, *,
                  t0: Optional[float] = None) -> None:
        """Concurrent instant: this node crashed (fault injection)."""
        at = self.now if t0 is None else t0
        if self.aggregate_only:
            self._counts[_FAIL] += 1
        elif self.columnar:
            self._seq.append(_FAIL)
            c = self._cols[_FAIL]
            c[0].append(at)
            c[1].append(node)
        else:
            self._events.append(NodeFail(at, node))

    def node_recover(self, node: int = -1, *, downtime_s: float = 0.0,
                     t0: Optional[float] = None) -> None:
        """Concurrent instant: this node rejoined after a crash."""
        at = self.now if t0 is None else t0
        if self.aggregate_only:
            self._counts[_RECOVER] += 1
        elif self.columnar:
            self._seq.append(_RECOVER)
            c = self._cols[_RECOVER]
            c[0].append(at)
            c[1].append(node)
            c[2].append(downtime_s)
        else:
            self._events.append(NodeRecover(at, node, downtime_s))

    def sample(self, power_W: float) -> None:
        if self.aggregate_only:
            self._counts[_SAMPLE] += 1
            return
        if self.columnar:
            self._seq.append(_SAMPLE)
            c = self._cols[_SAMPLE]
            c[0].append(self.now)
            c[1].append(power_W)
        else:
            self._events.append(EnergySample(self.now, power_W))

    # -- event materialization ----------------------------------------
    def _no_events(self) -> RuntimeError:
        return RuntimeError(
            "aggregate-only timeline stores no events; use the running "
            "aggregates (cycles/span_seconds/count) or record with "
            "aggregate_only=False")

    @property
    def n_events(self) -> int:
        """Event count without materializing anything — O(1)."""
        if self.aggregate_only:
            return sum(self._counts)
        return len(self._seq) if self.columnar else len(self._events)

    @property
    def events(self) -> List[Event]:
        """The dataclass event stream.  In columnar mode this is a lazy,
        incrementally extended materialization cache: appends after a
        read only materialize the new tail on the next read."""
        if self.aggregate_only:
            raise self._no_events()
        if not self.columnar:
            return self._events
        if len(self._mat) < len(self._seq):
            mat, cur, cols = self._mat, self._cursors, self._cols
            ctors = (ComputeSpan, C2CTransfer, ClusterWake, ClusterSleep,
                     EnergySample, TokenEmit, NodeFail, NodeRecover)
            for cid in self._seq[len(mat):]:
                i = cur[cid]
                mat.append(ctors[cid](*(col[i] for col in cols[cid])))
                cur[cid] = i + 1
        return self._mat

    def _iter_events(self) -> Iterator[Event]:
        """Yield events one at a time WITHOUT caching a materialized list
        (columnar mode) — the streaming export path for million-event
        traces."""
        if self.aggregate_only:
            raise self._no_events()
        if not self.columnar:
            yield from self._events
            return
        ctors = (ComputeSpan, C2CTransfer, ClusterWake, ClusterSleep,
                 EnergySample, TokenEmit, NodeFail, NodeRecover)
        cur = [0] * 8
        cols = self._cols
        for cid in self._seq:
            i = cur[cid]
            yield ctors[cid](*(col[i] for col in cols[cid]))
            cur[cid] = i + 1

    _FIELDS = {
        "ComputeSpan": ("t0", "dur_s", "kind", "power_W", "cycles",
                        "batch", "name"),
        "C2CTransfer": ("t0", "dur_s", "nbytes", "phase", "source"),
        "ClusterWake": ("t0", "dur_s", "cycles", "cluster"),
        "ClusterSleep": ("t0", "dur_s", "power_W"),
        "EnergySample": ("t0", "power_W"),
        "TokenEmit": ("t0", "n", "request_id"),
        "NodeFail": ("t0", "node"),
        "NodeRecover": ("t0", "node", "downtime_s"),
    }

    def column(self, cls: Type, field: str) -> list:
        """One raw column of ``cls`` (e.g. ``column(ComputeSpan, "dur_s")``)
        in append order — the zero-copy analysis path in columnar mode."""
        name = cls.__name__
        fields = self._FIELDS[name]
        if field not in fields:
            raise KeyError(f"{name} has no field {field!r}")
        if self.aggregate_only:
            raise self._no_events()
        if self.columnar:
            return list(self._cols[self._CIDS[name]][fields.index(field)])
        return [getattr(e, field) for e in self._events
                if isinstance(e, cls)]

    # -- derived queries (O(1): running aggregates) --------------------
    def cycles(self, cls: Type = ComputeSpan,
               kind: Optional[str] = None) -> int:
        """Exact integer cycle sum over events of ``cls`` (optionally a
        ComputeSpan ``kind``) — the lossless bridge back to the cycle
        model's arithmetic."""
        return self._cycles.get((cls.__name__, kind), 0)

    def span_seconds(self, cls: Type = ComputeSpan,
                     kind: Optional[str] = None) -> float:
        return self._span_s.get((cls.__name__, kind), 0.0)

    _CIDS = {"ComputeSpan": _COMPUTE, "C2CTransfer": _C2C,
             "ClusterWake": _WAKE, "ClusterSleep": _SLEEP,
             "EnergySample": _SAMPLE, "TokenEmit": _TOKEN,
             "NodeFail": _FAIL, "NodeRecover": _RECOVER}

    def count(self, cls: Type) -> int:
        if self.aggregate_only:
            return self._counts[self._CIDS[cls.__name__]]
        if self.columnar:
            return len(self._cols[self._CIDS[cls.__name__]][0])
        return sum(1 for e in self._events if isinstance(e, cls))

    def c2c_energy_J(self, wall_s: Optional[float] = None) -> float:
        """Link energy for the delivered bytes: average power at the
        delivered rate (bursty traffic, duty-cycled laser bias) over the
        wall clock."""
        wall = max(self.now if wall_s is None else wall_s, 1e-12)
        return c2c_average_power(self.c2c_bytes / wall, self.link) * wall

    def total_energy_J(self) -> float:
        return self.energy_J + self.c2c_energy_J()

    def power_trace(self) -> List[Tuple[float, float]]:
        """(t, W) steps from the EnergySample stream."""
        if self.aggregate_only:
            raise self._no_events()
        if self.columnar:
            t0s, ws = self._cols[_SAMPLE]
            return list(zip(t0s, ws))
        return [(e.t0, e.power_W) for e in self._events
                if isinstance(e, EnergySample)]

    # -- Chrome trace export ------------------------------------------
    _TIDS = {"ComputeSpan": 1, "C2CTransfer": 2, "ClusterWake": 3,
             "ClusterSleep": 4, "TokenEmit": 5}
    # fault lanes: their thread metadata is emitted ONLY when such
    # events exist, so zero-fault traces stay byte-identical
    _FAULT_TIDS = {"NodeFail": 6, "NodeRecover": 7}

    def iter_chrome_events(self, *, process_name: str = "picnic",
                           pid: int = 0) -> Iterator[Dict]:
        """Yield `chrome://tracing` event dicts one at a time (metadata
        first), without holding the whole trace in memory.  ``pid``
        attributes every event to one trace process — fleet runs export
        each NODE's timeline under its own pid (see
        :func:`merge_chrome_traces`); the default 0 keeps single-node
        output byte-identical to the pre-fleet exporter."""
        yield {"ph": "M", "pid": pid, "name": "process_name",
               "args": {"name": process_name}}
        for lane, tid in sorted(self._TIDS.items(), key=lambda kv: kv[1]):
            yield {"ph": "M", "pid": pid, "tid": tid,
                   "name": "thread_name", "args": {"name": lane}}
        for lane, tid in sorted(self._FAULT_TIDS.items(),
                                key=lambda kv: kv[1]):
            if self.count(ALL_EVENT_CATEGORIES[tid]) > 0:
                yield {"ph": "M", "pid": pid, "tid": tid,
                       "name": "thread_name", "args": {"name": lane}}

        def span(cat, name, e, args):
            return {"ph": "X", "pid": pid, "tid": self._TIDS[cat],
                    "cat": cat, "name": name, "ts": e.t0 * 1e6,
                    "dur": e.dur_s * 1e6, "args": args}

        for e in self._iter_events():
            ts = e.t0 * 1e6                     # chrome wants microseconds
            if isinstance(e, ComputeSpan):
                yield span("ComputeSpan", e.name or e.kind, e,
                           {"kind": e.kind, "cycles": e.cycles,
                            "batch": e.batch, "power_W": e.power_W})
            elif isinstance(e, C2CTransfer):
                yield span("C2CTransfer", f"c2c:{e.phase or 'burst'}",
                           e, {"bytes": e.nbytes, "phase": e.phase,
                               "source": e.source})
            elif isinstance(e, ClusterWake):
                yield span("ClusterWake", "wake", e,
                           {"cycles": e.cycles, "cluster": e.cluster})
            elif isinstance(e, ClusterSleep):
                yield span("ClusterSleep", "sleep", e,
                           {"power_W": e.power_W})
            elif isinstance(e, EnergySample):
                yield {"ph": "C", "pid": pid, "cat": "EnergySample",
                       "name": "power_W", "ts": ts,
                       "args": {"power_W": e.power_W}}
            elif isinstance(e, TokenEmit):
                yield {"ph": "i", "pid": pid,
                       "tid": self._TIDS["TokenEmit"],
                       "cat": "TokenEmit", "name": f"tok x{e.n}",
                       "ts": ts, "s": "t",
                       "args": {"n": e.n, "request_id": e.request_id}}
            elif isinstance(e, NodeFail):
                yield {"ph": "i", "pid": pid,
                       "tid": self._FAULT_TIDS["NodeFail"],
                       "cat": "NodeFail", "name": "node_fail",
                       "ts": ts, "s": "p", "args": {"node": e.node}}
            elif isinstance(e, NodeRecover):
                yield {"ph": "i", "pid": pid,
                       "tid": self._FAULT_TIDS["NodeRecover"],
                       "cat": "NodeRecover", "name": "node_recover",
                       "ts": ts, "s": "p",
                       "args": {"node": e.node,
                                "downtime_s": e.downtime_s}}

    def to_chrome_trace(self, *, process_name: str = "picnic",
                        pid: int = 0) -> Dict:
        """`chrome://tracing` / Perfetto JSON: one thread lane per event
        category, power as a counter track, tokens as instant events."""
        return {"traceEvents":
                list(self.iter_chrome_events(process_name=process_name,
                                             pid=pid)),
                "displayTimeUnit": "ms"}

    def dump_chrome_trace(self, path, *, process_name: str = "picnic",
                          pid: int = 0) -> None:
        """Stream the Chrome trace to ``path`` one event at a time —
        constant memory, so ``--trace-out`` stays usable on
        million-event traces."""
        with open(path, "w") as f:
            f.write('{"displayTimeUnit": "ms", "traceEvents": [\n')
            first = True
            for ev in self.iter_chrome_events(process_name=process_name,
                                              pid=pid):
                if not first:
                    f.write(",\n")
                json.dump(ev, f)
                first = False
            f.write("\n]}\n")

    def save_chrome_trace(self, path, *, process_name: str = "picnic",
                          pid: int = 0) -> None:
        self.dump_chrome_trace(path, process_name=process_name, pid=pid)


def merge_chrome_traces(named_timelines) -> Dict:
    """One `chrome://tracing` document from several timelines: each
    ``(name, timeline)`` pair becomes its own trace PROCESS (pid = list
    position, process_name = name) — per-node attribution for fleet
    runs, where every node's events keep their own lanes but share the
    global clock."""
    events: List[Dict] = []
    for pid, (name, tl) in enumerate(named_timelines):
        events.extend(tl.iter_chrome_events(process_name=name, pid=pid))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# Cell-major aggregate block (the sweep engine's 2D recorder)
# ---------------------------------------------------------------------------

class SweepAggregates:
    """The running aggregates of N timelines as cell-major numpy arrays.

    One row of scalars per cell — the exact accumulator set an
    aggregate-only :class:`Timeline` maintains for the serving decode
    loop — so a vectorized round update::

        agg.now[idx] += dt; agg.energy_J[idx] += dt * power; ...

    performs, per cell, the same IEEE-754 float64 multiply-adds in the
    same order as N scalar timelines appending the same spans.  The
    vector axis is *cells*: lanes never mix, so every cell's float
    accumulation stays bit-identical to its scalar run.

    ``sync_in(i, tl)`` snapshots one cell's timeline into row ``i`` when
    that cell enters the vectorized path; ``sync_out(i, tl)`` writes the
    row back before the cell's scalar engine resumes (or reports).  Only
    the accumulators the vectorized decode round can touch are mirrored;
    everything else (prefill spans, sleeps, wakes, cycle sums) mutates
    exclusively on the scalar side and needs no mirror.
    """

    _SPAN_KEYS = (("ComputeSpan", None), ("ComputeSpan", "decode"),
                  ("C2CTransfer", None), ("ComputeSpan", "prefill"),
                  ("ClusterWake", None))

    def __init__(self, n_cells: int):
        self.n_cells = n_cells
        self.now = np.zeros(n_cells)
        self.busy_s = np.zeros(n_cells)
        self.energy_J = np.zeros(n_cells)
        self.occupancy_s = np.zeros(n_cells)
        self.tokens = np.zeros(n_cells, dtype=np.int64)
        self.c2c_bytes = np.zeros(n_cells, dtype=np.int64)
        # per-(class, kind) running span sums, one lane per tracked key
        self.span_compute = np.zeros(n_cells)
        self.span_decode = np.zeros(n_cells)
        self.span_c2c = np.zeros(n_cells)
        self.span_prefill = np.zeros(n_cells)
        self.span_wake = np.zeros(n_cells)
        self.cyc_wake = np.zeros(n_cells, dtype=np.int64)
        # aggregate-only event counts kept exact during vector rounds
        self.n_compute = np.zeros(n_cells, dtype=np.int64)
        self.n_sample = np.zeros(n_cells, dtype=np.int64)
        self.n_c2c = np.zeros(n_cells, dtype=np.int64)
        self.n_token = np.zeros(n_cells, dtype=np.int64)
        self.n_wake = np.zeros(n_cells, dtype=np.int64)

    def sync_in(self, i: int, tl: Timeline) -> None:
        self.now[i] = tl.now
        self.busy_s[i] = tl.busy_s
        self.energy_J[i] = tl.energy_J
        self.occupancy_s[i] = tl.occupancy_s
        self.tokens[i] = tl.tokens
        self.c2c_bytes[i] = tl.c2c_bytes
        span = tl._span_s
        self.span_compute[i] = span.get(self._SPAN_KEYS[0], 0.0)
        self.span_decode[i] = span.get(self._SPAN_KEYS[1], 0.0)
        self.span_c2c[i] = span.get(self._SPAN_KEYS[2], 0.0)
        self.span_prefill[i] = span.get(self._SPAN_KEYS[3], 0.0)
        self.span_wake[i] = span.get(self._SPAN_KEYS[4], 0.0)
        self.cyc_wake[i] = tl._cycles.get(self._SPAN_KEYS[4], 0)
        if tl.aggregate_only:
            cnt = tl._counts
            self.n_compute[i] = cnt[_COMPUTE]
            self.n_sample[i] = cnt[_SAMPLE]
            self.n_c2c[i] = cnt[_C2C]
            self.n_token[i] = cnt[_TOKEN]
            self.n_wake[i] = cnt[_WAKE]

    def sync_out(self, i: int, tl: Timeline) -> None:
        tl.now = float(self.now[i])
        tl.busy_s = float(self.busy_s[i])
        tl.energy_J = float(self.energy_J[i])
        tl.occupancy_s = float(self.occupancy_s[i])
        tl.tokens = int(self.tokens[i])
        tl.c2c_bytes = int(self.c2c_bytes[i])
        span = tl._span_s
        span[self._SPAN_KEYS[0]] = float(self.span_compute[i])
        span[self._SPAN_KEYS[1]] = float(self.span_decode[i])
        span[self._SPAN_KEYS[2]] = float(self.span_c2c[i])
        # prefill/wake lanes: only written when they carry anything (or
        # the key already exists) so a decode-only sweep does not grow
        # the span dict's key set relative to its scalar run
        for key, col in ((self._SPAN_KEYS[3], self.span_prefill),
                         (self._SPAN_KEYS[4], self.span_wake)):
            v = float(col[i])
            if v or key in span:
                span[key] = v
        cw = int(self.cyc_wake[i])
        if cw or self._SPAN_KEYS[4] in tl._cycles:
            tl._cycles[self._SPAN_KEYS[4]] = cw
        if tl.aggregate_only:
            cnt = tl._counts
            cnt[_COMPUTE] = int(self.n_compute[i])
            cnt[_SAMPLE] = int(self.n_sample[i])
            cnt[_C2C] = int(self.n_c2c[i])
            cnt[_TOKEN] = int(self.n_token[i])
            cnt[_WAKE] = int(self.n_wake[i])

    def sync_in_many(self, idx: np.ndarray, tls: Sequence[Timeline]) -> None:
        """Batched :meth:`sync_in` — one fancy-indexed scatter per column
        instead of per-lane scalar writes.  All ``tls`` must be
        aggregate-only recorders (the sweep engine's only mode)."""
        K0, K1, K2, K3, K4 = self._SPAN_KEYS
        f = np.array([(tl.now, tl.busy_s, tl.energy_J, tl.occupancy_s,
                       tl._span_s.get(K0, 0.0), tl._span_s.get(K1, 0.0),
                       tl._span_s.get(K2, 0.0), tl._span_s.get(K3, 0.0),
                       tl._span_s.get(K4, 0.0)) for tl in tls])
        self.now[idx] = f[:, 0]
        self.busy_s[idx] = f[:, 1]
        self.energy_J[idx] = f[:, 2]
        self.occupancy_s[idx] = f[:, 3]
        self.span_compute[idx] = f[:, 4]
        self.span_decode[idx] = f[:, 5]
        self.span_c2c[idx] = f[:, 6]
        self.span_prefill[idx] = f[:, 7]
        self.span_wake[idx] = f[:, 8]
        g = np.array([(tl.tokens, tl.c2c_bytes, tl._cycles.get(K4, 0),
                       tl._counts[_COMPUTE], tl._counts[_SAMPLE],
                       tl._counts[_C2C], tl._counts[_TOKEN],
                       tl._counts[_WAKE]) for tl in tls], dtype=np.int64)
        self.tokens[idx] = g[:, 0]
        self.c2c_bytes[idx] = g[:, 1]
        self.cyc_wake[idx] = g[:, 2]
        self.n_compute[idx] = g[:, 3]
        self.n_sample[idx] = g[:, 4]
        self.n_c2c[idx] = g[:, 5]
        self.n_token[idx] = g[:, 6]
        self.n_wake[idx] = g[:, 7]

    def sync_out_many(self, idx: np.ndarray, tls: Sequence[Timeline]) -> None:
        """Batched :meth:`sync_out`: gather every column once, then per-
        timeline attribute stores (aggregate-only recorders required)."""
        K0, K1, K2, K3, K4 = self._SPAN_KEYS
        now, busy, en, occ = (self.now[idx], self.busy_s[idx],
                              self.energy_J[idx], self.occupancy_s[idx])
        tok, cbytes = self.tokens[idx], self.c2c_bytes[idx]
        sc, sd, s2 = (self.span_compute[idx], self.span_decode[idx],
                      self.span_c2c[idx])
        sp, sw, cw = (self.span_prefill[idx], self.span_wake[idx],
                      self.cyc_wake[idx])
        nc, ns, n2, nt, nw = (self.n_compute[idx], self.n_sample[idx],
                              self.n_c2c[idx], self.n_token[idx],
                              self.n_wake[idx])
        for k, tl in enumerate(tls):
            tl.now = float(now[k])
            tl.busy_s = float(busy[k])
            tl.energy_J = float(en[k])
            tl.occupancy_s = float(occ[k])
            tl.tokens = int(tok[k])
            tl.c2c_bytes = int(cbytes[k])
            span = tl._span_s
            span[K0] = float(sc[k])
            span[K1] = float(sd[k])
            span[K2] = float(s2[k])
            v = float(sp[k])
            if v or K3 in span:
                span[K3] = v
            v = float(sw[k])
            if v or K4 in span:
                span[K4] = v
            c = int(cw[k])
            if c or K4 in tl._cycles:
                tl._cycles[K4] = c
            cnt = tl._counts
            cnt[_COMPUTE] = int(nc[k])
            cnt[_SAMPLE] = int(ns[k])
            cnt[_C2C] = int(n2[k])
            cnt[_TOKEN] = int(nt[k])
            cnt[_WAKE] = int(nw[k])

    def decode_round(self, idx: np.ndarray, dt: np.ndarray,
                     power_W: np.ndarray, batch: np.ndarray,
                     burst_bytes: np.ndarray, burst_dur: np.ndarray,
                     fetch_bytes: np.ndarray, fetch_dur: np.ndarray) -> None:
        """One batched decode round for the cells in ``idx`` — the
        vectorized equivalent of the scalar engine's per-round timeline
        appends, in the scalar append order:

          1. ``compute(dt, kind="decode", power_W, batch)``
          2. concurrent decode C2C burst (``burst_bytes`` over
             ``burst_dur``)
          3. advancing kv-fetch C2C at chip power (``fetch_bytes`` over
             ``fetch_dur``; zero for non-paged cells — adding 0.0 /
             +0 is bit-neutral on every accumulator, matching the scalar
             engine *skipping* those appends)
          4. one `TokenEmit` per resident

        Each numbered update is a separate elementwise op, so within a
        lane the float adds hit each accumulator in the scalar order.
        """
        # 1. decode ComputeSpan (+ its auto power sample)
        self.span_compute[idx] += dt
        self.span_decode[idx] += dt
        self.busy_s[idx] += dt
        self.energy_J[idx] += dt * power_W
        self.occupancy_s[idx] += dt * batch
        self.now[idx] += dt
        self.n_compute[idx] += 1
        self.n_sample[idx] += 1
        # 2. concurrent decode burst
        self.span_c2c[idx] += burst_dur
        self.c2c_bytes[idx] += burst_bytes
        self.n_c2c[idx] += (burst_bytes > 0)
        # 3. advancing kv fetch at chip power
        self.span_c2c[idx] += fetch_dur
        self.c2c_bytes[idx] += fetch_bytes
        self.energy_J[idx] += fetch_dur * power_W
        self.busy_s[idx] += fetch_dur
        self.now[idx] += fetch_dur
        has_fetch = fetch_bytes > 0
        self.n_c2c[idx] += has_fetch
        self.n_sample[idx] += has_fetch & (power_W > 0)
        # 4. token emits
        self.tokens[idx] += batch
        self.n_token[idx] += batch

    def decode_burst(self, idx: np.ndarray, h: np.ndarray, dt: np.ndarray,
                     power_W: np.ndarray, batch: np.ndarray,
                     burst_bytes: np.ndarray, burst_dur: np.ndarray,
                     fetch_bytes: np.ndarray, fetch_dur: np.ndarray,
                     next_arrival: np.ndarray,
                     wake_dt: Optional[np.ndarray] = None,
                     wake_cyc: Optional[np.ndarray] = None,
                     risk_eta: Optional[np.ndarray] = None,
                     risk_bound: Optional[np.ndarray] = None) -> np.ndarray:
        """Apply up to ``h[k]`` consecutive decode rounds to each lane
        ``idx[k]`` in one shot — bit-identical to calling
        :meth:`decode_round` that many times per lane, because
        ``np.add.accumulate`` is a strict sequential left fold (no
        pairwise regrouping) and each accumulator's fold starts from its
        current value (row 0 of the increment matrix).

        ``dt`` is the per-round compute duration, shape ``(H, n)`` with
        ``H >= h.max()``; row ``j`` prices round ``j+1`` of the burst.
        Rows beyond a lane's ``h`` are ignored (each lane's result is
        gathered at its own prefix position, so garbage rows past the
        horizon never contribute).

        Rounds are additionally truncated at the lane's next request
        arrival: the scalar engine admits (and leaves pure decode) once
        its clock reaches ``next_arrival``, so a burst must not price
        rounds past that point.  Returns the per-lane round counts
        actually applied (``>= 1`` — callers guarantee no arrival is due
        at burst entry).

        ``wake_dt`` / ``wake_cyc`` (dynamic CCPG): a per-lane constant
        ``ClusterWake`` walk replayed *before* each round's compute —
        ``busy/energy/now`` see an extra add per round in the scalar
        order, ``("ClusterWake", None)`` span/cycles and the wake/sample
        counts advance for lanes with ``wake_dt > 0``.  Zero-``wake_dt``
        lanes are bit-neutral.

        ``risk_eta`` / ``risk_bound`` (TTFT deadlines): rounds are also
        truncated once the lane's clock would put its queue head at
        deadline risk — round ``j`` runs only while
        ``clock_before_j + risk_eta < risk_bound`` (the scalar engine's
        ``clock + prefill_eta >= arrival + deadline_ttft`` at-risk test,
        same float expression).  Pass ``risk_eta = 0.0`` /
        ``risk_bound = inf`` for unconstrained lanes.
        """
        n = int(idx.size)
        H = int(h.max())
        dt = dt[:H]
        lanes = np.arange(n)
        if wake_dt is not None and wake_dt.any():
            return self._decode_burst_wake(
                idx, h, dt, power_W, batch, burst_bytes, burst_dur,
                fetch_bytes, fetch_dur, next_arrival, wake_dt, wake_cyc,
                risk_eta, risk_bound)
        if not fetch_bytes.any():
            # Fetch-free fast path: every accumulator sees exactly one
            # add per round (the fetch adds would all be `x + 0.0`,
            # which is bit-neutral on the non-negative accumulators but
            # doubles the fold depth) — fold all seven in one matrix.
            inc = np.empty((H + 1, 7 * n))
            starts = (self.now, self.busy_s, self.energy_J,
                      self.span_c2c, self.span_compute, self.span_decode,
                      self.occupancy_s)
            for k, a in enumerate(starts):
                inc[0, k * n:(k + 1) * n] = a[idx]
            inc[1:, 0 * n:1 * n] = dt
            inc[1:, 1 * n:2 * n] = dt
            inc[1:, 2 * n:3 * n] = dt * power_W
            inc[1:, 3 * n:4 * n] = burst_dur
            inc[1:, 4 * n:5 * n] = dt
            inc[1:, 5 * n:6 * n] = dt
            inc[1:, 6 * n:7 * n] = dt * batch
            acc = np.add.accumulate(inc, axis=0)
            # Round j+1 (0-based j) runs only while the clock *before*
            # it — acc row j of the `now` block — is short of the
            # arrival; monotone, so the count is the prefix length.
            j = np.arange(H)[:, None]
            clock = acc[:H, :n]
            ok = clock < next_arrival
            if risk_eta is not None:
                ok &= (clock + risk_eta) < risk_bound
            h = (ok & (j < h)).sum(axis=0)
            for k, a in enumerate(starts):
                a[idx] = acc[h, k * n + lanes]
            self.tokens[idx] += batch * h
            self.c2c_bytes[idx] += burst_bytes * h
            self.n_compute[idx] += h
            self.n_token[idx] += batch * h
            self.n_c2c[idx] += (burst_bytes > 0) * h
            self.n_sample[idx] += h
            return h
        # Clock prefix first: interleave (dt, fetch_dur) per round — the
        # scalar order is now += dt then now += fetch_dur — with the
        # current clock in row 0 so the fold seeds correctly.
        incN = np.empty((2 * H + 1, n))
        incN[0] = self.now[idx]
        incN[1::2] = dt
        incN[2::2] = fetch_dur
        accN = np.add.accumulate(incN, axis=0)
        # Round j+1 (0-based j) runs only while the clock *before* it —
        # accN[2j] — is still short of the arrival; the predicate is
        # monotone (clock never decreases) so the count is the prefix
        # length.
        j = np.arange(H)[:, None]
        clock = accN[0:2 * H:2]
        ok = clock < next_arrival
        if risk_eta is not None:
            ok &= (clock + risk_eta) < risk_bound
        h = (ok & (j < h)).sum(axis=0)
        r2 = 2 * h
        self.now[idx] = accN[r2, lanes]
        # busy / energy / span_c2c also see two adds per round, with
        # per-accumulator increments; fold all three in one accumulate.
        incB = np.empty((2 * H + 1, 3 * n))
        incB[0, :n] = self.busy_s[idx]
        incB[0, n:2 * n] = self.energy_J[idx]
        incB[0, 2 * n:] = self.span_c2c[idx]
        incB[1::2, :n] = dt
        incB[2::2, :n] = fetch_dur
        incB[1::2, n:2 * n] = dt * power_W
        incB[2::2, n:2 * n] = fetch_dur * power_W
        incB[1::2, 2 * n:] = burst_dur
        incB[2::2, 2 * n:] = fetch_dur
        accB = np.add.accumulate(incB, axis=0)
        self.busy_s[idx] = accB[r2, lanes]
        self.energy_J[idx] = accB[r2, n + lanes]
        self.span_c2c[idx] = accB[r2, 2 * n + lanes]
        # One-add-per-round accumulators: span_compute / span_decode
        # (same increments, different starts) and occupancy.
        incS = np.empty((H + 1, 3 * n))
        incS[0, :n] = self.span_compute[idx]
        incS[0, n:2 * n] = self.span_decode[idx]
        incS[0, 2 * n:] = self.occupancy_s[idx]
        incS[1:, :n] = dt
        incS[1:, n:2 * n] = dt
        incS[1:, 2 * n:] = dt * batch
        accS = np.add.accumulate(incS, axis=0)
        self.span_compute[idx] = accS[h, lanes]
        self.span_decode[idx] = accS[h, lanes + n]
        self.occupancy_s[idx] = accS[h, lanes + 2 * n]
        # Integer counters are associative — closed form is exact.
        self.tokens[idx] += batch * h
        self.c2c_bytes[idx] += (burst_bytes + fetch_bytes) * h
        self.n_compute[idx] += h
        self.n_token[idx] += batch * h
        self.n_c2c[idx] += ((burst_bytes > 0).astype(np.int64)
                            + (fetch_bytes > 0)) * h
        self.n_sample[idx] += h + ((fetch_bytes > 0) & (power_W > 0)) * h
        return h

    def _decode_burst_wake(self, idx, h, dt, power_W, batch,
                           burst_bytes, burst_dur, fetch_bytes, fetch_dur,
                           next_arrival, wake_dt, wake_cyc,
                           risk_eta, risk_bound) -> np.ndarray:
        """Dynamic-CCPG decode burst: each round replays the scalar
        engine's ``ClusterWake`` walk, then compute, then the (possibly
        zero) kv fetch — ``now/busy/energy`` fold three adds per round
        in that order.  ``dt`` is already trimmed to ``(H, n)``.
        Zero-``wake_dt`` lanes add ``x + 0.0`` on non-negative
        accumulators (bit-neutral) and are excluded from the wake
        span/cycle/count columns.
        """
        n = int(idx.size)
        H = dt.shape[0]
        lanes = np.arange(n)
        # now / busy / energy: wake, compute, fetch adds per round.
        inc3 = np.empty((3 * H + 1, 3 * n))
        inc3[0, :n] = self.now[idx]
        inc3[0, n:2 * n] = self.busy_s[idx]
        inc3[0, 2 * n:] = self.energy_J[idx]
        inc3[1::3, :n] = wake_dt
        inc3[2::3, :n] = dt
        inc3[3::3, :n] = fetch_dur
        inc3[1::3, n:2 * n] = wake_dt
        inc3[2::3, n:2 * n] = dt
        inc3[3::3, n:2 * n] = fetch_dur
        inc3[1::3, 2 * n:] = wake_dt * power_W
        inc3[2::3, 2 * n:] = dt * power_W
        inc3[3::3, 2 * n:] = fetch_dur * power_W
        acc3 = np.add.accumulate(inc3, axis=0)
        j = np.arange(H)[:, None]
        clock = acc3[0:3 * H:3, :n]
        ok = clock < next_arrival
        if risk_eta is not None:
            ok &= (clock + risk_eta) < risk_bound
        h = (ok & (j < h)).sum(axis=0)
        r3 = 3 * h
        self.now[idx] = acc3[r3, lanes]
        self.busy_s[idx] = acc3[r3, n + lanes]
        self.energy_J[idx] = acc3[r3, 2 * n + lanes]
        # span_c2c: two adds per round (decode burst, then fetch).
        inc2 = np.empty((2 * H + 1, n))
        inc2[0] = self.span_c2c[idx]
        inc2[1::2] = burst_dur
        inc2[2::2] = fetch_dur
        self.span_c2c[idx] = np.add.accumulate(inc2, axis=0)[2 * h, lanes]
        # One add per round: compute/decode spans, occupancy, wake span.
        incS = np.empty((H + 1, 4 * n))
        incS[0, :n] = self.span_compute[idx]
        incS[0, n:2 * n] = self.span_decode[idx]
        incS[0, 2 * n:3 * n] = self.occupancy_s[idx]
        incS[0, 3 * n:] = self.span_wake[idx]
        incS[1:, :n] = dt
        incS[1:, n:2 * n] = dt
        incS[1:, 2 * n:3 * n] = dt * batch
        incS[1:, 3 * n:] = wake_dt
        accS = np.add.accumulate(incS, axis=0)
        self.span_compute[idx] = accS[h, lanes]
        self.span_decode[idx] = accS[h, lanes + n]
        self.occupancy_s[idx] = accS[h, lanes + 2 * n]
        self.span_wake[idx] = accS[h, lanes + 3 * n]
        # Integer counters are associative — closed form is exact.
        woke = wake_dt > 0
        self.tokens[idx] += batch * h
        self.c2c_bytes[idx] += (burst_bytes + fetch_bytes) * h
        self.cyc_wake[idx] += woke * wake_cyc * h
        self.n_wake[idx] += woke * h
        self.n_compute[idx] += h
        self.n_token[idx] += batch * h
        self.n_c2c[idx] += ((burst_bytes > 0).astype(np.int64)
                            + (fetch_bytes > 0)) * h
        self.n_sample[idx] += (h + woke * h
                               + ((fetch_bytes > 0) & (power_W > 0)) * h)
        return h

    def prefill_burst(self, idx: np.ndarray, h: np.ndarray, dt: np.ndarray,
                      power_W: np.ndarray, burst_bytes: np.ndarray,
                      burst_dur: np.ndarray, next_arrival: np.ndarray,
                      wake_dt: Optional[np.ndarray] = None,
                      wake_cyc: Optional[np.ndarray] = None) -> np.ndarray:
        """Apply up to ``h[k]`` consecutive *full-cap prefill chunk*
        steps to each lane ``idx[k]`` — the vectorized equivalent of the
        scalar engine's per-chunk appends while a partial prefill cruises
        alone (no residents, no due arrival):

          1. optional dynamic-CCPG ``ClusterWake`` walk (``wake_dt``)
          2. ``compute(dt_j, kind="prefill", power_W, batch=1)``
          3. concurrent prefill C2C (``burst_bytes`` over ``burst_dur``;
             non-advancing, zero bytes skip the scalar append —
             bit-neutral here)

        ``dt`` has shape ``(H, n)``: row ``j`` prices chunk ``j+1``
        (context grows by the chunk cap each step).  Chunks truncate at
        the lane's next arrival, exactly like :meth:`decode_burst`.
        Returns the per-lane chunk counts applied.
        """
        n = int(idx.size)
        H = int(h.max())
        dt = dt[:H]
        lanes = np.arange(n)
        j = np.arange(H)[:, None]
        if wake_dt is not None and wake_dt.any():
            # now / busy / energy: wake + compute adds per round.
            inc2 = np.empty((2 * H + 1, 3 * n))
            inc2[0, :n] = self.now[idx]
            inc2[0, n:2 * n] = self.busy_s[idx]
            inc2[0, 2 * n:] = self.energy_J[idx]
            inc2[1::2, :n] = wake_dt
            inc2[2::2, :n] = dt
            inc2[1::2, n:2 * n] = wake_dt
            inc2[2::2, n:2 * n] = dt
            inc2[1::2, 2 * n:] = wake_dt * power_W
            inc2[2::2, 2 * n:] = dt * power_W
            acc2 = np.add.accumulate(inc2, axis=0)
            h = ((acc2[0:2 * H:2, :n] < next_arrival)
                 & (j < h)).sum(axis=0)
            r2 = 2 * h
            self.now[idx] = acc2[r2, lanes]
            self.busy_s[idx] = acc2[r2, n + lanes]
            self.energy_J[idx] = acc2[r2, 2 * n + lanes]
            # One add per round: spans, occupancy (batch == 1), wake.
            incS = np.empty((H + 1, 5 * n))
            incS[0, :n] = self.span_compute[idx]
            incS[0, n:2 * n] = self.span_prefill[idx]
            incS[0, 2 * n:3 * n] = self.span_c2c[idx]
            incS[0, 3 * n:4 * n] = self.occupancy_s[idx]
            incS[0, 4 * n:] = self.span_wake[idx]
            incS[1:, :n] = dt
            incS[1:, n:2 * n] = dt
            incS[1:, 2 * n:3 * n] = burst_dur
            incS[1:, 3 * n:4 * n] = dt
            incS[1:, 4 * n:] = wake_dt
            accS = np.add.accumulate(incS, axis=0)
            self.span_compute[idx] = accS[h, lanes]
            self.span_prefill[idx] = accS[h, lanes + n]
            self.span_c2c[idx] = accS[h, lanes + 2 * n]
            self.occupancy_s[idx] = accS[h, lanes + 3 * n]
            self.span_wake[idx] = accS[h, lanes + 4 * n]
            woke = wake_dt > 0
            self.cyc_wake[idx] += woke * wake_cyc * h
            self.n_wake[idx] += woke * h
            self.c2c_bytes[idx] += burst_bytes * h
            self.n_compute[idx] += h
            self.n_sample[idx] += h + woke * h
            self.n_c2c[idx] += (burst_bytes > 0) * h
            return h
        # Wake-free: one add per round on every accumulator.
        inc = np.empty((H + 1, 7 * n))
        starts = (self.now, self.busy_s, self.energy_J, self.span_c2c,
                  self.span_compute, self.span_prefill, self.occupancy_s)
        for k, a in enumerate(starts):
            inc[0, k * n:(k + 1) * n] = a[idx]
        inc[1:, 0 * n:1 * n] = dt
        inc[1:, 1 * n:2 * n] = dt
        inc[1:, 2 * n:3 * n] = dt * power_W
        inc[1:, 3 * n:4 * n] = burst_dur
        inc[1:, 4 * n:5 * n] = dt
        inc[1:, 5 * n:6 * n] = dt
        inc[1:, 6 * n:7 * n] = dt  # occupancy: batch == 1 during cruise
        acc = np.add.accumulate(inc, axis=0)
        h = ((acc[:H, :n] < next_arrival) & (j < h)).sum(axis=0)
        for k, a in enumerate(starts):
            a[idx] = acc[h, k * n + lanes]
        self.c2c_bytes[idx] += burst_bytes * h
        self.n_compute[idx] += h
        self.n_sample[idx] += h
        self.n_c2c[idx] += (burst_bytes > 0) * h
        return h
