"""Unified event-timeline simulation core — TimelineIR.

Every cost producer in the repo appends typed events to ONE `Timeline`:

  * ``PicnicSimulator.run``            — analytic prefill/decode/C2C spans
    (or `MeasuredTraffic`-sourced C2C transfers),
  * ``ContinuousBatchingEngine``       — per-round prefill/decode spans,
    idle (`ClusterSleep`) gaps, per-token `TokenEmit`s,
  * ``CCPGModel`` (dynamic mode)       — real `ClusterWake` latency on
    cluster transitions instead of a folded-in residue constant.

Every consumer derives its numbers from the same event stream:
`InferenceResult` (cycle/byte sums), `ServingReport` (percentiles,
tok/s, tok/J via the span-integrated energy), and the Chrome-trace
exporter (`chrome://tracing` / Perfetto JSON).

Energy is INTEGRATED over spans — ``sum(duration * power)`` in append
order — instead of multiplying one average power by the wall clock.
The paper's CCPG and interconnect claims are time-resolved effects
(cluster wake-up, bursty C2C, idle retention) that average-power models
cannot show; see PAPERS.md on CIM power-gating surveys.

Cursor semantics: *advancing* appends (`compute` / `wake` / `sleep`)
move ``now`` and integrate energy; *concurrent* appends (`c2c` /
`token` / `sample`, or any append with ``advance=False``) annotate the
stream at a given instant without advancing time — C2C bursts overlap
compute, token emits are instantaneous.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Type, Union

from .interconnect import LinkSpec, OPTICAL, c2c_average_power


# ---------------------------------------------------------------------------
# Event taxonomy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ComputeSpan:
    """A busy span of the active cluster(s): one prefill, one (batched)
    decode iteration, or one sampled chunk of the analytic decode walk."""
    t0: float
    dur_s: float
    kind: str                # "prefill" | "decode"
    power_W: float = 0.0
    cycles: int = 0          # exact cycle count (ints sum losslessly)
    batch: int = 1           # co-scheduled requests riding this span
    name: str = ""


@dataclass(frozen=True)
class C2CTransfer:
    """Photonic/electrical chip-to-chip burst.  Concurrent with compute
    (the link runs under the compute wave unless `overlap` < 1 exposes
    part of it — the exposed part is inside the owning ComputeSpan)."""
    t0: float
    dur_s: float
    nbytes: int
    phase: str = ""          # "prefill" | "decode"
    source: str = "analytic"  # "analytic" | MeasuredTraffic.source


@dataclass(frozen=True)
class ClusterWake:
    """Exposed cluster power-up latency (CCPG).  Static mode folds the
    pre-wake residue into decode cycles; dynamic mode emits the full
    regulator-settle walk as real timeline latency."""
    t0: float
    dur_s: float
    cycles: int = 0
    cluster: int = -1        # -1: aggregate walk over all transitions


@dataclass(frozen=True)
class ClusterSleep:
    """Idle/retention span: scratchpads only (CCPG) or full active burn
    (no gating path).  ``advance=False`` appends mark background sleepers
    concurrent with compute (their power is already inside the span's
    aggregate) and carry no energy of their own."""
    t0: float
    dur_s: float
    power_W: float = 0.0


@dataclass(frozen=True)
class EnergySample:
    """Instantaneous power sample (W) — the Fig-8-style power trace.
    Emitted automatically at every advancing span start; contributes no
    energy (spans carry the integral)."""
    t0: float
    power_W: float


@dataclass(frozen=True)
class TokenEmit:
    """``n`` tokens produced at instant ``t0`` (request_id -1: aggregate
    analytic walk, otherwise the serving engine's per-request emits)."""
    t0: float
    n: int = 1
    request_id: int = -1


Event = Union[ComputeSpan, C2CTransfer, ClusterWake, ClusterSleep,
              EnergySample, TokenEmit]

EVENT_CATEGORIES: Tuple[Type, ...] = (
    ComputeSpan, C2CTransfer, ClusterWake, ClusterSleep, EnergySample,
    TokenEmit)


# ---------------------------------------------------------------------------
# Accumulator
# ---------------------------------------------------------------------------

class Timeline:
    """Append-only event stream with a time cursor and running integrals.

    The integrals (`energy_J`, `busy_s`, `idle_s`, `occupancy_s`) are
    accumulated in append order with one multiply-add per span, so a
    producer that previously charged ``energy += dt * power`` inline
    reproduces its floats bit-for-bit by appending the same spans in the
    same order.
    """

    def __init__(self, link: LinkSpec = OPTICAL):
        self.link = link
        self.events: List[Event] = []
        self.now = 0.0
        self.energy_J = 0.0        # span-integrated chip energy
        self.busy_s = 0.0
        self.idle_s = 0.0
        self.c2c_bytes = 0
        self.tokens = 0
        self.occupancy_s = 0.0     # integral of batch occupancy over busy

    # -- advancing producers ------------------------------------------
    def compute(self, dur_s: float, *, kind: str, power_W: float = 0.0,
                cycles: int = 0, batch: int = 1, name: str = "") -> float:
        self.events.append(ComputeSpan(self.now, dur_s, kind, power_W,
                                       cycles, batch, name))
        self.events.append(EnergySample(self.now, power_W))
        self.busy_s += dur_s
        self.energy_J += dur_s * power_W
        self.occupancy_s += dur_s * batch
        self.now += dur_s
        return self.now

    def wake(self, dur_s: float, *, power_W: float = 0.0, cycles: int = 0,
             cluster: int = -1) -> float:
        self.events.append(ClusterWake(self.now, dur_s, cycles, cluster))
        self.events.append(EnergySample(self.now, power_W))
        self.busy_s += dur_s
        self.energy_J += dur_s * power_W
        self.now += dur_s
        return self.now

    def sleep(self, dur_s: float, *, power_W: float = 0.0,
              t0: Optional[float] = None, advance: bool = True) -> float:
        ev = ClusterSleep(self.now if t0 is None else t0, dur_s, power_W)
        self.events.append(ev)
        if advance:
            self.events.append(EnergySample(ev.t0, power_W))
            self.idle_s += dur_s
            self.energy_J += dur_s * power_W
            self.now += dur_s
        return self.now

    # -- concurrent annotations ---------------------------------------
    def c2c(self, nbytes: int, *, dur_s: float = 0.0, phase: str = "",
            t0: Optional[float] = None, source: str = "analytic",
            advance: bool = False, power_W: float = 0.0) -> None:
        """``advance=True`` serializes the burst (cursor moves past it) —
        the Fig-10 layer-boundary handoff view; the default treats it as
        concurrent with the surrounding compute (any exposed transfer
        time is already inside the owning ComputeSpan's cycles).
        ``power_W`` charges chip power over an *advancing* burst (the
        chiplets do not stop burning while stalled on a remote KV read);
        concurrent bursts carry no energy of their own."""
        self.events.append(C2CTransfer(
            self.now if t0 is None else t0, dur_s, int(nbytes), phase,
            source))
        self.c2c_bytes += int(nbytes)
        if advance:
            if power_W:
                self.events.append(EnergySample(self.now, power_W))
                self.energy_J += dur_s * power_W
            self.busy_s += dur_s
            self.now += dur_s

    def token(self, n: int = 1, *, request_id: int = -1,
              t0: Optional[float] = None) -> None:
        self.events.append(TokenEmit(
            self.now if t0 is None else t0, int(n), request_id))
        self.tokens += int(n)

    def sample(self, power_W: float) -> None:
        self.events.append(EnergySample(self.now, power_W))

    # -- derived queries ----------------------------------------------
    def cycles(self, cls: Type = ComputeSpan,
               kind: Optional[str] = None) -> int:
        """Exact integer cycle sum over events of ``cls`` (optionally a
        ComputeSpan ``kind``) — the lossless bridge back to the cycle
        model's arithmetic."""
        total = 0
        for e in self.events:
            if not isinstance(e, cls):
                continue
            if kind is not None and getattr(e, "kind", None) != kind:
                continue
            total += getattr(e, "cycles", 0)
        return total

    def span_seconds(self, cls: Type = ComputeSpan,
                     kind: Optional[str] = None) -> float:
        total = 0.0
        for e in self.events:
            if not isinstance(e, cls):
                continue
            if kind is not None and getattr(e, "kind", None) != kind:
                continue
            total += e.dur_s
        return total

    def count(self, cls: Type) -> int:
        return sum(1 for e in self.events if isinstance(e, cls))

    def c2c_energy_J(self, wall_s: Optional[float] = None) -> float:
        """Link energy for the delivered bytes: average power at the
        delivered rate (bursty traffic, duty-cycled laser bias) over the
        wall clock."""
        wall = max(self.now if wall_s is None else wall_s, 1e-12)
        return c2c_average_power(self.c2c_bytes / wall, self.link) * wall

    def total_energy_J(self) -> float:
        return self.energy_J + self.c2c_energy_J()

    def power_trace(self) -> List[Tuple[float, float]]:
        """(t, W) steps from the EnergySample stream."""
        return [(e.t0, e.power_W) for e in self.events
                if isinstance(e, EnergySample)]

    # -- Chrome trace export ------------------------------------------
    _TIDS = {"ComputeSpan": 1, "C2CTransfer": 2, "ClusterWake": 3,
             "ClusterSleep": 4, "TokenEmit": 5}

    def to_chrome_trace(self, *, process_name: str = "picnic") -> Dict:
        """`chrome://tracing` / Perfetto JSON: one thread lane per event
        category, power as a counter track, tokens as instant events."""
        evs: List[Dict] = [
            {"ph": "M", "pid": 0, "name": "process_name",
             "args": {"name": process_name}},
        ]
        for lane, tid in sorted(self._TIDS.items(), key=lambda kv: kv[1]):
            evs.append({"ph": "M", "pid": 0, "tid": tid,
                        "name": "thread_name", "args": {"name": lane}})
        def span(cat, name, e, args):
            return {"ph": "X", "pid": 0, "tid": self._TIDS[cat],
                    "cat": cat, "name": name, "ts": e.t0 * 1e6,
                    "dur": e.dur_s * 1e6, "args": args}

        for e in self.events:
            ts = e.t0 * 1e6                     # chrome wants microseconds
            if isinstance(e, ComputeSpan):
                evs.append(span("ComputeSpan", e.name or e.kind, e,
                                {"kind": e.kind, "cycles": e.cycles,
                                 "batch": e.batch, "power_W": e.power_W}))
            elif isinstance(e, C2CTransfer):
                evs.append(span("C2CTransfer", f"c2c:{e.phase or 'burst'}",
                                e, {"bytes": e.nbytes, "phase": e.phase,
                                    "source": e.source}))
            elif isinstance(e, ClusterWake):
                evs.append(span("ClusterWake", "wake", e,
                                {"cycles": e.cycles, "cluster": e.cluster}))
            elif isinstance(e, ClusterSleep):
                evs.append(span("ClusterSleep", "sleep", e,
                                {"power_W": e.power_W}))
            elif isinstance(e, EnergySample):
                evs.append({"ph": "C", "pid": 0, "cat": "EnergySample",
                            "name": "power_W", "ts": ts,
                            "args": {"power_W": e.power_W}})
            elif isinstance(e, TokenEmit):
                evs.append({"ph": "i", "pid": 0,
                            "tid": self._TIDS["TokenEmit"],
                            "cat": "TokenEmit", "name": f"tok x{e.n}",
                            "ts": ts, "s": "t",
                            "args": {"n": e.n, "request_id": e.request_id}})
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path, *, process_name: str = "picnic") -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(process_name=process_name), f)
