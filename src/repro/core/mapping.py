"""Spatial mapping of weight tiles onto the 32x32 router-PE grid
(paper §III-2, Fig 6).

Each matrix is constrained to a column-wise rectangular region; the mapper
optimizes three factors (paper's heuristic):
  1. intra-matrix shape — the (rows x cols) aspect of each matrix region,
  2. inter-matrix shape — how the K-Q-V-O (or FFN) regions pack side by side,
  3. row-column order  — whether tile rows advance along mesh rows or cols.

The objective mirrors the paper's goal of balanced, non-congestive traffic:
minimize (a) broadcast-tree depth of input rows into each region, and
(b) reduction-tree depth of partial outputs along tile columns, with
scratchpads for Q/K/V/S co-located in the producing region ("reduction in
the vicinity").
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .noc import Mesh2D, MeshConfig
from .partition import TileGrid

Coord = Tuple[int, int]


@dataclass
class Region:
    """A rectangular router-PE region holding one matrix's tile grid."""
    grid: TileGrid
    origin: Coord                    # top-left router
    shape: Tuple[int, int]           # (rows, cols) in routers
    row_major: bool = True           # row-column order factor

    def router_of_tile(self, i: int, j: int) -> Coord:
        r, c = self.shape
        if self.row_major:
            rr, cc = i % r, (i // r) * self.grid.grid[1] + j
            if cc >= c:  # fold overflow columns downward
                rr, cc = rr + (cc // c) * self.grid.grid[0] % r, cc % c
        else:
            rr, cc = j % r, (j // r) * self.grid.grid[0] + i
            rr, cc = rr % r, cc % c
        return (self.origin[0] + rr % r, self.origin[1] + cc % c)

    @property
    def routers(self) -> List[Coord]:
        return [(self.origin[0] + r, self.origin[1] + c)
                for r in range(self.shape[0]) for c in range(self.shape[1])]


@dataclass
class LayerMapping:
    regions: Dict[str, Region]
    mesh: Mesh2D
    cost: float = 0.0

    def scratchpad_region(self, tensor: str) -> Optional[Region]:
        """Q/K/V/S live in the scratchpads of their producing weight region
        (paper: 'Q is stored in the scratchpads of the router-PE pairs
        where W_Q has been pre-placed')."""
        owner = {"Q": "W_Q", "K": "W_K", "V": "W_V", "S": "W_Q"}
        return self.regions.get(owner.get(tensor, tensor))


def _region_cost(mesh: Mesh2D, region: Region) -> float:
    """Broadcast depth (input rows) + reduction depth (output columns)."""
    tg = region.grid
    # input broadcast: along tile-rows (same input row block feeds a row)
    bc = region.shape[0] + region.shape[1]        # tree depth bound in region
    # partial-output reduction: along tile-columns of the matrix
    red = tg.grid[0]                              # operands per output
    return bc + 2.0 * red


def _pack_columns(grids: Sequence[TileGrid], mesh_rows: int,
                  order: Sequence[int], row_major: bool,
                  mesh: Mesh2D) -> Optional[LayerMapping]:
    """Pack each grid as a column-band (the paper's column-wise rectangular
    constraint), in the given inter-matrix order."""
    regions: Dict[str, Region] = {}
    col = 0
    for gi in order:
        tg = grids[gi]
        n = tg.n_tiles
        rows = min(mesh_rows, n)
        cols = -(-n // rows)
        if col + cols > mesh.cfg.cols:
            # fold: not enough columns — try shorter rows
            rows = mesh_rows
            cols = -(-n // rows)
            if col + cols > mesh.cfg.cols:
                return None
        regions[tg.name] = Region(tg, (0, col), (rows, cols), row_major)
        col += cols
    cost = sum(_region_cost(mesh, r) for r in regions.values())
    # inter-matrix adjacency cost: Q->S->O chain wants Q,K adjacent etc.
    names = [grids[i].name for i in order]
    for a, b in zip(names, names[1:]):
        ra, rb = regions[a], regions[b]
        cost += mesh.hops((ra.origin[0], ra.origin[1] + ra.shape[1] // 2),
                          (rb.origin[0], rb.origin[1] + rb.shape[1] // 2)) * 0.1
    return LayerMapping(regions=regions, mesh=mesh, cost=cost)


def map_layer(grids: Sequence[TileGrid],
              mesh: Mesh2D | None = None) -> LayerMapping:
    """Heuristic search over the paper's three factors.  For K-Q-V-O the
    optimum found matches Fig 6: K-Q-V-O channel bands left to right with
    column-major tile order inside each band."""
    mesh = mesh or Mesh2D(MeshConfig())
    best: Optional[LayerMapping] = None
    names = list(range(len(grids)))
    # canonical paper order first (K, Q, V, O) if those names exist
    paper_order = sorted(
        names, key=lambda i: {"W_K": 0, "W_Q": 1, "W_V": 2, "W_O": 3}.get(
            grids[i].name, 4 + i))
    orders = [paper_order] + [list(p) for p in itertools.permutations(names)] \
        if len(names) <= 4 else [paper_order, names]
    for order in orders:
        for mesh_rows in (8, 16, 32):
            for row_major in (True, False):
                m = _pack_columns(grids, mesh_rows, order, row_major, mesh)
                if m is None:
                    continue
                if best is None or m.cost < best.cost:
                    best = m
    if best is None:
        raise ValueError(
            f"layer does not fit one chiplet: "
            f"{sum(g.n_tiles for g in grids)} tiles > "
            f"{mesh.n_routers} router-PE pairs")
    return best


def fits_one_chiplet(grids: Sequence[TileGrid],
                     mesh: Mesh2D | None = None) -> bool:
    mesh = mesh or Mesh2D(MeshConfig())
    return sum(g.n_tiles for g in grids) <= mesh.n_routers
