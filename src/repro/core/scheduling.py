"""Temporal scheduling & layer-to-chiplet allocation (paper §III).

* Layer-wise chiplet allocation: each chiplet stores an attention layer or
  a feed-forward layer (a decoder's gate/up/down count as separate FF
  layers, as the paper does for Llama); layers that exceed one chiplet's
  67.1M-weight capacity span multiple chiplets.
* FlashAttention schedule: the two-level nested loop (outer over KV blocks,
  inner over Q rows) is mapped so the inner loop partially unrolls across
  the DMAC lanes of the routers holding the K/V scratchpads.
* KV cache: cyclically striped across the scratchpads pre-allocated to
  K/V (partition.ScratchpadPlan), so utilization stays balanced at any
  sequence length.

The cycle model below turns a schedule into per-token cycles; its two
calibration constants are fitted once on the Llama-1B/512 row (see
simulator.calibrate) and then validated against the other 8 rows of
Table II.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .energy import TileSpec
from .mapping import map_layer
from .noc import Mesh2D, MeshConfig
from .partition import PEArraySpec, attention_grids, ffn_grids, TileGrid


@dataclass(frozen=True)
class LayerDesc:
    kind: str                 # "attn" | "ffn" | "moe_ffn" | "ssm"
    name: str
    matrices: Tuple[Tuple[str, int, int], ...]   # (name, in_dim, out_dim)

    @property
    def n_weights(self) -> int:
        return sum(i * o for _, i, o in self.matrices)


def llm_layers(cfg) -> List[LayerDesc]:
    """Decompose a ModelConfig into PICNIC layers (paper granularity)."""
    layers: List[LayerDesc] = []
    d = cfg.d_model
    for li in range(cfg.n_layers):
        if cfg.family in ("ssm",) :
            di = cfg.ssm.expand * d
            h = di // cfg.ssm.head_dim
            layers.append(LayerDesc("ssm", f"L{li}.ssm", (
                ("in_proj", d, 2 * di + 2 * cfg.ssm.d_state + h),
                ("out_proj", di, d))))
            continue
        is_hybrid_attn = (cfg.family == "hybrid"
                          and (li + 1) % max(cfg.attn_every, 1) == 0)
        if cfg.family == "hybrid" and not is_hybrid_attn:
            di = cfg.ssm.expand * d
            h = di // cfg.ssm.head_dim
            layers.append(LayerDesc("ssm", f"L{li}.ssm", (
                ("in_proj", d, 2 * di + 2 * cfg.ssm.d_state + h),
                ("out_proj", di, d))))
            continue
        layers.append(LayerDesc("attn", f"L{li}.attn", (
            ("W_Q", d, cfg.q_dim), ("W_K", d, cfg.kv_dim),
            ("W_V", d, cfg.kv_dim), ("W_O", cfg.q_dim, d))))
        dff = cfg.moe.d_ff_expert if (cfg.moe and
                                      (li % cfg.moe_every == cfg.moe_every - 1)) \
            else cfg.d_ff
        n_ff = (cfg.moe.top_k + cfg.moe.n_shared_experts) if (
            cfg.moe and (li % cfg.moe_every == cfg.moe_every - 1)) else 1
        gated = cfg.mlp in ("swiglu", "geglu")
        names = ("W_gate", "W_up", "W_down") if gated else ("W_up", "W_down")
        for e in range(n_ff):
            for nm in names:
                if nm == "W_down":
                    layers.append(LayerDesc(
                        "ffn", f"L{li}.{nm}{e}", ((nm, dff, d),)))
                else:
                    layers.append(LayerDesc(
                        "ffn", f"L{li}.{nm}{e}", ((nm, d, dff),)))
    return layers


def total_weight_params(cfg) -> int:
    """Weights resident in RRAM (embeddings stay in DRAM)."""
    n = cfg.n_params(include_embeddings=False)
    if cfg.moe:
        # all experts are resident (non-volatile), even if only top-k active
        pass
    return n


@dataclass
class ChipletAllocation:
    """Layer -> chiplet ids (a layer may span several chiplets)."""
    assignments: List[Tuple[LayerDesc, List[int]]]
    n_chiplets: int
    tile: TileSpec

    @property
    def n_clusters(self) -> int:
        return -(-self.n_chiplets // 4)          # clusters of 4 (paper Fig 5)


def layer_tiles(ld: LayerDesc, pe: PEArraySpec = PEArraySpec()) -> int:
    """256x256 crossbar tiles needed by a layer (partition.py tiling)."""
    t = 0
    for _, i, o in ld.matrices:
        t += (-(-i // pe.rows)) * (-(-o // pe.cols))
    return t


def allocate_chiplets(cfg, tile: TileSpec = TileSpec()) -> ChipletAllocation:
    """Tile-granular greedy packing in layer order (paper §III-1/2: matrices
    are partitioned into 256x256 crossbar tiles and packed into the 1024
    router-PE pairs of consecutive chiplets).  Table II's measured power is
    reproduced only by tile-granular packing — pure layer-per-chiplet
    rounding overshoots 13B power by ~65%."""
    pairs_per_chip = tile.n_pairs
    layers = llm_layers(cfg)
    assignments: List[Tuple[LayerDesc, List[int]]] = []
    tiles_used = 0
    for ld in layers:
        t = layer_tiles(ld)
        first = tiles_used // pairs_per_chip
        last = (tiles_used + t - 1) // pairs_per_chip
        assignments.append((ld, list(range(first, last + 1))))
        tiles_used += t
    n = -(-tiles_used // pairs_per_chip)
    return ChipletAllocation(assignments, max(n, 1), tile)


# ---------------------------------------------------------------------------
# Cycle model
# ---------------------------------------------------------------------------

@dataclass
class CycleModel:
    """Per-token cycle counts from the mapped schedule.

    alpha: global pipeline-inefficiency factor (program fetch, FSM fill,
           bank swaps) — calibrated.
    dmac_eff: effective utilization of the 16-lane router DMACs during the
           FlashAttention inner loop — calibrated.
    """
    mesh: MeshConfig = field(default_factory=MeshConfig)
    pe: PEArraySpec = field(default_factory=PEArraySpec)
    alpha: float = 1.0
    # Memoize per-shape cycle costs (the serving fast path).  The decode
    # cost of one iteration depends only on (batch, sum(contexts)) given
    # an allocation, and is AFFINE in sum(contexts) — verified against
    # the direct layer walk at cache-fill time, so a subclass with a
    # non-affine override transparently falls back to the walk.  All
    # calibration constants participate in the cache key, so mutating
    # `alpha` & friends (tests do) can never serve a stale entry.
    memoize: bool = True
    # Memo capacities (entries, LRU-evicted).  Thousand-cell sweeps share
    # one CycleModel across every cell of a grid; if the working set of
    # (alloc, batch) / (chunk, ctx_before) shapes exceeds these, the LRU
    # thrashes silently — memo_stats() exposes hit/miss/eviction counters
    # so the thrash is visible and the knobs make it fixable.
    decode_memo_max: int = 256
    prefill_memo_max: int = 4096
    # --- calibrated constants (least-squares fit on the nine Table II rows;
    #     all rows reproduced within +-7%, see EXPERIMENTS.md) -------------
    # 1. Per-token SMAC cost: 'cycles_per_tile' per active 256x256 crossbar
    #    tile (bit-serial DAC in + shared-ADC column readout + in-network
    #    partial-sum accumulation, pipelined as a wave across the region).
    #    Table II decomposes as T = a*tiles + b*L*ctx + c*L with a~34.4
    #    consistently across 1B/8B/13B.
    cycles_per_tile: float = 34.394
    # 2. FlashAttention inner loop: transport-bound on KV-scratchpad reads +
    #    SCU round trip -> ~53.6 cycles per context position per decoder
    #    layer, independent of head count (heads run in parallel lanes).
    ctx_cycles_per_pos: float = 53.618
    # 3. Per-decoder-layer fixed overhead (NPM bank swap, layer-boundary
    #    sync, C2C handoff) ~9.1k cycles.
    layer_fixed_cycles: float = 9112.0
    softmax_overhead: int = 16
    c2c_bytes_per_cycle: float = 64.0      # optical engine burst BW
    c2c_latency: int = 100
    # 4. Batched decode: weights are stationary in the RRAM crossbars, so a
    #    co-scheduled batch re-uses the same crossbar read/settle wave; each
    #    extra batch element only pays the bit-serial DAC input streaming +
    #    shared-ADC column readout slot of the pipelined wave (~18% of the
    #    full per-tile cost — the DAC-in/ADC-out stages of the 34.4-cycle
    #    tile pipeline).  KV-scratchpad reads and C2C activation traffic do
    #    NOT amortize: every request owns its context.
    batch_issue_frac: float = 0.18

    # the decode affinity check probes the direct walk at these ctx sums;
    # a mismatch at any of them marks the (alloc, b) entry non-affine
    _AFFINE_PROBES = (1, 1009, 65537)
    # legacy class-level capacity aliases (pre-knob callers); the
    # instance fields above are authoritative
    _DECODE_MEMO_MAX = 256
    _PREFILL_MEMO_MAX = 4096
    # any assignment to these invalidates the memo (via the version
    # stamp baked into every cache key); mutating a nested MeshConfig /
    # PEArraySpec IN PLACE is not observable — replace the object instead
    _CALIBRATION_FIELDS = frozenset({
        "mesh", "pe", "alpha", "cycles_per_tile", "ctx_cycles_per_pos",
        "layer_fixed_cycles", "softmax_overhead", "c2c_bytes_per_cycle",
        "c2c_latency", "batch_issue_frac"})

    def __setattr__(self, name, value):
        object.__setattr__(self, name, value)
        if name in self._CALIBRATION_FIELDS:
            # bump the memo-key version; tests mutate alpha & friends
            # freely and must never see a stale cached cost
            object.__setattr__(self, "_cal_ver",
                               getattr(self, "_cal_ver", 0) + 1)
            object.__setattr__(self, "_decode_hot", None)

    def __post_init__(self):
        # (key) -> (base_cycles, n_attn | None, c2c_cyc, c2c_bytes, alloc)
        # and (key) -> ((cycles, c2c_bytes), alloc); the alloc strong ref
        # pins id(alloc) for the lifetime of its entries
        self._decode_memo: "OrderedDict" = OrderedDict()
        self._decode_hot: Optional[tuple] = None   # last (key, entry)
        self._prefill_memo: "OrderedDict" = OrderedDict()
        self._stats = {
            "decode_hot_hits": 0, "decode_hits": 0, "decode_misses": 0,
            "decode_evictions": 0, "prefill_hits": 0, "prefill_misses": 0,
            "prefill_evictions": 0,
        }
        object.__setattr__(self, "_cal_ver", getattr(self, "_cal_ver", 0))

    def memo_stats(self) -> Dict[str, int]:
        """Hit/miss/eviction counters plus current sizes & capacities of
        the decode and prefill memos.  Non-zero ``*_evictions`` on a sweep
        means the LRU working set exceeds the capacity knobs
        (``decode_memo_max`` / ``prefill_memo_max``) and the grid is
        silently re-walking layer costs — raise the knob."""
        out = dict(self._stats)
        out["decode_size"] = len(self._decode_memo)
        out["decode_max"] = self.decode_memo_max
        out["prefill_size"] = len(self._prefill_memo)
        out["prefill_max"] = self.prefill_memo_max
        return out

    def _decode_key(self, cfg, alloc: ChipletAllocation, b: int) -> tuple:
        return (id(alloc), cfg.d_model, b, self._cal_ver)

    def smac_cycles(self, ld: LayerDesc) -> int:
        return int(self.cycles_per_tile * layer_tiles(ld, self.pe))

    def layer_decode_cycles_batched(self, ld: LayerDesc, ctx_sum: int,
                                    b: int) -> int:
        """One engine iteration through one layer for a batch of ``b``
        requests whose contexts sum to ``ctx_sum``: the weight-stationary
        crossbar wave is paid once (+``batch_issue_frac`` DAC/ADC
        streaming per extra request), KV-scratchpad reads are charged per
        request (``ctx_sum``), the layer-fixed overhead once, and the SCU
        softmax pass per request.  ``b == 1`` is the single-stream cost."""
        cyc = int(self.smac_cycles(ld)
                  * (1.0 + self.batch_issue_frac * (b - 1)))
        if ld.kind == "attn":
            cyc += int(self.ctx_cycles_per_pos * ctx_sum)
            cyc += int(self.layer_fixed_cycles) + self.softmax_overhead * b
        elif ld.kind == "ssm":
            cyc += int(self.layer_fixed_cycles)   # per-decoder overhead
        return cyc

    def layer_decode_cycles(self, ld: LayerDesc, d_model: int,
                            context: int, n_heads: int, q_dim: int,
                            kv_dim: int) -> int:
        """One token through one layer."""
        return self.layer_decode_cycles_batched(ld, context, 1)

    def c2c_transfer_cycles(self, payload_bytes: int) -> int:
        return self.c2c_latency + int(payload_bytes / self.c2c_bytes_per_cycle)

    def token_decode_cycles(self, cfg, alloc: ChipletAllocation,
                            context: int, *,
                            overlap: float = 0.0) -> Tuple[int, int]:
        """(cycles, c2c_bytes) for one decode token end to end."""
        return self.batched_token_decode_cycles(cfg, alloc, [context],
                                                overlap=overlap)

    def batched_token_decode_cycles(
            self, cfg, alloc: ChipletAllocation,
            contexts: List[int], *, overlap: float = 0.0) -> Tuple[int, int]:
        """(cycles, c2c_bytes) for ONE engine iteration that advances a
        co-scheduled batch of requests by one token each.

        Cost decomposition per layer (``b = len(contexts)``):
          * SMAC: the crossbar wave is paid once; extra batch elements
            stream through its DAC/ADC pipeline stages (``batch_issue_frac``
            each) — this is the weight-stationary amortization that makes
            batched decode sublinear in b.
          * Attention context: per-request KV-scratchpad reads, so the
            term is linear in sum(contexts) — no sharing.
          * Layer-fixed (NPM bank swap, boundary sync): once per
            iteration — the whole batch crosses the boundary together.
          * Softmax: one SCU pass per request.
          * C2C: per-request activation vectors cross chiplet boundaries
            together in one burst of ``b * d_model`` bytes.

        ``overlap`` (0..1) hides that fraction of the C2C transfer
        cycles under the next layer's compute wave (double-buffered
        activation forwarding); the default 0.0 serializes them — the
        calibrated Table II interpretation.

        ``b == 1`` at ``overlap == 0`` reproduces
        :meth:`token_decode_cycles`'s single-stream cost exactly (the
        calibrated Table II path is unchanged).
        """
        if not 0.0 <= overlap <= 1.0:
            raise ValueError(f"overlap must be in [0, 1], got {overlap}")
        compute_cyc, c2c_cyc, c2c_bytes = \
            self.batched_token_decode_cycles_split(cfg, alloc, contexts)
        if overlap:
            cyc = compute_cyc + (1.0 - overlap) * c2c_cyc
        else:
            cyc = compute_cyc + c2c_cyc   # exact int sum: legacy path
        return int(cyc * self.alpha), c2c_bytes

    def batched_token_decode_cycles_split(
            self, cfg, alloc: ChipletAllocation,
            contexts: Sequence[int]) -> Tuple[int, int, int]:
        """(compute_cycles, c2c_cycles, c2c_bytes) — the pre-``alpha``
        decomposition of one batched decode iteration, separating the
        layer compute wave from the chiplet-boundary C2C transfers so
        the timeline layer can model compute/C2C overlap explicitly.

        ``contexts`` may be any sequence (list or numpy array — the SoA
        serving engine passes its context column directly).  With
        ``memoize`` on, the O(layers) walk runs once per distinct
        ``(alloc, batch)`` shape; every later call is an O(1) affine
        lookup in ``sum(contexts)`` — bit-identical to the walk, which
        adds one independently truncated ``int(ctx_cycles_per_pos *
        ctx_sum)`` term per attention layer."""
        b = len(contexts)
        if b == 0:
            return 0, 0, 0
        ctx_sum = int(contexts.sum()) if hasattr(contexts, "sum") \
            else sum(contexts)
        if not self.memoize:
            return self._decode_split_walk(cfg, alloc, ctx_sum, b)
        key = self._decode_key(cfg, alloc, b)
        hot = self._decode_hot            # last (key, entry): the serving
        if hot is not None and hot[0] == key:  # loop repeats one shape
            entry = hot[1]
            base, n_attn, c2c_cyc, c2c_bytes, _ = entry
            if n_attn is not None:
                self._stats["decode_hot_hits"] += 1
                return (base
                        + n_attn * int(self.ctx_cycles_per_pos * ctx_sum),
                        c2c_cyc, c2c_bytes)
        memo = self._decode_memo
        entry = memo.get(key)
        if entry is None:
            self._stats["decode_misses"] += 1
            base, c2c_cyc, c2c_bytes = \
                self._decode_split_walk(cfg, alloc, 0, b)
            n_attn = sum(1 for ld, _ in alloc.assignments
                         if ld.kind == "attn")
            affine = all(
                self._decode_split_walk(cfg, alloc, p, b)[0]
                == base + n_attn * int(self.ctx_cycles_per_pos * p)
                for p in self._AFFINE_PROBES)
            entry = (base, n_attn if affine else None, c2c_cyc,
                     c2c_bytes, alloc)
            memo[key] = entry
            while len(memo) > self.decode_memo_max:
                memo.popitem(last=False)
                self._stats["decode_evictions"] += 1
        else:
            self._stats["decode_hits"] += 1
            memo.move_to_end(key)
        self._decode_hot = (key, entry)
        base, n_attn, c2c_cyc, c2c_bytes, _ = entry
        if n_attn is None:      # non-affine subclass: direct walk
            return self._decode_split_walk(cfg, alloc, ctx_sum, b)
        return (base + n_attn * int(self.ctx_cycles_per_pos * ctx_sum),
                c2c_cyc, c2c_bytes)

    def decode_affine_split(self, cfg, alloc: ChipletAllocation, b: int
                            ) -> Optional[Tuple[int, int, int, int,
                                                float, float, int]]:
        """Like :meth:`decode_affine` but with the serialized C2C cycles
        kept SEPARATE from the compute base: ``(base_compute_cycles,
        n_attn, c2c_cycles, c2c_bytes, ctx_cycles_per_pos, alpha,
        cal_ver)`` such that one batch-``b`` iteration at C2C overlap
        fraction ``ov`` costs exactly

            int((base_compute + n_attn * int(cpp * ctx_sum)
                 + (1.0 - ov) * c2c_cycles) * alpha)

        — the :meth:`batched_token_decode_cycles` ``overlap`` branch as
        plain arithmetic (the sweep engine's vectorized split-cost lane).
        At ``ov == 0`` the scalar engine folds ``c2c_cycles`` into the
        base as an exact int sum instead; both reductions are reproduced
        bit-for-bit from this decomposition.  ``None`` when memoization
        is off or the cost is non-affine."""
        if not self.memoize or b <= 0:
            return None
        key = self._decode_key(cfg, alloc, b)
        hot = self._decode_hot
        entry = hot[1] if (hot is not None and hot[0] == key) \
            else self._decode_memo.get(key)
        if entry is None:
            self._decode_hot = None      # force split() to (re)build
            self.batched_token_decode_cycles_split(cfg, alloc, [0] * b)
            entry = self._decode_memo[key]
        base, n_attn, c2c_cyc, c2c_bytes, _ = entry
        if n_attn is None:
            return None
        return (base, n_attn, c2c_cyc, c2c_bytes,
                self.ctx_cycles_per_pos, self.alpha, self._cal_ver)

    def decode_affine(self, cfg, alloc: ChipletAllocation, b: int
                      ) -> Optional[Tuple[int, int, int, float, float, int]]:
        """Fast-path export of the memoized decode decomposition:
        ``(base_cycles, n_attn, c2c_bytes, ctx_cycles_per_pos, alpha,
        cal_ver)`` such that one batch-``b`` iteration costs exactly

            int((base_cycles + n_attn * int(ctx_cycles_per_pos
                                            * sum(contexts))) * alpha)

        pre-CCPG cycles (``base_cycles`` already folds the serialized C2C
        transfer cycles in).  The serving engine inlines this as plain
        arithmetic in its round loop; the snapshot is valid while the
        returned ``cal_ver`` equals the model's current one.  ``None``
        when memoization is off or a subclass made the cost non-affine —
        callers must fall back to :meth:`batched_token_decode_cycles`."""
        if not self.memoize or b <= 0:
            return None
        key = self._decode_key(cfg, alloc, b)
        hot = self._decode_hot
        entry = hot[1] if (hot is not None and hot[0] == key) \
            else self._decode_memo.get(key)
        if entry is None:
            self._decode_hot = None      # force split() to (re)build
            self.batched_token_decode_cycles_split(cfg, alloc, [0] * b)
            entry = self._decode_memo[key]
        base, n_attn, c2c_cyc, c2c_bytes, _ = entry
        if n_attn is None:
            return None
        return (base + c2c_cyc, n_attn, c2c_bytes,
                self.ctx_cycles_per_pos, self.alpha, self._cal_ver)

    def _decode_split_walk(self, cfg, alloc: ChipletAllocation,
                           ctx_sum: int, b: int) -> Tuple[int, int, int]:
        """The direct per-layer walk (the reference path memoization is
        verified against)."""
        compute_cyc = 0
        c2c_cyc = 0
        c2c_bytes = 0
        d = cfg.d_model
        prev_chips: Optional[List[int]] = None
        for ld, chips in alloc.assignments:
            compute_cyc += self.layer_decode_cycles_batched(ld, ctx_sum, b)
            if prev_chips is not None and chips != prev_chips:
                payload = d * b  # 8-bit activations, one per request
                c2c_cyc += self.c2c_transfer_cycles(payload)
                c2c_bytes += payload
            prev_chips = chips
        return compute_cyc, c2c_cyc, c2c_bytes

    def prefill_cycles(self, cfg, alloc: ChipletAllocation,
                       seq: int) -> Tuple[int, int]:
        """Prefill S tokens: weight-stationary streaming, tokens pipelined
        through the layer chain (chiplet pipeline): time ~ per-layer stream
        of S tokens + pipeline fill.  One whole-prompt chunk of the
        chunked form below (``ctx_before == 0`` keeps the float arithmetic
        bit-identical to the pre-chunking closed form — locked by the
        timeline golden)."""
        return self.prefill_chunk_cycles(cfg, alloc, seq, 0)

    def prefill_chunk_cycles(self, cfg, alloc: ChipletAllocation,
                             chunk: int, ctx_before: int) -> Tuple[int, int]:
        """(cycles, c2c_bytes) to prefill ``chunk`` prompt tokens on top of
        ``ctx_before`` already-cached context tokens — the unit of chunked
        prefill (vLLM-style), so one long prompt is spread over several
        engine iterations instead of monopolizing one.

        Same decomposition as the whole-prompt form: the streamed SMAC
        wave and pipeline fill depend only on the chunk, while the
        FlashAttention term now has a ``chunk x ctx_before`` rectangle
        (new queries attending to cached context) on top of the causal
        triangle within the chunk.  Each chunk re-pays the pipeline fill;
        summing chunks therefore costs slightly MORE than one monolithic
        prefill — the price of interleaving.

        LRU-memoized on the exact ``(chunk, ctx_before)`` shape (the
        quadratic attention term has no affine shortcut): the serving
        engine re-prices the queue head's prefill every admission check,
        so repeated shapes dominate."""
        if self.memoize:
            key = (id(alloc), cfg.d_model, cfg.q_dim, chunk, ctx_before,
                   self._cal_ver)
            memo = self._prefill_memo
            entry = memo.get(key)
            if entry is not None:
                self._stats["prefill_hits"] += 1
                memo.move_to_end(key)
                return entry[0]
            self._stats["prefill_misses"] += 1
            result = self._prefill_chunk_walk(cfg, alloc, chunk, ctx_before)
            memo[key] = (result, alloc)
            while len(memo) > self.prefill_memo_max:
                memo.popitem(last=False)
                self._stats["prefill_evictions"] += 1
            return result
        return self._prefill_chunk_walk(cfg, alloc, chunk, ctx_before)

    def _prefill_chunk_walk(self, cfg, alloc: ChipletAllocation,
                            chunk: int, ctx_before: int) -> Tuple[int, int]:
        d = cfg.d_model
        stages = len(alloc.assignments)
        # Prefill is token-PIPELINED through the chiplet chain (weight
        # stationary): steady-state per-token cost = total SMAC work over
        # the pipeline depth.  This is why Table II throughput is decode-
        # dominated (prefill ~3% of wall time at 512/512).
        total_smac = sum(self.smac_cycles(ld) for ld, _ in alloc.assignments)
        stream_cyc = chunk * total_smac / max(alloc.n_chiplets, 1)
        # attention quadratic term: with many tokens in flight the flash
        # inner loop partially unrolls across ALL router DMAC lanes
        n_attn = sum(1 for ld, _ in alloc.assignments if ld.kind == "attn")
        lanes = self.mesh.dmac_lanes * 1024 * 0.5
        attn_macs = (2.0 * (cfg.q_dim or d) * chunk * (chunk + 1) / 2
                     + 2.0 * (cfg.q_dim or d) * chunk * ctx_before)
        attn_cyc = n_attn * attn_macs / lanes
        fill = stages * self.c2c_latency
        cyc = stream_cyc + attn_cyc + fill
        c2c_bytes = chunk * d * max(0, alloc.n_chiplets - 1)
        return int(cyc * self.alpha), c2c_bytes


# ---------------------------------------------------------------------------
# Batched cost surface (the sweep engine's cell-major view)
# ---------------------------------------------------------------------------

class DecodeCostSurface:
    """Cell-major batched view of :meth:`CycleModel.decode_affine`.

    Where ``decode_affine`` exports the affine decode decomposition for one
    ``(alloc, b)`` at a time, the surface tabulates it for every batch size
    ``1..max_batch`` so a whole grid of cells can price one decode round in
    a handful of numpy ops::

        cyc = int((base[b] + n_attn[b] * int(cpp * ctx_sum)) * alpha)

    evaluated elementwise over cell vectors ``b_vec`` / ``ctx_sum_vec``.
    Each lane performs exactly the scalar engine's arithmetic (same
    truncation points, same float64 ops), so per-cell results are
    bit-identical to pricing the cells one at a time.

    The surface shares the model's memo (building it populates the decode
    LRU; rebuilds after a hit are O(1) lookups) and its invalidation
    story: ``cal_ver`` snapshots the model's ``__setattr__`` calibration
    stamp, so mutating ``alpha`` & friends on the shared model invalidates
    every cell of every sweep at once — callers re-validate with
    :meth:`refresh` before each use.

    ``affine[b]`` is False for batch sizes where a subclass made the cost
    non-affine (or ``memoize`` is off, in which case every lane is False);
    cells at those batch sizes must fall back to the scalar walk.
    """

    def __init__(self, model: CycleModel, cfg, alloc: ChipletAllocation,
                 max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.model = model
        self.cfg = cfg
        self.alloc = alloc
        self.max_batch = int(max_batch)
        self._build()

    # chunk/ctx_before shapes the closed-form prefill lane is verified
    # against the model's own pricing at build time; any mismatch (a
    # subclass overriding the walk) disables the lane for the surface
    _PREFILL_PROBES = ((1, 0), (64, 0), (128, 4096), (257, 65537))

    def _build(self) -> None:
        m = self.model
        n = self.max_batch + 1          # index directly by batch size
        self.base = np.zeros(n, dtype=np.int64)
        self.base_compute = np.zeros(n, dtype=np.int64)
        self.c2c_cyc = np.zeros(n, dtype=np.int64)
        self.n_attn = np.zeros(n, dtype=np.int64)
        self.c2c_bytes = np.zeros(n, dtype=np.int64)
        self.affine = np.zeros(n, dtype=bool)
        for b in range(1, n):
            aff = m.decode_affine_split(self.cfg, self.alloc, b)
            if aff is None:
                continue
            base_c, n_attn, c2c_cyc, c2cb, _cpp, _alpha, _ver = aff
            self.base[b] = base_c + c2c_cyc   # decode_affine's folded base
            self.base_compute[b] = base_c
            self.c2c_cyc[b] = c2c_cyc
            self.n_attn[b] = n_attn
            self.c2c_bytes[b] = c2cb
            self.affine[b] = True
        self.cpp = float(m.ctx_cycles_per_pos)
        self.alpha = float(m.alpha)
        self.cal_ver = m._cal_ver
        self._build_prefill()

    def _build_prefill(self) -> None:
        """Snapshot the closed-form prefill-chunk constants and verify
        them against the model's own pricing (`prefill_chunk_cycles`) at
        a few probe shapes — a subclass overriding the walk silently
        demotes the vectorized lane to the memo-backed gather."""
        m, cfg, alloc = self.model, self.cfg, self.alloc
        d = cfg.d_model
        self._pf_smac = sum(m.smac_cycles(ld)
                            for ld, _ in alloc.assignments)
        self._pf_den = max(alloc.n_chiplets, 1)
        self._pf_qd2 = 2.0 * (cfg.q_dim or d)
        self._pf_nattn = sum(1 for ld, _ in alloc.assignments
                             if ld.kind == "attn")
        self._pf_lanes = m.mesh.dmac_lanes * 1024 * 0.5
        self._pf_fill = len(alloc.assignments) * m.c2c_latency
        self._pf_c2cb = d * max(0, alloc.n_chiplets - 1)
        self.prefill_closed = True
        for c, cb in self._PREFILL_PROBES:
            want = m.prefill_chunk_cycles(cfg, alloc, c, cb)
            got_c, got_b = self._prefill_closed_form(
                np.array([c], dtype=np.int64),
                np.array([cb], dtype=np.int64))
            if (int(got_c[0]), int(got_b[0])) != want:
                self.prefill_closed = False
                break

    def _prefill_closed_form(self, chunk: np.ndarray, before: np.ndarray
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """`CycleModel._prefill_chunk_walk` as elementwise numpy — the
        same float64 ops at the same points in the same order, so each
        lane reproduces the scalar walk bit-for-bit."""
        stream_cyc = chunk * self._pf_smac / self._pf_den
        attn_macs = (self._pf_qd2 * chunk * (chunk + 1) / 2
                     + self._pf_qd2 * chunk * before)
        attn_cyc = self._pf_nattn * attn_macs / self._pf_lanes
        cyc = stream_cyc + attn_cyc + self._pf_fill
        c2cb = chunk * self._pf_c2cb
        return (cyc * self.alpha).astype(np.int64), c2cb

    def valid(self) -> bool:
        return self.cal_ver == self.model._cal_ver

    def refresh(self) -> bool:
        """Rebuild iff the model's calibration stamp moved since the last
        build.  Returns True when a rebuild happened (callers holding
        per-cell snapshots of base/n_attn must re-gather)."""
        if self.valid():
            return False
        self._build()
        return True

    def decode_cycles(self, b_vec, ctx_sum_vec) -> np.ndarray:
        """Pre-CCPG cycles of one decode round per cell — vectorized over
        cells.  ``b_vec`` are per-cell batch sizes (1..max_batch, affine
        lanes only), ``ctx_sum_vec`` per-cell context sums."""
        b = np.asarray(b_vec, dtype=np.int64)
        ctx = np.asarray(ctx_sum_vec, dtype=np.int64)
        cyc = self.base[b] + self.n_attn[b] * (self.cpp * ctx).astype(np.int64)
        return (cyc.astype(np.float64) * self.alpha).astype(np.int64)

    def prefill_chunk_cycles(self, chunk_vec, ctx_before_vec
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """(cycles, c2c_bytes) per cell for prefill chunk shapes — array
        in, array out.  When the build-time probes matched the model
        (``prefill_closed``), shapes are priced by the closed-form walk
        vectorized directly over the array — no memo traffic at all;
        otherwise (a subclass overrode the walk) each lane gathers
        through the model's shared prefill LRU."""
        chunk = np.asarray(chunk_vec, dtype=np.int64)
        before = np.asarray(ctx_before_vec, dtype=np.int64)
        if chunk.shape != before.shape:
            raise ValueError("chunk/ctx_before shape mismatch")
        if self.prefill_closed:
            return self._prefill_closed_form(chunk, before)
        cyc = np.empty(chunk.shape, dtype=np.int64)
        c2cb = np.empty(chunk.shape, dtype=np.int64)
        m, cfg, alloc = self.model, self.cfg, self.alloc
        flat_c, flat_b = chunk.ravel(), before.ravel()
        out_c, out_b = cyc.ravel(), c2cb.ravel()
        for i in range(flat_c.size):
            out_c[i], out_b[i] = m.prefill_chunk_cycles(
                cfg, alloc, int(flat_c[i]), int(flat_b[i]))
        return cyc, c2cb
