"""IPCN 2D-mesh network model: routers, hop routing, spanning-tree
collectives (paper §III-3 'Collective communication').

The mesh is the paper's 32x32 router-PE grid.  Broadcast and reduction
follow a BFS spanning tree rooted at the operation's source/sink; because
the mapping is regular and aligned, tree levels are contention-free (the
paper's claim) — the model checks link-disjointness per level and reports
congestion if a schedule ever violates it.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Set, Tuple

Coord = Tuple[int, int]


@dataclass
class MeshConfig:
    rows: int = 32
    cols: int = 32
    link_bytes_per_cycle: int = 8     # 64-bit links (Table I bit-width)
    fifo_bytes: int = 256
    hop_latency: int = 1              # cycles per router hop
    dmac_lanes: int = 16              # non-weighted MAC units per router
    scratchpad_bytes: int = 32 * 1024


class Mesh2D:
    def __init__(self, cfg: MeshConfig = MeshConfig()):
        self.cfg = cfg

    @property
    def n_routers(self) -> int:
        return self.cfg.rows * self.cfg.cols

    def rid(self, rc: Coord) -> int:
        return rc[0] * self.cfg.cols + rc[1]

    def coord(self, rid: int) -> Coord:
        return divmod(rid, self.cfg.cols)

    def neighbors(self, rc: Coord) -> List[Coord]:
        r, c = rc
        out = []
        if r > 0:
            out.append((r - 1, c))
        if r < self.cfg.rows - 1:
            out.append((r + 1, c))
        if c > 0:
            out.append((r, c - 1))
        if c < self.cfg.cols - 1:
            out.append((r, c + 1))
        return out

    def hops(self, a: Coord, b: Coord) -> int:
        return abs(a[0] - b[0]) + abs(a[1] - b[1])

    def xy_route(self, a: Coord, b: Coord) -> List[Coord]:
        """Deterministic X-then-Y path (inclusive of endpoints)."""
        path = [a]
        r, c = a
        step = 1 if b[1] > c else -1
        while c != b[1]:
            c += step
            path.append((r, c))
        step = 1 if b[0] > r else -1
        while r != b[0]:
            r += step
            path.append((r, c))
        return path

    # ------------------------------------------------------------------
    # Spanning-tree collectives
    # ------------------------------------------------------------------

    def spanning_tree(self, root: Coord,
                      members: Iterable[Coord]) -> Dict[Coord, List[Coord]]:
        """BFS tree over the mesh restricted to reach all members.
        Returns child-lists per node (only nodes on tree paths appear)."""
        members = set(members)
        parent: Dict[Coord, Coord] = {root: root}
        q = deque([root])
        found: Set[Coord] = {root} & members
        while q and found != members:
            cur = q.popleft()
            for nb in self.neighbors(cur):
                if nb not in parent:
                    parent[nb] = cur
                    q.append(nb)
                    if nb in members:
                        found.add(nb)
        # prune to paths root->member
        keep: Set[Coord] = set()
        for m in members:
            cur = m
            while cur not in keep:
                keep.add(cur)
                if cur == root:
                    break
                cur = parent[cur]
        children: Dict[Coord, List[Coord]] = {}
        for node in keep:
            if node == root:
                continue
            children.setdefault(parent[node], []).append(node)
        return children

    def tree_depth(self, children: Dict[Coord, List[Coord]],
                   root: Coord) -> int:
        depth, frontier = 0, [root]
        while frontier:
            nxt = []
            for n in frontier:
                nxt.extend(children.get(n, []))
            if not nxt:
                break
            depth += 1
            frontier = nxt
        return depth

    def broadcast_cycles(self, root: Coord, members: Sequence[Coord],
                         payload_bytes: int) -> int:
        """Pipelined wormhole broadcast down the spanning tree: latency =
        tree depth + serialization of the payload on the narrowest level."""
        tree = self.spanning_tree(root, members)
        depth = self.tree_depth(tree, root)
        ser = -(-payload_bytes // self.cfg.link_bytes_per_cycle)
        return depth * self.cfg.hop_latency + ser

    def reduce_cycles(self, root: Coord, members: Sequence[Coord],
                      payload_bytes: int) -> int:
        """In-network reduction up the tree: each router PSUMs its children's
        streams (paper: partial summation macro), so the payload is NOT
        multiplied by fan-in; latency mirrors broadcast plus one MAC pass."""
        tree = self.spanning_tree(root, members)
        depth = self.tree_depth(tree, root)
        ser = -(-payload_bytes // self.cfg.link_bytes_per_cycle)
        return depth * self.cfg.hop_latency + ser

    def check_level_disjoint(self, root: Coord,
                             members: Sequence[Coord]) -> bool:
        """The paper claims non-congestive traffic for aligned mappings:
        per tree level, links must be pairwise disjoint.  BFS trees on a
        mesh satisfy this by construction; the check guards schedule bugs."""
        tree = self.spanning_tree(root, members)
        frontier = [root]
        while frontier:
            links = set()
            nxt = []
            for n in frontier:
                for ch in tree.get(n, []):
                    link = (n, ch)
                    if link in links:
                        return False
                    links.add(link)
                    nxt.append(ch)
            frontier = nxt
        return True
