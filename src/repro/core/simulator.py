"""Instruction-level (cycle-approximate) PICNIC system simulator (paper §IV).

Pipeline: ModelConfig -> layer decomposition -> chiplet allocation ->
mapped schedule -> cycle counts (scheduling.CycleModel) -> throughput,
average power (energy/ccpg/interconnect models) -> tokens/J.

`calibrate()` fits the two free constants (alpha, dmac_eff) on ONE paper
row (Llama-3.2-1B, 512/512); every other Table II row is then a
prediction, reported against the paper in EXPERIMENTS.md §Paper-fidelity.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .ccpg import CCPGModel, CLUSTER_SIZE
from .energy import TileSpec
from .interconnect import (ELECTRICAL, OPTICAL, LinkSpec, MeasuredTraffic,
                           TrafficTrace, c2c_average_power)
from .scheduling import ChipletAllocation, CycleModel, allocate_chiplets
from .timeline import ClusterWake, ComputeSpan, Timeline


@dataclass
class InferenceResult:
    model: str
    ctx_in: int
    ctx_out: int
    throughput_tps: float
    avg_power_W: float
    efficiency_tpj: float
    n_chiplets: int
    prefill_s: float
    decode_s: float
    c2c_bytes_total: int
    c2c_avg_power_W: float
    ccpg: bool
    c2c_source: str = "analytic"

    def row(self) -> Dict:
        return {
            "model": self.model,
            "context": f"{self.ctx_in}/{self.ctx_out}",
            "throughput_tok_s": round(self.throughput_tps, 1),
            "avg_power_W": round(self.avg_power_W, 4),
            "efficiency_tok_J": round(self.efficiency_tpj, 1),
            "chiplets": self.n_chiplets,
        }


@dataclass
class PicnicSimulator:
    tile: TileSpec = field(default_factory=TileSpec)
    cycle_model: CycleModel = field(default_factory=CycleModel)
    ccpg_model: CCPGModel = field(default_factory=CCPGModel)
    link: LinkSpec = OPTICAL

    # ------------------------------------------------------------------
    def run(self, cfg, ctx_in: int, ctx_out: int, *,
            ccpg: bool = False,
            measured_c2c: Optional[MeasuredTraffic] = None,
            overlap: float = 0.0,
            dynamic_ccpg: bool = False,
            timeline: Optional[Timeline] = None) -> InferenceResult:
        """Emit the analytic prefill/decode walk as TimelineIR events and
        derive the `InferenceResult` from the timeline (exact integer
        cycle sums — the default no-overlap, static-CCPG configuration is
        byte-identical to the calibrated Table II closed form, locked by
        tests/test_timeline.py's golden regression).

        ``measured_c2c`` switches the photonic-link traffic term from the
        cycle model's analytic layer-boundary estimate to per-collective
        wire bytes measured on compiled HLO (collective_capture.py).
        ``overlap`` (0..1) hides that fraction of decode C2C transfer
        cycles under the compute wave.  ``dynamic_ccpg`` charges the FULL
        cluster wake latency per transition as `ClusterWake` events
        instead of the pre-wake residue.  Pass a fresh ``timeline`` to
        collect the event stream (Chrome-trace export, Fig-10 analysis).
        """
        tl = timeline if timeline is not None else Timeline(link=self.link)
        # aggregate snapshot (exact ints): a shared timeline may already
        # hold earlier runs' events, so derive this run's sums as O(1)
        # diffs of the running aggregates instead of an O(E) event scan
        pre0 = tl.cycles(ComputeSpan, kind="prefill")
        dec0 = tl.cycles(ComputeSpan, kind="decode")
        wake0 = tl.cycles(ClusterWake)
        byt0 = tl.c2c_bytes
        t_start = tl.now      # cursor-relative anchors: a shared timeline
        #                       may already hold earlier runs' events
        alloc = allocate_chiplets(cfg, self.tile)
        f = self.tile.frequency_hz
        chip_power = self.ccpg_model.system_power(alloc.n_chiplets, ccpg=ccpg)

        prefill_cyc, prefill_c2c = self.cycle_model.prefill_cycles(
            cfg, alloc, ctx_in)
        tl.compute(prefill_cyc / f, kind="prefill", power_W=chip_power,
                   cycles=prefill_cyc, name=f"prefill[{ctx_in}]")
        if measured_c2c is None:
            tl.c2c(prefill_c2c, phase="prefill", t0=t_start,
                   dur_s=prefill_c2c / self.link.bandwidth_Bps)
        tl.token(ctx_in)      # processed-token accounting (see below)

        # integrate decode over the growing context (exact sum, sampled
        # every `step` tokens for speed — the cycle model is affine in ctx)
        step = max(1, ctx_out // 64)
        for c in range(ctx_in, ctx_in + ctx_out, step):
            mult = min(step, ctx_in + ctx_out - c)
            cyc, c2c = self.cycle_model.token_decode_cycles(
                cfg, alloc, c, overlap=overlap)
            t0 = tl.now
            tl.compute(cyc * mult / f, kind="decode", power_W=chip_power,
                       cycles=cyc * mult, batch=1,
                       name=f"decode[ctx={c}]x{mult}")
            if measured_c2c is None and c2c:
                # bursts ride under the compute wave: anchor at span start
                tl.c2c(c2c * mult, phase="decode", t0=t0,
                       dur_s=c2c * mult / self.link.bandwidth_Bps)
            if ccpg:
                w = (self.ccpg_model.wake_latency_cycles(alloc)
                     if dynamic_ccpg
                     else self.ccpg_model.wake_overhead_cycles(alloc))
                if w:
                    tl.wake(w * mult / f, power_W=chip_power,
                            cycles=w * mult)
            tl.token(mult)

        if measured_c2c is not None:
            # timing stays with the cycle model; only the traffic term
            # (bytes -> link power) is replaced by the HLO measurement
            tl.c2c(int(measured_c2c.prefill_bytes), phase="prefill",
                   t0=t_start, source=measured_c2c.source)
            tl.c2c(int(measured_c2c.decode_bytes_per_token * ctx_out),
                   phase="decode", t0=t_start + prefill_cyc / f,
                   source=measured_c2c.source)
        if ccpg:
            # background sleepers: annotation concurrent with this run
            # only (their retention power is inside chip_power already)
            n_sleep = max(0, alloc.n_chiplets - CLUSTER_SIZE)
            if n_sleep:
                tl.sleep(tl.now - t_start, t0=t_start, advance=False,
                         power_W=n_sleep * self.tile.tile_power_sleep)

        # ---- derive the result FROM the timeline -----------------------
        # O(1) diffs of the running integer aggregates (lossless, so the
        # calibrated Table II floats are reproduced bit-for-bit)
        prefill_cyc_t = tl.cycles(ComputeSpan, kind="prefill") - pre0
        decode_cyc_t = ((tl.cycles(ComputeSpan, kind="decode") - dec0)
                        + (tl.cycles(ClusterWake) - wake0))
        prefill_s = prefill_cyc_t / f
        decode_s = decode_cyc_t / f
        total_s = prefill_s + decode_s
        # Table II's "throughput" counts processed tokens (input + output)
        # over wall time — the interpretation under which the paper's
        # context-length scaling is reproduced (see EXPERIMENTS.md).
        tput = (ctx_in + ctx_out) / total_s

        c2c_bytes = tl.c2c_bytes - byt0
        c2c_rate = c2c_bytes / total_s
        c2c_power = c2c_average_power(c2c_rate, self.link)
        power = chip_power + c2c_power
        return InferenceResult(
            model=cfg.name, ctx_in=ctx_in, ctx_out=ctx_out,
            throughput_tps=tput, avg_power_W=power,
            efficiency_tpj=tput / power, n_chiplets=alloc.n_chiplets,
            prefill_s=prefill_s, decode_s=decode_s,
            c2c_bytes_total=c2c_bytes, c2c_avg_power_W=c2c_power, ccpg=ccpg,
            c2c_source="analytic" if measured_c2c is None
            else measured_c2c.source)

    # ------------------------------------------------------------------
    # Serving-engine hooks (launch/serving_engine.py): per-iteration costs
    # in SECONDS, so the discrete-event loop never touches cycle math.
    # ------------------------------------------------------------------
    def prefill_seconds(self, cfg, alloc: ChipletAllocation,
                        prompt_len: int, *,
                        ccpg: bool = False) -> Tuple[float, int]:
        """(seconds, c2c_bytes) to prefill one request's prompt.  Prefill
        streams the prompt through every layer chain, so with CCPG it pays
        one full cluster walk of wake residue."""
        cyc, c2c = self.cycle_model.prefill_cycles(cfg, alloc, prompt_len)
        if ccpg:
            cyc += self.ccpg_model.wake_overhead_cycles(alloc)
        return cyc / self.tile.frequency_hz, c2c

    def prefill_chunk_seconds(self, cfg, alloc: ChipletAllocation,
                              chunk_len: int, ctx_before: int, *,
                              ccpg: bool = False) -> Tuple[float, int]:
        """(seconds, c2c_bytes) to prefill ``chunk_len`` prompt tokens on
        top of ``ctx_before`` cached tokens — chunked prefill, so a long
        prompt is spread across engine iterations.  Each chunk walks the
        full layer chain, so with CCPG each pays a cluster-walk residue.
        """
        cyc, c2c = self.cycle_model.prefill_chunk_cycles(
            cfg, alloc, chunk_len, ctx_before)
        if ccpg:
            cyc += self.ccpg_model.wake_overhead_cycles(alloc)
        return cyc / self.tile.frequency_hz, c2c

    def kv_transfer_seconds(self, nbytes: int) -> float:
        """Serialization time of ``nbytes`` of KV moved over the photonic
        C2C link (scratchpad <-> DRAM-hub spill/fetch traffic)."""
        return nbytes / self.link.bandwidth_Bps

    def decode_iteration_seconds(self, cfg, alloc: ChipletAllocation,
                                 contexts: List[int], *,
                                 ccpg: bool = False,
                                 overlap: float = 0.0) -> Tuple[float, int]:
        """(seconds, c2c_bytes) for one batched decode iteration advancing
        every request in ``contexts`` by one token.  CCPG wake overhead is
        charged once per iteration — co-batched requests share the active
        cluster (cluster residency), not once per request.  ``overlap``
        hides that fraction of C2C transfer cycles under compute."""
        cyc, c2c = self.cycle_model.batched_token_decode_cycles(
            cfg, alloc, contexts, overlap=overlap)
        if ccpg:
            cyc += self.ccpg_model.wake_overhead_cycles_batched(
                alloc, len(contexts))
        return cyc / self.tile.frequency_hz, c2c

    def wake_seconds(self, alloc: ChipletAllocation) -> Tuple[float, int]:
        """Dynamic-CCPG: (seconds, cycles) of the FULL exposed cluster-walk
        wake latency for one iteration — what the serving engine emits as
        a real `ClusterWake` timeline event per round instead of folding
        the pre-wake residue into the decode cost."""
        cyc = self.ccpg_model.wake_latency_cycles(alloc)
        return cyc / self.tile.frequency_hz, cyc

    # ------------------------------------------------------------------
    def c2c_trace(self, cfg, n_tokens: int = 32, context: int = 512,
                  timeline: Optional[Timeline] = None) -> TrafficTrace:
        """Burst timeline for Fig 10: C2C bursts at layer boundaries only.
        Emitted through TimelineIR (per-layer ComputeSpans + serialized
        C2CTransfers); pass ``timeline`` to keep the full event stream."""
        alloc = allocate_chiplets(cfg, self.tile)
        f = self.tile.frequency_hz
        tl = timeline if timeline is not None else Timeline(link=self.link)
        for tok in range(n_tokens):
            prev = None
            for ld, chips in alloc.assignments:
                cyc = self.cycle_model.layer_decode_cycles(
                    ld, cfg.d_model, context, cfg.n_heads,
                    cfg.q_dim or cfg.d_model, cfg.kv_dim or cfg.d_model)
                tl.compute(cyc * self.cycle_model.alpha / f, kind="decode",
                           cycles=cyc, name=ld.name)
                if prev is not None and chips != prev:
                    payload = cfg.d_model
                    dur = self.cycle_model.c2c_transfer_cycles(payload) / f
                    tl.c2c(payload, dur_s=dur, phase="decode", advance=True)
                prev = chips
            tl.token(1)
        return TrafficTrace.from_timeline(tl)

    # ------------------------------------------------------------------
    def calibrate(self, cfg_1b, target_tps: float = 1503.8,
                  ctx: Tuple[int, int] = (512, 512)) -> "PicnicSimulator":
        """Fit alpha so the Llama-1B/512 row matches the paper; dmac_eff is
        left at its datasheet-derived default."""
        self.cycle_model.alpha = 1.0
        r = self.run(cfg_1b, *ctx)
        self.cycle_model.alpha = r.throughput_tps / target_tps
        if self.cycle_model.alpha < 0.05:
            self.cycle_model.alpha = 0.05
        return self


# Table III platform constants (paper, Llama-8B 1024/1024 batch 1)
PLATFORMS = {
    "TransPIM": {"throughput": 270.0, "power": 40.0},
    "Cambricon-LLM": {"throughput": 36.34, "power": 36.3},
    "NV A100": {"throughput": 78.36, "power": 200.0},
    "NV H100": {"throughput": 274.26, "power": 280.0},
    "Apple M4-Max": {"throughput": 69.77, "power": 80.0},
    "Cerebras-2": {"throughput": 1800.0, "power": 15000.0},
}


def comparison_table(picnic: InferenceResult,
                     baseline: str = "NV H100") -> List[Dict]:
    base = PLATFORMS[baseline]
    base_eff = base["throughput"] / base["power"]
    rows = [{
        "platform": "PICNIC (this work)",
        "throughput_tok_s": round(picnic.throughput_tps, 2),
        "power_W": round(picnic.avg_power_W, 2),
        "efficiency_tok_J": round(picnic.efficiency_tpj, 2),
        "speedup_vs_h100": round(picnic.throughput_tps / base["throughput"], 2),
        "eff_impr_vs_h100": round(picnic.efficiency_tpj / base_eff, 1),
    }]
    for name, d in PLATFORMS.items():
        eff = d["throughput"] / d["power"]
        rows.append({
            "platform": name,
            "throughput_tok_s": d["throughput"],
            "power_W": d["power"],
            "efficiency_tok_J": round(eff, 2),
            "speedup_vs_h100": round(d["throughput"] / base["throughput"], 2),
            "eff_impr_vs_h100": round(eff / base_eff, 2),
        })
    return rows
