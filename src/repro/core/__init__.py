"""PICNIC core: the paper's contribution as a composable system model.

Layers:
  isa / program      — IPCN 30-bit ISA, NPM banks, assembler + hex compiler
  noc                — 32x32 router mesh, spanning-tree collectives
  partition/mapping  — crossbar tiling + Fig-6 spatial placement
  scheduling         — layer->chiplet allocation, flash-attention schedule,
                       cyclic KV striping, cycle model
  scu                — softmax unit (8-segment PWL exp) + FSM timing
  energy/ccpg        — Table I/IV power-area model, cluster power gating
  interconnect       — photonic vs electrical C2C
  timeline           — TimelineIR: typed event stream + span-integrated
                       energy, shared by simulator/serving/CCPG, with a
                       chrome://tracing exporter
  simulator          — end-to-end tokens/s, W, tokens/J (Tables II/III)
"""
from .isa import Instr, Mode, PORTS
from .program import ProgramBuilder, compile_to_hex, DoubleBufferedNPM
from .noc import Mesh2D, MeshConfig
from .partition import PEArraySpec, partition_matrix, attention_grids, ffn_grids
from .mapping import map_layer, fits_one_chiplet
from .scheduling import allocate_chiplets, llm_layers, CycleModel
from .scu import pwl_exp, pwl_softmax, SCUFsm, SCUTiming, max_pwl_exp_error
from .energy import TileSpec, MacroPower, MacroArea, table_iv
from .ccpg import CCPGModel, CLUSTER_SIZE
from .interconnect import (OPTICAL, ELECTRICAL, MeasuredTraffic,
                           c2c_average_power, TrafficTrace)
from .timeline import (Timeline, ComputeSpan, C2CTransfer, ClusterWake,
                       ClusterSleep, EnergySample, TokenEmit, NodeFail,
                       NodeRecover, EVENT_CATEGORIES,
                       FAULT_EVENT_CATEGORIES, ALL_EVENT_CATEGORIES)
from .simulator import PicnicSimulator, comparison_table, PLATFORMS
