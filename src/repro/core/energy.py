"""Power & area model — Table I / Table IV constants (7 nm node).

Per unit router-PE pair (Table IV):
  IMC PE (RRAM-CIM)  120 uW   0.1442 mm^2
  Scratchpad          42 uW   0.0130 mm^2
  Router              97 uW   0.0250 mm^2
  TSVs                 -      0.0020 mm^2
  total              259 uW   0.1842 mm^2
  Softmax CU         5.31 uW  0.0410 mm^2 (1024 per tile)

A compute tile (chiplet) is a 32x32 IPCN -> 1024 router-PE pairs,
189.6 mm^2.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class MacroPower:  # watts
    imc_pe: float = 120e-6
    scratchpad: float = 42e-6
    router: float = 97e-6
    softmax: float = 5.31e-6

    @property
    def router_pe_pair(self) -> float:
        return self.imc_pe + self.scratchpad + self.router   # 259 uW


@dataclass(frozen=True)
class MacroArea:  # mm^2
    imc_pe: float = 0.1442
    scratchpad: float = 0.013
    router: float = 0.025
    tsv: float = 0.002
    softmax: float = 0.041

    @property
    def router_pe_pair(self) -> float:
        return self.imc_pe + self.scratchpad + self.router + self.tsv


@dataclass(frozen=True)
class TileSpec:
    ipcn_dim: int = 32                  # Table I
    softmax_units: int = 1024
    frequency_hz: float = 1e9
    bit_width: int = 64
    power: MacroPower = field(default_factory=MacroPower)
    area: MacroArea = field(default_factory=MacroArea)

    @property
    def n_pairs(self) -> int:
        return self.ipcn_dim * self.ipcn_dim

    @property
    def tile_power_active(self) -> float:
        """Fully-active chiplet power."""
        return (self.n_pairs * self.power.router_pe_pair
                + self.softmax_units * self.power.softmax)

    @property
    def tile_power_sleep(self) -> float:
        """CCPG sleep: only scratchpads stay on for KV retention
        (paper §II-E); RRAM weights are non-volatile — zero retention power.
        """
        return self.n_pairs * self.power.scratchpad

    @property
    def tile_area_mm2(self) -> float:
        return (self.n_pairs * self.area.router_pe_pair
                + self.softmax_units * self.area.softmax)

    @property
    def weights_capacity(self) -> int:
        """Weights storable per chiplet: 1024 PE x 256x256 cells."""
        return self.n_pairs * 256 * 256


# Energy per bit for data movement (paper §I + refs [11])
E_ELECTRICAL_C2C = 3.0e-12      # J/bit
E_OPTICAL_C2C = 0.4e-12         # J/bit — silicon photonic MRM link [15]
E_DRAM_ACCESS = 30e-12          # J/bit off-chip
E_ONCHIP_HOP = 0.05e-12         # J/bit per mesh hop


def table_iv() -> dict:
    p, a = MacroPower(), MacroArea()
    return {
        "IMC PE": {"power_uW": p.imc_pe * 1e6, "area_mm2": a.imc_pe},
        "Scratchpad": {"power_uW": p.scratchpad * 1e6, "area_mm2": a.scratchpad},
        "Router": {"power_uW": p.router * 1e6, "area_mm2": a.router},
        "TSVs": {"power_uW": 0.0, "area_mm2": a.tsv},
        "Total (IPCN-PE)": {"power_uW": p.router_pe_pair * 1e6,
                            "area_mm2": a.router_pe_pair},
        "Softmax": {"power_uW": p.softmax * 1e6, "area_mm2": a.softmax},
    }
