"""Data pipeline: deterministic synthetic corpus -> packed token batches.

Production-shaped: documents are tokenized (byte-level stub tokenizer),
packed into fixed-length sequences with EOS separators, sharded per data-
parallel host, and streamed with a resumable cursor (checkpointable state:
one integer per host).  On a real cluster each host feeds its local devices
via ``jax.make_array_from_process_local_data``-style placement; here the
host count is 1 but the sharding math is the same.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


EOS = 1
PAD = 0


class ByteTokenizer:
    """Byte-level tokenizer stub (vocab 256 + specials), deterministic."""
    vocab_size = 258

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode(), np.uint8).astype(np.int32) + 2

    def decode(self, ids: np.ndarray) -> str:
        b = bytes(int(i) - 2 for i in ids if i >= 2)
        return b.decode(errors="replace")


def synthetic_documents(seed: int, vocab_size: int,
                        mean_len: int = 512) -> Iterator[np.ndarray]:
    """Infinite stream of Zipf-distributed synthetic documents (stable
    across restarts for a given seed)."""
    rng = np.random.default_rng(seed)
    while True:
        n = max(8, int(rng.exponential(mean_len)))
        # Zipf-ish unigram model over the model's vocab
        toks = (rng.zipf(1.3, size=n) + 1) % (vocab_size - 2) + 2
        yield toks.astype(np.int32)


@dataclasses.dataclass
class PackerState:
    doc_index: int = 0
    carry: Optional[np.ndarray] = None


class PackedStream:
    """Packs documents into (seq_len+1)-token rows; resumable."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed + 1000003 * host_id
        self.n_hosts = n_hosts
        self.state = PackerState()
        self._docs = synthetic_documents(self.seed, vocab_size)

    def _next_doc(self) -> np.ndarray:
        self.state.doc_index += 1
        return next(self._docs)

    def next_row(self) -> np.ndarray:
        need = self.seq_len + 1
        parts = []
        if self.state.carry is not None:
            parts.append(self.state.carry)
            self.state.carry = None
        total = sum(p.size for p in parts)
        while total < need:
            d = self._next_doc()
            parts.append(np.concatenate([d, [EOS]]).astype(np.int32))
            total += d.size + 1
        row = np.concatenate(parts)
        self.state.carry = row[need:].copy() if row.size > need else None
        return row[:need]

    def next_batch(self, local_batch: int) -> Dict[str, np.ndarray]:
        rows = np.stack([self.next_row() for _ in range(local_batch)])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
            "mask": (rows[:, 1:] != PAD).astype(np.float32),
        }

    # -- checkpointable cursor -------------------------------------------
    def snapshot(self) -> Dict:
        return {"doc_index": self.state.doc_index,
                "carry": None if self.state.carry is None
                else self.state.carry.tolist()}

    def restore(self, snap: Dict):
        # deterministic regeneration: re-wind the doc stream
        self._docs = synthetic_documents(self.seed, self.vocab_size)
        for _ in range(snap["doc_index"]):
            next(self._docs)
        self.state = PackerState(
            doc_index=snap["doc_index"],
            carry=None if snap["carry"] is None
            else np.asarray(snap["carry"], np.int32))


def make_train_batches(cfg, shape_seq: int, global_batch: int, *,
                       seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    stream = PackedStream(cfg.vocab_size, shape_seq, seed=seed)
    while True:
        yield stream.next_batch(global_batch)
