from .pipeline import (ByteTokenizer, PackedStream, make_train_batches,
                       synthetic_documents)

__all__ = ["ByteTokenizer", "PackedStream", "make_train_batches",
           "synthetic_documents"]
