"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scu import N_SEGMENTS, SEG_INTERCEPT, SEG_SLOPE, X_MAX, X_MIN
from repro.models.attention import full_attention
from repro.models.ssm import ssd_chunked
from .cim_matmul import TILE_K


def ref_pwl_exp(x):
    x = jnp.asarray(x, jnp.float32)
    xc = jnp.clip(x, X_MIN, X_MAX)
    idx = jnp.clip(((xc - X_MIN) / (X_MAX - X_MIN) * N_SEGMENTS)
                   .astype(jnp.int32), 0, N_SEGMENTS - 1)
    y = jnp.asarray(SEG_SLOPE)[idx] * xc + jnp.asarray(SEG_INTERCEPT)[idx]
    return jnp.where(x < X_MIN, 0.0, y)


def ref_pwl_softmax(x, axis: int = -1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = ref_pwl_exp(x - m)
    return (e / jnp.maximum(e.sum(axis=axis, keepdims=True), 1e-30)) \
        .astype(x.dtype)


def ref_softmax(x, axis: int = -1):
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis).astype(x.dtype)


def ref_flash_attention(q, k, v, *, causal=True):
    """Exact attention (same head count for q and k/v)."""
    return full_attention(q, k, v, causal=causal)


def ref_pwl_attention(q, k, v, *, causal=True):
    """Attention with PWL-exp softmax (the SCU numerics, dense form)."""
    B, Sq, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = ref_pwl_exp(s - m)
    p = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ref_paged_attention(q, k_cache, v_cache, block_tables, context_lens,
                        *, use_pwl=False):
    """Dense oracle of kernels.paged_attention: gather each sequence's
    K/V through its block table, mask past the context length, exact
    (or PWL) softmax.  q: (B, H, D); k/v_cache: (N, bt, H_kv, D)."""
    B, H, D = q.shape
    _, bt, H_kv, _ = k_cache.shape
    rep = H // H_kv
    exp_fn = ref_pwl_exp if use_pwl else jnp.exp
    outs = []
    for b in range(B):
        L = int(context_lens[b])
        if L == 0:
            # nothing attended: mirror the kernel's zero output
            outs.append(jnp.zeros((H, D), jnp.float32))
            continue
        nblk = -(-L // bt)
        ids = np.asarray(block_tables[b, :nblk])
        k = jnp.asarray(k_cache)[ids].reshape(nblk * bt, H_kv, D)[:L]
        v = jnp.asarray(v_cache)[ids].reshape(nblk * bt, H_kv, D)[:L]
        k = jnp.repeat(k, rep, axis=1)                  # (L, H, D)
        v = jnp.repeat(v, rep, axis=1)
        s = jnp.einsum("hd,lhd->hl", q[b].astype(jnp.float32),
                       k.astype(jnp.float32)) * (D ** -0.5)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = exp_fn(s - m)
        p = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
        outs.append(jnp.einsum("hl,lhd->hd", p, v.astype(jnp.float32)))
    return jnp.stack(outs).astype(q.dtype)


def ref_cim_matmul(x, wq, wscale, *, adc_bits=12, act_bits=8):
    """Tile-exact oracle of kernels.cim_matmul (block_m = M, block_n = N)."""
    M, K = x.shape
    N = wq.shape[1]
    kt = K // TILE_K
    x32 = x.astype(jnp.float32).reshape(M, kt, TILE_K)
    wq32 = wq.astype(jnp.float32).reshape(kt, TILE_K, N)
    qmax_a = 2.0 ** (act_bits - 1) - 1
    adc_max = 2.0 ** (adc_bits - 1) - 1
    out = jnp.zeros((M, N), jnp.float32)
    for ki in range(kt):
        xk = x32[:, ki]
        xs = (jnp.max(jnp.abs(xk), axis=1, keepdims=True) + 1e-9) / qmax_a
        xqk = jnp.clip(jnp.round(xk / xs), -qmax_a, qmax_a)
        psum = xqk @ wq32[ki]
        cal = jnp.maximum(jnp.max(jnp.abs(psum)), 1.0)
        code = jnp.clip(jnp.round(psum / cal * adc_max), -adc_max, adc_max)
        psum_q = code * (cal / adc_max)
        out = out + psum_q * xs * wscale[ki][None, :]
    return out


def ref_exact_matmul(x, w):
    return x.astype(jnp.float32) @ w.astype(jnp.float32)


def ref_ssd(x, dt, a_neg, B, C, *, chunk=128):
    y, _ = ssd_chunked(x, dt, a_neg, B, C, chunk)
    return y


def ref_ssd_recurrent(x, dt, a_neg, B, C):
    """Step-by-step recurrence — the independent slow oracle."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    state = jnp.zeros((b, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        dtt = dt[:, t].astype(jnp.float32)                     # (b, H)
        dA = jnp.exp(dtt * a_neg[None, :])
        upd = jnp.einsum("bhp,bn,bh->bhpn", x[:, t].astype(jnp.float32),
                         B[:, t].astype(jnp.float32), dtt)
        state = state * dA[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state,
                             C[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1)
