"""RRAM-CIM matmul arithmetic model as a Pallas TPU kernel.

The paper's PE is a 256x256 RRAM crossbar: 8-bit weights as conductances,
activations DAC'd in, analog MACs, ADC readout with a feedback-calibrated
scale that uses the full ADC input swing (paper §II-A).  Device physics
does not transfer to TPU (DESIGN.md §3); what we keep is the ARITHMETIC:

  * weights int8-quantized per 256-row tile with per-column scales,
  * activations int8-quantized per 256-row input slice (DAC range),
  * integer accumulate per tile (analog partial sum),
  * ADC: partial sums quantized to `adc_bits` codes with a per-tile
    calibration scale (the feedback loop maximizing ADC input swing),
  * fp32 recombination with the calibration scales.

The kernel walks a (M/bm, N/bn, K/256) grid; each K step is one crossbar's
contribution, accumulated in a VMEM scratch buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_K = 256


def quantize_weights(w, bits: int = 8):
    """Symmetric int8 quantization per (crossbar-tile, column).
    w: (K, N) -> (wq int8 (K, N), scales (K // TILE_K, N))."""
    K, N = w.shape
    kt = K // TILE_K
    wt = w.reshape(kt, TILE_K, N).astype(jnp.float32)
    qmax = 2.0 ** (bits - 1) - 1
    scale = (jnp.max(jnp.abs(wt), axis=1) + 1e-9) / qmax      # (kt, N)
    wq = jnp.clip(jnp.round(wt / scale[:, None, :]), -qmax, qmax)
    return wq.reshape(K, N).astype(jnp.int8), scale


def _cim_kernel(x_ref, wq_ref, wscale_ref, o_ref, acc_ref, *,
                kt, adc_bits, act_bits):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                   # (bm, TILE_K)
    # DAC: per-row activation quantization to the input range
    qmax_a = 2.0 ** (act_bits - 1) - 1
    xs = (jnp.max(jnp.abs(x), axis=1, keepdims=True) + 1e-9) / qmax_a
    xq = jnp.clip(jnp.round(x / xs), -qmax_a, qmax_a)
    wq = wq_ref[...].astype(jnp.float32)                 # (TILE_K, bn)
    # analog MAC: integer dot = one crossbar fire
    psum = xq @ wq                                       # (bm, bn)
    # ADC with feedback calibration to the observed swing (paper §II-A)
    adc_max = 2.0 ** (adc_bits - 1) - 1
    cal = jnp.maximum(jnp.max(jnp.abs(psum)), 1.0)
    code = jnp.clip(jnp.round(psum / cal * adc_max), -adc_max, adc_max)
    psum_q = code * (cal / adc_max)
    # recombine with DAC + weight scales
    wscale = wscale_ref[...].astype(jnp.float32)         # (1, bn)
    acc_ref[...] += psum_q * xs * wscale

    @pl.when(ki == kt - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "adc_bits", "act_bits", "interpret"))
def cim_matmul(x, wq, wscale, *, block_m: int = 128, block_n: int = 256,
               adc_bits: int = 12, act_bits: int = 8,
               interpret: bool = True):
    """x: (M, K) float; wq: (K, N) int8; wscale: (K//256, N) fp32.
    Returns (M, N) float32 — the CIM-quantized product."""
    M, K = x.shape
    _, N = wq.shape
    assert K % TILE_K == 0, "K must be a multiple of the crossbar rows"
    kt = K // TILE_K
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    assert M % block_m == 0 and N % block_n == 0
    grid = (M // block_m, N // block_n, kt)
    return pl.pallas_call(
        functools.partial(_cim_kernel, kt=kt, adc_bits=adc_bits,
                          act_bits=act_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, TILE_K), lambda i, j, k: (i, k)),
            pl.BlockSpec((TILE_K, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, wq, wscale)
