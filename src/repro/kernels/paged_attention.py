"""Paged decode-attention Pallas kernel: K/V gathered through a block table.

The serving engine (launch/serving_engine.py) stores KV in fixed-size
blocks of ``block_tokens`` tokens allocated by runtime/kv_cache.py; the
physical blocks of one sequence are scattered across the pool (scratchpad
striping + DRAM-hub spill), so decode attention must *gather* K/V through
the sequence's block table instead of slicing a contiguous cache.  This
is the vLLM PagedAttention access pattern mapped onto the repo's Pallas
idiom (flash_attention.py): one query token per sequence, online softmax
carried across KV blocks.

Layouts:
  q            (B, H, D)            one decode token per sequence
  k/v_cache    (N_blocks, block_tokens, H_kv, D)   the physical pool
  block_tables (B, max_blocks) int32  physical block id per logical block
                                      (entries past the context are unread)
  context_lens (B,) int32            tokens of valid context per sequence

The grid walks (B, H); the index maps slice the (GQA-shared) KV head and
the kernel body walks ``ceil(context/block_tokens)`` physical blocks with
``pl.dslice`` dynamic loads — block-table entries are read inside the
kernel, so the same program serves any paging layout.  ``use_pwl=True``
swaps jnp.exp for the SCU's 8-segment PWL approximation, as in
flash_attention.py — note the online-softmax rescaling then composes PWL
segments across blocks (PWL-exp is not multiplicative), so the result
approximates the SCU's one-pass softmax to PWL-segment accuracy rather
than bit-exactly; the exact-exp path matches the dense oracle to float
tolerance.  Validated against ``ref.ref_paged_attention`` in interpret
mode (tests/test_kv_cache.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pwl_softmax import _pwl_exp_vec

NEG_INF = -1e30


def _paged_kernel(q_ref, k_ref, v_ref, bt_ref, ctx_ref, o_ref, *,
                  block_tokens, use_pwl, scale):
    # q_ref: (D,); k_ref/v_ref: (N_blocks*block_tokens, D) for this kv
    # head; bt_ref: (max_blocks,); ctx_ref: (1,); o_ref: (D,)
    D = q_ref.shape[-1]
    q = q_ref[...].reshape(1, D).astype(jnp.float32) * scale
    ctx = ctx_ref[0]
    n_blocks = (ctx + block_tokens - 1) // block_tokens

    def exp_fn(x):
        return _pwl_exp_vec(x) if use_pwl else jnp.exp(x)

    def body(i, carry):
        m_prev, l_prev, acc = carry
        phys = bt_ref[i]
        k = k_ref[pl.dslice(phys * block_tokens, block_tokens), :] \
            .astype(jnp.float32)
        v = v_ref[pl.dslice(phys * block_tokens, block_tokens), :] \
            .astype(jnp.float32)
        s = q @ k.T                                  # (1, block_tokens)
        pos = i * block_tokens + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_tokens), 1)
        s = jnp.where(pos < ctx, s, NEG_INF)         # tail of last block
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = exp_fn(s - m_new[:, None])
        l_cur = jnp.sum(p, axis=-1)
        alpha = exp_fn(m_prev[:, None] - m_new[:, None])[:, 0]
        l_new = l_prev * alpha + l_cur
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((1,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    a0 = jnp.zeros((1, D), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]) \
        .reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("use_pwl", "interpret"))
def paged_attention(q, k_cache, v_cache, block_tables, context_lens, *,
                    use_pwl: bool = False, interpret: bool = True):
    """q: (B, H, D); k/v_cache: (N_blocks, block_tokens, H_kv, D);
    block_tables: (B, max_blocks) int32; context_lens: (B,) int32.
    H must be a multiple of H_kv (GQA share).  Returns (B, H, D)."""
    B, H, D = q.shape
    n_blocks, block_tokens, H_kv, Dk = k_cache.shape
    assert Dk == D and v_cache.shape == k_cache.shape
    assert H % H_kv == 0, "GQA requires H % H_kv == 0"
    rep = H // H_kv
    max_blocks = block_tables.shape[1]

    # pool flattened per kv head: (H_kv, N_blocks*block_tokens, D)
    kf = jnp.moveaxis(k_cache, 2, 0).reshape(H_kv, n_blocks * block_tokens, D)
    vf = jnp.moveaxis(v_cache, 2, 0).reshape(H_kv, n_blocks * block_tokens, D)
    bt = block_tables.astype(jnp.int32)
    ctx = context_lens.astype(jnp.int32).reshape(B, 1)

    grid = (B, H)
    out = pl.pallas_call(
        functools.partial(_paged_kernel, block_tokens=block_tokens,
                          use_pwl=use_pwl, scale=D ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, n_blocks * block_tokens, D),
                         lambda b, h: (h // rep, 0, 0)),
            pl.BlockSpec((None, n_blocks * block_tokens, D),
                         lambda b, h: (h // rep, 0, 0)),
            pl.BlockSpec((None, max_blocks), lambda b, h: (b, 0)),
            pl.BlockSpec((None, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(q, kf, vf, bt, ctx)
    return out
