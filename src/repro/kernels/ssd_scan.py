"""Mamba2 SSD chunk scan as a Pallas TPU kernel.

The chunked SSD algorithm (models/ssm.py) has one serial dimension — the
chunk index carrying the (P, N) state.  The kernel maps (batch*heads) to
grid dim 0 and chunks to grid dim 1; TPU grid iterations run sequentially
per core, so the inter-chunk state lives in a VMEM scratch that persists
across the chunk dimension.  Intra-chunk work is MXU matmuls on (L, L) and
(L, N) blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                nc):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xk = x_ref[...].astype(jnp.float32)          # (L, P)
    dtk = dt_ref[...].astype(jnp.float32)        # (L, 1)
    a = a_ref[0, 0]                              # scalar A (this head)
    Bk = b_ref[...].astype(jnp.float32)          # (L, N)
    Ck = c_ref[...].astype(jnp.float32)          # (L, N)
    L = xk.shape[0]

    dA = dtk[:, 0] * a                           # (L,)
    cs = jnp.cumsum(dA)                          # (L,)
    seg = cs[:, None] - cs[None, :]              # (L, L)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    att = (Ck @ Bk.T) * Lmat                     # (L, L)
    xdt = xk * dtk                               # (L, P)
    y = att @ xdt                                # intra-chunk
    state = state_ref[...].astype(jnp.float32)   # (P, N)
    y += jnp.exp(cs)[:, None] * (Ck @ state.T)   # inter-chunk contribution
    decay = jnp.exp(cs[-1] - cs)                 # (L,)
    new_state = (xk * dtk * decay[:, None]).T @ Bk      # (P, N)
    state_ref[...] = jnp.exp(cs[-1]) * state + new_state
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_neg, B, C, *, chunk: int = 128,
             interpret: bool = True):
    """x: (b, S, H, P); dt: (b, S, H) (>0); a_neg: (H,) (<0);
    B, C: (b, S, N).  Returns y: (b, S, H, P) float32.

    Equivalent to models.ssm.ssd_chunked (the jnp oracle)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    # layout: fold (b, H) into grid dim 0; chunks into grid dim 1
    xf = jnp.moveaxis(x, 2, 1).reshape(b * H, nc, L, P)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(b * H, nc, L, 1)
    af = jnp.tile(a_neg[None, :], (b, 1)).reshape(b * H, 1, 1)
    Bf = jnp.broadcast_to(B[:, None], (b, H, S, N)).reshape(b * H, nc, L, N)
    Cf = jnp.broadcast_to(C[:, None], (b, H, S, N)).reshape(b * H, nc, L, N)

    grid = (b * H, nc)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, L, P), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((None, None, L, 1), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((None, 1, 1), lambda g, c: (g, 0, 0)),
            pl.BlockSpec((None, None, L, N), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((None, None, L, N), lambda g, c: (g, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, L, P), lambda g, c: (g, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * H, nc, L, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, Bf, Cf)
    return jnp.moveaxis(y.reshape(b, H, S, P), 1, 2)
