"""SCU softmax as a Pallas TPU kernel (paper §II-C adapted to TPU).

The paper's Softmax Compute Unit evaluates exp() with an 8-segment
piecewise-linear approximation and streams: exp -> partial-sum -> reciprocal
-> scale.  The TPU adaptation tiles rows into VMEM blocks; the PWL exp is a
chain of vector selects (VPU-friendly — no transcendental unit needed,
matching the SCU's motivation).

Numerics match ``repro.core.scu.pwl_exp`` exactly (same segment coeffs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.scu import N_SEGMENTS, SEG_INTERCEPT, SEG_SLOPE, X_MAX, X_MIN

DEFAULT_BLOCK_ROWS = 256


def _pwl_exp_vec(x):
    """8-segment PWL exp for x <= 0 via select chain (vector-unit friendly)."""
    xc = jnp.clip(x, X_MIN, X_MAX)
    seg_w = (X_MAX - X_MIN) / N_SEGMENTS
    y = jnp.zeros_like(xc)
    for i in range(N_SEGMENTS):
        lo = X_MIN + i * seg_w
        sel = (xc >= lo) if i else jnp.ones_like(xc, bool)
        y = jnp.where(sel, SEG_SLOPE[i] * xc + SEG_INTERCEPT[i], y)
    return jnp.where(x < X_MIN, 0.0, y)


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = _pwl_exp_vec(x - m)                      # state 1: exp + cache
    s = jnp.sum(e, axis=-1, keepdims=True)       # state 1: partial sum
    r = 1.0 / jnp.maximum(s, 1e-30)              # state 2: reciprocal
    o_ref[...] = (e * r).astype(o_ref.dtype)     # state 3: scale


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pwl_softmax(x, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True):
    """Row softmax with PWL exp.  x: (..., n); softmax over the last dim."""
    orig_shape = x.shape
    n = orig_shape[-1]
    rows = int(np.prod(orig_shape[:-1])) if len(orig_shape) > 1 else 1
    x2 = x.reshape(rows, n)
    br = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        _softmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2)
    return out[:rows].reshape(orig_shape)
