"""FlashAttention Pallas TPU kernel with optional PWL-exp (SCU) softmax.

The paper schedules FlashAttention's two-level nested loop over the IPCN
mesh with DMAC routers doing QK^T/PV and the SCU die doing softmax.  The
TPU adaptation tiles the loop for VMEM/MXU instead: the grid walks
(batch*heads, q_blocks); the kernel body runs the kv loop with an online
softmax carried in VMEM scratch.  MXU-aligned block sizes (multiples of
128) are chosen by the wrapper.

``use_pwl=True`` swaps jnp.exp for the SCU's 8-segment PWL approximation —
the numerical-fidelity experiment for the paper's softmax unit lives in
tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .pwl_softmax import _pwl_exp_vec

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_blocks, block_k,
                  causal, use_pwl, scale):
    # q_ref: (block_q, D); k_ref/v_ref: (S, D); o_ref: (block_q, D)
    block_q, D = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    qi = pl.program_id(1)

    def exp_fn(x):
        return _pwl_exp_vec(x) if use_pwl else jnp.exp(x)

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.dslice(ki * block_k, block_k), :]
        v = v_ref[pl.dslice(ki * block_k, block_k), :]
        s = q @ k.astype(jnp.float32).T                     # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = exp_fn(s - m_new[:, None])
        l_cur = jnp.sum(p, axis=-1)
        alpha = exp_fn(m_prev[:, None] - m_new[:, None])[:, 0]
        l_new = l_prev * alpha + l_cur
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, D), jnp.float32)
    if causal:
        # only kv blocks at or below this q block contribute
        hi = jax.lax.min(jnp.int32(kv_blocks),
                         (qi + 1) * block_q // block_k
                         + jnp.int32(block_q % block_k != 0) + 1)
        hi = jax.lax.min(hi, jnp.int32(kv_blocks))
        m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    else:
        m, l, acc = jax.lax.fori_loop(0, kv_blocks, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "use_pwl", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, use_pwl: bool = False,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Skv, H, D) (same head count — the GQA
    repeat happens in ops.py).  Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, "pad upstream"

    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, D)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, Skv, D)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, Skv, D)

    kv_blocks = Skv // block_k
    grid = (B * H, Sq // block_q)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_blocks=kv_blocks,
                          block_k=block_k, causal=causal, use_pwl=use_pwl,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Skv, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Skv, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(B, H, Sq, D), 1, 2)
