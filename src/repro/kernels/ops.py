"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas kernels compile natively; everywhere else (this CPU
container) they run in interpret mode, and the framework's default model
paths use the pure-jnp implementations (models/attention.py etc.) which the
kernels are validated against in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import cim_matmul as _cim
from . import flash_attention as _fa
from . import paged_attention as _pa
from . import pwl_softmax as _ps
from . import ssd_scan as _ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def pwl_softmax(x, **kw):
    return _ps.pwl_softmax(x, interpret=_interp(), **kw)


def flash_attention(q, k, v, *, causal=True, use_pwl=False, **kw):
    """GQA-aware wrapper: repeats K/V heads to match Q, pads seq to the
    block size, then calls the kernel."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    block_q = kw.pop("block_q", 128)
    block_k = kw.pop("block_k", 128)
    bq = min(block_q, Sq)
    pad_q = (-Sq) % bq
    Skv = k.shape[1]
    bk = min(block_k, Skv)
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        # padded K rows must not win the softmax: rely on causal mask
        # (padded q rows attend only within real rows for causal=True)
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = _fa.flash_attention(q, k, v, causal=causal, use_pwl=use_pwl,
                              block_q=bq, block_k=bk,
                              interpret=_interp(), **kw)
    return out[:, :Sq]


def paged_attention(q, k_cache, v_cache, block_tables, context_lens, **kw):
    """Decode attention through a KV block table (runtime/kv_cache.py
    paging layout).  q: (B, H, D); k/v_cache: (N, block_tokens, H_kv, D)."""
    return _pa.paged_attention(q, k_cache, v_cache, block_tables,
                               context_lens, interpret=_interp(), **kw)


def cim_matmul(x, w, *, weight_bits=8, **kw):
    """Quantize weights then run the CIM kernel."""
    wq, wscale = _cim.quantize_weights(w, bits=weight_bits)
    return _cim.cim_matmul(x, wq, wscale, interpret=_interp(), **kw)


def ssd_scan(x, dt, a_neg, B, C, **kw):
    return _ssd.ssd_scan(x, dt, a_neg, B, C, interpret=_interp(), **kw)
