"""Global sharding context.

Model code is mesh-agnostic: it calls ``shard_hint(x, role)`` at activation
boundaries.  When a launcher has installed a :class:`ShardingCtx` (mesh +
role->PartitionSpec rules), the hint becomes a
``jax.lax.with_sharding_constraint``; otherwise it is a no-op (CPU smoke
tests, single-device examples).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: Dict[str, P],
                 options: Optional[Dict[str, object]] = None):
        self.mesh = mesh
        self.rules = dict(rules)
        # feature flags consumed by model code:
        #   sp_attention : shard_map ring-lite attention over the model
        #                  axis (seq-parallel) for train/prefill
        #   picnic_decode: shard_map partial-softmax decode over the
        #                  sequence-sharded KV cache (the PICNIC
        #                  distributed-scratchpad + in-network reduction)
        #   seq_axes     : mesh axes carrying the sequence dim
        #   dp_axes      : mesh axes carrying the batch dim
        self.options = dict(options or {})

    def spec(self, role: str) -> Optional[P]:
        return self.rules.get(role)

    def opt(self, name: str, default=None):
        return self.options.get(name, default)


def current() -> Optional[ShardingCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardingCtx]):
    prev = getattr(_state, "ctx", None)
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev


def shard_hint(x, role: str):
    ctx = current()
    if ctx is None:
        return x
    spec = ctx.spec(role)
    if spec is None:
        return x
    # Rank-adapt: drop trailing spec entries beyond x.ndim, pad with None.
    entries = list(spec)[: x.ndim]
    entries += [None] * (x.ndim - len(entries))
    # Drop axis entries that do not divide the dimension evenly.
    mesh = ctx.mesh
    fixed = []
    for dim, e in zip(x.shape, entries):
        if e is None:
            fixed.append(None)
            continue
        names = e if isinstance(e, tuple) else (e,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        fixed.append(e if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
