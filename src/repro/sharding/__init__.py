from .ctx import ShardingCtx, shard_hint, use_sharding, current
from .shmap import shard_map

__all__ = ["ShardingCtx", "shard_hint", "use_sharding", "current",
           "shard_map"]
