from .ctx import ShardingCtx, shard_hint, use_sharding, current

__all__ = ["ShardingCtx", "shard_hint", "use_sharding", "current"]
