"""PartitionSpec inference for params / optimizer state / caches / batches.

Three modes (DESIGN.md §5):
  * ``train`` / ``prefill``: ZeRO-3/FSDP — every weight sharded on its
    largest evenly-divisible dim over ``cfg.fsdp_axes``; the per-layer
    all-gather happens inside the layer scan (the CCPG analogue).
  * ``decode``: weights persistently TP-sharded on their largest dim over
    ``model`` (Megatron pairing falls out: for (d, f) the f/output dim is
    sharded, for (f, d) the f/input dim — one psum per block).  MoE experts
    are EP-sharded (expert dim over ``model``, falling back to expert dim
    over ``data`` + inner dim over ``model`` for 400B-class models).
  * KV caches are SEQUENCE-sharded over ``model`` (PICNIC distributed-
    scratchpad scheme) — over ("data","model") for the 500k single-batch
    shape.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.tree_util import tree_flatten_with_path, tree_unflatten, DictKey


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _best_dim(shape, skip_dims, divisor) -> int:
    """Largest dim (by size) not in skip_dims divisible by divisor; -1 if none."""
    best, best_size = -1, 0
    for i, s in enumerate(shape):
        if i in skip_dims:
            continue
        if s % divisor == 0 and s >= divisor and s > best_size:
            best, best_size = i, s
    return best


def _spec_with(ndim, assignments: Dict[int, Any]) -> P:
    entries = [assignments.get(i) for i in range(ndim)]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def param_specs(cfg, params_shapes, mesh: Mesh, mode: str,
                mlp_tp: bool = False):
    """Pytree of PartitionSpec matching params_shapes.

    mlp_tp: Megatron-style tensor parallelism for the MLP weights in
    training (d_ff dim over "model") — their grads then come out locally
    sharded instead of being all-reduced at full width inside the layer
    scan (EXPERIMENTS.md §Perf, train iteration 3)."""
    flat, treedef = tree_flatten_with_path(params_shapes)
    fsdp = tuple(a for a in cfg.fsdp_axes if a in mesh.shape)
    fsdp_div = _axes_size(mesh, fsdp)
    model_div = mesh.shape.get("model", 1)
    data_div = mesh.shape.get("data", 1)

    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = leaf.shape
        stacked = ("layers" in ps) and len(shape) >= 2
        skip = {0} if stacked else set()

        if len(shape) <= 1:
            specs.append(P())
            continue

        is_expert = ("moe" in ps and "router" not in ps and
                     len(shape) - (1 if stacked else 0) >= 3)
        leaf_name = ps.split("/")[-1].strip("'[]")

        if mode == "train" and mlp_tp and leaf_name in (
                "w_gate", "w_up", "w_down") and not is_expert:
            off = 1 if stacked else 0
            # w_gate/w_up: (..., d, f) -> shard f (last); w_down: (..., f, d)
            ff_dim = len(shape) - 1 if leaf_name in ("w_gate", "w_up") else off
            if shape[ff_dim] % model_div == 0:
                a = {ff_dim: ("model",)}
                # shard the other big dim over "data" (ZeRO-ish)
                other = off if ff_dim != off else len(shape) - 1
                if shape[other] % data_div == 0:
                    a[other] = ("data",)
                specs.append(_spec_with(len(shape), a))
                continue

        if mode in ("train", "prefill"):
            if is_expert:
                # shard expert dim over fsdp axes if divisible, else inner
                e_dim = 1 if stacked else 0
                E = shape[e_dim]
                if E % fsdp_div == 0:
                    specs.append(_spec_with(len(shape), {e_dim: fsdp}))
                    continue
                d = _best_dim(shape, skip | {e_dim}, fsdp_div)
                if d >= 0:
                    specs.append(_spec_with(len(shape), {d: fsdp}))
                    continue
            d = _best_dim(shape, skip, fsdp_div)
            if d >= 0:
                specs.append(_spec_with(len(shape), {d: fsdp}))
                continue
            d = _best_dim(shape, skip, model_div)
            if d >= 0:
                specs.append(_spec_with(len(shape), {d: ("model",)}))
                continue
            specs.append(P())
            continue

        # mode == "decode": persistent TP / EP
        if is_expert:
            e_dim = 1 if stacked else 0
            E = shape[e_dim]
            # prefer the MOST sharding: a 400B expert stack needs both axes
            if E % (data_div * model_div) == 0:
                specs.append(_spec_with(len(shape),
                                        {e_dim: ("data", "model")}))
                continue
            if E % data_div == 0:
                inner = _best_dim(shape, skip | {e_dim}, model_div)
                a = {e_dim: ("data",)}
                if inner >= 0:
                    a[inner] = ("model",)
                specs.append(_spec_with(len(shape), a))
                continue
            if E % model_div == 0:
                specs.append(_spec_with(len(shape), {e_dim: ("model",)}))
                continue
        d = _best_dim(shape, skip, model_div)
        if d >= 0:
            specs.append(_spec_with(len(shape), {d: ("model",)}))
            continue
        specs.append(P())

    return tree_unflatten(treedef, specs)


def opt_state_specs(cfg, opt_shapes, params_specs, mesh: Mesh,
                    opt_axes: Tuple[str, ...] = ("data", "model")):
    """Optimizer-state specs: always ZeRO-sharded over `opt_axes` (the
    fp32 moments must spread over as many chips as possible regardless of
    how the bf16 params themselves are sharded — a 34B AdamW state is
    17 GB/chip at 16-way but 1 GB/chip at 256-way)."""
    flat, treedef = tree_flatten_with_path(opt_shapes)
    axes = tuple(a for a in opt_axes if a in mesh.shape)
    div = _axes_size(mesh, axes)
    model_div = mesh.shape.get("model", 1)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        if len(leaf.shape) <= 1:
            specs.append(P())
            continue
        stacked = "layers" in ps and len(leaf.shape) >= 2
        skip = {0} if stacked else set()
        d = _best_dim(leaf.shape, skip, div)
        if d >= 0:
            specs.append(_spec_with(len(leaf.shape), {d: axes}))
            continue
        d = _best_dim(leaf.shape, skip, model_div)
        specs.append(_spec_with(len(leaf.shape), {d: ("model",)})
                     if d >= 0 else P())
    return tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Cache / batch specs
# ---------------------------------------------------------------------------

def cache_specs(cfg, cache_shapes, mesh: Mesh, *, long_context: bool = False):
    """Cache leaves: k/v (G,B,S,H,D) seq-sharded over model (PICNIC
    distributed scratchpad), (data,model) for the 500k batch-1 shape."""
    dp = dp_axes(mesh)
    dpsize = _axes_size(mesh, dp)
    model_div = mesh.shape.get("model", 1)
    seq_axes = ("data", "model") if long_context else ("model",)
    seq_div = _axes_size(mesh, seq_axes)

    flat, treedef = tree_flatten_with_path(cache_shapes)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        name = ps.split("/")[-1]
        shape = leaf.shape
        a: Dict[int, Any] = {}
        B = shape[1] if len(shape) >= 2 else 0
        if B and B % dpsize == 0:
            a[1] = dp
        elif B and B % mesh.shape.get("data", 1) == 0:
            a[1] = ("data",)
        if name in ("k", "v"):
            if shape[2] % seq_div == 0:
                a[2] = seq_axes
            elif shape[2] % model_div == 0:
                a[2] = ("model",)
        elif name in ("cross_k", "cross_v"):
            if shape[3] % model_div == 0:   # heads (20 not div 16 -> skip)
                a[3] = ("model",)
        elif name == "ssm":
            if shape[2] % model_div == 0:   # heads
                a[2] = ("model",)
        elif name == "conv":
            if shape[3] % model_div == 0:   # conv channels
                a[3] = ("model",)
        specs.append(_spec_with(len(shape), a))
    return tree_unflatten(treedef, specs)


def batch_specs(cfg, batch_shapes, mesh: Mesh):
    dp = dp_axes(mesh)
    dpsize = _axes_size(mesh, dp)
    flat, treedef = tree_flatten_with_path(batch_shapes)
    specs = []
    for path, leaf in flat:
        shape = leaf.shape
        a: Dict[int, Any] = {}
        if len(shape) >= 1 and shape[0] % dpsize == 0:
            a[0] = dp
        elif len(shape) >= 1 and shape[0] % mesh.shape.get("data", 1) == 0:
            a[0] = ("data",)
        specs.append(_spec_with(len(shape), a))
    return tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Activation rules (consumed by shard_hint via ShardingCtx)
# ---------------------------------------------------------------------------

def activation_rules(cfg, mesh: Mesh, mode: str, *,
                     long_context: bool = False) -> Dict[str, P]:
    dp = dp_axes(mesh)
    seq_axes = ("data", "model") if long_context else ("model",)
    model_div = mesh.shape.get("model", 1)
    # MoE dispatch buffers (B, E, C, d): shard E over "model" when it
    # divides, else the capacity dim — without this the (B,E,C,d) buffer
    # of a 128-expert model is 80+ GB/device at train shapes.
    if cfg.moe and cfg.moe.n_experts % model_div == 0:
        moe_buf = P(dp, ("model",))
    else:
        moe_buf = P(dp, None, ("model",))
    if mode == "train":
        # Sequence-parallel training: batch over dp, seq over "model".
        # Without the seq split every device in a model row would repeat
        # identical full-width matmuls on the same batch shard (16x wasted
        # FLOPs — caught by the trip-count-corrected dry-run accounting,
        # see EXPERIMENTS.md §Perf).
        return {
            "act_btd": P(dp, ("model",)),
            "act_ffn": P(dp, ("model",)),
            "act_heads": P(dp, ("model",)),      # q stays seq-sharded
            "act_kv_heads": P(dp),               # k/v gathered (GQA-small)
            "logits": P(dp, ("model",)),
            "moe_buffer": moe_buf,
            "moe_ffn": P(dp, None, None, ("model",)),
            "ssm_heads": P(dp),
        }
    if mode == "prefill":
        return {
            "act_btd": P(dp, ("model",)),        # sequence parallel
            "act_ffn": P(dp, ("model",)),
            "act_heads": P(dp, ("model",)),      # q stays seq-sharded
            "act_kv_heads": P(dp),               # k/v gathered (GQA-small)
            "logits": P(dp, ("model",)),
            "moe_buffer": moe_buf,
            "moe_ffn": P(dp, None, None, ("model",)),
            "ssm_heads": P(dp),
        }
    # decode
    return {
        "act_btd": P(dp),
        "act_ffn": P(dp, None, ("model",)),
        "act_heads": P(dp),
        "act_kv_heads": P(dp),
        "kv_cache": P(None, dp, seq_axes),
        "logits": P(dp, None, ("model",)),
        "moe_buffer": P(dp, ("model",)) if (cfg.moe and
            cfg.moe.n_experts % mesh.shape.get("model", 1) == 0) else P(dp),
        "moe_ffn": P(dp),
        "ssm_heads": P(dp, None, ("model",)),
    }


def to_named(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
