"""Version-portable ``shard_map``.

Every shard_map in the repo (SP ring-lite attention, partial-softmax PICNIC
decode, GPipe pipeline, compressed psum) goes through :func:`shard_map`
below, written against the NEW JAX surface (``check_vma`` +
``axis_names``-are-the-manual-axes) and translated at call time onto
whatever this JAX provides:

* JAX ≥ 0.6-era: ``jax.shard_map(..., check_vma=..., axis_names=...)``
  — passed through unchanged.
* JAX 0.4.x: ``jax.experimental.shard_map.shard_map(..., check_rep=...,
  auto=...)`` — ``check_vma`` renamed to ``check_rep``; the manual-axes
  set is complemented into ``auto`` (the axes GSPMD keeps automatic).

Callers may use either era's spelling (``check_rep``/``auto`` are accepted
as aliases); :mod:`repro.compat` holds the translation table.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro import compat


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None,
              axis_names=None, auto=None) -> Callable:
    """Portable shard_map.

    Parameters mirror ``jax.shard_map``; ``check_rep`` and ``auto`` are
    accepted as the legacy aliases of ``check_vma`` and the complement of
    ``axis_names``.  ``axis_names``/``auto`` omitted → fully manual.
    """
    native = compat.resolve_shard_map()
    kw = compat.translate_shard_map_kwargs(
        compat.shard_map_param_names(native), mesh.axis_names,
        check_vma=check_vma, check_rep=check_rep,
        axis_names=axis_names, auto=auto)
    return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
