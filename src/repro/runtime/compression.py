"""Gradient compression for DP all-reduce: int8 + error feedback.

Standard large-scale trick: quantize gradients to int8 with a per-tensor
scale before the data-parallel reduction (4x wire bytes saved), carry the
quantization residual into the next step (error feedback keeps convergence
unbiased to first order).  ``compressed_psum`` composes with shard_map or
plain pytree reduction; the hillclimb in EXPERIMENTS.md §Perf measures the
collective-term delta on the dry-run.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-9) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error_state):
    """-> (quantized pytree {q, scale}, new_error_state).
    error_state mirrors grads (fp32 residuals)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return {"q": q, "scale": s}, g32 - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    qs, es = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return treedef.unflatten(list(qs)), treedef.unflatten(list(es))


def decompress(qtree):
    is_q = lambda x: isinstance(x, dict) and "q" in x and "scale" in x
    return jax.tree_util.tree_map(
        lambda d: dequantize_int8(d["q"], d["scale"]), qtree, is_leaf=is_q)


def init_error_state(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, error_state, axis_name: str):
    """int8 ring-friendly psum: quantize locally (with feedback), psum the
    int32-widened codes, dequantize with the max scale.  Inside shard_map.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        s_max = jax.lax.pmax(s, axis_name)
        # requantize against the shared scale so the sum is exact in int32
        q2 = jnp.clip(jnp.round(g32 / s_max), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q2, axis_name)
        new_e = g32 - q2.astype(jnp.float32) * s_max
        return (total.astype(jnp.float32) * s_max).astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    outs, errs = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return treedef.unflatten(list(outs)), treedef.unflatten(list(errs))


def compressed_allreduce(grads, error_state, mesh, axis_name: str):
    """:func:`compressed_psum` wrapped in a (version-portable) shard_map.

    ``grads``/``error_state``: pytrees whose leaves are sharded on their
    leading dim over ``axis_name``.  Returns (reduced grads, new error
    state) with the same sharding.  This is the standalone entry point the
    DP hillclimb and the distributed tests drive; inside a larger
    shard_map call :func:`compressed_psum` directly.
    """
    from repro.sharding.shmap import shard_map

    spec = jax.sharding.PartitionSpec(axis_name)

    def body(g, e):
        return compressed_psum(g, e, axis_name)

    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec),
                   out_specs=(spec, spec), check_vma=False)
    return fn(grads, error_state)
