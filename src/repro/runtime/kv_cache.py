"""Paged KV-cache block allocator over the chiplet scratchpad budget.

The paper keeps KV in the 32 KB PE-local scratchpads (cyclically striped,
``core/partition.ScratchpadPlan``) with a DRAM hub reachable over the
photonic C2C link for everything that does not fit (paper §II; the same
tier split Sangam prices over CXL and the Photonic Fabric platform prices
over photonics — PAPERS.md).  This module is the vLLM-style allocator
that makes that hierarchy a *finite* resource the serving engine must
schedule against:

  * KV is allocated in fixed-size **blocks** of ``block_tokens`` tokens;
    a request owns a **block table** (ordered physical block ids).
  * Two tiers share one physical id space: scratchpad blocks are ids
    ``[0, n_blocks)``, DRAM-hub blocks are ``[n_blocks, n_blocks +
    dram_blocks)`` — a block's tier is just an id comparison.
  * When the scratchpad tier is exhausted and DRAM capacity remains, the
    allocator **spills** the coldest scratchpad-resident block (the
    oldest block of the request holding the most scratchpad blocks) to a
    DRAM block and hands the freed scratchpad block to the requester, so
    hot (recent) KV stays chiplet-local.  Every spill invokes
    ``on_spill(nbytes)`` — the serving engine charges it as a
    ``C2CTransfer`` on the TimelineIR plus DRAM access energy.
  * When both tiers are exhausted, ``OutOfBlocks`` is raised and the
    engine preempts (recompute-on-resume, watermark-gated).
  * With ``prefix_sharing`` enabled (ISSUE 6), every block carries a
    **refcount** and full prompt blocks are indexed by the chain hash of
    their token chunks (vLLM automatic-prefix-caching style): a new
    request whose prompt matches an indexed chain *adopts* the shared
    physical blocks instead of recomputing them, and at the first
    divergent token it **forks copy-on-write** — a private block whose
    matching head is copied (``on_cow(nbytes)``) and whose tail the
    request writes itself.  Shared blocks are immutable; spilling one
    re-tiers it in EVERY reader's table; freeing one reader only
    decrements the refcount — the block returns to the free list (and
    leaves the prefix index) when the last reader releases it.

Pure Python — no jax, no numpy — so the discrete-event serving loop
stays fast and import-light.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple)


class OutOfBlocks(RuntimeError):
    """Both KV tiers are exhausted; the caller must preempt or wait."""


@dataclass(frozen=True)
class KVCacheConfig:
    """Sizing of the two-tier paged KV cache.

    ``n_blocks``        scratchpad-tier blocks (the chiplet-local budget)
    ``block_tokens``    tokens per block (vLLM-style page size)
    ``dram_blocks``     DRAM-hub tier blocks reachable over the photonic
                        link; 0 disables spilling entirely
    ``watermark_frac``  preemption watermark: when a decode round needs
                        new blocks and the free total is below this
                        fraction of the scratchpad tier, the engine
                        preempts before allocating
    ``bytes_per_token`` KV bytes one token occupies across all layers
                        (see :func:`kv_bytes_per_token`)
    ``prefix_sharing``  enable vLLM-style prefix reuse: full prompt
                        blocks are hash-indexed, matching requests adopt
                        them (refcounted) and fork copy-on-write at the
                        first divergent token.  OFF by default — the
                        default path stays byte-identical to the
                        pre-sharing allocator/engine (golden-locked)
    """
    n_blocks: int
    block_tokens: int = 16
    dram_blocks: int = 0
    watermark_frac: float = 0.05
    bytes_per_token: int = 4096
    prefix_sharing: bool = False

    def __post_init__(self):
        if self.n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        if self.block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        if self.dram_blocks < 0:
            raise ValueError("dram_blocks must be >= 0")
        if not 0.0 <= self.watermark_frac < 1.0:
            raise ValueError("watermark_frac must be in [0, 1)")

    @property
    def block_bytes(self) -> int:
        return self.block_tokens * self.bytes_per_token

    @property
    def total_blocks(self) -> int:
        return self.n_blocks + self.dram_blocks

    @property
    def watermark_blocks(self) -> int:
        return max(1, int(self.n_blocks * self.watermark_frac))

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` context tokens."""
        return -(-max(n_tokens, 0) // self.block_tokens)


@dataclass
class BlockTable:
    """One request's ordered physical block ids (oldest tokens first).

    ``n_dram`` counts the DRAM-resident ids in ``blocks`` (maintained
    incrementally so per-iteration residency queries are O(1)), and
    ``scan`` is the oldest-scratch-block search hint: positions only
    ever convert scratch -> DRAM, so the hint advances monotonically and
    victim lookup is amortized O(1) over the table's lifetime."""
    request_id: int
    blocks: List[int] = field(default_factory=list)
    tokens: int = 0                  # context tokens currently stored
    n_dram: int = 0                  # DRAM-resident entries of `blocks`
    scan: int = 0                    # first index that may be scratch

    @property
    def n_scratch(self) -> int:
        return len(self.blocks) - self.n_dram


@dataclass(frozen=True)
class KVHandoff:
    """The serialized block set of one finished-prefill request — what
    moves over the photonic fabric in a prefill -> decode node handoff
    (launch/fleet_engine.py).  The simulator carries no tensor data, so only
    the logical shape travels: the context token count and the
    block-padded byte footprint.  The destination allocator
    re-materializes a fresh LOCAL table from it (:meth:`BlockAllocator
    .import_table`); physical block ids are allocator-private and never
    cross nodes."""
    request_id: int
    tokens: int                 # context tokens the table covered
    n_blocks: int
    nbytes: int                 # block-padded wire footprint


_CHAIN_SEED = 0x9E3779B9   # root of every prefix hash chain


class BlockAllocator:
    """Two-tier block allocator with spill-to-DRAM, refcounted prefix
    sharing / copy-on-write, and exact accounting.

    Invariants (property-tested in tests/test_kv_cache.py):
      * every physical id is either free or owned by >= 1 table, never
        both; ``refcnt[b]`` == the number of tables containing ``b``
        (a table never contains the same block twice);
      * ``free_scratch + free_dram + distinct owned == total_blocks``;
      * a table covers its token count: ``len(blocks) * block_tokens >=
        tokens`` with no over-allocation beyond one partial block;
      * an indexed (shareable) block's token contents never change while
        any table references it — shared blocks are immutable, divergent
        writers fork copy-on-write instead.
    """

    def __init__(self, cfg: KVCacheConfig,
                 on_spill: Optional[Callable[[int], None]] = None,
                 on_cow: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.on_spill = on_spill
        self.on_cow = on_cow
        # stacks: pop() from the end keeps allocation order deterministic
        self._free_scratch: List[int] = list(range(cfg.n_blocks))[::-1]
        self._free_dram: List[int] = list(
            range(cfg.n_blocks, cfg.n_blocks + cfg.dram_blocks))[::-1]
        self.tables: Dict[int, BlockTable] = {}
        # block ownership: physical id -> reader count / reader set.
        # Maintained on every path (refcnt is 1 everywhere with sharing
        # off) so spill re-tiering and free stay one code path.
        self.refcnt: Dict[int, int] = {}
        self._refs: Dict[int, Set[int]] = {}
        # prefix index (prefix_sharing only): chain hash of a prompt's
        # full token chunks -> the physical block holding that chunk.
        #   _hash_of / _parent_of   reverse maps for O(1) un-indexing
        #   _next                   parent hash -> first indexed child
        #                           (the COW divergence candidate)
        #   _tok_of                 indexed block -> its token chunk
        #                           (compared at COW fork time)
        self._index: Dict[int, int] = {}
        self._hash_of: Dict[int, int] = {}
        self._parent_of: Dict[int, int] = {}
        self._next: Dict[int, int] = {}
        self._tok_of: Dict[int, Tuple] = {}
        # bumped whenever the set of indexed chains changes, so callers
        # (the engine's admission probe) can cache lookup results
        self.index_version = 0
        # spill-victim index: a lazy max-heap of (-n_scratch, rid)
        # snapshots.  Every scratch-count change pushes the table's NEW
        # state, so the heap always contains one entry matching each
        # table's current count; stale snapshots are discarded on pop.
        # Selection is O(log n) amortized instead of the former
        # sorted(self.tables) + per-block enumeration scan.
        self._victim_heap: List[Tuple[int, int]] = []
        # lifetime stats
        self.spilled_blocks = 0
        self.spilled_bytes = 0
        self.peak_used = 0
        self.prefix_hits = 0          # whole blocks adopted via the index
        self.shared_tokens_saved = 0  # prompt tokens never recomputed
        self.cow_forks = 0
        self.cow_copied_bytes = 0
        self.n_shared_blocks = 0      # blocks with refcnt >= 2 right now
        self.peak_shared_blocks = 0
        # fleet handoff accounting (kept OFF KVCacheStats.row() so
        # single-node paged artifacts stay byte-identical)
        self.exported_tables = 0
        self.exported_bytes = 0
        self.imported_tables = 0

    # -- tier predicates ----------------------------------------------
    def is_dram(self, block_id: int) -> bool:
        return block_id >= self.cfg.n_blocks

    # -- capacity queries ---------------------------------------------
    def free_scratch(self) -> int:
        return len(self._free_scratch)

    def free_total(self) -> int:
        return len(self._free_scratch) + len(self._free_dram)

    def used_blocks(self) -> int:
        return self.cfg.total_blocks - self.free_total()

    def feasible(self, n_tokens: int) -> bool:
        """Could a request of ``n_tokens`` EVER fit (both tiers empty)?"""
        return self.cfg.blocks_for(n_tokens) <= self.cfg.total_blocks

    def can_admit(self, n_tokens: int, *, reserve: int = 0,
                  shared_blocks: int = 0) -> bool:
        """Are there enough free blocks (both tiers) to admit a request
        needing ``n_tokens``, keeping ``reserve`` blocks of headroom?
        ``shared_blocks`` credits blocks the request would ADOPT from the
        prefix index instead of allocating (see :meth:`probe_prefix`) —
        admission sees EFFECTIVE demand, net of sharing."""
        need = max(0, self.cfg.blocks_for(n_tokens) - shared_blocks)
        return need + reserve <= self.free_total()

    def scratch_tokens(self, request_id: int) -> int:
        t = self.tables[request_id]
        return t.tokens - self.dram_tokens(request_id)

    def dram_tokens(self, request_id: int) -> int:
        """Context tokens resident in the DRAM-hub tier — the per-decode-
        iteration remote-read volume for this request.  O(1): the table
        carries its DRAM-entry count instead of re-scanning its blocks
        every serving iteration."""
        t = self.tables[request_id]
        return min(t.n_dram * self.cfg.block_tokens, t.tokens)

    def dram_tokens_total(self, request_ids) -> int:
        """Sum of :meth:`dram_tokens` over ``request_ids`` in one pass —
        the per-round remote-read volume of a whole resident set."""
        bt = self.cfg.block_tokens
        tables = self.tables
        total = 0
        for rid in request_ids:
            t = tables[rid]
            n = t.n_dram * bt
            total += n if n < t.tokens else t.tokens
        return total

    # -- allocation ----------------------------------------------------
    def ensure(self, request_id: int, n_tokens: int) -> int:
        """Grow ``request_id``'s table to cover ``n_tokens`` context
        tokens; returns the number of newly allocated blocks.  Raises
        :class:`OutOfBlocks` (after allocating what it could — the
        partial growth is kept, a retry continues from it)."""
        t = self.tables.setdefault(request_id, BlockTable(request_id))
        grown = 0
        bt = self.cfg.block_tokens
        while len(t.blocks) * bt < n_tokens:
            try:
                block = self._take_block()
            except OutOfBlocks:
                # keep the table coherent with its partial growth so the
                # invariant len(blocks) == blocks_for(tokens) still holds
                # and a retry (after preemption) continues from here
                t.tokens = max(t.tokens, min(n_tokens, len(t.blocks) * bt))
                raise
            self._append_new(t, block)
            grown += 1
        t.tokens = max(t.tokens, n_tokens)
        used = self.used_blocks()
        if used > self.peak_used:
            self.peak_used = used
        return grown

    def grow_round(self, items) -> bool:
        """Batched :meth:`ensure` for one decode round: grow every
        ``(request_id, n_tokens)`` table in ``items`` in a single pass.
        Only takes the all-scratchpad fast path — when the round's total
        growth fits the scratch free list, the pops land in exactly the
        order sequential :meth:`ensure` calls would produce (same block
        ids to the same tables, same ``peak_used``).  Returns ``False``
        with NO state touched when spill/DRAM handling would be needed;
        the caller then falls back to per-request :meth:`ensure` with its
        preemption/retry loop."""
        cfg = self.cfg
        grow = []
        total = 0
        for request_id, n_tokens in items:
            t = self.tables[request_id]
            k = cfg.blocks_for(n_tokens) - len(t.blocks)
            if k > 0 or n_tokens > t.tokens:
                grow.append((t, n_tokens, k))
                total += k
        if total > len(self._free_scratch):
            return False
        pop = self._free_scratch.pop
        for t, n_tokens, k in grow:
            for _ in range(k):
                self._append_new(t, pop())
            if n_tokens > t.tokens:
                t.tokens = n_tokens
        if total:
            used = self.used_blocks()
            if used > self.peak_used:
                self.peak_used = used
        return True

    def free(self, request_id: int) -> int:
        """Release ``request_id``'s reference on every block of its
        table; a block returns to the free list (and leaves the prefix
        index) only when its LAST reader releases it.  Returns the
        table's block count."""
        t = self.tables.pop(request_id)
        for b in reversed(t.blocks):
            self._release_block(b, request_id)
        return len(t.blocks)

    # -- fleet handoff (serialize / re-admit a resident block set) -----
    def export_table(self, request_id: int) -> KVHandoff:
        """Serialize ``request_id``'s block set for a cross-node handoff
        and RELEASE it locally: the returned :class:`KVHandoff` carries
        the logical footprint (tokens, blocks, block-padded bytes) that
        rides the fabric; the physical blocks go back to this
        allocator's free lists (and leave the prefix index with their
        last reader, like any :meth:`free`)."""
        t = self.tables[request_id]
        h = KVHandoff(request_id=request_id, tokens=t.tokens,
                      n_blocks=len(t.blocks),
                      nbytes=len(t.blocks) * self.cfg.block_bytes)
        self.free(request_id)
        self.exported_tables += 1
        self.exported_bytes += h.nbytes
        return h

    def import_table(self, request_id: int, tokens) -> int:
        """Re-admit a handed-off block set on THIS allocator: allocate
        fresh local blocks covering ``tokens`` context tokens (a
        :class:`KVHandoff` or a plain count).  Raises
        :class:`OutOfBlocks` like :meth:`ensure` (partial growth kept —
        the caller frees or retries) and ``ValueError`` if the id is
        already resident.  Returns the number of blocks allocated."""
        if isinstance(tokens, KVHandoff):
            tokens = tokens.tokens
        if request_id in self.tables:
            raise ValueError(
                f"request {request_id} already resident; cannot import")
        n = self.ensure(request_id, int(tokens))
        self.imported_tables += 1
        return n

    # -- refcount plumbing ---------------------------------------------
    def _append_new(self, t: BlockTable, block: int) -> None:
        """Append a freshly allocated (refcount 1) block to a table."""
        t.blocks.append(block)
        self.refcnt[block] = 1
        self._refs[block] = {t.request_id}
        if self.is_dram(block):
            t.n_dram += 1
        else:
            heapq.heappush(self._victim_heap,
                           (-t.n_scratch, t.request_id))

    def _append_shared(self, t: BlockTable, block: int) -> None:
        """Append an existing block as an additional reader."""
        t.blocks.append(block)
        n = self.refcnt[block] = self.refcnt[block] + 1
        self._refs[block].add(t.request_id)
        if n == 2:
            self.n_shared_blocks += 1
            if self.n_shared_blocks > self.peak_shared_blocks:
                self.peak_shared_blocks = self.n_shared_blocks
        if self.is_dram(block):
            t.n_dram += 1
        else:
            heapq.heappush(self._victim_heap,
                           (-t.n_scratch, t.request_id))

    def _release_block(self, block: int, request_id: int) -> None:
        n = self.refcnt[block] - 1
        self._refs[block].discard(request_id)
        if n >= 1:
            self.refcnt[block] = n
            if n == 1:
                self.n_shared_blocks -= 1
            return
        del self.refcnt[block]
        del self._refs[block]
        self._unindex(block)
        (self._free_dram if self.is_dram(block)
         else self._free_scratch).append(block)

    # -- internals -----------------------------------------------------
    def _take_block(self) -> int:
        if self._free_scratch:
            return self._free_scratch.pop()
        if self._free_dram:
            victim = self._spill_victim()
            if victim is None:
                # nothing scratch-resident to displace: hand out DRAM
                return self._free_dram.pop()
            table, idx = victim
            dram_id = self._free_dram.pop()
            scratch_id = table.blocks[idx]
            self._retier(scratch_id, dram_id, table, idx)
            self.spilled_blocks += 1
            self.spilled_bytes += self.cfg.block_bytes
            if self.on_spill is not None:
                self.on_spill(self.cfg.block_bytes)
            return scratch_id                  # freed pad goes to caller
        raise OutOfBlocks(
            f"KV cache exhausted: {self.cfg.n_blocks} scratchpad + "
            f"{self.cfg.dram_blocks} DRAM blocks all in use")

    def _retier(self, old: int, new: int, victim: BlockTable,
                idx_hint: int) -> None:
        """Move block ``old`` (scratch) to physical id ``new`` (DRAM) in
        EVERY reader's table.  Shared prefix blocks sit at the same table
        position in every reader (the prefix invariant), so ``idx_hint``
        from the victim table almost always applies; ``.index`` is the
        defensive fallback."""
        for rid in self._refs[old]:
            t = victim if rid == victim.request_id else self.tables[rid]
            i = idx_hint if (idx_hint < len(t.blocks)
                             and t.blocks[idx_hint] == old) \
                else t.blocks.index(old)
            t.blocks[i] = new
            t.n_dram += 1
            heapq.heappush(self._victim_heap, (-t.n_scratch, rid))
        # ownership + prefix-index metadata follow the content to its id
        self.refcnt[new] = self.refcnt.pop(old)
        self._refs[new] = self._refs.pop(old)
        h = self._hash_of.pop(old, None)
        if h is not None:
            self._hash_of[new] = h
            if self._index.get(h) == old:
                self._index[h] = new
            parent = self._parent_of.pop(old)
            self._parent_of[new] = parent
            if self._next.get(parent) == old:
                self._next[parent] = new
            self._tok_of[new] = self._tok_of.pop(old)
            self.index_version += 1

    # -- prefix sharing / copy-on-write --------------------------------
    def chunk_hashes(self, tokens: Sequence[int]) -> List[int]:
        """Chain hashes of ``tokens``' FULL ``block_tokens``-sized chunks:
        ``h_i = hash((h_{i-1}, chunk_i))`` from ``_CHAIN_SEED``, so equal
        hashes imply equal whole prefixes (vLLM APC hashing).  Python
        hashes ints/tuples deterministically (PYTHONHASHSEED only
        randomizes str/bytes), so chains are stable across runs."""
        bt = self.cfg.block_tokens
        h = _CHAIN_SEED
        out: List[int] = []
        for i in range(len(tokens) // bt):
            h = hash((h, tuple(tokens[i * bt:(i + 1) * bt])))
            out.append(h)
        return out

    def probe_prefix(self, tokens: Sequence[int],
                     hashes: Optional[Sequence[int]] = None) -> int:
        """How many WHOLE leading blocks of this prompt are currently
        indexed (read-only — used by admission to credit ``can_admit``'s
        ``shared_blocks``).  Capped so at least one prompt token is left
        to prefill: a request must still produce its first KV write."""
        if not self.cfg.prefix_sharing:
            return 0
        if hashes is None:
            hashes = self.chunk_hashes(tokens)
        cap = max(0, (len(tokens) - 1) // self.cfg.block_tokens)
        n = 0
        for h in hashes[:cap]:
            if h not in self._index:
                break
            n += 1
        return n

    def adopt_prefix(self, request_id: int, tokens: Sequence[int],
                     hashes: Optional[Sequence[int]] = None) -> int:
        """Map the longest indexed prefix of ``tokens`` into a NEW table
        for ``request_id`` (refcount++ per block), then fork copy-on-
        write at the divergence block if its indexed sibling shares a
        head run of tokens.  Returns the number of context tokens the
        request now holds (== tokens it need not prefill).  Never raises:
        if the COW fork cannot get a block the fork is skipped and the
        request simply prefills from the shared boundary."""
        if not self.cfg.prefix_sharing:
            return 0
        t = self.tables.get(request_id)
        if t is not None and t.blocks:
            return t.tokens        # resumed request: keep what it has
        if hashes is None:
            hashes = self.chunk_hashes(tokens)
        n = self.probe_prefix(tokens, hashes)
        if n == 0:
            return 0
        bt = self.cfg.block_tokens
        t = self.tables.setdefault(request_id, BlockTable(request_id))
        for h in hashes[:n]:
            self._append_shared(t, self._index[h])
        shared = n * bt
        self.prefix_hits += n
        # copy-on-write fork: the indexed child of the last matched hash
        # holds the divergence chunk of some earlier prompt; copy its
        # matching token head into a PRIVATE block so those tokens need
        # no recompute either (the tail diverges and is prefilled).
        prev_h = hashes[n - 1]
        cand = self._next.get(prev_h)
        if cand is not None:
            have = self._tok_of.get(cand, ())
            want = tokens[shared:shared + bt]
            m = 0
            while m < len(have) and m < len(want) and have[m] == want[m]:
                m += 1
            m = min(m, len(tokens) - 1 - shared)   # leave >= 1 to prefill
            if m > 0:
                try:
                    block = self._take_block()
                except OutOfBlocks:
                    block = None               # no room: skip the fork
                if block is not None:
                    self._append_new(t, block)
                    nbytes = m * self.cfg.bytes_per_token
                    self.cow_forks += 1
                    self.cow_copied_bytes += nbytes
                    if self.on_cow is not None:
                        self.on_cow(nbytes)
                    shared += m
        self.shared_tokens_saved += shared
        t.tokens = max(t.tokens, shared)
        used = self.used_blocks()
        if used > self.peak_used:
            self.peak_used = used
        return shared

    def register_prefix(self, request_id: int, tokens: Sequence[int],
                        hashes: Optional[Sequence[int]] = None) -> int:
        """Index ``request_id``'s full prompt blocks under their chain
        hashes so later requests can adopt them.  Called after prefill
        completes (the blocks now hold final, immutable KV).  Returns the
        number of newly indexed blocks."""
        if not self.cfg.prefix_sharing:
            return 0
        t = self.tables.get(request_id)
        if t is None:
            return 0
        if hashes is None:
            hashes = self.chunk_hashes(tokens)
        n_full = min(len(hashes), len(t.blocks))
        added = 0
        prev = _CHAIN_SEED
        bt = self.cfg.block_tokens
        for i in range(n_full):
            h = hashes[i]
            if h not in self._index:
                b = t.blocks[i]
                if b not in self._hash_of:     # one hash per physical id
                    self._index[h] = b
                    self._hash_of[b] = h
                    self._parent_of[b] = prev
                    self._next.setdefault(prev, b)
                    self._tok_of[b] = tuple(tokens[i * bt:(i + 1) * bt])
                    added += 1
            prev = h
        if added:
            self.index_version += 1
        return added

    def _unindex(self, block: int) -> None:
        """Drop a dying block from the prefix index (last reader left)."""
        h = self._hash_of.pop(block, None)
        if h is None:
            return
        if self._index.get(h) == block:
            del self._index[h]
        parent = self._parent_of.pop(block)
        if self._next.get(parent) == block:
            del self._next[parent]
        self._tok_of.pop(block, None)
        self.index_version += 1

    def _spill_victim(self):
        """(table, index) of the coldest scratchpad-resident block: the
        oldest scratch block of the request holding the most scratch
        blocks (ties to the lowest request id) — deterministic, keeps
        the hottest context chiplet-local.

        O(log n) amortized via the lazy snapshot heap: the top entry is
        valid iff it matches its table's CURRENT scratch count (every
        count change pushed a fresh snapshot, so the current state is
        always present); stale or zero-count snapshots are popped.  The
        heap's (-count, rid) ordering reproduces the reference scan's
        ``(-len(idxs), rid)`` min-key exactly — locked against
        :meth:`_spill_victim_reference` by the hypothesis random-walk
        test in tests/test_kv_cache.py."""
        heap = self._victim_heap
        while heap:
            neg_n, rid = heap[0]
            t = self.tables.get(rid)
            if t is None or -neg_n != t.n_scratch or neg_n == 0:
                heapq.heappop(heap)            # stale / empty snapshot
                continue
            # oldest scratch block: advance the monotone scan hint past
            # entries that have since been converted to DRAM
            while self.is_dram(t.blocks[t.scan]):
                t.scan += 1
            return t, t.scan
        return None

    def _spill_victim_reference(self):
        """The original O(n_tables * blocks) selection scan, kept as the
        oracle the heap-based index is property-tested against."""
        best = None
        best_key = None
        for rid in sorted(self.tables):
            t = self.tables[rid]
            idxs = [i for i, b in enumerate(t.blocks)
                    if not self.is_dram(b)]
            if not idxs:
                continue
            key = (-len(idxs), rid)
            if best_key is None or key < best_key:
                best_key = key
                best = (t, idxs[0])
        return best


# ---------------------------------------------------------------------------
# Model-derived sizing
# ---------------------------------------------------------------------------

def kv_bytes_per_token(cfg, elem_bytes: int = 1) -> int:
    """KV bytes one context token occupies across the whole model: K + V
    rows of ``kv_dim`` for every attention layer, at 8-bit activations
    (``elem_bytes=1``) as the paper's scratchpads store them.  SSM layers
    carry recurrent state, not a KV cache, so only ``attn`` layers count.
    """
    from repro.core.scheduling import llm_layers
    n_attn = sum(1 for ld in llm_layers(cfg) if ld.kind == "attn")
    kv_dim = cfg.kv_dim or cfg.d_model
    return 2 * kv_dim * n_attn * elem_bytes


def kv_cache_from_model(cfg, *, tile=None, block_tokens: int = 16,
                        kv_frac: float = 0.5, dram_frac: float = 1.0,
                        watermark_frac: float = 0.05,
                        pad_bytes: int = 32 * 1024) -> KVCacheConfig:
    """Size the paged cache from the mapped model: the scratchpad tier is
    ``kv_frac`` of the allocated chiplets' total scratchpad capacity
    (the rest holds activations/partials), the DRAM-hub tier is
    ``dram_frac`` of the scratchpad tier."""
    from repro.core.energy import TileSpec
    from repro.core.scheduling import allocate_chiplets
    tile = tile if tile is not None else TileSpec()
    alloc = allocate_chiplets(cfg, tile)
    budget = int(alloc.n_chiplets * tile.n_pairs * pad_bytes * kv_frac)
    bpt = kv_bytes_per_token(cfg)
    n_blocks = max(1, budget // (block_tokens * bpt))
    return KVCacheConfig(
        n_blocks=n_blocks, block_tokens=block_tokens,
        dram_blocks=int(n_blocks * dram_frac),
        watermark_frac=watermark_frac, bytes_per_token=bpt)
