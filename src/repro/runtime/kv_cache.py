"""Paged KV-cache block allocator over the chiplet scratchpad budget.

The paper keeps KV in the 32 KB PE-local scratchpads (cyclically striped,
``core/partition.ScratchpadPlan``) with a DRAM hub reachable over the
photonic C2C link for everything that does not fit (paper §II; the same
tier split Sangam prices over CXL and the Photonic Fabric platform prices
over photonics — PAPERS.md).  This module is the vLLM-style allocator
that makes that hierarchy a *finite* resource the serving engine must
schedule against:

  * KV is allocated in fixed-size **blocks** of ``block_tokens`` tokens;
    a request owns a **block table** (ordered physical block ids).
  * Two tiers share one physical id space: scratchpad blocks are ids
    ``[0, n_blocks)``, DRAM-hub blocks are ``[n_blocks, n_blocks +
    dram_blocks)`` — a block's tier is just an id comparison.
  * When the scratchpad tier is exhausted and DRAM capacity remains, the
    allocator **spills** the coldest scratchpad-resident block (the
    oldest block of the request holding the most scratchpad blocks) to a
    DRAM block and hands the freed scratchpad block to the requester, so
    hot (recent) KV stays chiplet-local.  Every spill invokes
    ``on_spill(nbytes)`` — the serving engine charges it as a
    ``C2CTransfer`` on the TimelineIR plus DRAM access energy.
  * When both tiers are exhausted, ``OutOfBlocks`` is raised and the
    engine preempts (recompute-on-resume, watermark-gated).

Pure Python — no jax, no numpy — so the discrete-event serving loop
stays fast and import-light.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class OutOfBlocks(RuntimeError):
    """Both KV tiers are exhausted; the caller must preempt or wait."""


@dataclass(frozen=True)
class KVCacheConfig:
    """Sizing of the two-tier paged KV cache.

    ``n_blocks``        scratchpad-tier blocks (the chiplet-local budget)
    ``block_tokens``    tokens per block (vLLM-style page size)
    ``dram_blocks``     DRAM-hub tier blocks reachable over the photonic
                        link; 0 disables spilling entirely
    ``watermark_frac``  preemption watermark: when a decode round needs
                        new blocks and the free total is below this
                        fraction of the scratchpad tier, the engine
                        preempts before allocating
    ``bytes_per_token`` KV bytes one token occupies across all layers
                        (see :func:`kv_bytes_per_token`)
    """
    n_blocks: int
    block_tokens: int = 16
    dram_blocks: int = 0
    watermark_frac: float = 0.05
    bytes_per_token: int = 4096

    def __post_init__(self):
        if self.n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        if self.block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        if self.dram_blocks < 0:
            raise ValueError("dram_blocks must be >= 0")
        if not 0.0 <= self.watermark_frac < 1.0:
            raise ValueError("watermark_frac must be in [0, 1)")

    @property
    def block_bytes(self) -> int:
        return self.block_tokens * self.bytes_per_token

    @property
    def total_blocks(self) -> int:
        return self.n_blocks + self.dram_blocks

    @property
    def watermark_blocks(self) -> int:
        return max(1, int(self.n_blocks * self.watermark_frac))

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` context tokens."""
        return -(-max(n_tokens, 0) // self.block_tokens)


@dataclass
class BlockTable:
    """One request's ordered physical block ids (oldest tokens first).

    ``n_dram`` counts the DRAM-resident ids in ``blocks`` (maintained
    incrementally so per-iteration residency queries are O(1)), and
    ``scan`` is the oldest-scratch-block search hint: positions only
    ever convert scratch -> DRAM, so the hint advances monotonically and
    victim lookup is amortized O(1) over the table's lifetime."""
    request_id: int
    blocks: List[int] = field(default_factory=list)
    tokens: int = 0                  # context tokens currently stored
    n_dram: int = 0                  # DRAM-resident entries of `blocks`
    scan: int = 0                    # first index that may be scratch

    @property
    def n_scratch(self) -> int:
        return len(self.blocks) - self.n_dram


class BlockAllocator:
    """Two-tier block allocator with spill-to-DRAM and exact accounting.

    Invariants (property-tested in tests/test_kv_cache.py):
      * every physical id is either free or in exactly one table;
      * ``free_scratch + free_dram + sum(len(t.blocks)) == total_blocks``;
      * a table covers its token count: ``len(blocks) * block_tokens >=
        tokens`` with no over-allocation beyond one partial block.
    """

    def __init__(self, cfg: KVCacheConfig,
                 on_spill: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.on_spill = on_spill
        # stacks: pop() from the end keeps allocation order deterministic
        self._free_scratch: List[int] = list(range(cfg.n_blocks))[::-1]
        self._free_dram: List[int] = list(
            range(cfg.n_blocks, cfg.n_blocks + cfg.dram_blocks))[::-1]
        self.tables: Dict[int, BlockTable] = {}
        # spill-victim index: a lazy max-heap of (-n_scratch, rid)
        # snapshots.  Every scratch-count change pushes the table's NEW
        # state, so the heap always contains one entry matching each
        # table's current count; stale snapshots are discarded on pop.
        # Selection is O(log n) amortized instead of the former
        # sorted(self.tables) + per-block enumeration scan.
        self._victim_heap: List[Tuple[int, int]] = []
        # lifetime stats
        self.spilled_blocks = 0
        self.spilled_bytes = 0
        self.peak_used = 0

    # -- tier predicates ----------------------------------------------
    def is_dram(self, block_id: int) -> bool:
        return block_id >= self.cfg.n_blocks

    # -- capacity queries ---------------------------------------------
    def free_scratch(self) -> int:
        return len(self._free_scratch)

    def free_total(self) -> int:
        return len(self._free_scratch) + len(self._free_dram)

    def used_blocks(self) -> int:
        return self.cfg.total_blocks - self.free_total()

    def feasible(self, n_tokens: int) -> bool:
        """Could a request of ``n_tokens`` EVER fit (both tiers empty)?"""
        return self.cfg.blocks_for(n_tokens) <= self.cfg.total_blocks

    def can_admit(self, n_tokens: int, *, reserve: int = 0) -> bool:
        """Are there enough free blocks (both tiers) to admit a request
        needing ``n_tokens``, keeping ``reserve`` blocks of headroom?"""
        return self.cfg.blocks_for(n_tokens) + reserve <= self.free_total()

    def scratch_tokens(self, request_id: int) -> int:
        t = self.tables[request_id]
        return t.tokens - self.dram_tokens(request_id)

    def dram_tokens(self, request_id: int) -> int:
        """Context tokens resident in the DRAM-hub tier — the per-decode-
        iteration remote-read volume for this request.  O(1): the table
        carries its DRAM-entry count instead of re-scanning its blocks
        every serving iteration."""
        t = self.tables[request_id]
        return min(t.n_dram * self.cfg.block_tokens, t.tokens)

    # -- allocation ----------------------------------------------------
    def ensure(self, request_id: int, n_tokens: int) -> int:
        """Grow ``request_id``'s table to cover ``n_tokens`` context
        tokens; returns the number of newly allocated blocks.  Raises
        :class:`OutOfBlocks` (after allocating what it could — the
        partial growth is kept, a retry continues from it)."""
        t = self.tables.setdefault(request_id, BlockTable(request_id))
        grown = 0
        bt = self.cfg.block_tokens
        while len(t.blocks) * bt < n_tokens:
            try:
                block = self._take_block()
            except OutOfBlocks:
                # keep the table coherent with its partial growth so the
                # invariant len(blocks) == blocks_for(tokens) still holds
                # and a retry (after preemption) continues from here
                t.tokens = max(t.tokens, min(n_tokens, len(t.blocks) * bt))
                raise
            t.blocks.append(block)
            if self.is_dram(block):
                t.n_dram += 1
            else:
                heapq.heappush(self._victim_heap,
                               (-t.n_scratch, t.request_id))
            grown += 1
        t.tokens = max(t.tokens, n_tokens)
        used = self.used_blocks()
        if used > self.peak_used:
            self.peak_used = used
        return grown

    def free(self, request_id: int) -> int:
        """Release every block of ``request_id``; returns block count."""
        t = self.tables.pop(request_id)
        for b in reversed(t.blocks):
            (self._free_dram if self.is_dram(b)
             else self._free_scratch).append(b)
        return len(t.blocks)

    # -- internals -----------------------------------------------------
    def _take_block(self) -> int:
        if self._free_scratch:
            return self._free_scratch.pop()
        if self._free_dram:
            victim = self._spill_victim()
            if victim is None:
                # nothing scratch-resident to displace: hand out DRAM
                return self._free_dram.pop()
            table, idx = victim
            dram_id = self._free_dram.pop()
            scratch_id = table.blocks[idx]
            table.blocks[idx] = dram_id        # cold block moves to DRAM
            table.n_dram += 1
            heapq.heappush(self._victim_heap,
                           (-table.n_scratch, table.request_id))
            self.spilled_blocks += 1
            self.spilled_bytes += self.cfg.block_bytes
            if self.on_spill is not None:
                self.on_spill(self.cfg.block_bytes)
            return scratch_id                  # freed pad goes to caller
        raise OutOfBlocks(
            f"KV cache exhausted: {self.cfg.n_blocks} scratchpad + "
            f"{self.cfg.dram_blocks} DRAM blocks all in use")

    def _spill_victim(self):
        """(table, index) of the coldest scratchpad-resident block: the
        oldest scratch block of the request holding the most scratch
        blocks (ties to the lowest request id) — deterministic, keeps
        the hottest context chiplet-local.

        O(log n) amortized via the lazy snapshot heap: the top entry is
        valid iff it matches its table's CURRENT scratch count (every
        count change pushed a fresh snapshot, so the current state is
        always present); stale or zero-count snapshots are popped.  The
        heap's (-count, rid) ordering reproduces the reference scan's
        ``(-len(idxs), rid)`` min-key exactly — locked against
        :meth:`_spill_victim_reference` by the hypothesis random-walk
        test in tests/test_kv_cache.py."""
        heap = self._victim_heap
        while heap:
            neg_n, rid = heap[0]
            t = self.tables.get(rid)
            if t is None or -neg_n != t.n_scratch or neg_n == 0:
                heapq.heappop(heap)            # stale / empty snapshot
                continue
            # oldest scratch block: advance the monotone scan hint past
            # entries that have since been converted to DRAM
            while self.is_dram(t.blocks[t.scan]):
                t.scan += 1
            return t, t.scan
        return None

    def _spill_victim_reference(self):
        """The original O(n_tables * blocks) selection scan, kept as the
        oracle the heap-based index is property-tested against."""
        best = None
        best_key = None
        for rid in sorted(self.tables):
            t = self.tables[rid]
            idxs = [i for i, b in enumerate(t.blocks)
                    if not self.is_dram(b)]
            if not idxs:
                continue
            key = (-len(idxs), rid)
            if best_key is None or key < best_key:
                best_key = key
                best = (t, idxs[0])
        return best


# ---------------------------------------------------------------------------
# Model-derived sizing
# ---------------------------------------------------------------------------

def kv_bytes_per_token(cfg, elem_bytes: int = 1) -> int:
    """KV bytes one context token occupies across the whole model: K + V
    rows of ``kv_dim`` for every attention layer, at 8-bit activations
    (``elem_bytes=1``) as the paper's scratchpads store them.  SSM layers
    carry recurrent state, not a KV cache, so only ``attn`` layers count.
    """
    from repro.core.scheduling import llm_layers
    n_attn = sum(1 for ld in llm_layers(cfg) if ld.kind == "attn")
    kv_dim = cfg.kv_dim or cfg.d_model
    return 2 * kv_dim * n_attn * elem_bytes


def kv_cache_from_model(cfg, *, tile=None, block_tokens: int = 16,
                        kv_frac: float = 0.5, dram_frac: float = 1.0,
                        watermark_frac: float = 0.05,
                        pad_bytes: int = 32 * 1024) -> KVCacheConfig:
    """Size the paged cache from the mapped model: the scratchpad tier is
    ``kv_frac`` of the allocated chiplets' total scratchpad capacity
    (the rest holds activations/partials), the DRAM-hub tier is
    ``dram_frac`` of the scratchpad tier."""
    from repro.core.energy import TileSpec
    from repro.core.scheduling import allocate_chiplets
    tile = tile if tile is not None else TileSpec()
    alloc = allocate_chiplets(cfg, tile)
    budget = int(alloc.n_chiplets * tile.n_pairs * pad_bytes * kv_frac)
    bpt = kv_bytes_per_token(cfg)
    n_blocks = max(1, budget // (block_tokens * bpt))
    return KVCacheConfig(
        n_blocks=n_blocks, block_tokens=block_tokens,
        dram_blocks=int(n_blocks * dram_frac),
        watermark_frac=watermark_frac, bytes_per_token=bpt)
