"""Straggler mitigation.

Two standard mechanisms, both implemented:

1. Detection — per-worker step-time EWMA; a worker whose step time exceeds
   `threshold` x the fleet median is flagged.  On TPU pods stragglers are
   usually a host (input pipeline) or a chip (thermal), and the remedy is
   checkpoint-restart without that pod (plan_elastic_mesh) or input
   re-balancing.
2. Backup workers (speculative execution) for the INPUT pipeline — the
   slowest k hosts' shards are replicated on spare hosts; first result
   wins.  (Compute itself is SPMD-synchronous on TPU — backup execution
   applies to data loading, not the XLA step.)
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerReport:
    worker_id: int
    ewma_s: float
    fleet_median_s: float

    @property
    def slowdown(self) -> float:
        return self.ewma_s / max(self.fleet_median_s, 1e-9)


class StragglerDetector:
    def __init__(self, n_workers: int, alpha: float = 0.2,
                 threshold: float = 1.5, min_samples: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples
        self.ewma: Dict[int, Optional[float]] = {i: None
                                                 for i in range(n_workers)}
        self.counts: Dict[int, int] = defaultdict(int)

    def record(self, worker_id: int, step_time_s: float):
        prev = self.ewma[worker_id]
        self.ewma[worker_id] = (step_time_s if prev is None
                                else (1 - self.alpha) * prev
                                + self.alpha * step_time_s)
        self.counts[worker_id] += 1

    def stragglers(self) -> List[StragglerReport]:
        vals = [v for v in self.ewma.values() if v is not None]
        if not vals:
            return []
        med = statistics.median(vals)
        out = []
        for wid, v in self.ewma.items():
            if v is None or self.counts[wid] < self.min_samples:
                continue
            if v > self.threshold * med:
                out.append(StragglerReport(wid, v, med))
        return out


class BackupInputRunner:
    """Speculative input fetch: issue the shard read on the primary and, if
    it has straggled before, on a spare; take whichever returns first.
    Synchronous model (single-threaded container) — the policy logic is
    what's under test."""

    def __init__(self, detector: StragglerDetector, n_spares: int = 1):
        self.detector = detector
        self.n_spares = n_spares
        self.speculated = 0
        self.wins_by_backup = 0

    def fetch(self, worker_id: int, primary_fn, backup_fn=None,
              primary_time: float = 0.0, backup_time: float = 0.0):
        slow = {r.worker_id for r in self.detector.stragglers()}
        if worker_id in slow and backup_fn is not None and self.n_spares:
            self.speculated += 1
            if backup_time < primary_time:
                self.wins_by_backup += 1
                self.detector.record(worker_id, backup_time)
                return backup_fn()
        self.detector.record(worker_id, primary_time)
        return primary_fn()
