"""Fault tolerance: heartbeat failure detection, restart policy, elastic
remesh planning.

On a real multi-pod deployment the coordinator runs next to the jax
distributed service; worker liveness comes from heartbeats, and recovery is
checkpoint-restart with a (possibly smaller) elastic mesh.  The full control
loop is implemented here and driven in-process by tests and by
``launch/train.py --simulate-failures`` (this container has one host, so
failures are injected rather than real — the state machine is the part that
must be correct).
"""
from __future__ import annotations

import dataclasses
import time
from enum import Enum
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "WorkerState", "Worker", "HeartbeatMonitor", "RestartPolicy",
    "plan_elastic_mesh", "TrainingSupervisor", "WorkerFailure",
]


class WorkerState(str, Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class Worker:
    worker_id: int
    last_heartbeat: float
    state: WorkerState = WorkerState.HEALTHY
    incarnation: int = 0


class HeartbeatMonitor:
    """suspect after `suspect_s` without heartbeat, dead after `dead_s`.

    The clock is injected and mandatory: the same state machine runs on
    wall time in a real deployment and on the DES clock inside the fleet
    simulator, and a silent ``time.time`` fallback would let real time
    leak into simulations.
    """

    def __init__(self, n_workers: int, suspect_s: float = 10.0,
                 dead_s: float = 30.0, *, clock: Callable[[], float]):
        self.clock = clock
        now = clock()
        self.workers = {i: Worker(i, now) for i in range(n_workers)}
        self.suspect_s = suspect_s
        self.dead_s = dead_s

    def heartbeat(self, worker_id: int):
        w = self.workers[worker_id]
        w.last_heartbeat = self.clock()
        if w.state != WorkerState.DEAD:
            w.state = WorkerState.HEALTHY

    def sweep(self) -> List[int]:
        """Advance states; returns newly-dead worker ids."""
        now = self.clock()
        newly_dead = []
        for w in self.workers.values():
            dt = now - w.last_heartbeat
            if w.state == WorkerState.DEAD:
                continue
            if dt >= self.dead_s:
                w.state = WorkerState.DEAD
                newly_dead.append(w.worker_id)
            elif dt >= self.suspect_s:
                w.state = WorkerState.SUSPECT
        return newly_dead

    def revive(self, worker_id: int):
        w = self.workers[worker_id]
        w.state = WorkerState.HEALTHY
        w.incarnation += 1
        w.last_heartbeat = self.clock()

    def healthy_ids(self) -> List[int]:
        return [w.worker_id for w in self.workers.values()
                if w.state == WorkerState.HEALTHY]


@dataclasses.dataclass
class RestartPolicy:
    """Exponential backoff with a failure budget (fleet-standard)."""
    max_restarts: int = 100
    window_s: float = 3600.0
    base_backoff_s: float = 5.0
    max_backoff_s: float = 300.0

    def __post_init__(self):
        self.history: List[float] = []

    def should_restart(self, now: float) -> bool:
        self.history = [t for t in self.history if now - t < self.window_s]
        return len(self.history) < self.max_restarts

    def next_backoff(self, now: float) -> float:
        recent = [t for t in self.history if now - t < self.window_s]
        return min(self.base_backoff_s * (2 ** len(recent) if recent else 1),
                   self.max_backoff_s)

    def record_failure(self, now: float):
        self.history.append(now)


def plan_elastic_mesh(n_healthy_pods: int, chips_per_pod: int = 256,
                      model_axis: int = 16) -> Tuple[Tuple[int, ...],
                                                     Tuple[str, ...]]:
    """Elastic remesh: keep the model axis intact (weight shards must stay
    complete); shrink/grow the data(+pod) axes to the healthy pod count.
    Batch is re-sharded by the data pipeline; optimizer state re-shards via
    checkpoint restore with the new specs."""
    if n_healthy_pods < 1:
        raise ValueError("no healthy pods")
    data_axis = chips_per_pod // model_axis
    if n_healthy_pods == 1:
        return (data_axis, model_axis), ("data", "model")
    return (n_healthy_pods, data_axis, model_axis), ("pod", "data", "model")


class TrainingSupervisor:
    """The restart state machine: run -> (failure) -> restore -> resume.

    `run_step` raises WorkerFailure to simulate/surface a fault; the
    supervisor restores from the last complete checkpoint and replays.
    """

    def __init__(self, policy: RestartPolicy, save_every: int,
                 checkpointer, monitor: Optional[HeartbeatMonitor] = None,
                 clock: Callable[[], float] = time.time):
        self.policy = policy
        self.save_every = save_every
        self.ckpt = checkpointer
        self.monitor = monitor
        self.clock = clock
        self.restarts = 0

    def run(self, state, step: int, n_steps: int, run_step, make_batch,
            restore_fn):
        while step < n_steps:
            try:
                state, metrics = run_step(state, make_batch(step))
                step += 1
                if step % self.save_every == 0:
                    self.ckpt.save(step, state, {"step": step})
            except WorkerFailure as e:
                now = self.clock()
                self.policy.record_failure(now)
                if not self.policy.should_restart(now):
                    raise RuntimeError("failure budget exhausted") from e
                self.restarts += 1
                state, step = restore_fn()
        self.ckpt.wait() if hasattr(self.ckpt, "wait") else None
        return state, step


class WorkerFailure(RuntimeError):
    def __init__(self, worker_id: int, msg: str = ""):
        super().__init__(f"worker {worker_id} failed {msg}")
        self.worker_id = worker_id
