"""Runtime subsystems: fault tolerance, stragglers, gradient compression,
paged KV cache.

Lazy re-exports (PEP 562): ``compression`` imports jax at module scope,
but the paged KV allocator (`kv_cache`) is pure Python and is imported by
the jax-free serving engine — resolving attributes on demand keeps
``import repro.runtime.kv_cache`` from dragging jax in.
"""
from __future__ import annotations

_EXPORTS = {
    # fault_tolerance
    "HeartbeatMonitor": "fault_tolerance", "RestartPolicy": "fault_tolerance",
    "TrainingSupervisor": "fault_tolerance", "Worker": "fault_tolerance",
    "WorkerFailure": "fault_tolerance", "WorkerState": "fault_tolerance",
    "plan_elastic_mesh": "fault_tolerance",
    # straggler
    "BackupInputRunner": "straggler", "StragglerDetector": "straggler",
    "StragglerReport": "straggler",
    # compression (jax import happens only on first attribute access)
    "compress_with_feedback": "compression",
    "compressed_allreduce": "compression", "compressed_psum": "compression",
    "decompress": "compression", "dequantize_int8": "compression",
    "init_error_state": "compression", "quantize_int8": "compression",
    # kv_cache (pure Python)
    "BlockAllocator": "kv_cache", "BlockTable": "kv_cache",
    "KVCacheConfig": "kv_cache", "OutOfBlocks": "kv_cache",
    "kv_bytes_per_token": "kv_cache", "kv_cache_from_model": "kv_cache",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        val = getattr(mod, name)
        globals()[name] = val          # cache for subsequent lookups
        return val
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
