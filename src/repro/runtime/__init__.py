from .fault_tolerance import (HeartbeatMonitor, RestartPolicy,
                              TrainingSupervisor, Worker, WorkerFailure,
                              WorkerState, plan_elastic_mesh)
from .straggler import BackupInputRunner, StragglerDetector, StragglerReport
from .compression import (compress_with_feedback, compressed_allreduce,
                          compressed_psum, decompress, dequantize_int8,
                          init_error_state, quantize_int8)

__all__ = [
    "HeartbeatMonitor", "RestartPolicy", "TrainingSupervisor", "Worker",
    "WorkerFailure", "WorkerState", "plan_elastic_mesh",
    "BackupInputRunner", "StragglerDetector", "StragglerReport",
    "compress_with_feedback", "compressed_allreduce", "compressed_psum",
    "decompress", "dequantize_int8", "init_error_state", "quantize_int8",
]
