"""Mixtral-8x7B [arXiv:2401.04088] — 8 experts top-2, sliding-window attn.

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, SWA window 4096.
SWA makes the decode KV cache O(window), so long_500k runs for this arch.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    max_seq=524288,
)
