"""Config system for the repro framework.

`ModelConfig` is a frozen dataclass generic enough to describe every assigned
architecture (dense / MoE / SSM / hybrid / VLM / audio enc-dec) plus the
paper's own Llama models.  Shape specs (`ShapeSpec`) describe the four
assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # capacity factor for dense dispatch (tokens per expert = tokens/E * cf)
    capacity_factor: float = 1.25
    # llama4-style: a shared (always-on) expert in addition to routed ones
    n_shared_experts: int = 0
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD — state space duality) settings."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2          # d_inner = expand * d_model
    chunk: int = 256         # SSD chunk length
    conv_width: int = 4
    @property
    def n_heads_for(self):  # helper used by layers; actual heads derived
        return None


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default: d_model // n_heads
    norm: str = "rmsnorm"                 # rmsnorm | layernorm | nonparam_ln
    mlp: str = "swiglu"                   # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    use_rope: bool = True
    max_seq: int = 131072
    sliding_window: Optional[int] = None  # SWA (mixtral)
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    moe: Optional[MoEConfig] = None
    moe_every: int = 1            # MoE layers every k-th layer (llama4: 2)
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): mamba backbone; one SHARED attention block applied
    # every `attn_every` layers (params reused each application).
    attn_every: int = 0
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500               # precomputed frame embeddings (stub)
    # vlm (paligemma): prefix of precomputed patch embeddings (stub)
    n_prefix_tokens: int = 0
    # sharding knobs
    fsdp_axes: Tuple[str, ...] = ("data", "model")
    remat: bool = True
    optimizer: str = "adamw"     # "adafactor" for models whose fp32 moments
                                 # cannot fit HBM even fully sharded (llama4)
    # dtype of params/activations
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if serve_step at 500k sequence length is sub-quadratic /
        O(1)-state and therefore runnable per the assignment."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window is not None:
            return True
        return False

    def n_params(self, include_embeddings: bool = True) -> int:
        """Analytic parameter count (used by the PICNIC packing model and
        roofline MODEL_FLOPS)."""
        d, h = self.d_model, self.head_dim
        attn = d * self.n_heads * h + 2 * d * self.n_kv_heads * h + self.n_heads * h * d
        if self.mlp in ("swiglu", "geglu"):
            ffn_dense = 3 * d * self.d_ff
        else:
            ffn_dense = 2 * d * self.d_ff
        per_layer = 0
        if self.family == "ssm":
            ssm = self.ssm
            d_inner = ssm.expand * d
            n_h = d_inner // ssm.head_dim
            in_proj = d * (2 * d_inner + 2 * ssm.d_state + n_h)
            out_proj = d_inner * d
            conv = ssm.conv_width * (d_inner + 2 * ssm.d_state)
            per_layer = in_proj + out_proj + conv + 2 * n_h  # + A, dt_bias
            total = self.n_layers * per_layer
        elif self.family == "hybrid":
            ssm = self.ssm
            d_inner = ssm.expand * d
            n_h = d_inner // ssm.head_dim
            in_proj = d * (2 * d_inner + 2 * ssm.d_state + n_h)
            mamba_layer = in_proj + d_inner * d + ssm.conv_width * (d_inner + 2 * ssm.d_state) + 2 * n_h + d
            shared = attn + ffn_dense + 2 * d  # one shared attn+ffn block
            total = self.n_layers * mamba_layer + shared
        elif self.moe is not None:
            if self.mlp in ("swiglu", "geglu"):
                expert = 3 * d * self.moe.d_ff_expert
            else:
                expert = 2 * d * self.moe.d_ff_expert
            router = d * self.moe.n_experts
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            moe_layer = attn + self.moe.n_experts * expert + router
            moe_layer += self.moe.n_shared_experts * expert
            dense_layer = attn + ffn_dense
            total = n_moe * moe_layer + n_dense * dense_layer
        else:
            per_layer = attn + ffn_dense
            total = self.n_layers * per_layer
            if self.is_encoder_decoder:
                # encoder self-attn+ffn, decoder adds cross-attn
                total = self.n_encoder_layers * per_layer + self.n_layers * (per_layer + attn)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total + (emb if include_embeddings else 0)

    def active_params(self, include_embeddings: bool = False) -> int:
        """Active (per-token) parameters — differs from n_params for MoE."""
        if self.moe is None:
            return self.n_params(include_embeddings)
        d = self.d_model
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        expert = mult * d * self.moe.d_ff_expert
        n_moe = self.n_layers // self.moe_every
        n_dense = self.n_layers - n_moe
        moe_layer = attn + (self.moe.top_k + self.moe.n_shared_experts) * expert
        moe_layer += d * self.moe.n_experts
        dense_layer = attn + mult * d * self.d_ff
        total = n_moe * moe_layer + n_dense * dense_layer
        if include_embeddings:
            total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small smoke-test config in the same family (CPU-runnable)."""
    defaults = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.attn_every == 0 else cfg.attn_every),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        max_seq=512,
        fsdp_axes=("data",),
        remat=False,
    )
    if cfg.attn_every:
        defaults["n_layers"] = cfg.attn_every  # one group: mambas + shared attn
        defaults["attn_every"] = cfg.attn_every
    if cfg.moe is not None:
        defaults["moe"] = MoEConfig(
            n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=256,
            n_shared_experts=cfg.moe.n_shared_experts,
        )
    if cfg.ssm is not None:
        defaults["ssm"] = SSMConfig(d_state=16, head_dim=32, expand=2, chunk=32)
    if cfg.is_encoder_decoder:
        defaults["n_encoder_layers"] = 2
        defaults["encoder_seq"] = 64
    if cfg.n_prefix_tokens:
        defaults["n_prefix_tokens"] = 16
    if cfg.sliding_window:
        defaults["sliding_window"] = 64
    defaults.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **defaults)
