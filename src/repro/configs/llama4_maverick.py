"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4; unverified].

48L d_model=5120 40H (GQA kv=8) vocab=202048; MoE with 128 routed experts
top-1 + 1 shared expert, expert d_ff=8192, interleaved every 2nd layer
(llama4 style). ~400B total / ~17B active.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, n_shared_experts=1),
    moe_every=2,  # MoE layers interleaved with dense layers (llama4 style)
    optimizer="adafactor",  # AdamW fp32 moments (3.2TB) cannot fit 512x16GB
    rope_theta=500000.0,
    max_seq=131072,
)
