"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder, conv frontend STUB.

32L encoder + 32L decoder, d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
GELU MLP, LayerNorm, learned/sinusoidal positions (no RoPE). The conv
frontend is a STUB: input_specs() provides precomputed frame embeddings
(encoder_seq=1500, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    mlp="gelu",
    norm="layernorm",
    use_rope=False,
    is_encoder_decoder=True,
    n_encoder_layers=32,
    encoder_seq=1500,
    max_seq=32768,
)
