"""OLMo-1B [arXiv:2402.00838].

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
OLMo uses *non-parametric* LayerNorm (no learned scale/bias) and SwiGLU.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam_ln",
    tie_embeddings=True,
    rope_theta=10000.0,
    max_seq=2048,
)
