"""Config registry: ``get_config("<arch-id>")`` returns the full ModelConfig.

Arch ids use dashes (CLI style): e.g. ``--arch mistral-nemo-12b``.
"""
from __future__ import annotations

import importlib

from .base import ModelConfig, MoEConfig, SSMConfig, ShapeSpec, SHAPES, reduced  # noqa: F401

# arch-id -> module name
_REGISTRY = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "olmo-1b": "olmo_1b",
    "smollm-360m": "smollm_360m",
    "yi-34b": "yi_34b",
    "paligemma-3b": "paligemma_3b",
    "zamba2-2.7b": "zamba2_2p7b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-large-v3": "whisper_large_v3",
    "mamba2-2.7b": "mamba2_2p7b",
    # the paper's own evaluation models (Table II)
    "llama3.2-1b": "llama32_1b",
    "llama3-8b": "llama3_8b",
    "llama2-13b": "llama2_13b",
}

ASSIGNED_ARCHS = list(_REGISTRY)[:10]
PAPER_ARCHS = list(_REGISTRY)[10:]


def list_archs():
    return list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))
