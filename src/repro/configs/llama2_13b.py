"""Llama-2-13B — the paper's largest evaluation model (Table II)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=13824,
    vocab_size=32000,
    rope_theta=10000.0,
    max_seq=4096,
)
