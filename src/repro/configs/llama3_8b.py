"""Llama-3-8B — the paper's primary comparison model (Tables II/III)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    max_seq=8192,
)
