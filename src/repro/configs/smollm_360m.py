"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M] — llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, head_dim=64.
d_model=960 is not divisible by 256, so FSDP shards over ("model",) only.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    rope_theta=10000.0,
    max_seq=2048,
    fsdp_axes=("model",),
)
