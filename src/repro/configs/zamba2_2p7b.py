"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + SHARED attention blocks.

54 Mamba2 layers, d_model=2560, ssm_state=64; one shared attention+MLP block
(32H MHA kv=32, d_ff=10240) applied every 6 mamba layers with the SAME
parameters each application (zamba-style weight sharing). vocab=32000.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=256),
    attn_every=6,
    max_seq=524288,
)
