"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSD (state-space duality).

64L d_model=2560, d_state=128, head_dim=64, expand=2, vocab=50280.
O(1) decode state -> long_500k runs. The paper's attention-specific
scheduling (flash loop / KV striping) is inapplicable (see DESIGN.md).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    use_rope=False,
    max_seq=524288,
)
