"""PaliGemma-3B [arXiv:2407.07726] — SigLIP vision frontend + Gemma-2B LM.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216, head_dim=256.
The SigLIP frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (n_prefix_tokens, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
    max_seq=8192,
    n_prefix_tokens=256,
)
