"""TimelineIR in action: one event stream, three consumers.

Runs (1) the analytic Table II walk and (2) a multi-user serving trace
through the unified timeline core (repro.core.timeline), then

  * prints the derived headline numbers (which are byte-identical to the
    pre-timeline closed forms in the default configuration),
  * shows what the opt-in knobs change — compute/C2C ``overlap`` and
    ``dynamic_ccpg`` (real ClusterWake latency per cluster switch),
  * exports chrome://tracing JSONs (open in chrome://tracing or
    ui.perfetto.dev) with one lane per event category.

  PYTHONPATH=src python examples/timeline_trace.py
"""
import json
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core import EVENT_CATEGORIES, PicnicSimulator, Timeline
from repro.launch import ServingConfig, Trace
from repro.launch.serving_engine import ContinuousBatchingEngine

OUT = Path(__file__).resolve().parents[1] / "artifacts" / "trace"
OUT.mkdir(parents=True, exist_ok=True)

cfg = get_config("llama3.2-1b")
sim = PicnicSimulator()

# -- 1. analytic walk: default vs overlap vs dynamic CCPG -------------------
base = sim.run(cfg, 512, 128)
ov = sim.run(cfg, 512, 128, overlap=1.0)
tl = Timeline()
dyn = sim.run(cfg, 512, 128, ccpg=True, dynamic_ccpg=True, timeline=tl)
static = sim.run(cfg, 512, 128, ccpg=True)

print(f"analytic walk ({cfg.name}, 512/128)")
print(f"  default        {base.throughput_tps:8.1f} tok/s   "
      f"decode {base.decode_s * 1e3:7.2f} ms")
print(f"  overlap=1.0    {ov.throughput_tps:8.1f} tok/s   "
      f"decode {ov.decode_s * 1e3:7.2f} ms  (C2C hidden under compute)")
print(f"  ccpg static    {static.throughput_tps:8.1f} tok/s   "
      f"decode {static.decode_s * 1e3:7.2f} ms  (pre-wake residue)")
print(f"  ccpg dynamic   {dyn.throughput_tps:8.1f} tok/s   "
      f"decode {dyn.decode_s * 1e3:7.2f} ms  (full ClusterWake walk)")

sim_trace = OUT / "simulator_dynamic_ccpg.json"
tl.save_chrome_trace(sim_trace, process_name="picnic-sim")
counts = Counter(type(e).__name__ for e in tl.events)
print(f"  -> {sim_trace} ({len(tl.events)} events: "
      + ", ".join(f"{k}={v}" for k, v in sorted(counts.items())) + ")")

# -- 2. serving engine: the SAME timeline core under multi-user load --------
print("\nserving engine (24 requests, Poisson 40 req/s, batch 4)")
for label, kw in [("ccpg static ", dict(ccpg=True)),
                  ("ccpg dynamic", dict(ccpg=True, dynamic_ccpg=True))]:
    eng = ContinuousBatchingEngine(
        cfg, engine=ServingConfig(max_batch=4, **kw))
    rep = eng.run(Trace.poisson(24, rate_rps=40, seed=0, prompt_len=256,
                                max_new=32))
    print(f"  {label}  {rep.tokens_per_s:7.1f} tok/s  "
          f"{rep.tokens_per_J:6.1f} tok/J  "
          f"p99 latency {rep.p99_latency_s * 1e3:7.2f} ms")
    if kw.get("dynamic_ccpg"):
        eng_trace = OUT / "serving_dynamic_ccpg.json"
        eng.timeline.save_chrome_trace(eng_trace, process_name="picnic-serve")
        print(f"  -> {eng_trace} ({eng.timeline.n_events} events, "
              f"streamed — no materialized event list)")
        d = json.loads(eng_trace.read_text())
        cats = {e.get("cat") for e in d["traceEvents"]}
        assert {c.__name__ for c in EVENT_CATEGORIES} <= cats

assert ov.decode_s < base.decode_s
assert dyn.decode_s > static.decode_s
print("\nOK")
