"""Multi-pod dry-run walk-through for ONE cell: lower + compile yi-34b
decode_32k on the 512-chip mesh, print the memory/cost analysis and the
derived roofline terms — exactly what the full sweep does for all 40 cells.

  PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.dryrun import run_cell

arch = sys.argv[1] if len(sys.argv) > 1 else "yi-34b"
shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"

for mesh, variant in [("pod2", "baseline"), ("pod2", "picnic")]:
    rec = run_cell(arch, shape, mesh, variant, save=False)
    print(f"\n=== {rec['cell']} [{variant}] -> {rec['status']} ===")
    if rec["status"] != "ok":
        print(rec.get("reason") or rec.get("error"))
        continue
    m = rec["memory"]
    print(f"chips: {rec['nchips']}  compile: {rec['compile_s']}s")
    print(f"per-chip residency (args): {m['argument_bytes']/1e9:.2f} GB")
    print(f"flops/chip: {rec['flops_per_chip']:.3e} "
          f"(useful fraction {rec['useful_flop_frac']:.2f})")
    print("roofline terms (s):",
          {k: round(v, 5) for k, v in rec["roofline"].items()},
          "->", rec["dominant"])
    print("collectives:", {k: (int(v['count']), f"{v['wire_bytes']:.2e}B")
                           for k, v in rec["collectives"].items()})
