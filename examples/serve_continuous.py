"""Continuous-batching serving on PICNIC hardware (multi-user traffic).

Runs a 64-request Poisson arrival trace (Llama-3.2-1B, ~512-token
prompts, 64 new tokens each) through the discrete-event serving engine
(the repro.launch serve() facade) and prints the ServingReport — p50/p99
TTFT and end-to-end latency, aggregate tokens/s, tokens/J — with and
without CCPG (chiplet clustering & power gating, paper §II-E), plus the
1-at-a-time baseline the batched engine is measured against.

  PYTHONPATH=src python examples/serve_continuous.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.launch import ServingConfig, Trace, serve

N_REQUESTS = 64
RATE_RPS = 40.0
PROMPT_LEN = 512
MAX_NEW = 64
MAX_BATCH = 8

cfg = get_config("llama3.2-1b")
print(f"model: {cfg.name} — {N_REQUESTS} requests, Poisson {RATE_RPS} req/s, "
      f"~{PROMPT_LEN}-token prompts, {MAX_NEW} new tokens each\n")

reports = {}
for ccpg in (False, True):
    trace = Trace.poisson(N_REQUESTS, RATE_RPS, seed=0,
                          prompt_len=PROMPT_LEN, max_new=MAX_NEW)
    t0 = time.perf_counter()
    rep = serve(cfg, trace, config=ServingConfig(max_batch=MAX_BATCH,
                                                 ccpg=ccpg))
    wall = time.perf_counter() - t0
    reports[ccpg] = rep
    print(rep.summary())
    sim_tokens = rep.tokens_generated + rep.tokens_prefilled
    print(f"  engine speed      {sim_tokens / wall / 1e6:.1f}M simulated "
          f"tokens per wall-second ({wall * 1e3:.0f} ms, single cold "
          f"run; benchmarks/microbench.py measures the warmed fast-vs-"
          f"reference comparison)")
    print()

# the 1-at-a-time baseline on the SAME trace (what launch/serve.py's
# single-stream loop would deliver)
seq = serve(cfg, Trace.poisson(N_REQUESTS, RATE_RPS, seed=0,
                               prompt_len=PROMPT_LEN, max_new=MAX_NEW),
            config=ServingConfig(max_batch=1))
print(f"1-at-a-time baseline: {seq.tokens_per_s:.1f} tok/s, "
      f"p99 latency {seq.p99_latency_s * 1e3:.1f} ms")
print(f"batch-{MAX_BATCH} speedup: "
      f"{reports[False].tokens_per_s / seq.tokens_per_s:.2f}x throughput")
print(f"CCPG efficiency gain:  "
      f"{reports[True].tokens_per_J / reports[False].tokens_per_J:.2f}x "
      f"tokens/J at "
      f"{reports[True].tokens_per_s / reports[False].tokens_per_s:.3f}x "
      f"throughput")

assert reports[False].finished == N_REQUESTS
assert reports[True].finished == N_REQUESTS
assert reports[False].tokens_per_s > seq.tokens_per_s
assert reports[True].tokens_per_J > reports[False].tokens_per_J
print("\nOK")
