"""Continuous-batched serving example (the paper's kind of workload).

Admits N requests into KV-cache slots, decodes all slots in lock-step,
prints throughput.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "smollm-360m", "--smoke", "--n-requests", "4",
          "--max-new", "24", "--max-len", "128"])
