"""End-to-end training driver example: a ~100M-param llama-family model on
the synthetic packed-token pipeline with checkpointing + fault tolerance.

Default is a quick demo (40 steps); pass --steps 300 for the full run.

  PYTHONPATH=src python examples/train_100m.py [--steps N]
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # a ~100M-parameter member of the smollm family
    from repro.configs import get_config
    import repro.configs.base as base
    cfg = dataclasses.replace(
        get_config("smollm-360m"),
        name="smollm-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, head_dim=64, d_ff=2560, max_seq=2048,
        fsdp_axes=("data",), remat=False)
    print(f"training {cfg.name}: ~{cfg.n_params()/1e6:.0f}M params")

    losses = _train_direct(cfg, args)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")


def _train_direct(cfg, args):
    import time
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import AsyncCheckpointer
    from repro.data import PackedStream
    from repro.launch.steps import init_train_state, make_train_step

    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, base_lr=3e-4, warmup=20,
                                      total_steps=args.steps))
    stream = PackedStream(cfg.vocab_size, args.seq_len, seed=0)
    ckpt = AsyncCheckpointer(args.ckpt_dir)
    losses = []
    for step in range(1, args.steps + 1):
        b = stream.next_batch(args.batch)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.time()
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        if step % 10 == 0 or step == 1:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.2f}s/step)")
        if step % 20 == 0:
            ckpt.save(step, (params, opt_state),
                      {"step": step, "data_state": stream.snapshot()})
    ckpt.wait()
    assert losses[-1] < losses[0], "loss must improve"
    return losses


if __name__ == "__main__":
    main()
