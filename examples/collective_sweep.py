"""Mesh-shape sweep of MEASURED collective wire bytes (ISSUE 2 tentpole).

For each TP×SP mesh shape (and one PP mesh) this lowers + compiles the
sharded prefill/decode cells on forced host devices, extracts the
per-collective wire bytes from the compiled HLO
(`launch/collective_capture.py`), and feeds the decode traffic into the
PICNIC simulator as the measured photonic C2C term — printed next to the
default analytic estimate.  Smoke-sized configs by default; pass --full
for the real arch (slower lowering, paper-scale bytes).

  PYTHONPATH=src python examples/collective_sweep.py [arch] [--full]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.compat import force_host_devices
force_host_devices(8)   # before any jax import

from repro.configs import get_config, get_smoke_config
from repro.core import PicnicSimulator
from repro.launch.collective_capture import capture_cell, to_measured_traffic

arch = next((a for a in sys.argv[1:] if not a.startswith("-")),
            "llama3.2-1b")
smoke = "--full" not in sys.argv

print(f"=== {arch} ({'smoke' if smoke else 'full'} config), seq 512 ===")
captures = {}
for mesh in ("1x8", "2x4", "4x2"):
    row = {}
    for mode in ("prefill", "decode"):
        rec = capture_cell(arch, mode=mode, seq_len=512,
                           batch=int(mesh.split("x")[0]), mesh=mesh,
                           variant="picnic", smoke=smoke)
        row[mode] = rec
        colls = {op: f"{d['wire_bytes']:.2e}B"
                 for op, d in sorted(rec["collectives"].items())}
        print(f"mesh {mesh} (data x model) {mode:7s} "
              f"wire/chip={rec['wire_bytes_per_chip']:.3e}B  {colls}")
    captures[mesh] = row

# GPipe cell: pod x data x model, stage axis manual inside the shard_map
try:
    # batch 16: 8 microbatches (build_cell's pp schedule) x 2-way DP
    rec = capture_cell(arch, mode="train", seq_len=128, batch=16,
                       mesh="2x2x2", variant="pp", smoke=smoke)
    colls = {op: f"{d['wire_bytes']:.2e}B"
             for op, d in sorted(rec["collectives"].items())}
    print(f"mesh 2x2x2 (pod x data x model) pp-train "
          f"wire/chip={rec['wire_bytes_per_chip']:.3e}B  {colls}")
except Exception as e:  # noqa: BLE001 — the sweep reports, never aborts
    print(f"mesh 2x2x2 pp-train failed: {type(e).__name__}: {e}")

# feed the 1x8 decode traffic into the photonic cost model
cfg = get_smoke_config(arch) if smoke else get_config(arch)
mt = to_measured_traffic(captures["1x8"]["prefill"],
                         captures["1x8"]["decode"])
sim = PicnicSimulator()
r_an = sim.run(cfg, 512, 512)
r_me = sim.run(cfg, 512, 512, measured_c2c=mt)
print(f"\nsimulator C2C term    analytic: {r_an.c2c_bytes_total:.3e}B "
      f"-> {1e3 * r_an.c2c_avg_power_W:.3f} mW")
print(f"                      measured: {r_me.c2c_bytes_total:.3e}B "
      f"-> {1e3 * r_me.c2c_avg_power_W:.3f} mW "
      f"(source {r_me.c2c_source})")
print("throughput unchanged:", r_an.throughput_tps == r_me.throughput_tps)
