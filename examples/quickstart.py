"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import get_smoke_config, list_archs
from repro.launch.steps import init_train_state, make_train_step

print("assigned architectures:", ", ".join(list_archs()[:10]))

# 1. pick an architecture (smoke = CPU-sized config of the same family)
cfg = get_smoke_config("mixtral-8x7b")
print(f"\nmodel: {cfg.name} ({cfg.family}), "
      f"{cfg.n_layers}L d={cfg.d_model} experts={cfg.moe.n_experts}")

# 2. init + forward
params = models.init_params(cfg, jax.random.PRNGKey(0))
print("params:", f"{models.count_params(params)/1e6:.2f}M")
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                            cfg.vocab_size)
logits, aux_loss, _ = models.forward(cfg, params, tokens)
print("logits:", logits.shape, "router aux loss:", float(aux_loss))

# 3. a couple of train steps
params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg))
batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
for i in range(3):
    params, opt_state, metrics = step(params, opt_state, batch)
    print(f"step {i}: loss {float(metrics['loss']):.4f}")

# 4. prefill + greedy decode
logits, _, cache = models.forward(cfg, params, tokens[:, :32],
                                  collect_cache=True, kv_max=64)
tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
out = [tok]
for i in range(8):
    logits, cache = models.decode_step(cfg, params, tok, cache,
                                       jnp.int32(33 + i))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(tok)
print("greedy continuation:", jnp.concatenate(out, 1)[0].tolist())

# 5. the PICNIC hardware model on the paper's own benchmark
from repro.configs import get_config
from repro.core import PicnicSimulator
sim = PicnicSimulator()
r = sim.run(get_config("llama3-8b"), 1024, 1024, ccpg=True)
print(f"\nPICNIC Llama-8B 1024/1024 + CCPG: {r.throughput_tps:.1f} tok/s, "
      f"{r.avg_power_W:.2f} W, {r.efficiency_tpj:.1f} tok/J "
      f"(paper: 309.8 tok/s, 5.6 W, 55.4 tok/J)")
