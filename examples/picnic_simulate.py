"""PICNIC hardware walk-through: ISA -> program -> mapping -> simulation.

Reproduces the paper's Tables II/III and demonstrates the IPCN toolchain
(API + compiler -> NPM hex image).

  PYTHONPATH=src python examples/picnic_simulate.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core import (Instr, Mode, PicnicSimulator, ProgramBuilder,
                        allocate_chiplets, attention_grids, comparison_table,
                        compile_to_hex, map_layer)
from repro.core.isa import port_mask, unicast

# --- 1. the IPCN toolchain: write a tiny dataflow program ------------------
pb = ProgramBuilder(n_routers=1024)
# broadcast an input vector east across the W_Q region, fire the crossbars,
# PSUM partial outputs northward, stream scores to the SCU die.
pb.all_do(Instr(mode=Mode.ROUTE, rd_en=port_mask("W"),
                out_en=unicast("E")), repeat=256)
pb.all_do(Instr(mode=Mode.SMAC_FIRE), repeat=8)
pb.all_do(Instr(mode=Mode.PSUM, rd_en=port_mask("S", "PE"),
                out_en=unicast("N")), repeat=32)
pb.all_do(Instr(mode=Mode.SOFTMAX_FEED, out_en=port_mask("TSV_UP")),
          repeat=64)
hex_image = compile_to_hex(pb)
print(f"compiled IPCN program: {pb.total_cycles()} cycles, "
      f"{len(hex_image.splitlines())} hex words\n")

# --- 2. spatial mapping of a Llama-1B attention layer (Fig 6) --------------
grids = attention_grids(2048, 2048, 512)
mapping = map_layer(grids)
print("Fig-6 mapping (column bands, K-Q-V-O channels):")
for name, region in mapping.regions.items():
    print(f"  {name:6s} origin={region.origin} shape={region.shape} "
          f"tiles={region.grid.n_tiles}")

# --- 3. chiplet allocation + Table II ---------------------------------------
print("\nTable II reproduction:")
sim = PicnicSimulator()
for arch in ("llama3.2-1b", "llama3-8b", "llama2-13b"):
    cfg = get_config(arch)
    alloc = allocate_chiplets(cfg)
    for ctx in (512, 1024, 2048):
        r = sim.run(cfg, ctx, ctx)
        print(f"  {arch:12s} {ctx:5d}/{ctx:<5d} "
              f"{r.throughput_tps:8.1f} tok/s {r.avg_power_W:8.3f} W "
              f"{r.efficiency_tpj:7.1f} tok/J  ({alloc.n_chiplets} chiplets)")

# --- 4. CCPG + Table III -----------------------------------------------------
r = sim.run(get_config("llama3-8b"), 1024, 1024, ccpg=True)
print(f"\nwith CCPG: {r.avg_power_W:.2f} W, {r.efficiency_tpj:.1f} tok/J")
print("\nTable III comparison (H100 baseline):")
for row in comparison_table(r):
    print(f"  {row['platform']:22s} {row['throughput_tok_s']:8.1f} tok/s "
          f"{row['power_W']:8.1f} W  {row['efficiency_tok_J']:7.2f} tok/J  "
          f"{row['eff_impr_vs_h100']:6}x")
