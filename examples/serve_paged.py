"""Capacity-aware serving over the paged KV cache (finite scratchpads).

The infinite-capacity engine silently mispriced long contexts: KV lives
in the chiplets' 32 KB scratchpads, and what does not fit must ride the
photonic link to the DRAM hub.  This example sizes the two-tier paged
cache from the mapped model (runtime/kv_cache.kv_cache_from_model),
serves the SAME long-context trace with and without the capacity model,
and prints what the tier split costs: spill/remote-read traffic,
watermark preemptions, and the throughput/efficiency delta.

  PYTHONPATH=src python examples/serve_paged.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core import PicnicSimulator
from repro.launch import ServingConfig, Trace
from repro.launch.serving_engine import ContinuousBatchingEngine
from repro.runtime.kv_cache import kv_cache_from_model

N_REQUESTS = 16
RATE_RPS = 60.0
PROMPT_LEN = 4096
MAX_NEW = 256
MAX_BATCH = 8
CHUNK = 512

cfg = get_config("llama3.2-1b")
kvc = kv_cache_from_model(cfg, kv_frac=0.5, dram_frac=1.0)
print(f"model: {cfg.name} — {N_REQUESTS} requests, Poisson {RATE_RPS} req/s, "
      f"~{PROMPT_LEN}-token prompts, {MAX_NEW} new tokens each")
print(f"paged KV: {kvc.n_blocks} scratchpad blocks + {kvc.dram_blocks} "
      f"DRAM-hub blocks x {kvc.block_tokens} tokens "
      f"({kvc.bytes_per_token} B/token -> "
      f"{kvc.n_blocks * kvc.block_tokens} tokens chiplet-local)\n")

reports = {}
for paged in (False, True):
    sim = PicnicSimulator()
    if paged:
        sim.ccpg_model.include_dram_hub = True   # the hub is now in play
    eng = ContinuousBatchingEngine(cfg, sim=sim, engine=ServingConfig(
        max_batch=MAX_BATCH, ccpg=True,
        kv_cache=kvc if paged else None,
        chunked_prefill_tokens=CHUNK if paged else 0))
    trace = Trace.poisson(N_REQUESTS, RATE_RPS, seed=0,
                          prompt_len=PROMPT_LEN, max_new=MAX_NEW)
    rep = eng.run(trace)
    reports[paged] = rep
    label = "paged (finite scratchpad + DRAM hub)" if paged \
        else "infinite-capacity baseline"
    print(f"--- {label} ---")
    print(rep.summary())
    if paged:
        st = eng.kv_stats
        print(f"  kv blocks         peak {st.peak_blocks_used}/"
              f"{st.n_blocks + st.dram_blocks} used, "
              f"{st.preemptions} preemptions "
              f"({st.recomputed_tokens} tokens recomputed)")
        print(f"  kv traffic        {st.spilled_bytes / 1e6:.1f} MB spilled, "
              f"{st.dram_read_bytes / 1e6:.1f} MB remote-read over the "
              f"photonic link")
    print()

r0, r1 = reports[False], reports[True]
print(f"capacity pricing: {100 * r1.tokens_per_s / r0.tokens_per_s:.1f}% "
      f"of infinite-cache throughput, "
      f"{100 * r1.tokens_per_J / r0.tokens_per_J:.1f}% of its tokens/J — "
      f"what the scratchpad/DRAM-hub tier split actually costs")

# --- prefix sharing: copy-on-write block tables (ISSUE 6) ----------------
# A chat-style fleet where 90% of requests open with the same long system
# prompt.  Without sharing, every sharer pays the full KV footprint and
# co-residency collapses; with prefix_sharing=True the allocator indexes
# prefix blocks by chain hash, new requests adopt them (refcounted) and
# fork privately at the first divergent token.
import dataclasses

PREFIX_LEN = 3840
print(f"\nprefix-heavy workload: {N_REQUESTS} requests, 90% share a "
      f"{PREFIX_LEN}-token system prefix of the {PROMPT_LEN}-token prompt")
occ = {}
for share in (False, True):
    sim = PicnicSimulator()
    sim.ccpg_model.include_dram_hub = True
    eng = ContinuousBatchingEngine(cfg, sim=sim, engine=ServingConfig(
        max_batch=MAX_BATCH, ccpg=True,
        kv_cache=dataclasses.replace(kvc, prefix_sharing=share),
        chunked_prefill_tokens=CHUNK))
    trace = Trace.poisson(N_REQUESTS, RATE_RPS, seed=0,
                          prompt_len=PROMPT_LEN, max_new=MAX_NEW,
                          prefix_len=PREFIX_LEN, prefix_frac=0.9)
    rep = eng.run(trace)
    occ[share] = rep.mean_batch_occupancy
    st = eng.kv_stats
    tag = "sharing ON " if share else "sharing OFF"
    print(f"  {tag}: batch occupancy {rep.mean_batch_occupancy:.2f}, "
          f"{rep.tokens_per_s:.0f} tok/s, "
          f"{st.prefix_hits} prefix hits "
          f"({st.prefix_hit_tokens} tokens adopted, "
          f"hit rate {st.prefix_hit_rate:.0%}), "
          f"{st.cow_forks} COW forks "
          f"({st.cow_copied_bytes / 1e3:.0f} KB copied), "
          f"peak {st.shared_blocks_peak} shared blocks")
print(f"prefix sharing recovers batch occupancy "
      f"{occ[False]:.2f} -> {occ[True]:.2f} "
      f"({occ[True] / occ[False]:.2f}x) by deduplicating the common "
      f"prefix and copying only each fork's divergent head")
