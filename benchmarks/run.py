"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
producing computation on this host; derived = the headline quantity the
paper's table/figure reports).  Detailed tables go to artifacts/bench/;
headline benches additionally write ``BENCH_<name>.json`` artifacts
(throughput, tok/J, p50/p99 in one stable schema) so the perf trajectory
stays machine-readable across PRs (uploaded by CI).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table_ii   # one (alias: table2)
  python benchmarks/run.py table2 --trace-out /tmp/t.json   # + chrome trace
"""
from __future__ import annotations

import copy
import dataclasses
import itertools
import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np  # noqa: E402

ART = ROOT / "artifacts" / "bench"

PAPER_TABLE_II = {
    ("llama3.2-1b", 512): (1503.8, 4.0520, 371.1),
    ("llama3.2-1b", 1024): (969.2, 4.0513, 239.2),
    ("llama3.2-1b", 2048): (566.4, 4.0507, 139.8),
    ("llama3-8b", 512): (386.5, 28.4018, 13.6),
    ("llama3-8b", 1024): (309.8, 28.4015, 10.9),
    ("llama3-8b", 2048): (221.9, 28.4010, 7.8),
    ("llama2-13b", 512): (228.9, 52.3014, 4.4),
    ("llama2-13b", 1024): (192.4, 52.3012, 3.7),
    ("llama2-13b", 2048): (146.2, 52.3009, 2.8),
}


def _emit(name, t0, derived):
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")


def _save(name, obj):
    ART.mkdir(parents=True, exist_ok=True)
    with open(ART / f"{name}.json", "w") as f:
        json.dump(obj, f, indent=1, default=str)


def _bench_artifact(name, metrics, rows=None, extra=None):
    """BENCH_<name>.json — one stable schema per bench across PRs so the
    perf trajectory is machine-diffable (CI uploads these).  ``extra``
    merges additional top-level keys (e.g. the ``host_ops_per_s``
    calibration fingerprint that check_regression.py uses to decide
    wall-clock comparability)."""
    ART.mkdir(parents=True, exist_ok=True)
    doc = {"bench": name, "schema": 1, "metrics": metrics}
    if rows is not None:
        doc["rows"] = rows
    if extra:
        doc.update(extra)
    with open(ART / f"BENCH_{name}.json", "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)


@dataclasses.dataclass
class Cell:
    """One point of a benchmark sweep grid: ordered ``(axis, value)``
    pairs plus the abbreviation map used to render its artifact key."""
    axes: tuple
    abbrev: dict

    def __getitem__(self, name):
        for k, v in self.axes:
            if k == name:
                return v
        raise KeyError(name)

    def get(self, name, default=None):
        return next((v for k, v in self.axes if k == name), default)

    def key(self, *, without=()) -> str:
        """Stable artifact key: ``<abbrev><value>`` fragments joined by
        ``_`` in axis order.  Bools render as 0/1; a ``None`` axis value
        is skipped (sparse axes — e.g. ``prefix_sharing`` only appears
        on the prefix cells); ``without`` drops axes (the paged bench's
        tier-free keys)."""
        parts = []
        for name, value in self.axes:
            if name in without or value is None:
                continue
            if isinstance(value, bool):
                value = int(value)
            parts.append(f"{self.abbrev.get(name, name)}{value}")
        return "_".join(parts)


def cell_grid(axes, abbrev=None):
    """Cartesian product of named axes -> list of :class:`Cell` in
    row-major (last axis fastest) order.  Replaces the ad-hoc per-bench
    key builders (the `_prefix{0,1}` disambiguation pattern) with one
    stable naming scheme shared by every sweep bench."""
    abbrev = abbrev or {}
    names = list(axes)
    return [Cell(tuple(zip(names, vals)), abbrev)
            for vals in itertools.product(*axes.values())]


# ---------------------------------------------------------------------------

def bench_table_ii():
    """Table II: PICNIC LLM inference benchmark (9 rows) vs the paper."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    t0 = time.time()
    sim = PicnicSimulator()
    rows, errs = [], []
    for (arch, ctx), (tput, power, eff) in PAPER_TABLE_II.items():
        r = sim.run(get_config(arch), ctx, ctx)
        err = r.throughput_tps / tput - 1
        errs.append(abs(err))
        rows.append({**r.row(), "paper_tput": tput, "paper_power": power,
                     "paper_eff": eff, "tput_err_%": round(100 * err, 1)})
    mean_err = 100 * float(np.mean(errs))
    _save("table_ii", rows)
    _bench_artifact("table_ii", {
        "mean_abs_tput_err_pct": round(mean_err, 3),
        "throughput_tok_s": {f"{r['model']}/{r['context']}":
                             r["throughput_tok_s"] for r in rows},
        "efficiency_tok_J": {f"{r['model']}/{r['context']}":
                             r["efficiency_tok_J"] for r in rows},
    }, rows=rows)
    _emit("table_ii", t0, f"mean_abs_tput_err_pct={mean_err:.2f}")
    return rows


def bench_table_iii():
    """Table III: platform comparison (Llama-8B 1024/1024, H100 baseline)."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator, comparison_table
    t0 = time.time()
    sim = PicnicSimulator()
    r = sim.run(get_config("llama3-8b"), 1024, 1024, ccpg=True)
    rows = comparison_table(r)
    _save("table_iii", rows)
    _bench_artifact("table_iii", {
        "throughput_tok_s": rows[0]["throughput_tok_s"],
        "efficiency_tok_J": rows[0]["efficiency_tok_J"],
        "eff_impr_vs_h100": rows[0]["eff_impr_vs_h100"],
        "speedup_vs_h100": rows[0]["speedup_vs_h100"],
    })
    _emit("table_iii", t0,
          f"eff_impr_vs_h100={rows[0]['eff_impr_vs_h100']}x_paper=57x")
    return rows


def bench_table_iv():
    """Table IV: power & area breakdown of the PICNIC macros."""
    from repro.core import table_iv, TileSpec
    t0 = time.time()
    t = table_iv()
    ts = TileSpec()
    t["_tile"] = {"area_mm2": ts.tile_area_mm2,
                  "active_W": ts.tile_power_active,
                  "sleep_W": ts.tile_power_sleep}
    _save("table_iv", t)
    _emit("table_iv", t0,
          f"router_pe_pair_uW={t['Total (IPCN-PE)']['power_uW']:.0f}")
    return t


def bench_fig8_ccpg():
    """Fig 8: system power & efficiency with/without CCPG."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    t0 = time.time()
    sim = PicnicSimulator()
    rows = []
    for arch in ("llama3.2-1b", "llama3-8b", "llama2-13b"):
        cfg = get_config(arch)
        r0 = sim.run(cfg, 1024, 1024, ccpg=False)
        r1 = sim.run(cfg, 1024, 1024, ccpg=True)
        rows.append({
            "model": arch,
            "power_W": round(r0.avg_power_W, 3),
            "power_ccpg_W": round(r1.avg_power_W, 3),
            "saving_%": round(100 * (1 - r1.avg_power_W / r0.avg_power_W), 1),
            "eff_tpj": round(r0.efficiency_tpj, 2),
            "eff_ccpg_tpj": round(r1.efficiency_tpj, 2),
            "tput_ratio": round(r1.throughput_tps / r0.throughput_tps, 4),
        })
    _save("fig8_ccpg", rows)
    saving_8b = [r for r in rows if r["model"] == "llama3-8b"][0]["saving_%"]
    _emit("fig8_ccpg", t0, f"llama8b_power_saving_pct={saving_8b}_paper=80")
    return rows


def bench_fig9_c2c():
    """Fig 9: average C2C power, electrical vs optical, per model/ctx."""
    from repro.configs import get_config
    from repro.core import ELECTRICAL, OPTICAL, PicnicSimulator
    from repro.core.interconnect import c2c_average_power
    t0 = time.time()
    sim = PicnicSimulator()
    rows = []
    for arch in ("llama3.2-1b", "llama3-8b", "llama2-13b"):
        for ctx in (512, 1024, 2048):
            r = sim.run(get_config(arch), ctx, ctx)
            rate = r.c2c_bytes_total / (r.prefill_s + r.decode_s)
            rows.append({
                "model": arch, "ctx": ctx,
                "c2c_rate_MBps": round(rate / 1e6, 2),
                "optical_mW": round(1e3 * c2c_average_power(rate, OPTICAL), 3),
                "electrical_mW": round(
                    1e3 * c2c_average_power(rate, ELECTRICAL), 3),
            })
    _save("fig9_c2c", rows)
    # the paper's two claims: optical < electrical, power falls with ctx
    ok1 = all(r["optical_mW"] < r["electrical_mW"] for r in rows)
    by_model = {}
    for r in rows:
        by_model.setdefault(r["model"], []).append(r["electrical_mW"])
    ok2 = all(v[0] >= v[-1] for v in by_model.values())
    _emit("fig9_c2c", t0, f"optical_lt_electrical={ok1}_falls_with_ctx={ok2}")
    return rows


def bench_fig10_timeline():
    """Fig 10: C2C transfer distribution over time (Llama-1B)."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    t0 = time.time()
    sim = PicnicSimulator()
    trace = sim.c2c_trace(get_config("llama3.2-1b"), n_tokens=8, context=512)
    horizon = max(t + d for t, d, _ in trace.events) * 1.01
    bins = trace.binned(horizon, 100)
    out = {"utilization": trace.utilization(horizon),
           "n_bursts": len(trace.events), "bins_GBps": bins}
    _save("fig10_timeline", out)
    _emit("fig10_timeline", t0,
          f"link_utilization={out['utilization']:.4f}_bursty=True")
    return out


def bench_serving():
    """Continuous-batching serving engine: the same 64-request Poisson
    trace (Llama-1B 512/64) served 1-at-a-time vs batch-8, ccpg off/on.
    Headline: batched decode throughput at batch 8 vs sequential.  The
    four cells run as one batched pass through launch/sweep_engine
    (byte-identical to the scalar engine per cell — locked by the sweep
    differential suite)."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    from repro.launch import ServingConfig, Trace
    from repro.launch.sweep_engine import SweepCell, sweep_serve
    t0 = time.time()
    cfg = get_config("llama3.2-1b")
    sim = PicnicSimulator()
    grid = cell_grid({"max_batch": (1, 8), "ccpg": (False, True)})
    cells = [SweepCell(c.key(), cfg,
                       Trace.poisson(64, rate_rps=40, seed=0,
                                     prompt_len=512, max_new=64),
                       ServingConfig(max_batch=c["max_batch"],
                                     ccpg=c["ccpg"]), sim=sim)
             for c in grid]
    results = sweep_serve(cells)
    rows = [{"max_batch": c["max_batch"], **r.report.row()}
            for c, r in zip(grid, results)]
    tput = {(c["max_batch"], c["ccpg"]): r.report.tokens_per_s
            for c, r in zip(grid, results)}
    speedup = tput[(8, False)] / tput[(1, False)]
    _save("serving", rows)
    _bench_artifact("serving", {
        "batch8_vs_1_speedup": round(speedup, 3),
        "tokens_per_s": {f"b{r['max_batch']}_ccpg{int(r['ccpg'])}":
                         r["tokens_per_s"] for r in rows},
        "tokens_per_J": {f"b{r['max_batch']}_ccpg{int(r['ccpg'])}":
                         r["tokens_per_J"] for r in rows},
        "p50_latency_s": {f"b{r['max_batch']}_ccpg{int(r['ccpg'])}":
                          r["p50_latency_s"] for r in rows},
        "p99_latency_s": {f"b{r['max_batch']}_ccpg{int(r['ccpg'])}":
                          r["p99_latency_s"] for r in rows},
    }, rows=rows)
    _emit("serving", t0, f"batch8_vs_1at_a_time_tput={speedup:.2f}x")
    return rows


def bench_paged():
    """Paged KV-cache serving (ISSUE 4 tentpole): context length x
    arrival rate sweep over the FINITE scratchpad budget (blocks sized
    from the mapped model, DRAM-hub spill tier behind the photonic link,
    chunked prefill) vs the infinite-capacity engine that silently
    mispriced long contexts.  Headline: how much of the infinite-cache
    throughput the paged engine keeps at the longest context — plus the
    ISSUE 6 prefix-heavy cell, where copy-on-write prefix sharing
    recovers the batch occupancy that long shared system prompts cost."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    from repro.launch import ServingConfig, Trace
    from repro.launch.sweep_engine import SweepCell, sweep_serve
    from repro.runtime.kv_cache import kv_cache_from_model
    t0 = time.time()
    arch = "llama3.2-1b"
    cfg = get_config(arch)
    kvc = kv_cache_from_model(cfg, kv_frac=0.5, dram_frac=1.0)
    abbrev = {"rate_rps": "r", "paged": "p", "prefix_sharing": "prefix"}
    grid = cell_grid({"ctx": (512, 2048, 8192), "rate_rps": (20, 60),
                      "paged": (False, True)}, abbrev=abbrev)
    # prefix-heavy cells (ISSUE 6): 90% of requests carry a long shared
    # system prefix (8064 of 8192 prompt tokens) at the capacity-bound
    # corner — without sharing each sharer pays the full footprint and
    # mean batch collapses to ~2.4; COW prefix sharing dedups the common
    # blocks and recovers most of the occupancy
    grid += cell_grid({"ctx": (8192,), "rate_rps": (60,), "paged": (True,),
                       "prefix_sharing": (False, True)}, abbrev=abbrev)
    sim_plain = PicnicSimulator()
    sim_hub = PicnicSimulator()
    sim_hub.ccpg_model.include_dram_hub = True
    cells = []
    for c in grid:
        share = c.get("prefix_sharing")
        if share is None:
            # max_new keeps residents decoding long enough to build
            # co-residency — the regime where capacity binds (short
            # decodes are prefill-serial and never stress the cache)
            kv = kvc if c["paged"] else None
            trace = Trace.poisson(16, rate_rps=c["rate_rps"], seed=0,
                                  prompt_len=c["ctx"], max_new=256)
        else:
            kv = dataclasses.replace(kvc, prefix_sharing=share)
            trace = Trace.poisson(24, rate_rps=60, seed=0, prompt_len=8192,
                                  max_new=512, prefix_len=8064,
                                  prefix_frac=0.9)
        cells.append(SweepCell(
            c.key(), cfg, trace,
            ServingConfig(max_batch=8, ccpg=True, kv_cache=kv,
                          chunked_prefill_tokens=512 if kv else 0),
            sim=sim_hub if c["paged"] else sim_plain))
    results = sweep_serve(cells)

    rows, tput, mean_batch = [], {}, {}
    for c, res in zip(grid, results):
        rep, st = res.report, res.kv_stats
        share = c.get("prefix_sharing")
        if share is None:
            tput[(c["ctx"], c["rate_rps"], c["paged"])] = rep.tokens_per_s
            rows.append({
                "ctx": c["ctx"], "rate_rps": c["rate_rps"],
                "paged": c["paged"], **rep.row(),
                **({"kv": st.row()} if st is not None else {}),
            })
        else:
            mean_batch[share] = rep.mean_batch_occupancy
            rows.append({
                "ctx": c["ctx"], "rate_rps": c["rate_rps"], "paged": True,
                "prefix": True, "prefix_sharing": share,
                **rep.row(), "kv": st.row(),
            })
    keep = tput[(8192, 60, True)] / tput[(8192, 60, False)]
    recovery = mean_batch[True] / mean_batch[False]

    _save("paged", rows)
    keyed = list(zip((c.key() for c in grid), rows))
    tiered = [(c.key(without=("paged",)), r)
              for c, r in zip(grid, rows) if r["paged"]]
    _bench_artifact("paged", {
        "paged_vs_infinite_tput_at_8k": round(keep, 3),
        "prefix_batch_recovery_speedup": round(recovery, 3),
        "prefix_mean_batch": {"off": round(mean_batch[False], 2),
                              "on": round(mean_batch[True], 2)},
        "kv_blocks": kvc.n_blocks,
        "tokens_per_s": {k: r["tokens_per_s"] for k, r in keyed},
        "tokens_per_J": {k: r["tokens_per_J"] for k, r in keyed},
        "p99_latency_s": {k: r["p99_latency_s"] for k, r in keyed},
        "preemptions": {k: r["kv"]["preemptions"] for k, r in tiered},
        "spilled_MB": {k: round(r["kv"]["spilled_bytes"] / 1e6, 2)
                       for k, r in tiered},
    }, rows=rows)
    _emit("paged", t0, f"paged_vs_infinite_tput_at_8k={keep:.3f} "
                       f"prefix_batch_recovery_speedup={recovery:.2f}x")
    return rows


def _sweep_grid_vs_scalar(cells):
    """One sweep grid both ways: batched SweepEngine vs the scalar fast
    engine cell-by-cell with a fresh simulator per cell (exactly how
    this harness executed sweeps before launch/sweep_engine existed).
    Asserts per-cell report identity and a fallback-free vector path
    before any number is recorded, so the speedup can never be bought
    with a behavior change.  Returns (results, engine, t_sweep_s,
    t_scalar_s)."""
    import copy
    from repro.core import PicnicSimulator
    from repro.launch.serving_engine import ContinuousBatchingEngine
    from repro.launch.sweep_engine import SweepEngine
    eng = SweepEngine(cells)
    t_sw = time.perf_counter()
    results = eng.run()
    t_sw = time.perf_counter() - t_sw
    t_sc = time.perf_counter()
    refs = []
    for c in cells:
        s2 = PicnicSimulator()
        if c.sim is not None and c.sim.ccpg_model.include_dram_hub:
            s2.ccpg_model.include_dram_hub = True
        ref = ContinuousBatchingEngine(c.cfg, sim=s2, engine=c.engine)
        refs.append(ref.run([copy.copy(r) for r in c.trace]))
    t_sc = time.perf_counter() - t_sc
    for c, res, ref in zip(cells, results, refs):
        assert res.fallback is None, (c.key, res.fallback)
        assert res.report.row() == ref.row(), \
            f"sweep cell {c.key}: batched engine diverged from scalar"
    return results, eng, t_sw, t_sc


def bench_sweep():
    """Vectorized sweep engine (ISSUE 7 tentpole, ISSUE 8 finish): three
    grids through launch/sweep_engine vs the scalar engine per cell.

      * decode grid — 64 paged cells, ctx x arrival-rate x max_batch x
        max_new in the long-generation decode regime (reasoning-style
        workloads, coarse 2048-token KV blocks);
      * prefill grid — 64 prefill-heavy/short-generation cells (32k
        prompts streamed in 64-token chunks, 1-2 generated tokens), the
        regime the prefill cruise vectorizes;
      * lifted grid — 16 decode-heavy cells over the previously-fallback
        knobs (overlap in (0,1], dynamic CCPG, TTFT deadlines), now on
        the vector path.

    The doc carries the host-calibration fingerprint (see
    microbench.py); wall-derived speedups gate loose and per-cell
    tokens_per_s values are deterministic simulated outputs gating tight
    via the check_regression.py TOLERANCE_OVERRIDES table.
    ``cells_per_s`` is split vector vs scalar-fallback wall time (the
    fallback share no longer silently dilutes the headline) and the
    summary line carries the per-reason fallback counts."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    from repro.launch import ServingConfig, Trace
    from repro.launch.sweep_engine import SweepCell
    from repro.runtime.kv_cache import kv_cache_from_model
    try:
        from benchmarks.microbench import _host_calibration
    except ImportError:                     # `python benchmarks/run.py`
        from microbench import _host_calibration
    t0 = time.time()
    cfg = get_config("llama3.2-1b")
    cal = _host_calibration()

    kvc = dataclasses.replace(
        kv_cache_from_model(cfg, kv_frac=0.5, dram_frac=1.0),
        block_tokens=2048, n_blocks=24, dram_blocks=24)
    sim = PicnicSimulator()
    sim.ccpg_model.include_dram_hub = True
    grid = cell_grid({"ctx": (256, 1024),
                      "rate_rps": (10, 20, 30, 40, 50, 60, 80, 100),
                      "max_batch": (4, 8), "max_new": (2048, 4096)},
                     abbrev={"rate_rps": "r", "max_batch": "b",
                             "max_new": "n"})
    dec_cells = [SweepCell(c.key(), cfg,
                           Trace.poisson(6, rate_rps=c["rate_rps"], seed=0,
                                         prompt_len=c["ctx"],
                                         max_new=c["max_new"]),
                           ServingConfig(max_batch=c["max_batch"], ccpg=True,
                                         kv_cache=kvc,
                                         chunked_prefill_tokens=512),
                           sim=sim)
                 for c in grid]
    pf_cells = [SweepCell(f"pf_r{rate}_n{mn}_s{sd}", cfg,
                          Trace.poisson(2, rate_rps=rate, seed=sd,
                                        prompt_len=32768, max_new=mn),
                          ServingConfig(max_batch=8, ccpg=True,
                                        chunked_prefill_tokens=64))
                for rate in (1, 2, 4, 8, 16, 32, 64, 128)
                for mn in (1, 2) for sd in (0, 1, 2, 3)]
    lift_cells = [SweepCell(f"lift_o{ov}_d{int(dyn)}_t{tt}_r{rate}", cfg,
                            Trace.poisson(6, rate_rps=rate, seed=0,
                                          prompt_len=256, max_new=4096,
                                          **({} if tt is None
                                             else dict(deadline_ttft=tt))),
                            ServingConfig(max_batch=8, overlap=ov,
                                          ccpg=True, dynamic_ccpg=dyn))
                  for ov in (0.25, 0.75) for dyn in (False, True)
                  for tt in (None, 0.25) for rate in (30, 60)]

    dec_res, dec_eng, dec_sw, dec_sc = _sweep_grid_vs_scalar(dec_cells)
    pf_res, pf_eng, pf_sw, pf_sc = _sweep_grid_vs_scalar(pf_cells)
    lf_res, lf_eng, lf_sw, lf_sc = _sweep_grid_vs_scalar(lift_cells)

    engines = (dec_eng, pf_eng, lf_eng)
    n_cells = len(dec_cells) + len(pf_cells) + len(lift_cells)
    fb_counts: dict = {}
    for e in engines:
        for reason, cnt in e.fallback_counts.items():
            fb_counts[reason] = fb_counts.get(reason, 0) + cnt
    n_fb = sum(fb_counts.values())
    vec_wall = sum(e.vector_wall_s for e in engines)
    fb_wall = sum(e.fallback_wall_s for e in engines)
    speedup = dec_sc / dec_sw
    pf_speedup = pf_sc / pf_sw
    lf_speedup = lf_sc / lf_sw

    pairs = list(zip(dec_cells, dec_res)) + list(zip(pf_cells, pf_res)) \
        + list(zip(lift_cells, lf_res))
    rows = [{"cell": c.key, **r.report.row()} for c, r in pairs]
    _save("sweep", rows)
    _bench_artifact("sweep", {
        "sweep_speedup_64cell": round(speedup, 2),
        "sweep_speedup_prefill_64cell": round(pf_speedup, 2),
        "sweep_speedup_lifted_16cell": round(lf_speedup, 2),
        # vector vs scalar-fallback wall split: every cell of every grid
        # rides the vector path, so the fallback share must stay zero
        "cells_per_s": {
            "vector": round((n_cells - n_fb) / vec_wall, 1),
            "fallback": round(n_fb / fb_wall, 1) if fb_wall else 0.0},
        "wall_ms": {"sweep": round(dec_sw * 1e3, 1),
                    "scalar_per_cell": round(dec_sc * 1e3, 1),
                    "prefill_sweep": round(pf_sw * 1e3, 1),
                    "prefill_scalar_per_cell": round(pf_sc * 1e3, 1),
                    "lifted_sweep": round(lf_sw * 1e3, 1),
                    "lifted_scalar_per_cell": round(lf_sc * 1e3, 1),
                    "fallback": round(fb_wall * 1e3, 1)},
        "n_cells": n_cells,
        "fallback_cells": n_fb,
        "tokens_per_s": {c.key: r.report.tokens_per_s for c, r in pairs},
    }, rows=rows, extra={"host_ops_per_s": round(cal, 1)})
    _emit("sweep", t0,
          f"speedup decode={speedup:.1f}x prefill={pf_speedup:.1f}x "
          f"lifted={lf_speedup:.1f}x fallback_cells={n_fb} ({fb_counts})")
    return rows


def bench_fleet():
    """Disaggregated prefill/decode fleet (ISSUE 9 tentpole): node count x
    prefill:decode split x arrival rate over launch/fleet_engine.py.  Each cell
    serves the same Poisson trace (Llama-1B 512/64, CCPG on) through a
    FleetEngine; disaggregated splits hand finished-prefill KV to a
    decode node as an inter-node C2CTransfer priced by
    core/interconnect.fleet_handoff_bytes.  The combined cells (handoff
    off, same node count) are the like-for-like baseline, so the
    headline is the tok/J-optimal disaggregation point and its
    efficiency ratio vs combined serving — honest even when < 1.  An
    autoscale pair at low arrival rate surfaces CCPG node wake counts
    (whole nodes sleep, scale-up pays real ClusterWake latency)."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    from repro.launch import FleetConfig, ServingConfig, Trace
    from repro.launch.fleet_engine import FleetEngine
    try:
        from benchmarks.microbench import _host_calibration
    except ImportError:                     # `python benchmarks/run.py`
        from microbench import _host_calibration
    t0 = time.time()
    cfg = get_config("llama3.2-1b")
    cal = _host_calibration()
    ecfg = ServingConfig(max_batch=8, ccpg=True)

    # (n_prefill, n_decode, handoff): combined baselines keep the node
    # count so the ratio sweep is like-for-like
    shapes = {2: [(1, 1, False), (1, 1, True)],
              4: [(2, 2, False), (1, 3, True), (2, 2, True), (3, 1, True)]}
    rates = (60, 120)
    t_wall = time.perf_counter()
    rows, tput, eff = [], {}, {}
    for rate in rates:
        trace = Trace.poisson(48, rate_rps=rate, seed=0,
                              prompt_len=512, max_new=64)
        for n, splits in shapes.items():
            for (p, d, handoff) in splits:
                fc = FleetConfig(n_prefill=p, n_decode=d, handoff=handoff,
                                 engine=ecfg)
                eng = FleetEngine(cfg, fc, sim=PicnicSimulator())
                rep = eng.run([copy.copy(r) for r in trace])
                key = (f"n{n}_p{p}d{d}_"
                       f"{'dis' if handoff else 'comb'}_r{rate}")
                assert rep.finished == len(trace), \
                    f"fleet cell {key}: dropped requests"
                rows.append({"cell": key, **rep.row()})
                tput[key] = rep.tokens_per_s
                eff[(n, rate, handoff)] = max(
                    eff.get((n, rate, handoff), 0.0), rep.tokens_per_J)

    # autoscale pair: low arrival rate, 2+2 nodes — with autoscaling the
    # fleet parks idle nodes asleep and pays ClusterWake on scale-up
    wakes = {}
    trace = Trace.poisson(48, rate_rps=20, seed=0,
                          prompt_len=512, max_new=64)
    for auto in (False, True):
        fc = FleetConfig(n_prefill=2, n_decode=2, handoff=True,
                         engine=ecfg, autoscale=auto, min_awake=1,
                         scale_up_queue=2)
        rep = FleetEngine(cfg, fc, sim=PicnicSimulator()).run(
            [copy.copy(r) for r in trace])
        key = f"n4_p2d2_dis_r20_auto{int(auto)}"
        rows.append({"cell": key, **rep.row()})
        wakes[auto] = rep.wakes
    t_wall = time.perf_counter() - t_wall

    best_eff = max(v for (_, _, h), v in eff.items() if h)
    ratio = max(eff[(n, r, True)] / eff[(n, r, False)]
                for n in shapes for r in rates)
    _save("fleet", rows)
    _bench_artifact("fleet", {
        "fleet_best_tokens_per_J": round(best_eff, 2),
        "disagg_vs_combined_eff_speedup": round(ratio, 3),
        "autoscale_wakes": {"off": wakes[False], "on": wakes[True]},
        "tokens_per_s": {r["cell"]: r["tokens_per_s"] for r in rows},
        "tokens_per_J": {r["cell"]: r["tokens_per_J"] for r in rows},
        "handoff_MB": {r["cell"]: r["handoff_MB"] for r in rows},
        "p99_ttft_s": {r["cell"]: r["p99_ttft_s"] for r in rows},
        "wall_ms": round(t_wall * 1e3, 1),
    }, rows=rows, extra={"host_ops_per_s": round(cal, 1)})
    _emit("fleet", t0,
          f"disagg_vs_combined_eff={ratio:.3f}x "
          f"autoscale_wakes={wakes[True]}")
    return rows


def bench_chaos():
    """Deterministic fault injection over the fleet (ISSUE 10 tentpole):
    fault profile x node count through launch/fleet_engine.py with a
    seeded FaultConfig schedule.  Profiles: zero-fault baseline, link
    degradation windows (FEC/retransmit overhead on every handoff in
    the window), node crash + recovery (KV lost; survivors re-routed
    with recompute-from-prompt) and full chaos (links + crashes + CCPG
    wake failures).  Headlines: worst-case availability, chaos goodput
    retention vs the zero-fault baseline, and MTTR.  The zero-fault
    default is asserted hex-identical to an inert FaultConfig in-bench,
    so the fault machinery provably prices nothing when no fault is
    declared."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    from repro.launch import FleetConfig, ServingConfig, Trace
    from repro.launch.config import FaultConfig
    from repro.launch.fleet_engine import FleetEngine
    try:
        from benchmarks.microbench import _host_calibration
    except ImportError:                     # `python benchmarks/run.py`
        from microbench import _host_calibration
    t0 = time.time()
    cfg = get_config("llama3.2-1b")
    cal = _host_calibration()
    ecfg = ServingConfig(max_batch=8, ccpg=True)

    def profile(name, n_nodes):
        if name == "none":
            return None
        if name == "links":
            return FaultConfig.seeded(seed=11, n_nodes=n_nodes,
                                      horizon_s=0.8, link_windows=2)
        if name == "crash":
            return FaultConfig.seeded(seed=12, n_nodes=n_nodes,
                                      horizon_s=0.8, node_crashes=1)
        return FaultConfig.seeded(seed=13, n_nodes=n_nodes,
                                  horizon_s=0.8, link_windows=2,
                                  node_crashes=2, wake_faults=1)

    def hexrow(row):
        return {k: (v.hex() if isinstance(v, float) else v)
                for k, v in row.items()}

    shapes = {2: (1, 1), 4: (2, 2)}
    profiles = ("none", "links", "crash", "chaos")
    t_wall = time.perf_counter()
    rows, avail, goodput, mttr = [], {}, {}, {}
    base_tput = {}
    for n, (p, d) in shapes.items():
        trace = Trace.poisson(48, rate_rps=60, seed=0,
                              prompt_len=512, max_new=64)
        for prof in profiles:
            fc = FleetConfig(n_prefill=p, n_decode=d, handoff=True,
                             engine=ecfg, fault=profile(prof, n))
            eng = FleetEngine(cfg, fc, sim=PicnicSimulator())
            rep = eng.run([copy.copy(r) for r in trace])
            key = f"n{n}_p{p}d{d}_{prof}"
            assert rep.finished + rep.rejected == rep.n_requests, \
                f"chaos cell {key}: silent request loss"
            row = rep.row()
            rows.append({"cell": key, **row})
            if prof == "none":
                base_tput[n] = rep.tokens_per_s
                # zero-fault identity: an INERT FaultConfig must price
                # nothing — hex-identical row to fault=None
                fc_inert = FleetConfig(n_prefill=p, n_decode=d,
                                       handoff=True, engine=ecfg,
                                       fault=FaultConfig())
                rep_i = FleetEngine(cfg, fc_inert,
                                    sim=PicnicSimulator()).run(
                    [copy.copy(r) for r in trace])
                assert hexrow(rep_i.row()) == hexrow(row), \
                    f"chaos cell {key}: inert FaultConfig not inert"
            else:
                avail[key] = row["availability"]
                goodput[key] = row["goodput_tokens_per_s"]
                if row["mttr_s"] is not None:
                    mttr[key] = row["mttr_s"]
    t_wall = time.perf_counter() - t_wall

    worst_avail = min(avail.values())
    retention = min(goodput[f"n{n}_p{p}d{d}_chaos"] / base_tput[n]
                    for n, (p, d) in shapes.items())
    _save("chaos", rows)
    _bench_artifact("chaos", {
        "worst_availability": round(worst_avail, 6),
        "chaos_goodput_retention": round(retention, 4),
        "availability": avail,
        "goodput_tokens_per_s": goodput,
        "mttr_s": mttr,
        "p99_ttft_s": {r["cell"]: r["p99_ttft_s"] for r in rows},
        "finished": {r["cell"]: r["finished"] for r in rows},
        "rejected": {r["cell"]: r["rejected"] for r in rows},
        "wall_ms": round(t_wall * 1e3, 1),
    }, rows=rows, extra={"host_ops_per_s": round(cal, 1)})
    _emit("chaos", t0,
          f"worst_availability={worst_avail:.4f} "
          f"goodput_retention={retention:.3f}")
    return rows


def bench_distributed():
    """Measured HLO collectives -> photonic cost model (ISSUE 2 tentpole).

    Lowers the sharded llama-1B prefill + decode cells (picnic variant:
    shard_map SP attention / partial-softmax decode) on a forced 8-host-
    device 1x8 (data x model) mesh in a subprocess, extracts per-collective
    wire bytes from the compiled HLO, and feeds them into the simulator as
    the photonic C2C traffic term — next to the default analytic path,
    which must keep reproducing the calibrated Table II row exactly."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    from repro.launch.collective_capture import (capture_in_subprocess,
                                                 to_measured_traffic)
    t0 = time.time()
    arch, ctx = "llama3.2-1b", 512
    recs = capture_in_subprocess(arch, modes=("prefill", "decode"),
                                 seq_len=ctx, batch=1, mesh="1x8",
                                 variant="picnic")
    pre = next(r for r in recs if r["mode"] == "prefill")
    dec = next(r for r in recs if r["mode"] == "decode")
    mt = to_measured_traffic(pre, dec)

    sim = PicnicSimulator()
    cfg = get_config(arch)
    r_an = sim.run(cfg, ctx, ctx)                      # default: analytic
    r_me = sim.run(cfg, ctx, ctx, measured_c2c=mt)     # measured traffic
    # guard: the default b=1 path must still hit the calibrated Table II row
    paper_tput = PAPER_TABLE_II[(arch, ctx)][0]
    tput_err = abs(r_an.throughput_tps / paper_tput - 1)
    assert tput_err < 0.07, (r_an.throughput_tps, paper_tput)
    assert r_me.throughput_tps == r_an.throughput_tps  # traffic != timing

    out = {
        "arch": arch, "ctx": ctx, "mesh": dec["mesh"],
        "per_collective_decode": dec["collectives"],
        "per_collective_prefill": pre["collectives"],
        "measured": {
            "prefill_bytes": mt.prefill_bytes,
            "decode_bytes_per_token": mt.decode_bytes_per_token,
            "c2c_bytes_total": r_me.c2c_bytes_total,
            "c2c_power_W": r_me.c2c_avg_power_W,
            "c2c_source": r_me.c2c_source,
        },
        "analytic": {
            "c2c_bytes_total": r_an.c2c_bytes_total,
            "c2c_power_W": r_an.c2c_avg_power_W,
            "tput_err_vs_paper_%": round(100 * tput_err, 2),
        },
    }
    _save("distributed", out)
    ratio = r_me.c2c_bytes_total / max(r_an.c2c_bytes_total, 1)
    _emit("distributed", t0,
          f"measured_B_per_tok={mt.decode_bytes_per_token:.0f}_"
          f"measured_vs_analytic_c2c={ratio:.2f}x_tableII_err_pct="
          f"{100 * tput_err:.2f}")
    return out


def bench_roofline():
    """The dry-run roofline table (reads artifacts/dryrun/*.json)."""
    t0 = time.time()
    dry = ROOT / "artifacts" / "dryrun"
    rows = []
    for f in sorted(dry.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        rows.append({
            "cell": r["cell"], "variant": r.get("variant", "baseline"),
            **{k: round(v, 4) for k, v in r["roofline"].items()},
            "dominant": r["dominant"],
            "useful_flop_frac": round(r.get("useful_flop_frac") or 0, 3),
        })
    _save("roofline", rows)
    n_base = sum(1 for r in rows if r["variant"] == "baseline")
    n_opt = len(rows) - n_base
    _emit("roofline", t0, f"cells_baseline={n_base}_optimized={n_opt}")
    return rows


def bench_kernels():
    """Microbenchmarks of the Pallas kernels (interpret mode on CPU: the
    number that matters here is allclose-to-oracle; wall time is recorded
    for harness completeness)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    results = []

    t0 = time.time()
    x = jax.random.normal(key, (256, 512)) * 3
    o = ops.pwl_softmax(x)
    err = float(jnp.max(jnp.abs(o - ref.ref_pwl_softmax(x))))
    _emit("kernel_pwl_softmax", t0, f"max_err={err:.2e}")
    results.append(("pwl_softmax", err))

    t0 = time.time()
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64))
    o = ops.flash_attention(q, k, v)
    r = ref.ref_flash_attention(q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2))
    err = float(jnp.max(jnp.abs(o - r)))
    _emit("kernel_flash_attention", t0, f"max_err={err:.2e}")
    results.append(("flash_attention", err))

    t0 = time.time()
    x = jax.random.normal(key, (64, 512))
    w = jax.random.normal(jax.random.PRNGKey(3), (512, 128)) * 0.05
    ex = ref.ref_exact_matmul(x, w)
    o = ops.cim_matmul(x, w, block_m=64, block_n=128)
    rel = float(jnp.linalg.norm(o - ex) / jnp.linalg.norm(ex))
    _emit("kernel_cim_matmul", t0, f"rel_err_vs_exact={rel:.3f}")
    results.append(("cim_matmul", rel))

    t0 = time.time()
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 4, 64)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(16, 16, 2, 64)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(16, 16, 2, 64)), jnp.float32)
    tables = jnp.asarray([[0, 2, 4, 0], [1, 3, 0, 0]], jnp.int32)
    ctxs = jnp.asarray([50, 20], jnp.int32)
    o = ops.paged_attention(q, kc, vc, tables, ctxs)
    r = ref.ref_paged_attention(q, kc, vc, tables, ctxs)
    err = float(jnp.max(jnp.abs(o - r)))
    _emit("kernel_paged_attention", t0, f"max_err={err:.2e}")
    results.append(("paged_attention", err))

    t0 = time.time()
    xs = jax.random.normal(key, (1, 128, 2, 32))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5), (1, 128, 2)))
    an = -jnp.exp(jax.random.normal(jax.random.PRNGKey(6), (2,)) * 0.2)
    B_ = jax.random.normal(jax.random.PRNGKey(7), (1, 128, 8)) * 0.3
    C_ = jax.random.normal(jax.random.PRNGKey(8), (1, 128, 8)) * 0.3
    o = ops.ssd_scan(xs, dt, an, B_, C_, chunk=32)
    err = float(jnp.max(jnp.abs(o - ref.ref_ssd(xs, dt, an, B_, C_,
                                                chunk=32))))
    _emit("kernel_ssd_scan", t0, f"max_err={err:.2e}")
    results.append(("ssd_scan", err))
    _save("kernels", results)
    return results


def bench_ablations():
    """Beyond-paper ablation: CIM ADC resolution and SCU PWL segment count
    vs numerical fidelity (the hardware knobs behind §II-A/§II-C)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops, ref
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    rows = []
    # ADC bits sweep on a transformer-shaped matmul
    x = jax.random.normal(key, (64, 1024))
    w = jax.random.normal(jax.random.PRNGKey(1), (1024, 256)) * 0.03
    ex = ref.ref_exact_matmul(x, w)
    for adc in (6, 8, 10, 12, 14):
        o = ops.cim_matmul(x, w, adc_bits=adc, block_m=64, block_n=256)
        rel = float(jnp.linalg.norm(o - ex) / jnp.linalg.norm(ex))
        rows.append({"knob": "adc_bits", "value": adc,
                     "rel_err_vs_fp": round(rel, 5)})
    # PWL softmax: top-1 agreement with exact softmax at attention scale
    s_ = jax.random.normal(jax.random.PRNGKey(2), (4096, 128)) * 4
    pwl = np.asarray(ops.pwl_softmax(s_))
    exact = np.asarray(ref.ref_softmax(s_))
    agree = float((pwl.argmax(-1) == exact.argmax(-1)).mean())
    maxdev = float(np.abs(pwl - exact).max())
    rows.append({"knob": "pwl_softmax_top1_agreement", "value": 8,
                 "rel_err_vs_fp": round(1 - agree, 5)})
    rows.append({"knob": "pwl_softmax_max_dev", "value": 8,
                 "rel_err_vs_fp": round(maxdev, 5)})
    _save("ablations", rows)
    adc12 = [r for r in rows if r["knob"] == "adc_bits"
             and r["value"] == 12][0]["rel_err_vs_fp"]
    _emit("ablations", t0,
          f"adc12_rel_err={adc12}_pwl_top1_agree={agree:.4f}")
    return rows


def export_trace(path):
    """--trace-out: export a chrome://tracing JSON of one dynamic-CCPG
    Llama-1B 512/64 walk — every TimelineIR category (ComputeSpan,
    C2CTransfer, ClusterWake, ClusterSleep, EnergySample, TokenEmit) in
    one trace.  Open with chrome://tracing or ui.perfetto.dev.  The
    export STREAMS (Timeline.dump_chrome_trace): no materialized event
    list, so million-event traces stay in constant memory."""
    from repro.configs import get_config
    from repro.core import PicnicSimulator, Timeline
    t0 = time.time()
    tl = Timeline()
    sim = PicnicSimulator()
    sim.run(get_config("llama3.2-1b"), 512, 64, ccpg=True,
            dynamic_ccpg=True, timeline=tl)
    tl.save_chrome_trace(path)
    _emit("trace_export", t0, f"events={tl.n_events}_path={path}")


BENCHES = {
    "table_ii": bench_table_ii,
    "table_iii": bench_table_iii,
    "table_iv": bench_table_iv,
    "fig8_ccpg": bench_fig8_ccpg,
    "fig9_c2c": bench_fig9_c2c,
    "fig10_timeline": bench_fig10_timeline,
    "serving": bench_serving,
    "paged": bench_paged,
    "sweep": bench_sweep,
    "fleet": bench_fleet,
    "chaos": bench_chaos,
    "distributed": bench_distributed,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
    "ablations": bench_ablations,
}


# short CLI aliases for the paper-table benches
ALIASES = {"table2": "table_ii", "table3": "table_iii", "table4": "table_iv"}


def main() -> None:
    argv = sys.argv[1:]
    trace_out = None
    if "--trace-out" in argv:
        i = argv.index("--trace-out")
        try:
            trace_out = argv[i + 1]
        except IndexError:
            raise SystemExit("--trace-out requires a path argument")
        del argv[i:i + 2]
    which = [ALIASES.get(a, a) for a in argv] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in which:
        BENCHES[name]()
    if trace_out is not None:
        export_trace(trace_out)


if __name__ == "__main__":
    main()
