"""Bench-regression gate: fail CI when a headline metric regresses >10%
vs the committed baseline.

Compares ``artifacts/bench/BENCH_*.json`` (produced by ``benchmarks/run.py``
and ``benchmarks/microbench.py`` in the same CI run) against
``artifacts/bench/baseline/BENCH_*.json`` (committed to the repo).  Only
*headline* metrics are gated, DIRECTION-AWARE:

  * higher-is-better families (throughput tok/s, efficiency tok/J,
    speedups) fail when the current value drops >tolerance below the
    baseline;
  * lower-is-better families (``wall_ms`` wall clocks from the simulator
    microbench) fail when the current value rises >tolerance ABOVE the
    baseline.

Wall-clock benches (any doc carrying a ``host_ops_per_s`` calibration,
i.e. ``BENCH_speed.json``) are only compared when the baseline was
recorded on a similar-speed host (within ``HOST_TOL``) AND on the same
workload size (``smoke`` flag) — a slower CI runner is not a code
regression.  On foreign hosts the microbench's own ``--min-speedup``
floor is the (host-independent) gate.

Individual metrics can override the tolerance via ``TOLERANCE_OVERRIDES``
(longest key-prefix match per bench file) — so noisy wall-clock sweep
metrics gate loose while deterministic simulated outputs in the same doc
gate tight.

Everything else (latency percentiles, byte counts, error percentages) is
informational.  The simulator itself is deterministic, so a >10% drop in
a simulated metric is a real modeling/scheduling regression, not noise.

  python benchmarks/check_regression.py             # gate (exit 1 on fail)
  python benchmarks/check_regression.py --refresh   # accept current as baseline
  python benchmarks/check_regression.py --tolerance 0.05

A new bench with no committed baseline is reported but does not fail the
gate (commit its baseline with --refresh); a *missing* current file for a
baselined bench DOES fail — the bench silently disappearing is exactly
the kind of regression the gate exists to catch.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
BENCH_DIR = ROOT / "artifacts" / "bench"
BASELINE_DIR = BENCH_DIR / "baseline"

# higher-is-better headline families (substring match on the metric key)
HEADLINE = ("tokens_per_s", "tokens_per_J", "throughput_tok_s",
            "efficiency_tok_J", "speedup", "eff_impr",
            "paged_vs_infinite_tput", "cells_per_s", "availability",
            "goodput_retention")
# lower-is-better families: real wall clocks (see microbench.py)
LOWER_IS_BETTER = ("wall_ms",)
# max relative host-calibration mismatch for wall-clock comparability
HOST_TOL = 0.30
# measured wall clocks jitter far more than the deterministic simulated
# metrics even on one host (scheduler noise, neighbors, cache state —
# observed same-host best-of-5 swings up to ~35%): wall-clock benches
# gate at this floor tolerance instead — wide enough to ignore
# run-to-run noise, tight enough to catch "the fast path lost its
# speedup" (a real regression there is 3-15x, not 50%)
WALL_BENCH_TOL = 0.50

# Per-metric tolerance overrides: (bench artifact name, flattened-key
# prefix) -> tolerance; the longest matching prefix wins, and an
# override beats both the CLI tolerance and the wall-clock widening.
# This lets one doc mix metric classes: BENCH_sweep.json carries noisy
# wall-clock-derived numbers (speedup / cells-per-second — loose) NEXT
# TO deterministic simulated outputs (per-cell tokens_per_s — tight),
# which the doc-level WALL_BENCH_TOL widening alone cannot express.
# The table is documented in EXPERIMENTS.md §Sweep-throughput.
TOLERANCE_OVERRIDES = {
    # ratio of two wall clocks in the same run: steadier than absolute
    # walls, but still host-scheduler noise on both sides
    ("BENCH_sweep.json", "sweep_speedup"): 0.35,
    ("BENCH_sweep.json", "sweep_speedup_prefill"): 0.35,
    ("BENCH_sweep.json", "sweep_speedup_lifted"): 0.35,
    ("BENCH_sweep.json", "cells_per_s"): 0.50,
    ("BENCH_sweep.json", "cells_per_s.vector"): 0.50,
    ("BENCH_sweep.json", "wall_ms"): 0.50,
    # deterministic simulator outputs: exact, gate tight even though
    # the doc carries a host calibration
    ("BENCH_sweep.json", "tokens_per_s"): 0.10,
    # the sweep-grid microbench speedups (prefill cruise / lifted-knob
    # grids) are wall ratios like the rest of BENCH_speed.json but much
    # larger (20-80x), so relative jitter runs wider than the 3-15x
    # engine-path cases the 0.50 doc tolerance was sized for
    ("BENCH_speed.json", "speedup.sweep_prefill"): 0.40,
    ("BENCH_speed.json", "speedup.sweep_lifted"): 0.40,
    # fleet doc (ISSUE 9): carries host_ops_per_s, so the doc-level
    # WALL_BENCH_TOL widening applies — pin the deterministic simulated
    # metrics back to tight and leave only the harness wall loose.
    # Documented in EXPERIMENTS.md §Disaggregation-sweep.
    ("BENCH_fleet.json", "wall_ms"): 0.50,
    ("BENCH_fleet.json", "tokens_per_s"): 0.10,
    ("BENCH_fleet.json", "tokens_per_J"): 0.10,
    ("BENCH_fleet.json", "fleet_best_tokens_per_J"): 0.10,
    ("BENCH_fleet.json", "disagg_vs_combined_eff_speedup"): 0.10,
    # chaos doc (ISSUE 10): same shape as the fleet doc — it carries
    # host_ops_per_s (doc-level WALL_BENCH_TOL widening), but every
    # availability/goodput number is a deterministic DES output of the
    # seeded fault schedule: pin them tight, leave only the harness
    # wall loose.  Documented in EXPERIMENTS.md §Chaos-sweep.
    ("BENCH_chaos.json", "wall_ms"): 0.50,
    ("BENCH_chaos.json", "availability"): 0.10,
    ("BENCH_chaos.json", "worst_availability"): 0.10,
    ("BENCH_chaos.json", "goodput_tokens_per_s"): 0.10,
    ("BENCH_chaos.json", "chaos_goodput_retention"): 0.10,
}


def metric_tolerance(bench: str, key: str, default: float) -> float:
    """Effective tolerance for one metric: longest-prefix override for
    ``(bench, key)`` if any, else ``default``."""
    best = None
    for (b, prefix), tol in TOLERANCE_OVERRIDES.items():
        if b == bench and key.startswith(prefix):
            if best is None or len(prefix) > best[0]:
                best = (len(prefix), tol)
    return default if best is None else best[1]


def _flatten(prefix: str, obj, out: dict) -> None:
    if isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def metric_direction(key: str) -> str:
    """'lower' | 'higher' | '' (not a gated headline metric)."""
    if any(h in key for h in LOWER_IS_BETTER):
        return "lower"
    if any(h in key for h in HEADLINE):
        return "higher"
    return ""


def headline_metrics(doc: dict) -> dict:
    flat: dict = {}
    _flatten("", doc.get("metrics", {}), flat)
    return {k: v for k, v in flat.items() if metric_direction(k)}


def hosts_comparable(base_doc: dict, cur_doc: dict) -> bool:
    """Wall clocks are only gated between runs on similar-speed hosts
    and identical workload sizes; benches that carry no calibration are
    always comparable (their metrics are simulated, not measured)."""
    b = base_doc.get("host_ops_per_s")
    c = cur_doc.get("host_ops_per_s")
    if b is None or c is None or b <= 0:
        return True
    if base_doc.get("smoke") != cur_doc.get("smoke"):
        return False
    return abs(c / b - 1.0) <= HOST_TOL


def compare(tolerance: float) -> int:
    if not BASELINE_DIR.is_dir():
        print(f"no baseline dir at {BASELINE_DIR}; nothing to gate")
        return 0
    failures, checked, new = [], 0, []
    for base_path in sorted(BASELINE_DIR.glob("BENCH_*.json")):
        cur_path = BENCH_DIR / base_path.name
        if not cur_path.exists():
            failures.append(f"{base_path.name}: current run produced no "
                            f"artifact (bench removed or failed?)")
            continue
        base_doc = json.loads(base_path.read_text())
        cur_doc = json.loads(cur_path.read_text())
        base = headline_metrics(base_doc)
        cur = headline_metrics(cur_doc)
        if not hosts_comparable(base_doc, cur_doc):
            # every metric in a wall-clock bench is host-sensitive
            # (speedup ratios included) — the microbench's own
            # --min-speedup floor gates foreign hosts instead
            print(f"{base_path.name}: host calibration / workload "
                  f"differs (host_ops_per_s "
                  f"{base_doc.get('host_ops_per_s')} vs "
                  f"{cur_doc.get('host_ops_per_s')}, smoke "
                  f"{base_doc.get('smoke')} vs {cur_doc.get('smoke')}); "
                  f"skipping its wall-clock gates")
            continue
        tol = tolerance
        if base_doc.get("host_ops_per_s") is not None:
            tol = max(tolerance, WALL_BENCH_TOL)
        for key, b in sorted(base.items()):
            direction = metric_direction(key)
            if key not in cur:
                failures.append(f"{base_path.name}:{key}: metric vanished")
                continue
            checked += 1
            c = cur[key]
            if b <= 0:
                continue
            tol_k = metric_tolerance(base_path.name, key, tol)
            if direction == "higher" and c < (1.0 - tol_k) * b:
                failures.append(
                    f"{base_path.name}:{key}: {c:.4g} < "
                    f"{(1 - tol_k) * b:.4g} "
                    f"(baseline {b:.4g}, -{100 * (1 - c / b):.1f}%)")
            elif direction == "lower" and c > (1.0 + tol_k) * b:
                failures.append(
                    f"{base_path.name}:{key}: {c:.4g} > "
                    f"{(1 + tol_k) * b:.4g} "
                    f"(baseline {b:.4g}, +{100 * (c / b - 1):.1f}% "
                    f"wall-clock slowdown)")
    for cur_path in sorted(BENCH_DIR.glob("BENCH_*.json")):
        if not (BASELINE_DIR / cur_path.name).exists():
            new.append(cur_path.name)
    if new:
        print(f"unbaselined benches (run --refresh to adopt): {new}")
    if failures:
        print(f"BENCH REGRESSION: {len(failures)} headline metric(s) "
              f"regressed more than {100 * tolerance:.0f}%:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"bench gate ok: {checked} headline metrics within "
          f"{100 * tolerance:.0f}% of baseline")
    return 0


def refresh() -> int:
    BASELINE_DIR.mkdir(parents=True, exist_ok=True)
    n = 0
    for cur_path in sorted(BENCH_DIR.glob("BENCH_*.json")):
        shutil.copy2(cur_path, BASELINE_DIR / cur_path.name)
        n += 1
    print(f"baseline refreshed: {n} BENCH_*.json copied to {BASELINE_DIR}")
    return 0 if n else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--refresh", action="store_true",
                    help="adopt the current BENCH_*.json as the baseline")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    args = ap.parse_args()
    return refresh() if args.refresh else compare(args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
