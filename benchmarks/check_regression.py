"""Bench-regression gate: fail CI when a headline metric regresses >10%
vs the committed baseline.

Compares ``artifacts/bench/BENCH_*.json`` (produced by ``benchmarks/run.py``
in the same CI run) against ``artifacts/bench/baseline/BENCH_*.json``
(committed to the repo).  Only *headline* metrics are gated — throughput
(tok/s) and efficiency (tok/J) families, where higher is better; latency
percentiles, byte counts and error percentages are informational.  The
simulator is deterministic, so a >10% drop is a real modeling/scheduling
regression, not machine noise.

  python benchmarks/check_regression.py             # gate (exit 1 on fail)
  python benchmarks/check_regression.py --refresh   # accept current as baseline
  python benchmarks/check_regression.py --tolerance 0.05

A new bench with no committed baseline is reported but does not fail the
gate (commit its baseline with --refresh); a *missing* current file for a
baselined bench DOES fail — the bench silently disappearing is exactly
the kind of regression the gate exists to catch.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
BENCH_DIR = ROOT / "artifacts" / "bench"
BASELINE_DIR = BENCH_DIR / "baseline"

# higher-is-better headline families (substring match on the metric key)
HEADLINE = ("tokens_per_s", "tokens_per_J", "throughput_tok_s",
            "efficiency_tok_J", "speedup", "eff_impr",
            "paged_vs_infinite_tput")


def _flatten(prefix: str, obj, out: dict) -> None:
    if isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def headline_metrics(doc: dict) -> dict:
    flat: dict = {}
    _flatten("", doc.get("metrics", {}), flat)
    return {k: v for k, v in flat.items()
            if any(h in k for h in HEADLINE)}


def compare(tolerance: float) -> int:
    if not BASELINE_DIR.is_dir():
        print(f"no baseline dir at {BASELINE_DIR}; nothing to gate")
        return 0
    failures, checked, new = [], 0, []
    for base_path in sorted(BASELINE_DIR.glob("BENCH_*.json")):
        cur_path = BENCH_DIR / base_path.name
        if not cur_path.exists():
            failures.append(f"{base_path.name}: current run produced no "
                            f"artifact (bench removed or failed?)")
            continue
        base = headline_metrics(json.loads(base_path.read_text()))
        cur = headline_metrics(json.loads(cur_path.read_text()))
        for key, b in sorted(base.items()):
            if key not in cur:
                failures.append(f"{base_path.name}:{key}: metric vanished")
                continue
            checked += 1
            c = cur[key]
            if b > 0 and c < (1.0 - tolerance) * b:
                failures.append(
                    f"{base_path.name}:{key}: {c:.4g} < "
                    f"{(1 - tolerance) * b:.4g} "
                    f"(baseline {b:.4g}, -{100 * (1 - c / b):.1f}%)")
    for cur_path in sorted(BENCH_DIR.glob("BENCH_*.json")):
        if not (BASELINE_DIR / cur_path.name).exists():
            new.append(cur_path.name)
    if new:
        print(f"unbaselined benches (run --refresh to adopt): {new}")
    if failures:
        print(f"BENCH REGRESSION: {len(failures)} headline metric(s) "
              f"regressed more than {100 * tolerance:.0f}%:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"bench gate ok: {checked} headline metrics within "
          f"{100 * tolerance:.0f}% of baseline")
    return 0


def refresh() -> int:
    BASELINE_DIR.mkdir(parents=True, exist_ok=True)
    n = 0
    for cur_path in sorted(BENCH_DIR.glob("BENCH_*.json")):
        shutil.copy2(cur_path, BASELINE_DIR / cur_path.name)
        n += 1
    print(f"baseline refreshed: {n} BENCH_*.json copied to {BASELINE_DIR}")
    return 0 if n else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--refresh", action="store_true",
                    help="adopt the current BENCH_*.json as the baseline")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    args = ap.parse_args()
    return refresh() if args.refresh else compare(args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
