"""Simulator-speed microbench: wall-clock throughput of the simulation
core itself (ISSUE 5) — simulated-tokens-per-wall-second and
events-per-wall-second for the serving, paged and Table-II paths, fast
path (columnar TimelineIR + SoA engine + memoized CycleModel, the
defaults) vs the reference object path (``columnar_timeline=False`` +
``CycleModel(memoize=False)``).

The two paths are asserted REPORT-IDENTICAL in-run before any number is
recorded, so the speedup can never be bought with a behavior change.

Emits ``artifacts/bench/BENCH_speed.json``:

  * ``metrics.speedup.*``            — fast/reference wall ratio per path
    (machine-portable: both sides run on the same host in the same
    process) — gated by benchmarks/check_regression.py as
    higher-is-better headline metrics;
  * ``metrics.wall_ms.*``            — absolute wall clocks, gated as
    LOWER-is-better but only when the recorded ``host_ops_per_s``
    calibration matches the baseline's host (cross-machine wall clocks
    are not comparable);
  * ``metrics.sim_tokens_per_wall_s.* / events_per_wall_s.*`` —
    informational trajectory numbers.

  python benchmarks/microbench.py                  # full: what CI runs
  #                                                  and what the committed
  #                                                  baseline was made from
  python benchmarks/microbench.py --min-speedup 3  # CI's hard floor
  python benchmarks/microbench.py --smoke          # quick local iteration
  #   (NB: smoke runs are never gated against a full-workload baseline —
  #    check_regression skips wall-clock docs whose `smoke` flag differs)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

ART = ROOT / "artifacts" / "bench"


def _host_calibration() -> float:
    """Fixed pure-Python workload timed once: a machine-speed fingerprint
    stored next to the wall clocks, so the regression gate can tell
    "slower code" apart from "slower host"."""
    t0 = time.perf_counter()
    acc = 0
    for i in range(2_000_000):
        acc += i * 3
    dt = time.perf_counter() - t0
    assert acc  # keep the loop un-optimizable
    return 2_000_000 / dt


def _best_wall(fn, repeats: int):
    """(best_wall_s, last_result): min over repeats — the standard
    microbench estimator for a deterministic workload."""
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _engines(cfg, engine_kw):
    """(fast, reference) engine pair over identical configs."""
    from repro.core import CycleModel, PicnicSimulator
    from repro.launch import ServingConfig
    from repro.launch.serving_engine import ContinuousBatchingEngine
    fast = ContinuousBatchingEngine(
        cfg, sim=PicnicSimulator(),
        engine=ServingConfig(**engine_kw))
    ref = ContinuousBatchingEngine(
        cfg, sim=PicnicSimulator(cycle_model=CycleModel(memoize=False)),
        engine=ServingConfig(columnar_timeline=False, **engine_kw))
    return fast, ref


def _engine_case(name, cfg, trace, engine_kw, repeats):
    """Measure one serving-path case; assert fast == reference first."""
    fast, ref = _engines(cfg, engine_kw)
    rep_fast = fast.run(list(trace))
    rep_ref = ref.run(list(trace))
    assert rep_fast.row() == rep_ref.row(), \
        f"{name}: fast path diverged from reference"
    if fast.kv_stats is not None:
        assert fast.kv_stats.row() == ref.kv_stats.row(), \
            f"{name}: fast path kv_stats diverged from reference"

    wall_fast, _ = _best_wall(lambda: fast.run(list(trace)), repeats)
    wall_ref, _ = _best_wall(lambda: ref.run(list(trace)), repeats)
    tokens = rep_fast.tokens_generated + rep_fast.tokens_prefilled
    return {
        "name": name,
        "sim_tokens": tokens,
        "events": fast.timeline.n_events,
        "wall_fast_s": wall_fast,
        "wall_reference_s": wall_ref,
        "speedup": wall_ref / wall_fast,
        "tokens_per_wall_s_fast": tokens / wall_fast,
        "tokens_per_wall_s_reference": tokens / wall_ref,
        "events_per_wall_s_fast": fast.timeline.n_events / wall_fast,
    }


def bench_serving_path(smoke: bool, repeats: int):
    from repro.configs import get_config
    from repro.launch import Trace
    cfg = get_config("llama3.2-1b")
    n = 24 if smoke else 64
    trace = Trace.poisson(n, rate_rps=40, seed=0, prompt_len=512,
                          max_new=64)
    return _engine_case("serving", cfg, trace, dict(max_batch=8, ccpg=True),
                        repeats)


def bench_paged_path(smoke: bool, repeats: int):
    from repro.configs import get_config
    from repro.launch import Trace
    from repro.runtime.kv_cache import kv_cache_from_model
    cfg = get_config("llama3.2-1b")
    kvc = kv_cache_from_model(cfg, kv_frac=0.5, dram_frac=1.0)
    n = 8 if smoke else 16
    trace = Trace.poisson(n, rate_rps=60, seed=0, prompt_len=2048,
                          max_new=256)
    return _engine_case("paged", cfg, trace,
                        dict(max_batch=8, ccpg=True, kv_cache=kvc,
                             chunked_prefill_tokens=512), repeats)


def bench_sweep_path(smoke: bool, repeats: int):
    """Batched sweep engine vs the scalar fast engine cell-by-cell
    (ISSUE 7): the same paged grid both ways, asserted report- and
    kv_stats-identical per cell before timing.  The scalar side
    constructs a fresh simulator + engine per cell — exactly what every
    sweep bench did before launch/sweep_engine existed."""
    import copy
    import dataclasses
    from repro.configs import get_config
    from repro.core import PicnicSimulator
    from repro.launch import ServingConfig, Trace
    from repro.launch.serving_engine import ContinuousBatchingEngine
    from repro.launch.sweep_engine import SweepCell, sweep_serve
    from repro.runtime.kv_cache import kv_cache_from_model
    cfg = get_config("llama3.2-1b")
    kvc = dataclasses.replace(
        kv_cache_from_model(cfg, kv_frac=0.5, dram_frac=1.0),
        block_tokens=1024, n_blocks=24, dram_blocks=24)
    sim = PicnicSimulator()
    sim.ccpg_model.include_dram_hub = True
    ctxs = (256,) if smoke else (256, 512)
    mns = (1024,) if smoke else (512, 1024)
    cells = [SweepCell(f"c{ctx}r{rate}b{mb}n{mn}", cfg,
                       Trace.poisson(6, rate_rps=rate, seed=0,
                                     prompt_len=ctx, max_new=mn),
                       ServingConfig(max_batch=mb, ccpg=True, kv_cache=kvc,
                                    chunked_prefill_tokens=512), sim=sim)
             for ctx in ctxs for rate in (20, 60) for mb in (4, 8)
             for mn in mns]

    def scalar():
        out = []
        for c in cells:
            s2 = PicnicSimulator()
            s2.ccpg_model.include_dram_hub = True
            eng = ContinuousBatchingEngine(c.cfg, sim=s2, engine=c.engine)
            rep = eng.run([copy.copy(r) for r in c.trace])
            out.append((rep, eng.kv_stats))
        return out

    res = sweep_serve(cells)
    for c, r, (rep, st) in zip(cells, res, scalar()):
        assert r.report.row() == rep.row(), \
            f"sweep cell {c.key}: batched engine diverged from scalar"
        assert r.kv_stats.row() == st.row(), \
            f"sweep cell {c.key}: batched kv_stats diverged from scalar"

    wall_fast, _ = _best_wall(lambda: sweep_serve(cells), repeats)
    wall_ref, _ = _best_wall(scalar, repeats)
    tokens = sum(r.report.tokens_generated + r.report.tokens_prefilled
                 for r in res)
    return {
        "name": "sweep",
        "n_cells": len(cells),
        "sim_tokens": tokens,
        "wall_fast_s": wall_fast,
        "wall_reference_s": wall_ref,
        "speedup": wall_ref / wall_fast,
        "tokens_per_wall_s_fast": tokens / wall_fast,
        "tokens_per_wall_s_reference": tokens / wall_ref,
    }


def _sweep_grid_case(name, cells, repeats, floor):
    """Batched sweep vs per-cell scalar engines (fresh simulator each,
    the pre-sweep_engine execution model): identity asserted per cell
    and ``fallback is None`` enforced before timing.  ``floor`` is the
    case's own host-independent --min-speedup gate (the generic 3x
    floor is far below what these vectorized grids must sustain)."""
    import copy
    from repro.core import PicnicSimulator
    from repro.launch.serving_engine import ContinuousBatchingEngine
    from repro.launch.sweep_engine import sweep_serve

    def scalar():
        out = []
        for c in cells:
            eng = ContinuousBatchingEngine(c.cfg, sim=PicnicSimulator(),
                                           engine=c.engine)
            out.append(eng.run([copy.copy(r) for r in c.trace]))
        return out

    res = sweep_serve(cells)
    for c, r, rep in zip(cells, res, scalar()):
        assert r.fallback is None, (c.key, r.fallback)
        assert r.report.row() == rep.row(), \
            f"{name} cell {c.key}: batched engine diverged from scalar"
    wall_fast, _ = _best_wall(lambda: sweep_serve(cells), repeats)
    wall_ref, _ = _best_wall(scalar, repeats)
    tokens = sum(r.report.tokens_generated + r.report.tokens_prefilled
                 for r in res)
    return {
        "name": name,
        "n_cells": len(cells),
        "sim_tokens": tokens,
        "wall_fast_s": wall_fast,
        "wall_reference_s": wall_ref,
        "speedup": wall_ref / wall_fast,
        "floor": floor,
        "tokens_per_wall_s_fast": tokens / wall_fast,
        "tokens_per_wall_s_reference": tokens / wall_ref,
    }


def bench_sweep_prefill_path(smoke: bool, repeats: int):
    """Prefill-heavy / short-generation sweep grid (ISSUE 8): long
    prompts chunk-streamed 64 tokens at a time, one or two generated
    tokens — the regime PR 7 left on python-per-step scalar costs.  The
    prefill cruise folds each request's full-cap chunk streak into one
    closed-form array pass, so the sustainable floor sits an order of
    magnitude above the generic 3x gate."""
    from repro.configs import get_config
    from repro.launch import ServingConfig, Trace
    from repro.launch.sweep_engine import SweepCell
    cfg = get_config("llama3.2-1b")
    ctx = 16384 if smoke else 32768
    rates = (2, 16) if smoke else (1, 4, 16, 64)
    cells = [SweepCell(f"pf{ctx}_r{rate}_n{mn}_s{sd}", cfg,
                       Trace.poisson(2, rate_rps=rate, seed=sd,
                                     prompt_len=ctx, max_new=mn),
                       ServingConfig(max_batch=8, ccpg=True,
                                    chunked_prefill_tokens=64))
             for rate in rates for mn in (1, 2) for sd in (0, 1)]
    # ~43x full / ~19x smoke on the baseline host
    return _sweep_grid_case("sweep_prefill", cells, repeats,
                            floor=8.0 if smoke else 20.0)


def bench_sweep_lifted_path(smoke: bool, repeats: int):
    """The previously-fallback knobs — overlap in (0,1], dynamic CCPG,
    TTFT deadlines — on the vector path (ISSUE 8 lift): decode-heavy
    cells exercising the split-cost lane, wake residue columns and the
    at-risk burst horizon, still bit-identical and well above the
    generic floor."""
    from repro.configs import get_config
    from repro.launch import ServingConfig, Trace
    from repro.launch.sweep_engine import SweepCell
    cfg = get_config("llama3.2-1b")
    mn = 2048 if smoke else 4096
    cells = [SweepCell(f"lift_o{ov}_d{int(dyn)}_t{tt}", cfg,
                       Trace.poisson(6, rate_rps=40, seed=0,
                                     prompt_len=256, max_new=mn,
                                     **({} if tt is None
                                        else dict(deadline_ttft=tt))),
                       ServingConfig(max_batch=8, overlap=ov, ccpg=True,
                                    dynamic_ccpg=dyn))
             for ov in (0.25, 0.75) for dyn in (False, True)
             for tt in (None, 0.25)]
    # ~27x full / ~16x smoke on the baseline host
    return _sweep_grid_case("sweep_lifted", cells, repeats,
                            floor=6.0 if smoke else 10.0)


def bench_table_ii_path(smoke: bool, repeats: int):
    """The analytic Table-II walk: columnar vs object TimelineIR (the
    cycle-model memo hits across the 9-row sweep's repeated shapes)."""
    from repro.configs import get_config
    from repro.core import CycleModel, PicnicSimulator, Timeline
    table_ii = [("llama3.2-1b", 512), ("llama3.2-1b", 1024),
                ("llama3.2-1b", 2048), ("llama3-8b", 512),
                ("llama3-8b", 1024), ("llama3-8b", 2048),
                ("llama2-13b", 512), ("llama2-13b", 1024),
                ("llama2-13b", 2048)]
    rows = table_ii[:3] if smoke else table_ii
    cfgs = {arch: get_config(arch) for arch, _ in rows}

    def run_fast():
        sim = PicnicSimulator()
        tl = Timeline()
        for arch, ctx in rows:
            sim.run(cfgs[arch], ctx, ctx, timeline=tl)
        return tl

    def run_ref():
        sim = PicnicSimulator(cycle_model=CycleModel(memoize=False))
        tl = Timeline(columnar=False)
        for arch, ctx in rows:
            sim.run(cfgs[arch], ctx, ctx, timeline=tl)
        return tl

    wall_fast, tl_fast = _best_wall(run_fast, repeats)
    wall_ref, tl_ref = _best_wall(run_ref, repeats)
    assert tl_fast.events == tl_ref.events, \
        "table_ii: columnar timeline diverged from object recorder"
    tokens = sum(2 * ctx for _, ctx in rows)
    return {
        "name": "table_ii",
        "sim_tokens": tokens,
        "events": tl_fast.n_events,
        "wall_fast_s": wall_fast,
        "wall_reference_s": wall_ref,
        "speedup": wall_ref / wall_fast,
        "tokens_per_wall_s_fast": tokens / wall_fast,
        "tokens_per_wall_s_reference": tokens / wall_ref,
        "events_per_wall_s_fast": tl_fast.n_events / wall_fast,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small traces, single repeat (CI fast lane)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="wall-clock repeats (best-of); default 2 smoke / 5")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail if any path's fast-vs-reference speedup "
                         "falls below this floor (host-independent gate)")
    ap.add_argument("--out", type=Path, default=ART / "BENCH_speed.json")
    args = ap.parse_args()
    repeats = args.repeats or (2 if args.smoke else 5)

    cal = _host_calibration()
    cases = [
        bench_serving_path(args.smoke, repeats),
        bench_paged_path(args.smoke, repeats),
        bench_table_ii_path(args.smoke, repeats),
        bench_sweep_path(args.smoke, repeats),
        bench_sweep_prefill_path(args.smoke, repeats),
        bench_sweep_lifted_path(args.smoke, repeats),
    ]

    doc = {
        "bench": "speed", "schema": 1, "smoke": args.smoke,
        "repeats": repeats,
        # host fingerprint: the regression gate compares wall_ms only
        # when this matches the baseline's host (see check_regression)
        "host_ops_per_s": round(cal, 1),
        "metrics": {
            "speedup": {c["name"]: round(c["speedup"], 3) for c in cases},
            "wall_ms": {f"{c['name']}_fast":
                        round(c["wall_fast_s"] * 1e3, 3) for c in cases},
            "sim_tokens_per_wall_s": {
                f"{c['name']}_fast":
                    round(c["tokens_per_wall_s_fast"], 1) for c in cases} | {
                f"{c['name']}_reference":
                    round(c["tokens_per_wall_s_reference"], 1)
                for c in cases},
            "events_per_wall_s": {
                c["name"]: round(c["events_per_wall_s_fast"], 1)
                for c in cases if "events_per_wall_s_fast" in c},
        },
        "rows": cases,
    }
    args.out.parent.mkdir(parents=True, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)

    print("path,speedup,tokens_per_wall_s_fast,tokens_per_wall_s_reference,"
          "events_per_wall_s")
    for c in cases:
        print(f"{c['name']},{c['speedup']:.2f},"
              f"{c['tokens_per_wall_s_fast']:.0f},"
              f"{c['tokens_per_wall_s_reference']:.0f},"
              f"{c.get('events_per_wall_s_fast', float('nan')):.0f}")
    print(f"wrote {args.out}")

    if args.min_speedup is not None:
        # a case can carry its own higher "floor" (the vectorized sweep
        # grids must hold far more than the generic 3x)
        slow = [c for c in cases
                if c["speedup"] < max(args.min_speedup,
                                      c.get("floor", 0.0))]
        if slow:
            print(f"SPEED REGRESSION: "
                  f"{[(c['name'], round(c['speedup'], 1)) for c in slow]} "
                  f"below the fast-vs-reference floor (--min-speedup "
                  f"{args.min_speedup} or the case's own floor)")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
