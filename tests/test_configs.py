import pytest

from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config,
                           get_smoke_config, list_archs)

EXPECTED_PARAMS_B = {  # arch -> (lo, hi) plausible total params
    "mistral-nemo-12b": (11.5, 13.0),
    "olmo-1b": (1.0, 1.4),
    "smollm-360m": (0.3, 0.45),
    "yi-34b": (33.0, 35.5),
    "paligemma-3b": (2.0, 3.2),
    "zamba2-2.7b": (2.1, 3.0),
    "llama4-maverick-400b-a17b": (380.0, 410.0),
    "mixtral-8x7b": (45.0, 48.0),
    "whisper-large-v3": (1.4, 1.8),
    "mamba2-2.7b": (2.4, 3.1),
}


def test_registry_has_all_assigned():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        assert get_config(a).name == a


@pytest.mark.parametrize("arch", list(EXPECTED_PARAMS_B))
def test_param_counts(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).n_params() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    cfg = get_config("llama4-maverick-400b-a17b")
    assert cfg.active_params(True) < 0.06 * cfg.n_params(True)
    mix = get_config("mixtral-8x7b")
    assert 11e9 < mix.active_params(True) < 15e9


def test_long_context_support_flags():
    runs = {a for a in ASSIGNED_ARCHS
            if get_config(a).supports_long_context}
    assert runs == {"zamba2-2.7b", "mixtral-8x7b", "mamba2-2.7b"}


def test_shapes_table():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_configs_are_small(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 256
    assert cfg.n_params() < 30e6


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nope-7b")
