"""End-to-end PICNIC simulator vs the paper's published numbers."""
import pytest

from repro.configs import get_config
from repro.core import PLATFORMS, PicnicSimulator, comparison_table

TABLE_II = {
    ("llama3.2-1b", 512): (1503.8, 4.0520, 371.1),
    ("llama3.2-1b", 1024): (969.2, 4.0513, 239.2),
    ("llama3.2-1b", 2048): (566.4, 4.0507, 139.8),
    ("llama3-8b", 512): (386.5, 28.4018, 13.6),
    ("llama3-8b", 1024): (309.8, 28.4015, 10.9),
    ("llama3-8b", 2048): (221.9, 28.4010, 7.8),
    ("llama2-13b", 512): (228.9, 52.3014, 4.4),
    ("llama2-13b", 1024): (192.4, 52.3012, 3.7),
    ("llama2-13b", 2048): (146.2, 52.3009, 2.8),
}


@pytest.fixture(scope="module")
def sim():
    return PicnicSimulator()


@pytest.mark.parametrize("arch,ctx", list(TABLE_II))
def test_table_ii_throughput(sim, arch, ctx):
    tput, power, eff = TABLE_II[(arch, ctx)]
    r = sim.run(get_config(arch), ctx, ctx)
    assert abs(r.throughput_tps / tput - 1) < 0.10, \
        f"{arch}/{ctx}: {r.throughput_tps:.1f} vs {tput}"
    assert abs(r.avg_power_W / power - 1) < 0.05
    assert abs(r.efficiency_tpj / eff - 1) < 0.12


def test_ccpg_8b_matches_table_iii(sim):
    """With CCPG: ~5.6 W, ~55 tokens/J, 57x over H100, ~80% power saved."""
    cfg = get_config("llama3-8b")
    r = sim.run(cfg, 1024, 1024, ccpg=True)
    r0 = sim.run(cfg, 1024, 1024, ccpg=False)
    assert abs(r.avg_power_W / 5.6 - 1) < 0.08
    assert abs(r.efficiency_tpj / 55.38 - 1) < 0.08
    h100 = PLATFORMS["NV H100"]
    impr = r.efficiency_tpj / (h100["throughput"] / h100["power"])
    assert 52 < impr < 62                      # paper: 57x
    saving = 1 - r.avg_power_W / r0.avg_power_W
    assert 0.75 < saving < 0.85                # paper: ~80%
    # "similar throughput": CCPG costs < 3% throughput
    assert r.throughput_tps > 0.97 * r0.throughput_tps


def test_headline_vs_a100(sim):
    """3.95x speedup and 30x efficiency over A100 (paper abstract),
    reproduced within 15%."""
    cfg = get_config("llama3-8b")
    r = sim.run(cfg, 1024, 1024)
    a100 = PLATFORMS["NV A100"]
    speedup = r.throughput_tps / a100["throughput"]
    eff_impr = r.efficiency_tpj / (a100["throughput"] / a100["power"])
    assert abs(speedup / 3.95 - 1) < 0.15
    assert abs(eff_impr / 30.0 - 1) < 0.15


def test_throughput_decreases_with_context(sim):
    cfg = get_config("llama3.2-1b")
    t = [sim.run(cfg, c, c).throughput_tps for c in (512, 1024, 2048)]
    assert t[0] > t[1] > t[2]


def test_power_nearly_flat_with_context(sim):
    """Paper: average power reduces slightly with context length."""
    cfg = get_config("llama3-8b")
    p = [sim.run(cfg, c, c).avg_power_W for c in (512, 2048)]
    assert abs(p[0] - p[1]) / p[0] < 0.01
    assert p[1] <= p[0] + 1e-6


def test_comparison_table_ratios(sim):
    r = sim.run(get_config("llama3-8b"), 1024, 1024, ccpg=True)
    rows = comparison_table(r)
    ours = rows[0]
    assert ours["eff_impr_vs_h100"] > 50
    cerebras = [x for x in rows if x["platform"] == "Cerebras-2"][0]
    assert cerebras["speedup_vs_h100"] == pytest.approx(6.57, abs=0.05)


def test_c2c_trace_is_bursty(sim):
    """Fig 10: C2C transfers happen in bursts at layer boundaries; the
    link is idle most of the time."""
    trace = sim.c2c_trace(get_config("llama3.2-1b"), n_tokens=4)
    horizon = max(t + d for t, d, _ in trace.events) * 1.01
    assert trace.utilization(horizon) < 0.05
    bins = trace.binned(horizon, 50)
    assert max(bins) > 0 and min(bins) == 0.0


def test_optical_beats_electrical(sim):
    from repro.core import ELECTRICAL, OPTICAL, c2c_average_power
    rate = 200e6  # bytes/s
    assert c2c_average_power(rate, OPTICAL) < \
        c2c_average_power(rate, ELECTRICAL)
