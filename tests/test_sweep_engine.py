"""Vectorized sweep engine (ISSUE 7): ``sweep_serve`` over a grid of
cells must be BIT-IDENTICAL, per cell, to running each cell through its
own scalar ``ContinuousBatchingEngine`` — property-tested on randomized
grids, with the aggregate-only recorder, the batched decode cost surface
and the burst fold each locked down in isolation."""
import copy
import dataclasses
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (C2CTransfer, ClusterSleep, ClusterWake, ComputeSpan,
                        CycleModel, EnergySample, PicnicSimulator, Timeline,
                        TokenEmit)
from repro.core.scheduling import DecodeCostSurface, allocate_chiplets
from repro.core.timeline import SweepAggregates
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingConfig, poisson_trace,
                                         replay_trace)
from repro.launch.sweep_engine import SweepCell, SweepEngine, sweep_serve
from repro.runtime.kv_cache import KVCacheConfig, kv_bytes_per_token


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


def _hexdict(obj) -> dict:
    d = dataclasses.asdict(obj)
    d.pop("queue_depth", None)
    return {k: (v.hex() if isinstance(v, float) else v) for k, v in d.items()}


def _scalar_run(cell: SweepCell):
    """The reference: this cell alone, a fresh simulator, the plain
    scalar engine (full per-event recording, no aggregate mirror)."""
    sim = PicnicSimulator()
    if cell.sim is not None and cell.sim.ccpg_model.include_dram_hub:
        sim.ccpg_model.include_dram_hub = True
    eng = ContinuousBatchingEngine(cell.cfg, sim=sim, engine=cell.engine)
    rep = eng.run([copy.copy(r) for r in cell.trace])
    return rep, eng.kv_stats


def _assert_cell_identical(res, cell: SweepCell):
    rep, kv = _scalar_run(cell)
    assert _hexdict(res.report) == _hexdict(rep), cell.key
    if kv is None:
        assert res.kv_stats is None
    else:
        assert res.kv_stats.row() == kv.row(), cell.key


# ---------------------------------------------------------------------------
# sweep_serve == scalar engines, per cell
# ---------------------------------------------------------------------------

def test_sweep_matches_scalar_mixed_grid(cfg):
    """A grid mixing batch sizes, CCPG and chunked prefill: every cell's
    report is byte-identical to its own scalar engine, all vectorized."""
    cells = []
    for mb in (1, 4, 8):
        for ccpg in (False, True):
            trace = poisson_trace(24, 40.0, seed=3, prompt_len=384,
                                  max_new=48)
            cells.append(SweepCell(
                key=f"b{mb}_g{int(ccpg)}", cfg=cfg, trace=trace,
                engine=ServingConfig(max_batch=mb, ccpg=ccpg,
                                    chunked_prefill_tokens=256)))
    results = sweep_serve(cells)
    assert len(results) == len(cells)
    for res, cell in zip(results, cells):
        assert res.fallback is None
        assert res.key == cell.key
        _assert_cell_identical(res, cell)


def test_sweep_single_cell_and_empty_grid(cfg):
    assert sweep_serve([]) == []
    cell = SweepCell("only", cfg, poisson_trace(8, 30.0, seed=1,
                                                max_new=32))
    (res,) = sweep_serve([cell])
    assert res.fallback is None
    _assert_cell_identical(res, cell)


@settings(max_examples=6, deadline=None)
@given(rate=st.sampled_from([15.0, 45.0, 90.0]),
       mb=st.integers(min_value=1, max_value=8),
       ccpg=st.booleans(),
       chunk=st.sampled_from([0, 128]),
       seed=st.integers(min_value=0, max_value=5))
def test_sweep_property_random_cells(cfg, rate, mb, ccpg, chunk, seed):
    """Randomized 3-cell grids (shared default sim, varying prompt
    regimes) stay bit-identical to per-cell scalar engines."""
    cells = [
        SweepCell(f"c{i}", cfg,
                  poisson_trace(10, rate, seed=seed + i,
                                prompt_len=pl, max_new=mn),
                  engine=ServingConfig(max_batch=mb, ccpg=ccpg,
                                      chunked_prefill_tokens=chunk))
        for i, (pl, mn) in enumerate(((128, 16), (512, 64), (96, 96)))
    ]
    for res, cell in zip(sweep_serve(cells), cells):
        assert res.fallback is None
        _assert_cell_identical(res, cell)


@settings(max_examples=6, deadline=None)
@given(n_blocks=st.integers(min_value=6, max_value=24),
       bt=st.sampled_from([16, 64, 256]),
       dram=st.sampled_from([0, 16]),
       share=st.booleans(),
       seed=st.integers(min_value=0, max_value=3))
def test_sweep_property_paged_cells(cfg, n_blocks, bt, dram, share, seed):
    """Paged/prefix cells: kv_stats rows (preemptions, spills, COW
    forks) must survive the vectorized path bit-for-bit."""
    kvc = KVCacheConfig(n_blocks=n_blocks, block_tokens=bt,
                        dram_blocks=dram, prefix_sharing=share,
                        bytes_per_token=kv_bytes_per_token(cfg))
    sim = PicnicSimulator()
    sim.ccpg_model.include_dram_hub = dram > 0
    trace = poisson_trace(12, 50.0, seed=seed, prompt_len=256, max_new=64,
                          prefix_len=192 if share else 0, prefix_frac=0.75)
    cell = SweepCell("paged", cfg, trace, sim=sim,
                     engine=ServingConfig(max_batch=4, ccpg=True,
                                         kv_cache=kvc))
    (res,) = sweep_serve([cell])
    assert res.fallback is None
    assert res.kv_stats is not None
    _assert_cell_identical(res, cell)


def test_sweep_shared_trace_object_not_mutated(cfg):
    """Grid builders reuse one trace list across cells; the engine must
    defensively copy (TrackedRequest is mutable bookkeeping)."""
    trace = poisson_trace(8, 40.0, seed=0, max_new=24)
    snap = [(r.arrival, r.prompt_len, r.max_new) for r in trace]
    cells = [SweepCell(f"c{i}", cfg, trace,
                       engine=ServingConfig(max_batch=1 + i))
             for i in range(3)]
    for res, cell in zip(sweep_serve(cells), cells):
        _assert_cell_identical(res, cell)
    assert [(r.arrival, r.prompt_len, r.max_new) for r in trace] == snap


# ---------------------------------------------------------------------------
# lifted lanes (ISSUE 8): overlap / dynamic CCPG / TTFT deadlines ride
# the vector path — fallback-free AND bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_kw, trace_kw", [
    (dict(overlap=0.5), {}),
    (dict(overlap=1.0), {}),
    (dict(ccpg=True, dynamic_ccpg=True), {}),
    (dict(), dict(deadline_ttft=0.25)),
    (dict(), dict(deadline_ttft=0.05)),
    (dict(overlap=0.25, ccpg=True, dynamic_ccpg=True),
     dict(deadline_ttft=0.1)),
])
def test_sweep_lifted_lanes_vectorized(cfg, engine_kw, trace_kw):
    """The PR-7 scalar-fallback feature axes now run vectorized: the
    result is unflagged (``fallback is None``) and byte-identical."""
    trace = poisson_trace(8, 30.0, seed=2, max_new=24, **trace_kw)
    cell = SweepCell("fb", cfg, trace, engine=ServingConfig(**engine_kw))
    vanilla = SweepCell("ok", cfg, poisson_trace(8, 30.0, seed=2,
                                                 max_new=24))
    lifted, ok = sweep_serve([cell, vanilla])
    assert lifted.fallback is None
    assert ok.fallback is None
    _assert_cell_identical(lifted, cell)
    _assert_cell_identical(ok, vanilla)


@settings(max_examples=8, deadline=None)
@given(overlap=st.sampled_from([0.0, 0.25, 1.0]),
       dyn=st.booleans(),
       ttft=st.sampled_from([None, 0.05, 0.3]),
       chunk=st.sampled_from([0, 128]),
       mb=st.integers(min_value=1, max_value=6),
       seed=st.integers(min_value=0, max_value=4))
def test_sweep_property_lifted_lane_cells(cfg, overlap, dyn, ttft, chunk,
                                          mb, seed):
    """Randomized differential sweeps over the previously-fallback
    feature axes (overlap, dynamic CCPG, TTFT deadlines, chunking) stay
    bit-identical to per-cell scalar engines on the vector path."""
    trace_kw = {} if ttft is None else dict(deadline_ttft=ttft)
    trace = poisson_trace(10, 60.0, seed=seed, prompt_len=192, max_new=40,
                          **trace_kw)
    cell = SweepCell(
        "lift", cfg, trace,
        engine=ServingConfig(max_batch=mb, overlap=overlap,
                            ccpg=dyn, dynamic_ccpg=dyn,
                            chunked_prefill_tokens=chunk))
    (res,) = sweep_serve([cell])
    assert res.fallback is None
    _assert_cell_identical(res, cell)


@settings(max_examples=6, deadline=None)
@given(dyn=st.booleans(),
       overlap=st.sampled_from([0.0, 0.5]),
       ttft=st.sampled_from([None, 0.2]),
       seed=st.integers(min_value=0, max_value=3))
def test_sweep_property_paged_lifted_cells(cfg, dyn, overlap, ttft, seed):
    """Paged KV combined with the lifted lanes: growth-round prep mid
    cruise (which can preempt and change the queue head under a TTFT
    deadline) must stay bit-identical."""
    kvc = KVCacheConfig(n_blocks=12, block_tokens=64, dram_blocks=8,
                        bytes_per_token=kv_bytes_per_token(cfg))
    sim = PicnicSimulator()
    sim.ccpg_model.include_dram_hub = True
    trace_kw = {} if ttft is None else dict(deadline_ttft=ttft)
    trace = poisson_trace(10, 50.0, seed=seed, prompt_len=256, max_new=64,
                          **trace_kw)
    cell = SweepCell("pl", cfg, trace, sim=sim,
                     engine=ServingConfig(max_batch=4, ccpg=True,
                                         dynamic_ccpg=dyn, overlap=overlap,
                                         kv_cache=kvc))
    (res,) = sweep_serve([cell])
    assert res.fallback is None
    assert res.kv_stats is not None
    _assert_cell_identical(res, cell)


def test_sweep_prefill_cruise_identical(cfg):
    """Prefill-dominated cells (long prompts, tiny generation, spaced
    arrivals) exercise the prefill-chunk cruise: byte-identical reports
    (the mid-chunk PREFILL progress markers folded into a cruise are
    sample-only and never enter the report)."""
    trace = poisson_trace(6, 4.0, seed=9, prompt_len=8192, max_new=2)
    for kw in (dict(), dict(ccpg=True, dynamic_ccpg=True),
               dict(overlap=0.5)):
        cell = SweepCell("pf", cfg, trace,
                         engine=ServingConfig(chunked_prefill_tokens=128,
                                             **kw))
        (res,) = sweep_serve([cell])
        assert res.fallback is None, kw
        _assert_cell_identical(res, cell)


def test_sweep_engine_single_shot(cfg):
    cell = SweepCell("one", cfg, poisson_trace(4, 30.0, seed=0, max_new=8))
    eng = SweepEngine([cell])
    eng.run()
    with pytest.raises(RuntimeError, match="single-shot"):
        eng.run()


def test_sweep_wall_split_and_fallback_counts(cfg):
    """The run() bookkeeping the benchmarks report: wall clock split
    between the vector and scalar-fallback paths, and per-reason
    fallback cell counts."""
    sim = PicnicSimulator(cycle_model=CycleModel(memoize=False))
    cells = [
        SweepCell("v", cfg, poisson_trace(4, 30.0, seed=0, max_new=8)),
        SweepCell("f1", cfg, poisson_trace(4, 30.0, seed=1, max_new=8),
                  sim=sim),
        SweepCell("f2", cfg, poisson_trace(4, 30.0, seed=2, max_new=8),
                  sim=sim),
    ]
    eng = SweepEngine(cells)
    eng.run()
    assert eng.vector_wall_s > 0.0 and eng.fallback_wall_s > 0.0
    assert sum(eng.fallback_counts.values()) == 2
    (reason,) = eng.fallback_counts
    assert "non-affine" in reason and eng.fallback_counts[reason] == 2


def test_sweep_fallback_non_affine_surface(cfg):
    """memoize=False kills the affine export — whole group runs scalar,
    flagged as such, results still identical."""
    sim = PicnicSimulator(cycle_model=CycleModel(memoize=False))
    cell = SweepCell("noaff", cfg, poisson_trace(6, 30.0, seed=4,
                                                 max_new=16), sim=sim)
    (res,) = sweep_serve([cell])
    assert res.fallback is not None and "non-affine" in res.fallback
    eng = ContinuousBatchingEngine(
        cfg, sim=PicnicSimulator(cycle_model=CycleModel(memoize=False)))
    ref = eng.run([copy.copy(r) for r in cell.trace])
    assert _hexdict(res.report) == _hexdict(ref)


# ---------------------------------------------------------------------------
# calibration mutation on the shared model between (and across) sweeps
# ---------------------------------------------------------------------------

def test_sweep_recalibration_between_runs(cfg):
    """Mutating a calibration field on the SHARED CycleModel between two
    sweeps must invalidate every memoized cost and the batched surface:
    the second sweep prices with the new constants (bit-identical to a
    fresh scalar engine carrying the same mutation), not stale memos."""
    sim = PicnicSimulator()
    trace = poisson_trace(10, 40.0, seed=5, max_new=32)
    mk = lambda: [SweepCell(f"c{mb}", cfg, trace,
                            sim=sim, engine=ServingConfig(max_batch=mb))
                  for mb in (2, 8)]
    before = sweep_serve(mk())
    sim.cycle_model.alpha = sim.cycle_model.alpha * 0.5   # __setattr__ stamp
    after = sweep_serve(mk())
    for res_b, res_a, mb in zip(before, after, (2, 8)):
        assert res_a.fallback is None
        # the mutation visibly changed the physics...
        assert res_a.report.wall_s != res_b.report.wall_s
        # ...and matches a from-scratch scalar engine under the new alpha
        ref_sim = PicnicSimulator()
        ref_sim.cycle_model.alpha = ref_sim.cycle_model.alpha * 0.5
        ref = ContinuousBatchingEngine(
            cfg, sim=ref_sim, engine=ServingConfig(max_batch=mb)
        ).run([copy.copy(r) for r in trace])
        assert _hexdict(res_a.report) == _hexdict(ref)


def test_cost_surface_refresh_on_calibration_bump(cfg):
    m = CycleModel()
    alloc = allocate_chiplets(cfg, PicnicSimulator().tile)
    surf = DecodeCostSurface(m, cfg, alloc, max_batch=4)
    assert surf.valid() and not surf.refresh()
    old_alpha = surf.alpha
    m.alpha = m.alpha * 2.0
    assert not surf.valid()
    assert surf.refresh()            # rebuild happened
    assert surf.alpha == old_alpha * 2.0
    assert surf.valid() and not surf.refresh()


def test_cost_surface_prefill_lane_refresh(cfg):
    """The closed-form prefill lane invalidates with the decode lane on
    calibration mutation: after refresh() the surface prices chunks
    under the new constants, bit-equal to the model's own (memoized)
    chunk walk — and the closed form stays memo-free (no prefill LRU
    traffic beyond the build probes)."""
    m = CycleModel()
    alloc = allocate_chiplets(cfg, PicnicSimulator().tile)
    surf = DecodeCostSurface(m, cfg, alloc, max_batch=2)
    assert surf.prefill_closed
    probes = m.memo_stats()["prefill_misses"]
    chunk = np.array([128, 128, 64], dtype=np.int64)
    before = np.array([0, 4096, 1023], dtype=np.int64)
    cyc0, c2cb0 = surf.prefill_chunk_cycles(chunk, before)
    assert m.memo_stats()["prefill_misses"] == probes   # closed form
    m.alpha = m.alpha * 2.0
    assert not surf.valid()
    assert surf.refresh()
    assert surf.prefill_closed
    cyc1, c2cb1 = surf.prefill_chunk_cycles(chunk, before)
    assert np.array_equal(c2cb1, c2cb0)                 # bytes: no alpha
    assert not np.array_equal(cyc1, cyc0)               # physics moved
    for k in range(chunk.size):
        want_c, want_b = m.prefill_chunk_cycles(cfg, alloc, int(chunk[k]),
                                                int(before[k]))
        assert int(cyc1[k]) == want_c and int(c2cb1[k]) == want_b


def test_cost_surface_matches_affine_export(cfg):
    """decode_cycles must reproduce the scalar engine's exact pricing
    arithmetic (same int truncation points) for every (b, ctx) lane."""
    m = CycleModel()
    alloc = allocate_chiplets(cfg, PicnicSimulator().tile)
    surf = DecodeCostSurface(m, cfg, alloc, max_batch=6)
    assert surf.affine[1:].all()
    bs = np.array([1, 2, 3, 6, 4, 5], dtype=np.int64)
    ctxs = np.array([1, 17, 1009, 65537, 4096, 31], dtype=np.int64)
    got = surf.decode_cycles(bs, ctxs)
    for k, (b, ctx) in enumerate(zip(bs, ctxs)):
        base, n_attn, _c2cb, cpp, alpha, _ver = m.decode_affine(
            cfg, alloc, int(b))
        want = int((base + n_attn * int(cpp * int(ctx))) * alpha)
        assert got[k] == want
    with pytest.raises(ValueError):
        DecodeCostSurface(m, cfg, alloc, max_batch=0)


def test_cost_surface_shares_model_memo(cfg):
    """Building a surface populates the model's decode LRU; a rebuild is
    pure hits.  The capacity knobs bound the LRU and memo_stats() makes
    evictions visible."""
    m = CycleModel()
    alloc = allocate_chiplets(cfg, PicnicSimulator().tile)
    DecodeCostSurface(m, cfg, alloc, max_batch=4)
    s0 = m.memo_stats()
    assert s0["decode_misses"] >= 4 and s0["decode_size"] >= 4
    DecodeCostSurface(m, cfg, alloc, max_batch=4)
    s1 = m.memo_stats()
    assert s1["decode_misses"] == s0["decode_misses"]      # no re-walk
    assert s1["decode_size"] == s0["decode_size"]
    # tiny capacity knob -> evictions surface in the counters
    tiny = CycleModel(decode_memo_max=2)
    DecodeCostSurface(tiny, cfg, alloc, max_batch=5)
    st_tiny = tiny.memo_stats()
    assert st_tiny["decode_max"] == 2
    assert st_tiny["decode_size"] <= 2
    assert st_tiny["decode_evictions"] > 0


# ---------------------------------------------------------------------------
# aggregate-only Timeline: same integrals, no event storage
# ---------------------------------------------------------------------------

def _drive(tl: Timeline) -> None:
    tl.compute(1e-3, kind="prefill", power_W=4.0, cycles=123, batch=2,
               name="p0")
    tl.c2c(4096, phase="prefill", t0=0.0, dur_s=1e-6)
    tl.token(3, request_id=7)
    tl.compute(2e-3, kind="decode", power_W=4.0, cycles=456, batch=3)
    tl.token_each([1, 2, 5])
    tl.wake(1e-4, power_W=2.0, cycles=99, cluster=1)
    tl.c2c(128, dur_s=5e-7, phase="kv_fetch", advance=True, power_W=3.0)
    tl.sleep(5e-4, power_W=0.5)
    tl.sleep(1e-3, t0=0.0, advance=False, power_W=9.0)
    tl.sample(1.25)


def test_aggregate_only_matches_recording_timeline():
    agg, col = Timeline(aggregate_only=True), Timeline(columnar=True)
    _drive(agg)
    _drive(col)
    for attr in ("now", "energy_J", "busy_s", "idle_s", "c2c_bytes",
                 "tokens", "occupancy_s"):
        assert getattr(agg, attr) == getattr(col, attr), attr
    for cls in (ComputeSpan, C2CTransfer, ClusterWake, ClusterSleep,
                EnergySample, TokenEmit):
        assert agg.count(cls) == col.count(cls), cls.__name__
    for kind in (None, "prefill", "decode"):
        assert agg.cycles(ComputeSpan, kind=kind) \
            == col.cycles(ComputeSpan, kind=kind)
        assert agg.span_seconds(ComputeSpan, kind=kind) \
            == col.span_seconds(ComputeSpan, kind=kind)
    assert agg.n_events == col.n_events
    assert agg.total_energy_J() == col.total_energy_J()


def test_aggregate_only_refuses_event_access():
    tl = Timeline(aggregate_only=True)
    tl.compute(1e-3, kind="decode", cycles=9)
    for op in (lambda: tl.events, lambda: list(tl._iter_events()),
               lambda: tl.power_trace(),
               lambda: tl.column(ComputeSpan, "dur_s")):
        with pytest.raises(RuntimeError, match="aggregate-only"):
            op()
    assert tl.n_events == 2          # O(1) count still works


def test_aggregate_only_engine_report_identical(cfg):
    """ServingConfig.aggregate_timeline drops event storage but must not
    perturb a single reported float."""
    base = ServingConfig(max_batch=4, ccpg=True)
    trace = poisson_trace(16, 40.0, seed=6, max_new=48)
    fast = ContinuousBatchingEngine(
        cfg, sim=PicnicSimulator(),
        engine=dataclasses.replace(base, aggregate_timeline=True))
    ref = ContinuousBatchingEngine(cfg, sim=PicnicSimulator(), engine=base)
    r_fast = fast.run([copy.copy(r) for r in trace])
    r_ref = ref.run([copy.copy(r) for r in trace])
    assert _hexdict(r_fast) == _hexdict(r_ref)
    assert fast.timeline.n_events == ref.timeline.n_events


# ---------------------------------------------------------------------------
# SweepAggregates: sync round-trip and the burst fold
# ---------------------------------------------------------------------------

def test_sweep_aggregates_sync_roundtrip():
    tl = Timeline(aggregate_only=True)
    _drive(tl)
    agg = SweepAggregates(3)
    agg.sync_in(1, tl)
    out = Timeline(aggregate_only=True)
    agg.sync_out(1, out)
    for attr in ("now", "energy_J", "busy_s", "c2c_bytes", "tokens",
                 "occupancy_s"):
        assert getattr(out, attr) == getattr(tl, attr), attr
    # only the counts a vector round can touch are mirrored (compute,
    # sample, c2c, token) — wakes/sleeps mutate scalar-side only
    from repro.core.timeline import _C2C, _COMPUTE, _SAMPLE, _TOKEN
    for slot in (_COMPUTE, _SAMPLE, _C2C, _TOKEN):
        assert out._counts[slot] == tl._counts[slot]
    for key in SweepAggregates._SPAN_KEYS:
        assert out._span_s.get(key, 0.0) == tl._span_s.get(key, 0.0)


def _random_agg(rng, n):
    agg = SweepAggregates(n)
    for name in ("now", "busy_s", "energy_J", "occupancy_s",
                 "span_compute", "span_decode", "span_c2c"):
        getattr(agg, name)[:] = rng.uniform(0.0, 2.0, n)
    for name in ("tokens", "c2c_bytes", "n_compute", "n_sample", "n_c2c",
                 "n_token"):
        getattr(agg, name)[:] = rng.integers(0, 1000, n)
    return agg


def _clone_agg(agg):
    c = SweepAggregates(agg.n_cells)
    for name in vars(agg):
        v = getattr(agg, name)
        if isinstance(v, np.ndarray):
            getattr(c, name)[:] = v
    return c


def _reference_rounds(agg, idx, h, dt, power, batch, bb, bd, fb, fd, arr):
    """h[k] sequential decode_round calls per lane, with the scalar
    engine's arrival cutoff (round j+1 only runs while now < arrival)."""
    applied = np.zeros(idx.size, dtype=np.int64)
    for j in range(int(h.max())):
        live = (applied == j) & (j < h) & (agg.now[idx] < arr)
        if not live.any():
            break
        sel = idx[live]
        agg.decode_round(sel, dt[j][live], power[live], batch[live],
                         bb[live], bd[live], fb[live], fd[live])
        applied[live] += 1
    return applied


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       with_fetch=st.booleans(),
       truncate=st.booleans())
def test_decode_burst_bit_identical_to_rounds(seed, with_fetch, truncate):
    """decode_burst == h repeated decode_round calls, bit for bit, on
    both the fetch-free fast path and the interleaved general path,
    with and without arrival truncation."""
    rng = np.random.default_rng(seed)
    n, H = 5, 7
    idx = np.sort(rng.choice(8, size=n, replace=False)).astype(np.int64)
    h = rng.integers(1, H + 1, n)
    dt = rng.uniform(1e-5, 1e-3, (H, n))
    power = rng.uniform(0.0, 8.0, n)
    batch = rng.integers(1, 9, n)
    bb = rng.integers(0, 4096, n) * rng.integers(0, 2, n)
    bd = np.where(bb > 0, bb / 64e9, 0.0)
    if with_fetch:
        fb = rng.integers(0, 2048, n) * rng.integers(0, 2, n)
        if not fb.any():
            fb[0] = 512
    else:
        fb = np.zeros(n, dtype=np.int64)
    fd = np.where(fb > 0, fb / 64e9, 0.0)
    a = _random_agg(np.random.default_rng(seed + 1), 8)
    if truncate:
        # arrivals land mid-burst for some lanes, far future for others
        arr = a.now[idx] + rng.uniform(0.0, 3e-3, n)
    else:
        arr = np.full(n, np.inf)
    # callers guarantee no arrival due at entry
    arr = np.maximum(arr, np.nextafter(a.now[idx], np.inf))
    ref = _clone_agg(a)
    h_fast = a.decode_burst(idx, h, dt.copy(), power, batch, bb, bd, fb,
                            fd, arr)
    h_ref = _reference_rounds(ref, idx, h, dt, power, batch, bb, bd, fb,
                              fd, arr)
    assert np.array_equal(h_fast, h_ref)
    assert (h_fast >= 1).all()
    for name in vars(a):
        va, vr = getattr(a, name), getattr(ref, name)
        if isinstance(va, np.ndarray):
            assert va.tobytes() == vr.tobytes(), name


def _apply_wake(agg, lane, wdt, wcyc, power):
    """The scalar engine's ClusterWake charge ahead of a round/chunk."""
    agg.now[lane] += wdt
    agg.busy_s[lane] += wdt
    agg.energy_J[lane] += wdt * power
    agg.span_wake[lane] += wdt
    agg.cyc_wake[lane] += wcyc
    agg.n_wake[lane] += 1
    agg.n_sample[lane] += 1


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       with_wake=st.booleans(),
       with_risk=st.booleans())
def test_decode_burst_wake_and_risk_bit_identical(seed, with_wake,
                                                  with_risk):
    """The extended burst fold (dynamic-CCPG wake rows interleaved, TTFT
    at-risk truncation) == wake + decode_round applied sequentially."""
    rng = np.random.default_rng(seed)
    n, H = 5, 7
    idx = np.sort(rng.choice(8, size=n, replace=False)).astype(np.int64)
    h = rng.integers(1, H + 1, n)
    dt = rng.uniform(1e-5, 1e-3, (H, n))
    power = rng.uniform(0.5, 8.0, n)
    batch = rng.integers(1, 9, n)
    bb = rng.integers(1, 4096, n)
    bd = bb / 64e9
    fb = rng.integers(0, 2048, n) * rng.integers(0, 2, n)
    fd = np.where(fb > 0, fb / 64e9, 0.0)
    wdt = (rng.uniform(1e-6, 1e-4, n) * rng.integers(0, 2, n)
           if with_wake else np.zeros(n))
    if with_wake and not wdt.any():
        wdt[0] = 3e-5
    wcyc = rng.integers(1, 999, n)
    a = _random_agg(np.random.default_rng(seed + 1), 8)
    arr = a.now[idx] + rng.uniform(0.0, 3e-3, n)
    arr = np.maximum(arr, np.nextafter(a.now[idx], np.inf))
    if with_risk:
        eta = rng.uniform(0.0, 1e-3, n)
        bound = a.now[idx] + eta + rng.uniform(-1e-3, 3e-3, n)
        bound = np.maximum(bound,
                           np.nextafter(a.now[idx] + eta, np.inf))
    else:
        eta, bound = None, None
    ref = _clone_agg(a)
    h_fast = a.decode_burst(idx, h, dt.copy(), power, batch, bb, bd, fb,
                            fd, arr,
                            wake_dt=wdt if wdt.any() else None,
                            wake_cyc=wcyc, risk_eta=eta, risk_bound=bound)
    applied = np.zeros(n, dtype=np.int64)
    for j in range(int(h.max())):
        live = (applied == j) & (j < h) & (ref.now[idx] < arr)
        if eta is not None:
            live &= (ref.now[idx] + eta) < bound
        if not live.any():
            break
        for k in np.nonzero(live & (wdt > 0))[0]:
            _apply_wake(ref, int(idx[k]), wdt[k], wcyc[k], power[k])
        sel = idx[live]
        ref.decode_round(sel, dt[j][live], power[live], batch[live],
                         bb[live], bd[live], fb[live], fd[live])
        applied[live] += 1
    assert np.array_equal(h_fast, applied)
    assert (h_fast >= 1).all()
    for name in vars(a):
        va, vr = getattr(a, name), getattr(ref, name)
        if isinstance(va, np.ndarray):
            assert va.tobytes() == vr.tobytes(), name


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       with_wake=st.booleans(),
       truncate=st.booleans())
def test_prefill_burst_bit_identical_to_chunks(seed, with_wake, truncate):
    """prefill_burst == h sequential scalar-order chunk applications
    ([wake] -> compute(prefill, batch 1) -> non-advancing c2c)."""
    rng = np.random.default_rng(seed)
    n, H = 5, 7
    idx = np.sort(rng.choice(8, size=n, replace=False)).astype(np.int64)
    h = rng.integers(1, H + 1, n)
    dt = rng.uniform(1e-5, 1e-3, (H, n))
    power = rng.uniform(0.5, 8.0, n)
    bb = rng.integers(1, 65536, n)
    bd = bb / 64e9
    wdt = (rng.uniform(1e-6, 1e-4, n) * rng.integers(0, 2, n)
           if with_wake else np.zeros(n))
    if with_wake and not wdt.any():
        wdt[0] = 3e-5
    wcyc = rng.integers(1, 999, n)
    a = _random_agg(np.random.default_rng(seed + 1), 8)
    arr = (a.now[idx] + rng.uniform(0.0, 3e-3, n) if truncate
           else np.full(n, np.inf))
    arr = np.maximum(arr, np.nextafter(a.now[idx], np.inf))
    ref = _clone_agg(a)
    h_fast = a.prefill_burst(idx, h, dt.copy(), power, bb, bd, arr,
                             wake_dt=wdt if wdt.any() else None,
                             wake_cyc=wcyc)
    applied = np.zeros(n, dtype=np.int64)
    for k, lane in enumerate(idx.tolist()):
        for j in range(int(h[k])):
            if not ref.now[lane] < arr[k]:
                break
            if wdt[k] > 0:
                _apply_wake(ref, lane, wdt[k], wcyc[k], power[k])
            d = dt[j, k]
            ref.now[lane] += d
            ref.busy_s[lane] += d
            ref.energy_J[lane] += d * power[k]
            ref.span_compute[lane] += d
            ref.span_prefill[lane] += d
            ref.occupancy_s[lane] += d          # chunk batch is 1
            ref.n_compute[lane] += 1
            ref.n_sample[lane] += 1
            ref.span_c2c[lane] += bd[k]         # non-advancing transfer
            ref.c2c_bytes[lane] += bb[k]
            ref.n_c2c[lane] += 1
            applied[k] += 1
    assert np.array_equal(h_fast, applied)
    assert (h_fast >= 1).all()
    for name in vars(a):
        va, vr = getattr(a, name), getattr(ref, name)
        if isinstance(va, np.ndarray):
            assert va.tobytes() == vr.tobytes(), name


def test_decode_burst_untouched_lanes_stay_put():
    rng = np.random.default_rng(7)
    a = _random_agg(rng, 6)
    before = {k: v.copy() for k, v in vars(a).items()
              if isinstance(v, np.ndarray)}
    idx = np.array([1, 4], dtype=np.int64)
    n = idx.size
    H = 3
    a.decode_burst(idx, np.array([3, 2]),
                   rng.uniform(1e-5, 1e-4, (H, n)),
                   rng.uniform(0.0, 4.0, n), np.array([2, 1]),
                   np.zeros(n, dtype=np.int64), np.zeros(n),
                   np.zeros(n, dtype=np.int64), np.zeros(n),
                   np.full(n, np.inf))
    others = np.array([0, 2, 3, 5])
    for name, old in before.items():
        assert np.array_equal(getattr(a, name)[others], old[others]), name


# ---------------------------------------------------------------------------
# engine internals: grouping and surface sharing
# ---------------------------------------------------------------------------

def test_sweep_groups_share_allocation_and_surface(cfg):
    sim = PicnicSimulator()
    cells = [SweepCell(f"c{i}", cfg,
                       poisson_trace(4, 30.0, seed=i, max_new=8),
                       sim=sim, engine=ServingConfig(max_batch=mb))
             for i, mb in enumerate((2, 8, 4))]
    eng = SweepEngine(cells)
    assert len(eng._groups) == 1
    (group,) = eng._groups.values()
    assert group.max_batch == 8
    assert group.surface is not None
    assert group.surface.max_batch == 8
    allocs = {id(s.eng.alloc) for s in eng._states}
    assert allocs == {id(group.alloc)}
