"""PICNIC core: ISA, NPM/assembler, NoC, partition/mapping, SCU, energy."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CCPGModel, CLUSTER_SIZE, DoubleBufferedNPM, Instr,
                        Mesh2D, MeshConfig, Mode, ProgramBuilder, SCUFsm,
                        TileSpec, allocate_chiplets, attention_grids,
                        compile_to_hex, ffn_grids, fits_one_chiplet,
                        map_layer, partition_matrix, pwl_softmax, table_iv)
from repro.core.isa import PORTS, TOTAL_BITS, broadcast, port_mask, unicast
from repro.core.program import Bank, parse_hex
from repro.core.scheduling import layer_tiles, llm_layers
from repro.configs import get_config


# ---------------------------------------------------------------------------
# ISA
# ---------------------------------------------------------------------------

def test_isa_is_30_bits():
    assert TOTAL_BITS == 30
    assert len(PORTS) == 7          # 4 planar + PE + 2 TSV (paper Fig 3e)


@settings(max_examples=100, deadline=None)
@given(rd=st.integers(0, 127), mode=st.sampled_from(list(Mode)),
       out=st.integers(0, 127), intx=st.integers(0, 3),
       sp=st.integers(0, 1023))
def test_isa_roundtrip(rd, mode, out, intx, sp):
    i = Instr(rd_en=rd, mode=mode, out_en=out, intxfer_en=intx, sp_addr=sp)
    w = i.encode()
    assert 0 <= w < (1 << 30)
    assert Instr.decode(w) == i


def test_unicast_broadcast_masks():
    assert unicast("N") == 1
    assert port_mask("N", "E") == 0b11
    assert broadcast() == 0b1111111     # all ports (paper: up to all I/O)


# ---------------------------------------------------------------------------
# NPM / assembler / compiler
# ---------------------------------------------------------------------------

def test_program_hex_roundtrip():
    pb = ProgramBuilder(n_routers=16)
    pb.all_do(Instr(mode=Mode.ROUTE, out_en=unicast("E")), repeat=4)
    pb.emit(Instr(mode=Mode.DMAC, rd_en=port_mask("PE")),
            Instr(mode=Mode.PSUM), {0: 1, 5: 2}, repeat=2)
    hx = compile_to_hex(pb)
    sections = parse_hex(hx, 16)
    assert sections and sections[0][0].startswith("BANK1")
    # each row: cmd1, cmd2, repeat, + ceil(16*2/32)=1 select word
    assert len(sections[0][1]) == 2 * 4
    # cmd word decodes back
    w = int(sections[0][1][0], 16)
    assert Instr.decode(w).mode == Mode.ROUTE


def test_double_buffered_npm_no_stalls_when_balanced():
    pb = ProgramBuilder(n_routers=4)
    for _ in range(600):                   # spans 3 banks
        pb.all_do(Instr(mode=Mode.ROUTE), repeat=4)
    npm = DoubleBufferedNPM(pb.split_banks(), refill_cycles_per_row=2)
    rows = list(npm.run())
    assert len(rows) == 600
    # refill (2 cyc/row) is slower than never... drain is 4 cyc/row, so the
    # co-processor keeps up: zero NMC stalls (paper §II-B.2 claim)
    assert npm.stall_cycles == 0


def test_double_buffered_npm_stalls_when_refill_slow():
    pb = ProgramBuilder(n_routers=4)
    for _ in range(512):
        pb.all_do(Instr(mode=Mode.ROUTE), repeat=1)
    npm = DoubleBufferedNPM(pb.split_banks(), refill_cycles_per_row=8)
    list(npm.run())
    assert npm.stall_cycles > 0


# ---------------------------------------------------------------------------
# NoC / spanning tree
# ---------------------------------------------------------------------------

def test_xy_route_len():
    m = Mesh2D()
    p = m.xy_route((0, 0), (3, 5))
    assert p[0] == (0, 0) and p[-1] == (3, 5)
    assert len(p) == 1 + m.hops((0, 0), (3, 5))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999))
def test_spanning_tree_reaches_all_members(seed):
    rng = np.random.default_rng(seed)
    m = Mesh2D(MeshConfig(rows=8, cols=8))
    members = {(int(r), int(c))
               for r, c in rng.integers(0, 8, size=(6, 2))}
    root = (0, 0)
    tree = m.spanning_tree(root, members)
    reached = {root}
    frontier = [root]
    while frontier:
        n = frontier.pop()
        for ch in tree.get(n, []):
            reached.add(ch)
            frontier.append(ch)
    assert members <= reached


def test_spanning_tree_level_disjoint():
    m = Mesh2D()
    members = [(r, c) for r in range(0, 32, 4) for c in range(0, 32, 4)]
    assert m.check_level_disjoint((16, 16), members)


def test_broadcast_reduce_cycles_scale_with_payload():
    m = Mesh2D()
    members = [(r, c) for r in range(8) for c in range(8)]
    c1 = m.broadcast_cycles((0, 0), members, 256)
    c2 = m.broadcast_cycles((0, 0), members, 4096)
    assert c2 > c1
    assert m.reduce_cycles((0, 0), members, 256) >= c1 - 1


# ---------------------------------------------------------------------------
# Partition / mapping
# ---------------------------------------------------------------------------

def test_partition_tile_grid():
    tg = partition_matrix("W_Q", 2048, 2048)
    assert tg.grid == (8, 8)
    assert tg.n_tiles == 64
    assert tg.utilization == 1.0
    tg2 = partition_matrix("W", 2000, 100)
    assert tg2.grid == (8, 1)
    assert tg2.tile_shape(7, 0) == (2000 - 7 * 256, 100)


def test_llama1b_attention_fits_one_chiplet():
    grids = attention_grids(2048, 2048, 512)
    assert fits_one_chiplet(grids)
    mapping = map_layer(grids)
    # all regions inside the 32x32 mesh, pairwise column-disjoint
    cols = []
    for r in mapping.regions.values():
        assert 0 <= r.origin[1] and r.origin[1] + r.shape[1] <= 32
        cols.append((r.origin[1], r.origin[1] + r.shape[1]))
    cols.sort()
    for (a0, a1), (b0, b1) in zip(cols, cols[1:]):
        assert a1 <= b0


def test_scratchpad_colocation():
    grids = attention_grids(2048, 2048, 512)
    mapping = map_layer(grids)
    assert mapping.scratchpad_region("Q") is mapping.regions["W_Q"]
    assert mapping.scratchpad_region("K") is mapping.regions["W_K"]


def test_kv_cyclic_striping_balanced():
    from repro.core.partition import plan_kv_cache
    plan = plan_kv_cache(kv_dim=512, n_pads=16)
    pads = [plan.pad_of_token(t) for t in range(160)]
    counts = np.bincount(pads, minlength=16)
    assert counts.max() - counts.min() <= 1       # balanced at ANY length


def test_chiplet_allocation_counts_match_paper():
    """Tile-granular packing reproduces the implied Table II chiplet
    counts: power = chiplets * 0.271 W ~= paper's average power."""
    tile = TileSpec()
    for arch, paper_power in [("llama3.2-1b", 4.05), ("llama3-8b", 28.40),
                              ("llama2-13b", 52.30)]:
        alloc = allocate_chiplets(get_config(arch), tile)
        power = alloc.n_chiplets * tile.tile_power_active
        assert abs(power / paper_power - 1) < 0.06, (arch, power)


# ---------------------------------------------------------------------------
# SCU
# ---------------------------------------------------------------------------

def test_scu_fsm_matches_pwl_softmax():
    fsm = SCUFsm()
    row = np.random.default_rng(0).normal(size=64).astype(np.float32) * 3
    out, cycles = fsm.run(row)
    np.testing.assert_allclose(out, pwl_softmax(row), atol=1e-6)
    assert cycles == 64 + 4 + 12 + 64      # stream + fill + recip + scale


def test_scu_throughput_overlap():
    from repro.core.scu import SCUTiming
    t = SCUTiming()
    assert t.throughput_softmax_cycles(256) < t.softmax_cycles(256)


# ---------------------------------------------------------------------------
# Energy / CCPG
# ---------------------------------------------------------------------------

def test_table_iv_constants():
    t = table_iv()
    assert t["Total (IPCN-PE)"]["power_uW"] == pytest.approx(259.0)
    assert t["Total (IPCN-PE)"]["area_mm2"] == pytest.approx(0.1842)


def test_ccpg_power_saving_increases_with_model_size():
    m = CCPGModel()
    savings = [m.power_saving_frac(n) for n in (15, 104, 190)]
    assert savings[0] < savings[1] < savings[2]
    assert 0.78 < savings[1] < 0.86          # ~80% for Llama-8B (paper)


def test_ccpg_sleep_keeps_scratchpads():
    t = TileSpec()
    assert t.tile_power_sleep == pytest.approx(1024 * 42e-6)
    assert t.tile_power_sleep < 0.2 * t.tile_power_active


def test_ccpg_small_system_edge_cases():
    """n_chiplets < CLUSTER_SIZE: everything fits one cluster, so gating
    has nothing to put to sleep — zero saving, identical power."""
    m = CCPGModel()
    for n in (1, CLUSTER_SIZE - 1, CLUSTER_SIZE):
        assert m.system_power(n, ccpg=True) \
            == pytest.approx(m.system_power(n, ccpg=False))
        assert m.power_saving_frac(n) == pytest.approx(0.0)
    # strictly positive saving only once a second cluster exists
    assert m.power_saving_frac(CLUSTER_SIZE + 1) > 0.0


def test_ccpg_zero_chiplets_is_welldefined():
    """n_chiplets == 0 must not divide by zero (empty allocation)."""
    m = CCPGModel()
    assert m.system_power(0, ccpg=False) == 0.0
    assert m.system_power(0, ccpg=True) == 0.0
    assert m.power_saving_frac(0) == 0.0


def test_ccpg_dram_hub_flag():
    """`dram_hub_watts` is only charged when explicitly opted in — the
    default matches Table II (which excludes the DRAM hub) and the old
    hardcoded-zero behavior."""
    off = CCPGModel()
    on = CCPGModel(include_dram_hub=True)
    for n in (0, 2, 16):
        for ccpg in (False, True):
            assert on.system_power(n, ccpg=ccpg) == pytest.approx(
                off.system_power(n, ccpg=ccpg) + on.dram_hub_watts)


def test_ccpg_dram_hub_not_gated_when_idle():
    """The DRAM hub has no gating path: with include_dram_hub on, idle
    power must keep charging it in BOTH ccpg branches."""
    on = CCPGModel(include_dram_hub=True)
    off = CCPGModel()
    for n in (4, 16):
        assert on.idle_power(n, ccpg=True) == pytest.approx(
            off.idle_power(n, ccpg=True) + on.dram_hub_watts)
        assert on.idle_power(n, ccpg=False) == pytest.approx(
            off.idle_power(n, ccpg=False) + on.dram_hub_watts)


def test_ccpg_dynamic_wake_latency():
    """Dynamic mode exposes the FULL wake_cycles per cluster transition;
    the static path only keeps the pre-wake residue (dead at default
    wake_cycles=1000 < the 2000-cycle pre-wake window)."""
    m = CCPGModel()
    alloc = allocate_chiplets(get_config("llama3.2-1b"), TileSpec())
    n_tr = alloc.n_clusters - 1
    assert m.wake_latency_cycles(alloc) == n_tr * (m.wake_cycles + 16)
    assert m.wake_latency_cycles(alloc) > m.wake_overhead_cycles(alloc)
    # single-cluster system: no transitions, no wake latency
    single = allocate_chiplets(get_config("llama3.2-1b"), TileSpec())
    single.n_chiplets = CLUSTER_SIZE
    assert single.n_clusters == 1
    assert m.wake_latency_cycles(single) == 0
    assert m.wake_overhead_cycles(single) == 0


# ---------------------------------------------------------------------------
# Code generation (mapping -> ISA stream -> NPM)
# ---------------------------------------------------------------------------

def test_codegen_attention_decode_program():
    from repro.core.codegen import emit_attention_decode
    from repro.core.partition import plan_kv_cache
    from repro.core.program import DoubleBufferedNPM, compile_to_hex

    grids = attention_grids(2048, 2048, 512)
    mapping = map_layer(grids)
    plan = plan_kv_cache(512, n_pads=16)
    prog = emit_attention_decode(mapping, d_model=2048, kv_dim=512,
                                 context_blocks=8, kv_plan=plan)
    assert prog.npm_rows > 10
    assert prog.c2c_bytes == 2048
    # the program compiles to a hex image and round-trips
    hx = compile_to_hex(prog.builder)
    assert hx.startswith("@BANK1")
    # the NPM double-buffering sustains this program without stalls
    npm = DoubleBufferedNPM(prog.builder.split_banks(),
                            refill_cycles_per_row=2)
    rows = list(npm.run())
    assert len(rows) == prog.npm_rows
    assert npm.stall_cycles == 0
    # cycle count is consistent with the analytic model's order
    assert prog.builder.total_cycles() > 8 * 64  # flash loop dominates


def test_codegen_program_fits_context_scaling():
    """Program rows grow linearly with context blocks (the flash loop),
    while the fixed prologue/epilogue stays constant."""
    from repro.core.codegen import emit_attention_decode
    from repro.core.partition import plan_kv_cache
    grids = attention_grids(2048, 2048, 512)
    mapping = map_layer(grids)
    plan = plan_kv_cache(512, n_pads=16)
    r8 = emit_attention_decode(mapping, d_model=2048, kv_dim=512,
                               context_blocks=8, kv_plan=plan).npm_rows
    r16 = emit_attention_decode(mapping, d_model=2048, kv_dim=512,
                                context_blocks=16, kv_plan=plan).npm_rows
    assert r16 - r8 == 8 * 3      # 3 rows per extra context block


def test_codegen_ffn_program():
    from repro.core.codegen import emit_ffn
    from repro.core.mapping import map_layer as ml
    from repro.core.partition import ffn_grids
    grids = ffn_grids(2048, 8192)
    mapping = ml(grids)
    from repro.core.noc import Mesh2D
    prog = emit_ffn(mapping.regions, mapping.mesh, 2048)
    assert prog.npm_rows == 4
    assert prog.c2c_bytes == 2048
