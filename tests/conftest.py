import os
import sys
from pathlib import Path

# make src importable regardless of how pytest is invoked
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# hypothesis is optional (CI has no network): fall back to the seeded
# example runner in tests/_hyp_compat.py so property-test modules still
# collect and run.  No-op when the real package is installed.
sys.path.insert(0, str(Path(__file__).resolve().parent))
import _hyp_compat  # noqa: E402

_hyp_compat.install()

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see the real
# (single-CPU) device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
