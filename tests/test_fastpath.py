"""Fast simulation core (ISSUE 5): the columnar TimelineIR recorder, the
SoA serving loop and the memoized CycleModel must be BIT-IDENTICAL to
the reference object path — property-tested on random traces, locked on
the committed golden, and exercised through every consumer (reports,
kv_stats, chrome traces, O(1) aggregate queries)."""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (C2CTransfer, ClusterWake, ComputeSpan, CycleModel,
                        EnergySample, PicnicSimulator, Timeline, TokenEmit)
from repro.core.scheduling import allocate_chiplets
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingConfig, poisson_trace,
                                         replay_trace)
from repro.runtime.kv_cache import KVCacheConfig, kv_bytes_per_token

GOLDEN = json.loads(
    (Path(__file__).parent / "golden" / "timeline_golden.json").read_text())


def _hexdict(obj) -> dict:
    d = dataclasses.asdict(obj)
    d.pop("queue_depth", None)
    # per-node attribution (ISSUE 9 fleet) stays None outside a fleet and
    # is absent from the committed golden — drop it exactly when unset
    for k in ("node_id", "pool"):
        if k in d and d[k] is None:
            d.pop(k)
    return {k: (v.hex() if isinstance(v, float) else v) for k, v in d.items()}


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


def _engine_pair(cfg, **engine_kw):
    """(fast, reference): identical policy/config, different recorders."""
    fast = ContinuousBatchingEngine(
        cfg, sim=PicnicSimulator(), engine=ServingConfig(**engine_kw))
    ref = ContinuousBatchingEngine(
        cfg, sim=PicnicSimulator(cycle_model=CycleModel(memoize=False)),
        engine=ServingConfig(columnar_timeline=False, **engine_kw))
    return fast, ref


# ---------------------------------------------------------------------------
# Columnar recorder == object recorder
# ---------------------------------------------------------------------------

def _drive(tl: Timeline) -> None:
    tl.compute(1e-3, kind="prefill", power_W=4.0, cycles=123, batch=2,
               name="p0")
    tl.c2c(4096, phase="prefill", t0=0.0, dur_s=1e-6)
    tl.token(3, request_id=7)
    tl.compute(2e-3, kind="decode", power_W=4.0, cycles=456, batch=3)
    tl.token_each([1, 2, 5])
    tl.wake(1e-4, power_W=2.0, cycles=99, cluster=1)
    tl.c2c(128, dur_s=5e-7, phase="kv_fetch", advance=True, power_W=3.0)
    tl.sleep(5e-4, power_W=0.5)
    tl.sleep(1e-3, t0=0.0, advance=False, power_W=9.0)
    tl.sample(1.25)


def test_columnar_matches_object_recorder_exactly():
    col, obj = Timeline(columnar=True), Timeline(columnar=False)
    assert col.columnar and not obj.columnar
    _drive(col)
    _drive(obj)
    # materialized dataclass stream, cursor and every running integral
    assert col.events == obj.events
    assert col.n_events == obj.n_events == len(obj.events)
    for attr in ("now", "energy_J", "busy_s", "idle_s", "c2c_bytes",
                 "tokens", "occupancy_s"):
        assert getattr(col, attr) == getattr(obj, attr), attr
    # O(1) aggregate queries agree between modes (and with a raw scan)
    for cls in (ComputeSpan, C2CTransfer, ClusterWake, EnergySample,
                TokenEmit):
        assert col.count(cls) == obj.count(cls)
    for kind in (None, "prefill", "decode"):
        assert col.cycles(ComputeSpan, kind=kind) \
            == obj.cycles(ComputeSpan, kind=kind) \
            == sum(e.cycles for e in obj.events
                   if isinstance(e, ComputeSpan)
                   and (kind is None or e.kind == kind))
        assert col.span_seconds(ComputeSpan, kind=kind) \
            == obj.span_seconds(ComputeSpan, kind=kind)
    assert col.cycles(ClusterWake) == obj.cycles(ClusterWake) == 99
    assert col.power_trace() == obj.power_trace()
    assert col.total_energy_J() == obj.total_energy_J()
    # chrome export byte-identical across modes
    assert json.dumps(col.to_chrome_trace()) \
        == json.dumps(obj.to_chrome_trace())


def test_columnar_events_cache_extends_incrementally():
    tl = Timeline()
    tl.compute(1e-3, kind="decode", cycles=1)
    first = tl.events
    assert len(first) == 2                    # span + auto sample
    tl.token(1, request_id=0)
    again = tl.events
    assert again is first and len(again) == 3  # same cache, extended
    assert again[:2] == first[:2]


def test_column_accessor_matches_events(cfg):
    for columnar in (True, False):
        tl = Timeline(columnar=columnar)
        PicnicSimulator().run(cfg, 256, 32, ccpg=True, timeline=tl)
        durs = tl.column(ComputeSpan, "dur_s")
        assert durs == [e.dur_s for e in tl.events
                        if isinstance(e, ComputeSpan)]
        assert tl.column(TokenEmit, "n") == \
            [e.n for e in tl.events if isinstance(e, TokenEmit)]
        with pytest.raises(KeyError):
            tl.column(ComputeSpan, "nbytes")


def test_simulator_identical_on_both_recorders(cfg):
    for kw in ({}, {"ccpg": True}, {"ccpg": True, "dynamic_ccpg": True},
               {"overlap": 0.5}):
        col, obj = Timeline(columnar=True), Timeline(columnar=False)
        r_col = PicnicSimulator().run(cfg, 384, 64, timeline=col, **kw)
        r_obj = PicnicSimulator(cycle_model=CycleModel(memoize=False)) \
            .run(cfg, 384, 64, timeline=obj, **kw)
        assert _hexdict(r_col) == _hexdict(r_obj)
        assert col.events == obj.events


# ---------------------------------------------------------------------------
# Golden byte-identity with the columnar recorder (and the object one)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("columnar", [True, False])
def test_serving_golden_byte_identical_both_recorders(cfg, columnar):
    """The committed golden (captured from the seed code) is reproduced
    byte-for-byte by BOTH recording modes of the SoA engine."""
    for key in sorted(GOLDEN["serving"]):
        eng = ContinuousBatchingEngine(
            cfg, engine=ServingConfig(max_batch=4, ccpg=(key == "ccpg=True"),
                                     columnar_timeline=columnar))
        rep = eng.run(poisson_trace(24, rate_rps=40, seed=0, prompt_len=256,
                                    max_new=32))
        assert eng.timeline.columnar == columnar
        assert _hexdict(rep) == GOLDEN["serving"][key]


def test_table_ii_golden_byte_identical_columnar():
    for key in sorted(GOLDEN["table_ii"]):
        arch, ctx, cc = key.split("/")
        tl = Timeline(columnar=True)
        r = PicnicSimulator().run(get_config(arch), int(ctx), int(ctx),
                                  ccpg=(cc == "ccpg=True"), timeline=tl)
        assert _hexdict(r) == GOLDEN["table_ii"][key]


# ---------------------------------------------------------------------------
# SoA engine == reference engine on randomized traces
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 20), batch=st.integers(1, 8),
       rate=st.floats(10.0, 200.0), seed=st.integers(0, 99),
       ccpg=st.booleans())
def test_fast_engine_matches_reference_on_poisson(n, batch, rate, seed,
                                                  ccpg):
    cfg = get_config("llama3.2-1b")
    fast, ref = _engine_pair(cfg, max_batch=batch, ccpg=ccpg)
    trace = poisson_trace(n, rate_rps=rate, seed=seed, prompt_len=192,
                          max_new=24)
    r_fast = fast.run(list(trace))
    r_ref = ref.run(list(trace))
    assert _hexdict(r_fast) == _hexdict(r_ref)
    assert r_fast.queue_depth == r_ref.queue_depth
    assert fast.timeline.events == ref.timeline.events
    assert fast.events == ref.events


@settings(max_examples=6, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 99),
       n_blocks=st.integers(24, 60), dram=st.integers(0, 60),
       chunk=st.sampled_from([0, 64]))
def test_fast_engine_matches_reference_paged(n, seed, n_blocks, dram,
                                             chunk):
    """Randomized PAGED traces: identical reports, kv_stats AND engine
    event logs through preemption/spill/chunked-prefill paths."""
    cfg = get_config("llama3.2-1b")
    rng = np.random.default_rng(seed)
    rows = [(float(rng.uniform(0, 0.05)), int(rng.integers(16, 300)),
             int(rng.integers(1, 40))) for _ in range(n)]
    kvc = KVCacheConfig(n_blocks=n_blocks, block_tokens=16,
                        dram_blocks=dram,
                        bytes_per_token=kv_bytes_per_token(cfg))
    kw = dict(max_batch=4, ccpg=True, kv_cache=kvc,
              chunked_prefill_tokens=chunk)
    fast, ref = _engine_pair(cfg, **kw)
    r_fast = fast.run(replay_trace(rows))
    r_ref = ref.run(replay_trace(rows))
    assert _hexdict(r_fast) == _hexdict(r_ref)
    assert fast.kv_stats.row() == ref.kv_stats.row()
    assert fast.events == ref.events
    assert fast.timeline.events == ref.timeline.events


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), n_blocks=st.integers(32, 80),
       dram=st.sampled_from([0, 40]), chunk=st.sampled_from([0, 64]),
       prefix_len=st.sampled_from([96, 120]))
def test_fast_engine_matches_reference_prefix_sharing(seed, n_blocks,
                                                      dram, chunk,
                                                      prefix_len):
    """Randomized PREFIX-SHARING traces (adopt/COW/register on top of
    preemption/spill/chunked prefill): the SoA fast core and the
    reference recorder must stay bit-identical — reports, kv_stats
    (including the new sharing counters) and both event streams."""
    cfg = get_config("llama3.2-1b")
    kvc = KVCacheConfig(n_blocks=n_blocks, block_tokens=16,
                        dram_blocks=dram,
                        bytes_per_token=kv_bytes_per_token(cfg),
                        prefix_sharing=True)
    kw = dict(max_batch=4, ccpg=True, kv_cache=kvc,
              chunked_prefill_tokens=chunk)
    trace = poisson_trace(10, rate_rps=80, seed=seed, prompt_len=160,
                          max_new=24, prefix_len=prefix_len,
                          prefix_frac=0.8, prefix_groups=2)
    fast, ref = _engine_pair(cfg, **kw)
    r_fast = fast.run(list(trace))
    r_ref = ref.run(list(trace))
    assert _hexdict(r_fast) == _hexdict(r_ref)
    assert fast.kv_stats.row() == ref.kv_stats.row()
    assert fast.events == ref.events
    assert fast.timeline.events == ref.timeline.events


def test_fast_engine_matches_reference_with_deadlines(cfg):
    rows = [(0.0, 256, 64), (0.01, 64, 8, 0.02), (0.02, 32, 4, None),
            (0.03, 128, 16, 0.5)]
    fast, ref = _engine_pair(cfg, max_batch=2, decode_quantum=64)
    r_fast = fast.run(replay_trace(rows))
    r_ref = ref.run(replay_trace(rows))
    assert _hexdict(r_fast) == _hexdict(r_ref)
    assert fast.events == ref.events


# ---------------------------------------------------------------------------
# Memoized CycleModel == direct walk
# ---------------------------------------------------------------------------

def test_memoized_decode_costs_match_direct_walk(cfg):
    alloc = allocate_chiplets(cfg)
    memo, direct = CycleModel(), CycleModel(memoize=False)
    rng = np.random.default_rng(0)
    for _ in range(40):
        b = int(rng.integers(1, 12))
        contexts = [int(rng.integers(1, 4096)) for _ in range(b)]
        for overlap in (0.0, 0.37):
            assert memo.batched_token_decode_cycles(
                cfg, alloc, contexts, overlap=overlap) \
                == direct.batched_token_decode_cycles(
                    cfg, alloc, contexts, overlap=overlap)
    assert memo.batched_token_decode_cycles(cfg, alloc, []) == (0, 0)


def test_memoized_prefill_costs_match_direct_walk(cfg):
    alloc = allocate_chiplets(cfg)
    memo, direct = CycleModel(), CycleModel(memoize=False)
    for chunk, before in [(1, 0), (512, 0), (512, 512), (100, 3),
                          (2048, 0), (64, 8192)]:
        for _ in range(2):      # second call = cache hit
            assert memo.prefill_chunk_cycles(cfg, alloc, chunk, before) \
                == direct.prefill_chunk_cycles(cfg, alloc, chunk, before)
    assert memo.prefill_cycles(cfg, alloc, 777) \
        == direct.prefill_cycles(cfg, alloc, 777)


def test_calibration_mutation_invalidates_memo(cfg):
    """Mutating any calibrated constant (calibrate() does this to alpha)
    must never serve a stale cached cost."""
    alloc = allocate_chiplets(cfg)
    cm = CycleModel()
    before = cm.batched_token_decode_cycles(cfg, alloc, [512] * 4)
    p_before = cm.prefill_cycles(cfg, alloc, 512)
    cm.alpha = 0.5
    cm.ctx_cycles_per_pos = 100.0
    after = cm.batched_token_decode_cycles(cfg, alloc, [512] * 4)
    p_after = cm.prefill_cycles(cfg, alloc, 512)
    fresh = CycleModel(alpha=0.5, ctx_cycles_per_pos=100.0,
                       memoize=False)
    assert after == fresh.batched_token_decode_cycles(cfg, alloc, [512] * 4)
    assert p_after == fresh.prefill_cycles(cfg, alloc, 512)
    assert after != before and p_after != p_before


def test_nonaffine_subclass_falls_back_to_walk(cfg):
    """A subclass whose per-layer cost is NOT affine in ctx_sum must be
    detected by the cache-fill probes and served by the direct walk."""
    class Quadratic(CycleModel):
        def layer_decode_cycles_batched(self, ld, ctx_sum, b):
            base = super().layer_decode_cycles_batched(ld, ctx_sum, b)
            if ld.kind == "attn":
                base += int(0.001 * ctx_sum * ctx_sum)
            return base

    alloc = allocate_chiplets(cfg)
    memo, direct = Quadratic(), Quadratic(memoize=False)
    for ctxs in ([100], [512, 2048], [7, 7, 7, 7]):
        assert memo.batched_token_decode_cycles(cfg, alloc, ctxs) \
            == direct.batched_token_decode_cycles(cfg, alloc, ctxs)
    assert memo.decode_affine(cfg, alloc, 2) is None


def test_engine_fallback_hands_subclass_real_contexts(cfg):
    """A CycleModel subclass may legitimately ITERATE the contexts
    sequence (the documented signature).  The engine's non-affine
    fallback must hand it the real per-request values — reconstructed
    from the SoA offsets, exactly matching the request objects'
    contexts at that round."""
    seen = []

    class PerRequest(CycleModel):
        def layer_decode_cycles_batched(self, ld, ctx_sum, b):
            base = super().layer_decode_cycles_batched(ld, ctx_sum, b)
            return base + (7 if ld.kind == "attn" else 0) * b * b

        def batched_token_decode_cycles_split(self, cfg_, alloc, contexts):
            contexts = [int(c) for c in contexts]      # iterates!
            seen.append(tuple(contexts))
            return super().batched_token_decode_cycles_split(
                cfg_, alloc, contexts)

    rows = [(0.0, 40, 12), (0.001, 60, 6), (0.002, 20, 9)]

    def run(cm):
        eng = ContinuousBatchingEngine(
            cfg, sim=PicnicSimulator(cycle_model=cm),
            engine=ServingConfig(max_batch=3, decode_quantum=1))
        return eng.run(replay_trace(rows))

    r_sub = run(PerRequest())                # memoized: probes -> affine?
    assert seen, "subclass walk never saw a contexts sequence"
    # the per-b*b term IS affine in ctx_sum at fixed b, so also pin the
    # memoize=False configuration, which always takes the fallback
    seen.clear()
    r_direct = run(PerRequest(memoize=False))
    assert _hexdict(r_sub) == _hexdict(r_direct)
    # contexts handed to the walk are the true per-request values:
    # strictly positive, and each round's batch sums consistently
    assert all(c > 0 for ctxs in seen for c in ctxs)
    assert any(len(ctxs) > 1 for ctxs in seen)        # batched rounds ran


def test_decode_affine_reproduces_model_exactly(cfg):
    """The affine export the SoA engine inlines == the full model call,
    including a non-unit alpha (the int truncation point)."""
    alloc = allocate_chiplets(cfg)
    for alpha in (1.0, 0.6180339887):
        cm = CycleModel(alpha=alpha)
        for b in (1, 3, 8):
            base, n_attn, c2c_bytes, cpp, a, ver = \
                cm.decode_affine(cfg, alloc, b)
            assert a == alpha and ver == cm._cal_ver
            for ctx_sum in (b, 513, 16384):
                contexts = [ctx_sum // b] * (b - 1) \
                    + [ctx_sum - (ctx_sum // b) * (b - 1)]
                want = cm.batched_token_decode_cycles(cfg, alloc, contexts)
                got = (int((base + n_attn * int(cpp * ctx_sum)) * a),
                       c2c_bytes)
                assert got == want


# ---------------------------------------------------------------------------
# Trace construction: sort-once + monotonic-arrival handling
# ---------------------------------------------------------------------------

def test_replay_trace_sorts_once_at_construction():
    rows = [(0.5, 16, 2), (0.1, 32, 4), (0.3, 8, 1)]
    trace = replay_trace(rows)
    assert [r.arrival for r in trace] == sorted(r[0] for r in rows)
    # ids were assigned in ROW order before sorting (stable identity)
    assert [r.request_id for r in trace] == [1, 2, 0]


def test_run_handles_hand_built_unsorted_trace(cfg):
    from repro.launch.serving_engine import TrackedRequest
    unsorted_trace = [
        TrackedRequest(arrival=0.4, request_id=0, prompt_len=16, max_new=2),
        TrackedRequest(arrival=0.0, request_id=1, prompt_len=16, max_new=2),
    ]
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(max_batch=2))
    rep = eng.run(unsorted_trace)
    assert rep.finished == 2
    prefills = {rid: t for t, k, rid in eng.events if k.value == "prefill"}
    assert prefills[1] <= prefills[0]       # earlier arrival served first


def test_rerun_after_construction_sort_is_idempotent(cfg):
    trace = replay_trace([(0.2, 32, 4), (0.0, 64, 8)])
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(max_batch=2))
    assert eng.run(trace).row() == eng.run(trace).row()


# ---------------------------------------------------------------------------
# Streaming chrome-trace export
# ---------------------------------------------------------------------------

def test_dump_chrome_trace_streams_identical_json(cfg, tmp_path):
    for columnar in (True, False):
        tl = Timeline(columnar=columnar)
        PicnicSimulator().run(cfg, 256, 32, ccpg=True, dynamic_ccpg=True,
                              timeline=tl)
        path = tmp_path / f"trace_{columnar}.json"
        tl.dump_chrome_trace(path)
        streamed = json.loads(path.read_text())
        assert streamed == tl.to_chrome_trace()
        assert len(streamed["traceEvents"]) > tl.n_events  # + metadata


def test_engine_streamed_trace_has_all_categories(cfg, tmp_path):
    eng = ContinuousBatchingEngine(
        cfg, engine=ServingConfig(max_batch=2, ccpg=True, dynamic_ccpg=True))
    eng.run(replay_trace([(0.0, 32, 4), (0.5, 32, 4)]))
    path = tmp_path / "eng.json"
    eng.timeline.save_chrome_trace(path)        # alias of dump_
    d = json.loads(path.read_text())
    cats = {e.get("cat") for e in d["traceEvents"] if e.get("cat")}
    assert {"ComputeSpan", "C2CTransfer", "ClusterWake", "ClusterSleep",
            "EnergySample", "TokenEmit"} <= cats
