"""Data pipeline, checkpointing, optimizer, fault tolerance, straggler,
gradient compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import ByteTokenizer, PackedStream
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm,
                         linear_warmup_cosine)
from repro.runtime import (BackupInputRunner, HeartbeatMonitor,
                           RestartPolicy, StragglerDetector, WorkerState,
                           compress_with_feedback, decompress,
                           init_error_state, plan_elastic_mesh,
                           quantize_int8)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_tokenizer_roundtrip():
    t = ByteTokenizer()
    s = "PICNIC chiplets!"
    assert t.decode(t.encode(s)) == s


def test_packed_stream_shapes_and_determinism():
    a = PackedStream(1000, 64, seed=7)
    b = PackedStream(1000, 64, seed=7)
    ba = a.next_batch(4)
    bb = b.next_batch(4)
    assert ba["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(ba["tokens"][:, 1:], ba["labels"][:, :-1])


def test_packed_stream_resume():
    a = PackedStream(1000, 32, seed=3)
    a.next_batch(8)
    snap = a.snapshot()
    want = a.next_batch(2)
    b = PackedStream(1000, 32, seed=3)
    b.restore(snap)
    got = b.next_batch(2)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_host_sharded_streams_differ():
    a = PackedStream(1000, 32, seed=0, host_id=0)
    b = PackedStream(1000, 32, seed=0, host_id=1)
    assert not np.array_equal(a.next_batch(2)["tokens"],
                              b.next_batch(2)["tokens"])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3),
            "opt": {"m": jnp.ones((4,)), "step": jnp.int32(7)}}
    save(tmp_path, 42, tree, {"lr": 0.1})
    got, extras = restore(tmp_path, tree)
    assert extras["lr"] == 0.1
    np.testing.assert_array_equal(got["w"], tree["w"])
    assert latest_step(tmp_path) == 42


def test_checkpoint_atomicity(tmp_path):
    """An incomplete write (no .complete marker) is invisible."""
    tree = {"w": jnp.ones((2,))}
    p = save(tmp_path, 1, tree)
    (p / ".complete").unlink()
    assert latest_step(tmp_path) is None


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save(tmp_path, 1, {"w": jnp.ones((2,))})
    with pytest.raises(ValueError):
        restore(tmp_path, {"different": jnp.ones((2,))})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (10, 20, 30):
        ck.save(s, {"w": jnp.full((3,), float(s))})
    ck.wait()
    assert latest_step(tmp_path) == 30
    got, _ = restore(tmp_path, {"w": jnp.zeros((3,))})
    np.testing.assert_array_equal(got["w"], np.full((3,), 30.0))
    # gc kept only 2
    steps = [p.name for p in tmp_path.iterdir() if p.name.startswith("step")]
    assert len(steps) == 2


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _rosenbrock_like(params):
    return jnp.sum((params["a"] - 1.5) ** 2) + jnp.sum((params["b"] + 2.0) ** 2)


@pytest.mark.parametrize("init,update", [(adamw_init, adamw_update),
                                         (adafactor_init, adafactor_update)])
def test_optimizer_converges(init, update):
    params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    state = init(params)
    loss0 = float(_rosenbrock_like(params))
    for _ in range(200):
        grads = jax.grad(_rosenbrock_like)(params)
        params, state = update(params, grads, state, lr=5e-2,
                               weight_decay=0.0)
    assert float(_rosenbrock_like(params)) < 0.05 * loss0


def test_grad_clip():
    g = {"x": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) > 1.0
    n2 = jnp.linalg.norm(clipped["x"])
    assert float(n2) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shape():
    lrs = [float(linear_warmup_cosine(jnp.float32(s), base_lr=1.0,
                                      warmup_steps=10, total_steps=100))
           for s in range(0, 100, 5)]
    assert lrs[0] < lrs[1]            # warmup
    assert lrs[-1] < max(lrs)         # decay
    assert max(lrs) <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_state_machine():
    t = [0.0]
    mon = HeartbeatMonitor(3, suspect_s=5, dead_s=10, clock=lambda: t[0])
    t[0] = 6.0
    mon.heartbeat(0)
    mon.sweep()
    assert mon.workers[0].state == WorkerState.HEALTHY
    assert mon.workers[1].state == WorkerState.SUSPECT
    t[0] = 11.0
    mon.heartbeat(0)
    dead = mon.sweep()
    assert set(dead) == {1, 2}
    assert mon.healthy_ids() == [0]
    mon.revive(1)
    assert mon.workers[1].incarnation == 1
    assert 1 in mon.healthy_ids()


def test_restart_policy_budget_and_backoff():
    p = RestartPolicy(max_restarts=3, window_s=100, base_backoff_s=1,
                      max_backoff_s=8)
    now = 0.0
    assert p.should_restart(now)
    for i in range(3):
        p.record_failure(now + i)
    assert not p.should_restart(now + 3)
    assert p.should_restart(now + 200)      # window expired
    assert p.next_backoff(now + 3) <= 8


def test_elastic_mesh_plan():
    shape, axes = plan_elastic_mesh(2)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = plan_elastic_mesh(1)
    assert shape == (16, 16) and axes == ("data", "model")
    with pytest.raises(ValueError):
        plan_elastic_mesh(0)


def test_train_driver_recovers_from_injected_failure(tmp_path):
    """End-to-end: the training driver checkpoints, dies, restarts from
    the checkpoint, and still reaches the target step with improving loss."""
    from repro.launch.train import main
    losses = main(["--arch", "smollm-360m", "--smoke", "--steps", "16",
                   "--batch", "2", "--seq-len", "64", "--save-every", "4",
                   "--ckpt-dir", str(tmp_path), "--simulate-failures", "1",
                   "--log-every", "100"])
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# straggler
# ---------------------------------------------------------------------------

def test_straggler_detection():
    d = StragglerDetector(4, min_samples=3)
    for step in range(6):
        for w in range(4):
            d.record(w, 1.0 if w != 2 else 3.0)
    reps = d.stragglers()
    assert [r.worker_id for r in reps] == [2]
    assert reps[0].slowdown > 2


def test_backup_input_runner_speculates():
    d = StragglerDetector(2, min_samples=2)
    for _ in range(4):
        d.record(0, 1.0)
        d.record(1, 5.0)
    runner = BackupInputRunner(d)
    out = runner.fetch(1, lambda: "primary", lambda: "backup",
                       primary_time=5.0, backup_time=1.0)
    assert out == "backup" and runner.wins_by_backup == 1


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize_int8(x)
    err = jnp.abs(q.astype(jnp.float32) * s - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback the accumulated compressed sum tracks the true
    sum much better than naive quantization."""
    key = jax.random.PRNGKey(1)
    grads = {"w": jax.random.normal(key, (512,)) * 1e-3}
    e = init_error_state(grads)
    acc_fb = jnp.zeros((512,))
    acc_naive = jnp.zeros((512,))
    true = jnp.zeros((512,))
    for i in range(50):
        g = {"w": grads["w"] * (1 + 0.01 * i)}
        true += g["w"]
        qt, e = compress_with_feedback(g, e)
        acc_fb += decompress(qt)["w"]
        qn, _ = compress_with_feedback(g, init_error_state(g))
        acc_naive += decompress(qn)["w"]
    err_fb = float(jnp.linalg.norm(acc_fb - true))
    err_naive = float(jnp.linalg.norm(acc_naive - true))
    assert err_fb < err_naive


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), scale=st.floats(1e-4, 10.0))
def test_compression_roundtrip_property(seed, scale):
    x = {"g": jax.random.normal(jax.random.PRNGKey(seed), (64, 3)) * scale}
    qt, e = compress_with_feedback(x, init_error_state(x))
    deq = decompress(qt)["g"]
    # error bounded by half an int8 step of the max-abs scale
    bound = float(qt["g"]["scale"]) * 0.5 + 1e-9
    assert float(jnp.abs(deq - x["g"]).max()) <= bound * 1.01


def test_noise_resilient_training_converges():
    """Paper §IV: RRAM conductance relaxation is handled by noise-resilient
    training — multiplicative weight noise during the forward pass.
    Training must still converge with noise enabled."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.steps import init_train_state, make_train_step
    cfg = get_smoke_config("smollm-360m")
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    step = jax.jit(make_train_step(cfg, weight_noise_std=0.02,
                                   base_lr=1e-3, warmup=0))
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
