"""Regression tests for the trip-count-aware HLO cost parser — the
methodological backbone of the roofline numbers (EXPERIMENTS.md §Dry-run).

Runs in a subprocess with 4 host devices so the main process keeps its
single-device view."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, {src!r})
        import jax
        import jax.numpy as jnp
    """).format(src=SRC) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-2000:]}"
    return r.stdout


@pytest.mark.slow
def test_scan_flops_counted_with_trip_count():
    run_sub("""
    from repro import compat
    from repro.launch import hlo_cost

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    ws = jax.ShapeDtypeStruct((10, 512, 512), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((64, 512), jnp.bfloat16)
    c = jax.jit(f).lower(ws, x).compile()
    # the raw xla number undercounts by the trip count...
    # (compat normalizes the list-vs-dict cost_analysis return)
    raw = compat.cost_analysis(c)["flops"]
    analytic = 10 * 2 * 64 * 512 * 512
    assert raw < 0.2 * analytic
    # ...the parser does not
    cost = hlo_cost.analyze(c.as_text(), 4)
    assert abs(cost.flops / analytic - 1) < 0.05, cost.flops
    """)


@pytest.mark.slow
def test_collectives_and_tp_flops_exact():
    run_sub("""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo_cost
    mesh = jax.make_mesh((4,), ("model",))

    def g(w, x):
        return x @ w

    w = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((64, 512), jnp.bfloat16)
    fn = jax.jit(g, in_shardings=(NamedSharding(mesh, P("model", None)),
                                  NamedSharding(mesh, P())),
                 out_shardings=NamedSharding(mesh, P()))
    c = fn.lower(w, x).compile()
    cost = hlo_cost.analyze(c.as_text(), 4)
    assert cost.flops == 2 * 64 * 512 * 512 / 4       # per-chip
    assert "all-reduce" in cost.coll
    ar = cost.coll["all-reduce"]
    # ring all-reduce of the (64,512) f32 output: 2*(g-1)/g*bytes
    expect = 2 * (3/4) * 64 * 512 * 4
    assert abs(ar["wire_bytes"] / expect - 1) < 0.05
    """)


@pytest.mark.slow
def test_nested_scan_trip_products():
    run_sub("""
    from repro.launch import hlo_cost

    def f(ws, x):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    ws = jax.ShapeDtypeStruct((6, 256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    c = jax.jit(f).lower(ws, x).compile()
    cost = hlo_cost.analyze(c.as_text(), 4)
    analytic = 6 * 5 * 2 * 32 * 256 * 256
    assert abs(cost.flops / analytic - 1) < 0.1, (cost.flops, analytic)
    """)
