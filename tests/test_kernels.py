"""Per-kernel validation: shape/dtype sweeps + hypothesis, each against the
ref.py pure-jnp oracle (interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.cim_matmul import quantize_weights

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# pwl_softmax (SCU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,n", [(8, 64), (32, 300), (256, 128), (5, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pwl_softmax_shapes(rows, n, dtype):
    x = (jax.random.normal(KEY, (rows, n)) * 3).astype(dtype)
    o = ops.pwl_softmax(x)
    r = ref.ref_pwl_softmax(x)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


def test_pwl_softmax_sums_to_one():
    x = jax.random.normal(KEY, (16, 77)) * 5
    o = ops.pwl_softmax(x)
    np.testing.assert_allclose(np.asarray(o.sum(-1)), 1.0, atol=1e-5)


def test_pwl_exp_error_bound():
    """SCU 8-segment PWL with uniform segments on [-8, 0]: the worst
    segment is [-1, 0] where the secant-with-midpoint-offset fit has
    max error exp-curvature/8 ~= 0.039."""
    from repro.core.scu import max_pwl_exp_error
    assert max_pwl_exp_error() < 0.04


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 64), n=st.integers(2, 257),
       scale=st.floats(0.1, 20))
def test_pwl_softmax_property(rows, n, scale):
    x = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n)) * scale
    o = np.asarray(ops.pwl_softmax(x))
    assert (o >= 0).all()
    np.testing.assert_allclose(o.sum(-1), 1.0, atol=1e-4)
    # PWL softmax approximates the exact one
    ex = np.asarray(ref.ref_softmax(x))
    assert np.abs(o - ex).max() < 0.05


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,hkv,d", [(128, 4, 4, 32), (256, 4, 2, 64),
                                       (128, 8, 1, 128), (384, 2, 2, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_vs_oracle(s, h, hkv, d, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, s, h, d))
    k = jax.random.normal(ks[1], (2, s, hkv, d))
    v = jax.random.normal(ks[2], (2, s, hkv, d))
    o = ops.flash_attention(q, k, v, causal=causal)
    kf = jnp.repeat(k, h // hkv, 2)
    vf = jnp.repeat(v, h // hkv, 2)
    r = ref.ref_flash_attention(q, kf, vf, causal=causal)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(dtype)
    o = ops.flash_attention(q, k, v)
    r = ref.ref_flash_attention(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                 - r.astype(jnp.float32)))) < tol


def test_flash_kernel_pwl_matches_dense_pwl_single_block():
    """With one KV pass per row the kernel's PWL softmax is exactly the
    SCU (dense) semantics; multi-block online rescaling adds a small
    composition error (documented)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    o = ops.flash_attention(q, k, v, use_pwl=True, block_k=128)
    r = ref.ref_pwl_attention(q, k, v)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-4


def test_flash_kernel_nonmultiple_seq_padding():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 200, 2, 32))
    k = jax.random.normal(ks[1], (1, 200, 2, 32))
    v = jax.random.normal(ks[2], (1, 200, 2, 32))
    o = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    r = ref.ref_flash_attention(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-4


# ---------------------------------------------------------------------------
# cim matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(64, 256, 128), (128, 512, 256),
                                   (32, 1024, 64)])
def test_cim_kernel_vs_oracle(m, k, n):
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    wq, ws = quantize_weights(w)
    o = ops.cim_matmul(x, w, block_m=min(64, m), block_n=min(128, n))
    r = ref.ref_cim_matmul(x, wq, ws)
    # NOTE: the kernel's ADC calibration is per (block, tile); the oracle's
    # is per tile over the full M — identical when block_m == M, else the
    # quantization error bound below is the contract.
    ex = ref.ref_exact_matmul(x, w)
    rel = float(jnp.linalg.norm(o - ex) / jnp.linalg.norm(ex))
    assert rel < 0.03, rel


def test_cim_kernel_exact_match_when_unblocked():
    x = jax.random.normal(KEY, (64, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 128)) * 0.05
    wq, ws = quantize_weights(w)
    o = ops.cim_matmul(x, w, block_m=64, block_n=128)
    r = ref.ref_cim_matmul(x, wq, ws)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(adc=st.sampled_from([8, 10, 12, 14]))
def test_cim_adc_bits_monotone(adc):
    """More ADC bits -> lower error vs exact (the calibration story)."""
    x = jax.random.normal(KEY, (32, 512))
    w = jax.random.normal(jax.random.PRNGKey(2), (512, 64)) * 0.05
    ex = ref.ref_exact_matmul(x, w)
    o = ops.cim_matmul(x, w, adc_bits=adc, block_m=32, block_n=64)
    rel = float(jnp.linalg.norm(o - ex) / jnp.linalg.norm(ex))
    o16 = ops.cim_matmul(x, w, adc_bits=16, block_m=32, block_n=64)
    rel16 = float(jnp.linalg.norm(o16 - ex) / jnp.linalg.norm(ex))
    assert rel16 <= rel + 1e-4


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,p,n,chunk", [(128, 2, 32, 16, 32),
                                           (256, 4, 16, 8, 64),
                                           (64, 1, 64, 32, 64)])
def test_ssd_kernel_vs_oracles(s, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (2, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B_ = jax.random.normal(ks[3], (2, s, n)) * 0.3
    C_ = jax.random.normal(ks[4], (2, s, n)) * 0.3
    o = ops.ssd_scan(x, dt, a, B_, C_, chunk=chunk)
    r = ref.ref_ssd(x, dt, a, B_, C_, chunk=chunk)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-4
    r2 = ref.ref_ssd_recurrent(x, dt, a, B_, C_)
    assert float(jnp.max(jnp.abs(o - r2))) < 1e-3


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100))
def test_ssd_kernel_property_random(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    s, h, p, n = 64, 2, 16, 8
    x = jax.random.normal(ks[0], (1, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, s, h)) - 1)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B_ = jax.random.normal(ks[3], (1, s, n)) * 0.5
    C_ = jax.random.normal(ks[4], (1, s, n)) * 0.5
    o = ops.ssd_scan(x, dt, a, B_, C_, chunk=16)
    r = ref.ref_ssd_recurrent(x, dt, a, B_, C_)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-3


# ---------------------------------------------------------------------------
# paged attention over SHARED / COW-forked block tables (ISSUE 6):
# the kernel reads aliased physical blocks through per-request tables
# built by the real sharing allocator — differential vs ref.py per request
# ---------------------------------------------------------------------------

def _tok_kv(tok: int, hkv: int, d: int):
    """Deterministic K/V rows from a token identity: identical tokens
    yield identical KV, which is exactly the contract that makes a
    shared physical block valid for every reader."""
    r = np.random.default_rng(tok % (2 ** 32))
    return r.normal(size=(hkv, d)), r.normal(size=(hkv, d))


def _alloc_shared_case(prompts, *, bt=8, n_blocks=64, dram=0,
                       h=4, hkv=2, d=32, seed=0):
    """Drive the REAL sharing allocator (adopt -> ensure -> register per
    request, in order), then materialize physical caches by writing each
    request's token-derived KV through its own table.  Shared blocks get
    written by several readers — asserting those writes agree IS the
    aliasing check: a request may only share a block whose contents it
    would have produced itself."""
    from repro.runtime.kv_cache import BlockAllocator, KVCacheConfig
    cfg = KVCacheConfig(n_blocks=n_blocks, block_tokens=bt,
                        dram_blocks=dram, bytes_per_token=4,
                        prefix_sharing=True)
    a = BlockAllocator(cfg)
    for rid, toks in enumerate(prompts):
        hs = a.chunk_hashes(toks)
        a.adopt_prefix(rid, toks, hs)
        a.ensure(rid, len(toks))
        a.register_prefix(rid, toks, hs)
    B = len(prompts)
    max_blocks = max(len(a.tables[r].blocks) for r in range(B))
    tables = np.zeros((B, max_blocks), np.int32)
    kc = np.zeros((cfg.total_blocks, bt, hkv, d), np.float32)
    vc = np.zeros((cfg.total_blocks, bt, hkv, d), np.float32)
    writers = {}
    for rid, toks in enumerate(prompts):
        blocks = a.tables[rid].blocks
        tables[rid, :len(blocks)] = blocks
        for i, b in enumerate(blocks):
            for j, tok in enumerate(toks[i * bt:(i + 1) * bt]):
                prev = writers.setdefault((b, j), tok)
                assert prev == tok, \
                    f"aliased block {b}@{j} holds {prev}, reader wants {tok}"
                kc[b, j], vc[b, j] = _tok_kv(tok, hkv, d)
    ctx = np.asarray([len(t) for t in prompts], np.int32)
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, h, d)), jnp.float32)
    return a, q, jnp.asarray(kc), jnp.asarray(vc), tables, ctx


def _private_copy_case(prompts, tables, ctx, *, bt, hkv, d):
    """The same logical caches with NO aliasing: every request gets
    private consecutive blocks holding its own token-derived KV."""
    B = len(prompts)
    nb = [(-(-int(c) // bt)) for c in ctx]
    kc = np.zeros((sum(nb) + 1, bt, hkv, d), np.float32)
    vc = np.zeros_like(kc)
    priv = np.zeros_like(tables)
    off = 0
    for rid, toks in enumerate(prompts):
        for i in range(nb[rid]):
            priv[rid, i] = off
            for j, tok in enumerate(toks[i * bt:(i + 1) * bt]):
                kc[off, j], vc[off, j] = _tok_kv(tok, hkv, d)
            off += 1
    return jnp.asarray(kc), jnp.asarray(vc), priv


# the divergence structure the allocator must represent: a long shared
# system prompt, a mid-block COW fork, a fork exactly at a block
# boundary, and a non-sharing stranger — ragged lengths throughout
_SHARED_PROMPTS = [
    [100 + j for j in range(20)],                       # r0: indexes 2 blocks
    [100 + j for j in range(13)] + [-201, -202, -203],  # r1: COW mid-block
    [100 + j for j in range(16)] + [-301, -302],        # r2: boundary fork
    [-400 - j for j in range(9)],                       # r3: no sharing
]


def test_paged_attention_shared_forked_tables_match_oracle():
    a, q, kc, vc, tables, ctx = _alloc_shared_case(_SHARED_PROMPTS)
    assert a.prefix_hits > 0 and a.cow_forks > 0      # case really shares
    assert a.n_shared_blocks > 0
    o = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                            jnp.asarray(ctx))
    r = ref.ref_paged_attention(q, kc, vc, tables, ctx)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5
    # aliased layout == fully private layout: sharing is invisible to
    # attention outputs (the whole point of COW block tables)
    kp, vp, priv = _private_copy_case(_SHARED_PROMPTS, tables, ctx,
                                      bt=8, hkv=2, d=32)
    op = ops.paged_attention(q, kp, vp, jnp.asarray(priv),
                             jnp.asarray(ctx))
    assert float(jnp.max(jnp.abs(o - op))) < 1e-5


def test_paged_attention_gqa_over_shared_tables():
    a, q, kc, vc, tables, ctx = _alloc_shared_case(
        _SHARED_PROMPTS, h=8, hkv=1, d=16, seed=3)
    o = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                            jnp.asarray(ctx))
    r = ref.ref_paged_attention(q, kc, vc, tables, ctx)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5


def test_paged_attention_spilled_shared_tables_match_oracle():
    """Shared blocks re-tiered to the DRAM id range mid-adoption: the
    tables mix scratch and DRAM physical ids, outputs unchanged."""
    a, q, kc, vc, tables, ctx = _alloc_shared_case(
        _SHARED_PROMPTS, n_blocks=4, dram=8, seed=5)
    assert a.spilled_blocks > 0                       # re-tiering happened
    assert tables.max() >= 4                          # DRAM ids in tables
    o = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                            jnp.asarray(ctx))
    r = ref.ref_paged_attention(q, kc, vc, tables, ctx)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5


def test_paged_attention_poisoned_stale_shared_block():
    """Free one reader of every shared block, poison every FREED
    physical block with NaN: the survivors' outputs must not change —
    no table may still point at a released block."""
    a, q, kc, vc, tables, ctx = _alloc_shared_case(_SHARED_PROMPTS)
    keep = np.asarray([1, 2, 3])
    before = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                                 jnp.asarray(ctx))[keep]
    a.free(0)                                         # r0 leaves
    freed = set(a._free_scratch) | set(a._free_dram)
    live = {b for rid in keep for b in a.tables[rid].blocks}
    assert freed and not (freed & live)
    for b in freed:
        kc = kc.at[b].set(jnp.nan)
        vc = vc.at[b].set(jnp.nan)
    after = ops.paged_attention(q[keep], kc, vc,
                                jnp.asarray(tables[keep]),
                                jnp.asarray(ctx[keep]))
    assert bool(jnp.all(before == after))
    assert not bool(jnp.any(jnp.isnan(after)))
