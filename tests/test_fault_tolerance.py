"""runtime/fault_tolerance.py + runtime/straggler.py unit coverage
(ISSUE 10 satellite).

Locks the primitives the fleet fault layer is built on:

  * HeartbeatMonitor state machine on an INJECTED clock — suspect/dead
    thresholds, revive incarnation bumps, and the no-wall-clock
    contract (a missing ``clock=`` is a TypeError, not a silent
    ``time.time`` fallback that would leak real time into a DES run);
  * RestartPolicy exponential backoff monotonicity + window'd failure
    budget, all on explicit ``now`` arguments;
  * plan_elastic_mesh shapes and the no-healthy-pods error;
  * StragglerDetector EWMA arithmetic and median-relative flagging,
    plus BackupInputRunner speculative-fetch wins.
"""
import math

import pytest

from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           RestartPolicy,
                                           TrainingSupervisor,
                                           WorkerFailure, WorkerState,
                                           plan_elastic_mesh)
from repro.runtime.straggler import BackupInputRunner, StragglerDetector


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------

def test_monitor_requires_injected_clock():
    with pytest.raises(TypeError):
        HeartbeatMonitor(2)                     # no clock: no fallback
    with pytest.raises(TypeError):
        HeartbeatMonitor(2, 1.0, 2.0, lambda: 0.0)   # clock is kw-only


def test_monitor_suspect_then_dead_then_revive():
    t = [0.0]
    mon = HeartbeatMonitor(3, suspect_s=5.0, dead_s=10.0,
                           clock=lambda: t[0])
    assert mon.healthy_ids() == [0, 1, 2]

    t[0] = 6.0
    mon.heartbeat(1)            # only worker 1 phones home
    mon.heartbeat(2)
    assert mon.sweep() == []    # 0 is suspect, nobody dead yet
    assert mon.workers[0].state is WorkerState.SUSPECT
    assert mon.healthy_ids() == [1, 2]

    t[0] = 11.0
    mon.heartbeat(2)
    assert mon.sweep() == [0]   # crossed dead_s exactly at the gap
    assert mon.workers[0].state is WorkerState.DEAD
    # a DEAD worker's heartbeat does NOT resurrect it — revive only
    mon.heartbeat(0)
    assert mon.workers[0].state is WorkerState.DEAD
    assert mon.sweep() == []    # newly-dead reported exactly once

    inc = mon.workers[0].incarnation
    mon.revive(0)
    assert mon.workers[0].state is WorkerState.HEALTHY
    assert mon.workers[0].incarnation == inc + 1
    assert mon.workers[0].last_heartbeat == t[0]


def test_monitor_runs_on_des_clock_without_wall_time():
    """The whole lifecycle at simulated times far from wall time — if
    any code path consulted time.time() the states would be wrong."""
    t = [1e-3]
    mon = HeartbeatMonitor(2, suspect_s=1e-3, dead_s=2e-3,
                           clock=lambda: t[0])
    t[0] = 3.5e-3
    mon.heartbeat(1)
    assert mon.sweep() == [0]
    assert mon.healthy_ids() == [1]


# ---------------------------------------------------------------------------
# RestartPolicy
# ---------------------------------------------------------------------------

def test_backoff_monotone_and_capped():
    p = RestartPolicy(base_backoff_s=1.0, max_backoff_s=16.0,
                      window_s=3600.0)
    backoffs = []
    now = 100.0
    for k in range(8):
        backoffs.append(p.next_backoff(now + k))
        p.record_failure(now + k)
    # empty history -> base; then doubles per recent failure, capped
    assert backoffs[0] == 1.0
    assert all(b2 >= b1 for b1, b2 in zip(backoffs, backoffs[1:]))
    assert backoffs[-1] == 16.0
    assert max(backoffs) <= 16.0


def test_backoff_window_forgets_old_failures():
    p = RestartPolicy(base_backoff_s=1.0, max_backoff_s=300.0,
                      window_s=10.0)
    p.record_failure(0.0)
    p.record_failure(1.0)
    assert p.next_backoff(2.0) == 4.0        # 2 recent -> base * 2**2
    assert p.next_backoff(100.0) == 1.0      # both aged out


def test_restart_budget_window():
    p = RestartPolicy(max_restarts=2, window_s=10.0)
    assert p.should_restart(0.0)
    p.record_failure(0.0)
    p.record_failure(1.0)
    assert not p.should_restart(2.0)         # budget consumed
    assert p.should_restart(20.0)            # window slid past both
    # should_restart also PRUNES aged history
    assert p.history == []


def test_policy_methods_require_explicit_now():
    p = RestartPolicy()
    with pytest.raises(TypeError):
        p.should_restart()
    with pytest.raises(TypeError):
        p.next_backoff()
    with pytest.raises(TypeError):
        p.record_failure()


# ---------------------------------------------------------------------------
# plan_elastic_mesh
# ---------------------------------------------------------------------------

def test_elastic_mesh_shapes():
    assert plan_elastic_mesh(2, 256, 16) == ((2, 16, 16),
                                             ("pod", "data", "model"))
    assert plan_elastic_mesh(1, 256, 16) == ((16, 16), ("data", "model"))
    # the model axis survives any shrink; data axis follows chips/pod
    shape, axes = plan_elastic_mesh(5, 128, 8)
    assert shape == (5, 16, 8) and axes[-1] == "model"
    with pytest.raises(ValueError):
        plan_elastic_mesh(0)


# ---------------------------------------------------------------------------
# TrainingSupervisor on an injected clock
# ---------------------------------------------------------------------------

class _Ckpt:
    def __init__(self):
        self.saved = []

    def save(self, step, state, meta):
        self.saved.append(step)


def test_supervisor_restarts_on_injected_clock():
    t = [0.0]
    policy = RestartPolicy(max_restarts=3, window_s=100.0)
    sup = TrainingSupervisor(policy, save_every=2, checkpointer=_Ckpt(),
                             clock=lambda: t[0])
    fails = {3: True}

    def run_step(state, batch):
        step = state["step"]
        if fails.pop(step, False):
            raise WorkerFailure(0, "injected")
        state["step"] += 1
        return state, {}

    def make_batch(step):
        return step

    def restore_fn():
        return {"step": 2}, 2

    state = {"step": 0}

    def wrapped(state, batch):
        t[0] += 1.0
        return run_step(state, batch)

    out, step = sup.run(state, 0, 5, wrapped, make_batch, restore_fn)
    assert step == 5 and sup.restarts == 1
    assert policy.history == [4.0]           # stamped at the DES clock


def test_supervisor_budget_exhaustion_raises():
    policy = RestartPolicy(max_restarts=1, window_s=100.0)
    sup = TrainingSupervisor(policy, save_every=100, checkpointer=_Ckpt(),
                             clock=lambda: 0.0)

    def run_step(state, batch):
        raise WorkerFailure(0)

    with pytest.raises(RuntimeError, match="budget exhausted"):
        sup.run({}, 0, 5, run_step, lambda s: s, lambda: ({}, 0))


# ---------------------------------------------------------------------------
# StragglerDetector / BackupInputRunner
# ---------------------------------------------------------------------------

def test_straggler_ewma_arithmetic():
    det = StragglerDetector(2, alpha=0.5, min_samples=1)
    det.record(0, 1.0)
    assert det.ewma[0] == 1.0                # first sample verbatim
    det.record(0, 3.0)
    assert det.ewma[0] == (1 - 0.5) * 1.0 + 0.5 * 3.0
    assert det.ewma[1] is None


def test_straggler_flagging_is_median_relative():
    det = StragglerDetector(4, alpha=1.0, threshold=1.5, min_samples=2)
    for _ in range(2):
        det.record(0, 1.0)
        det.record(1, 1.0)
        det.record(2, 1.0)
        det.record(3, 4.0)
    out = det.stragglers()
    assert [r.worker_id for r in out] == [3]
    assert out[0].fleet_median_s == 1.0
    assert out[0].slowdown == pytest.approx(4.0)
    # under min_samples: never flagged even if slow
    det2 = StragglerDetector(2, alpha=1.0, threshold=1.5, min_samples=5)
    det2.record(0, 1.0)
    det2.record(1, 50.0)
    assert det2.stragglers() == []


def test_backup_runner_speculates_only_for_stragglers():
    det = StragglerDetector(2, alpha=1.0, threshold=1.5, min_samples=1)
    runner = BackupInputRunner(det, n_spares=1)
    # prime: worker 1 is 10x slower than the median
    for _ in range(2):
        runner.fetch(0, lambda: "p0", primary_time=1.0)
        runner.fetch(1, lambda: "p1", primary_time=10.0)
    assert runner.speculated == 0            # no backup_fn offered yet
    got = runner.fetch(1, lambda: "primary", backup_fn=lambda: "backup",
                       primary_time=10.0, backup_time=2.0)
    assert got == "backup"
    assert runner.speculated == 1 and runner.wins_by_backup == 1
    # healthy worker never speculates
    got = runner.fetch(0, lambda: "primary", backup_fn=lambda: "backup",
                       primary_time=1.0, backup_time=0.1)
    assert got == "primary"
    assert runner.speculated == 1
