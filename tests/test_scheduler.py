"""Serving scheduler: admission, interleave policy, starvation freedom."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.launch.scheduler import (ContinuousBatchScheduler, CostModel,
                                    EventKind, Request, SchedulerConfig,
                                    ttft_of)


def _mk(i, arrival=0.0, prompt=16, max_new=8, ttft=None):
    return Request(arrival=arrival, request_id=i, prompt_len=prompt,
                   max_new=max_new, deadline_ttft=ttft)


def test_all_requests_finish():
    s = ContinuousBatchScheduler(SchedulerConfig(max_slots=2))
    reqs = [_mk(i) for i in range(6)]
    for r in reqs:
        assert s.submit(r)
    m = s.run_until_drained()
    assert m["finished"] == 6
    assert m["rejected"] == 0
    assert all(r.finished_at is not None for r in reqs)


def test_queue_limit_rejects():
    s = ContinuousBatchScheduler(SchedulerConfig(max_slots=1, queue_limit=2))
    ok = [s.submit(_mk(i)) for i in range(5)]
    assert ok == [True, True, False, False, False]
    assert s.rejected == 3


def test_decode_quantum_limits_prefill_rate():
    """With full slots worth of work, at most one prefill per quantum of
    decode rounds (running streams are not starved)."""
    s = ContinuousBatchScheduler(
        SchedulerConfig(max_slots=4, decode_quantum=4),
        CostModel(decode_round_s=0.01))
    for i in range(12):
        s.submit(_mk(i, max_new=32))
    kinds = [s.step() for _ in range(60)]
    # no two consecutive prefills once streams are running
    ran = False
    for a, b in zip(kinds, kinds[1:]):
        if a == EventKind.DECODE:
            ran = True
        if ran and a == EventKind.PREFILL:
            assert b == EventKind.DECODE or b == EventKind.PREFILL and \
                not any(x == EventKind.DECODE for x in kinds[:kinds.index(b)])
    # overall mix contains both kinds
    assert EventKind.PREFILL in kinds and EventKind.DECODE in kinds


def test_ttft_deadline_forces_admission():
    """A request with a tight TTFT deadline jumps the decode quantum."""
    cost = CostModel(decode_round_s=0.01, prefill_fixed_s=0.001,
                     prefill_s_per_token=0.0001)
    s = ContinuousBatchScheduler(
        SchedulerConfig(max_slots=4, decode_quantum=100), cost)
    s.submit(_mk(0, max_new=64))
    s.step()                      # prefill request 0
    s.submit(_mk(1, ttft=0.05, prompt=8, max_new=4))
    kinds = []
    for _ in range(30):
        kinds.append(s.step())
        if s.slots[1] is not None or any(
                k == EventKind.PREFILL for k in kinds[1:]):
            break
    reqs = [r for r in [s.slots[1]] if r]
    # request 1 got admitted well before 100 decode rounds
    assert EventKind.PREFILL in kinds
    ttfts = ttft_of(s, [_r for _r in ([s.slots[1]] if s.slots[1] else [])])
    for v in ttfts.values():
        assert v <= 0.06


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), slots=st.integers(1, 8),
       quantum=st.integers(1, 8), seed=st.integers(0, 99))
def test_no_starvation_property(n, slots, quantum, seed):
    """Every submitted request eventually finishes, regardless of load,
    slot count or quantum (starvation-freedom of the deficit policy)."""
    rng = np.random.default_rng(seed)
    s = ContinuousBatchScheduler(
        SchedulerConfig(max_slots=slots, queue_limit=1000,
                        decode_quantum=quantum))
    reqs = [_mk(i, arrival=float(rng.uniform(0, 0.1)),
                prompt=int(rng.integers(1, 64)),
                max_new=int(rng.integers(1, 16))) for i in range(n)]
    for r in sorted(reqs):
        s.submit(r)
    m = s.run_until_drained()
    assert m["finished"] == n
    assert all(r.finished_at is not None for r in reqs)


def test_cost_model_from_roofline():
    """The decode-round cost can be taken straight from the dry-run
    roofline artifact of the matching cell."""
    import json
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    f = art / "yi-34b__decode_32k__pod1__picnic.json"
    if not f.exists():
        pytest.skip("dry-run artifacts absent")
    rec = json.loads(f.read_text())
    step_s = max(rec["roofline"].values())
    cm = CostModel(decode_round_s=step_s)
    s = ContinuousBatchScheduler(SchedulerConfig(max_slots=4), cm)
    for i in range(4):
        s.submit(_mk(i, max_new=4))
    m = s.run_until_drained()
    assert m["finished"] == 4
    # 4 streams x 4 tokens at ~10.4ms/round + prefill
    assert m["clock_s"] < 1.0
