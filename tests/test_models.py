import dataclasses

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import models
from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models.attention import flash_attention, full_attention

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.n_prefix_tokens:
        kw["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_tokens, cfg.d_model)) * 0.1
    if cfg.is_encoder_decoder:
        kw["encoder_embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return toks, kw


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_smoke(arch):
    """Reduced config of each family: one forward on CPU, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    params = models.init_params(cfg, KEY)
    toks, kw = _inputs(cfg)
    logits, aux, _ = models.forward(cfg, params, toks, **kw)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()
    assert not jnp.isinf(logits.astype(jnp.float32)).any()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    """One gradient step per arch: finite loss and grads."""
    from repro.launch.steps import make_train_step, init_train_state
    cfg = get_smoke_config(arch)
    params, opt_state = init_train_state(cfg, KEY)
    toks, kw = _inputs(cfg)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1), **kw}
    step = make_train_step(cfg)
    params, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["smollm-360m", "olmo-1b", "mixtral-8x7b",
                                  "mamba2-2.7b", "zamba2-2.7b",
                                  "whisper-large-v3", "paligemma-3b",
                                  "llama4-maverick-400b-a17b", "yi-34b",
                                  "mistral-nemo-12b"])
def test_decode_matches_forward(arch):
    """prefill(S-1) + decode(1) == forward(S) for the last token."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = models.init_params(cfg, KEY)
    B, S = 2, 24
    toks, kw = _inputs(cfg, B, S)
    prefix = cfg.n_prefix_tokens
    full, _, _ = models.forward(cfg, params, toks, **kw)
    _, _, cache = models.forward(cfg, params, toks[:, :S - 1],
                                 collect_cache=True,
                                 kv_max=S + prefix + 4, **kw)
    lg, _ = models.decode_step(cfg, params, toks[:, S - 1:S], cache,
                               jnp.int32(S + prefix))
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, -1])))
    rel = err / (float(jnp.max(jnp.abs(full[:, -1]))) + 1e-9)
    assert rel < 1e-3, f"{arch}: rel {rel}"


def test_multi_token_greedy_decode_stable():
    """8 decode steps produce valid tokens and a growing cache."""
    cfg = get_smoke_config("smollm-360m")
    params = models.init_params(cfg, KEY)
    toks, _ = _inputs(cfg, 2, 8)
    logits, _, cache = models.forward(cfg, params, toks, collect_cache=True,
                                      kv_max=32)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i in range(8):
        logits, cache = models.decode_step(cfg, params, tok, cache,
                                           jnp.int32(9 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert ((tok >= 0) & (tok < cfg.vocab_size)).all()


# ---------------------------------------------------------------------------
# flash attention properties
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    s=st.integers(3, 65),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 3]),
    d=st.sampled_from([8, 32]),
    causal=st.booleans(),
    qc=st.sampled_from([16, 32]),
    kc=st.sampled_from([16, 48]),
)
def test_flash_equals_full_property(b, s, hkv, g, d, causal, qc, kc):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s * 7 + d), 3)
    q = jax.random.normal(k1, (b, s, hkv * g, d))
    k = jax.random.normal(k2, (b, s, hkv, d))
    v = jax.random.normal(k3, (b, s, hkv, d))
    o1 = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    o2 = full_attention(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-4


@settings(max_examples=8, deadline=None)
@given(w=st.sampled_from([4, 16, 63]), s=st.integers(8, 96))
def test_flash_sliding_window_property(w, s):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(w * 131 + s), 3)
    q = jax.random.normal(k1, (1, s, 2, 16))
    k = jax.random.normal(k2, (1, s, 2, 16))
    v = jax.random.normal(k3, (1, s, 2, 16))
    o1 = flash_attention(q, k, v, causal=True, window=w, q_chunk=32,
                         kv_chunk=16)
    o2 = full_attention(q, k, v, causal=True, window=w)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-4


def test_attention_is_permutation_equivariant_over_batch():
    q = jax.random.normal(KEY, (4, 16, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 2, 8))
    perm = jnp.array([2, 0, 3, 1])
    o = flash_attention(q, k, v)
    op = flash_attention(q[perm], k[perm], v[perm])
    assert jnp.allclose(o[perm], op, atol=1e-5)


def test_causality():
    """Changing future tokens must not change past logits."""
    cfg = get_smoke_config("smollm-360m")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = models.init_params(cfg, KEY)
    toks, _ = _inputs(cfg, 1, 16)
    l1, _, _ = models.forward(cfg, params, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 7) % cfg.vocab_size)
    l2, _, _ = models.forward(cfg, params, toks2)
    assert jnp.allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (associativity)."""
    from repro.models.ssm import ssd_chunked
    b, S, H, P, N = 1, 64, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    B_ = jax.random.normal(ks[3], (b, S, N)) * 0.3
    C_ = jax.random.normal(ks[4], (b, S, N)) * 0.3
    y8, _ = ssd_chunked(x, dt, a, B_, C_, 8)
    y64, _ = ssd_chunked(x, dt, a, B_, C_, 64)
    assert float(jnp.max(jnp.abs(y8 - y64))) < 1e-4


def test_moe_dense_path_matches_dispatch():
    """The tiny-token dense-experts path (used at decode) must equal the
    capacity-dispatch path exactly (no drops possible at these sizes)."""
    import repro.models.moe as X
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = X.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model)) * 0.3
    y_dense, aux1 = X.moe_sublayer(cfg, p, x)
    thr = X.DENSE_TOKEN_THRESHOLD
    try:
        X.DENSE_TOKEN_THRESHOLD = 0
        y_disp, aux2 = X.moe_sublayer(cfg, p, x)
    finally:
        X.DENSE_TOKEN_THRESHOLD = thr
    assert float(jnp.max(jnp.abs(y_dense - y_disp))) < 1e-4
    assert float(jnp.abs(aux1 - aux2)) < 1e-6


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and skewed routing some tokens drop; the output for
    dropped tokens must be zero (not garbage)."""
    import repro.models.moe as X
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    p = X.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.d_model))
    y, _ = X.moe_sublayer(cfg, p, x)
    assert not jnp.isnan(y).any()
    assert jnp.isfinite(y).all()
