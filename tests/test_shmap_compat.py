"""Shim coverage (ISSUE 2 satellite): kwarg translation across the JAX
shard_map API generations, and the cost_analysis list-vs-dict normalizer.

Fast lane — no subprocesses, no multi-device meshes, no slow marker."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.sharding import shmap

AXES = ("pod", "data", "model")
LEGACY = frozenset({"f", "mesh", "in_specs", "out_specs",
                    "check_rep", "auto"})
MODERN = frozenset({"f", "mesh", "in_specs", "out_specs",
                    "check_vma", "axis_names"})


# ---------------------------------------------------------------------------
# kwarg translation
# ---------------------------------------------------------------------------

def test_check_vma_maps_to_check_rep_on_legacy():
    kw = compat.translate_shard_map_kwargs(LEGACY, AXES, check_vma=False)
    assert kw == {"check_rep": False}


def test_check_rep_alias_accepted_on_modern():
    kw = compat.translate_shard_map_kwargs(MODERN, AXES, check_rep=False)
    assert kw == {"check_vma": False}


def test_check_flag_omitted_when_unset():
    assert compat.translate_shard_map_kwargs(LEGACY, AXES) == {}


def test_conflicting_check_flags_raise():
    with pytest.raises(ValueError):
        compat.translate_shard_map_kwargs(LEGACY, AXES, check_vma=True,
                                          check_rep=False)


def test_axis_names_complemented_into_auto_on_legacy():
    kw = compat.translate_shard_map_kwargs(
        LEGACY, AXES, axis_names=frozenset({"pod"}))
    assert kw == {"auto": frozenset({"data", "model"})}


def test_auto_complemented_into_axis_names_on_modern():
    kw = compat.translate_shard_map_kwargs(
        MODERN, AXES, auto=frozenset({"data", "model"}))
    assert kw == {"axis_names": frozenset({"pod"})}


def test_fully_manual_passes_no_partial_kwarg():
    kw = compat.translate_shard_map_kwargs(
        LEGACY, AXES, axis_names=frozenset(AXES))
    assert kw == {}


def test_non_partitioning_axis_sets_raise():
    with pytest.raises(ValueError):
        compat.translate_shard_map_kwargs(
            LEGACY, AXES, axis_names=frozenset({"pod"}),
            auto=frozenset({"pod", "data"}))


def test_partial_manual_unsupported_signature_raises():
    bare = frozenset({"f", "mesh", "in_specs", "out_specs"})
    with pytest.raises(NotImplementedError):
        compat.translate_shard_map_kwargs(
            bare, AXES, axis_names=frozenset({"pod"}))


# ---------------------------------------------------------------------------
# shim -> native plumbing (mocked native fn)
# ---------------------------------------------------------------------------

class _FakeMesh:
    axis_names = ("data", "model")


def test_shim_translates_for_this_jax(monkeypatch):
    seen = {}

    def fake_native(f, *, mesh, in_specs, out_specs, check_rep=True,
                    auto=frozenset()):
        seen.update(mesh=mesh, check_rep=check_rep, auto=auto)
        return f

    monkeypatch.setattr(compat, "resolve_shard_map", lambda: fake_native)
    out = shmap.shard_map(lambda x: x, mesh=_FakeMesh(), in_specs=P(),
                          out_specs=P(), check_vma=False,
                          axis_names=frozenset({"model"}))
    assert out(3) == 3
    assert seen["check_rep"] is False
    assert seen["auto"] == frozenset({"data"})


def test_resolve_shard_map_finds_a_callable():
    fn = compat.resolve_shard_map()
    assert callable(fn)
    names = compat.shard_map_param_names(fn)
    # every supported JAX spells one of each pair
    assert {"check_rep", "check_vma"} & names
    assert {"auto", "axis_names"} & names


def test_shim_runs_on_single_device_mesh():
    mesh = jax.make_mesh((1,), ("model",))
    fn = shmap.shard_map(
        lambda x: jax.lax.psum(x, "model"), mesh=mesh,
        in_specs=P(), out_specs=P(), check_vma=False)
    assert float(jax.jit(fn)(jnp.float32(2.0))) == 2.0


# ---------------------------------------------------------------------------
# cost_analysis normalizer
# ---------------------------------------------------------------------------

class _FakeCompiled:
    def __init__(self, ret):
        self._ret = ret

    def cost_analysis(self):
        return self._ret


def test_cost_analysis_list_shape():
    c = compat.cost_analysis(_FakeCompiled([{"flops": 5.0, "bytes": 2.0}]))
    assert c["flops"] == 5.0 and c["bytes"] == 2.0


def test_cost_analysis_dict_shape():
    assert compat.cost_analysis(_FakeCompiled({"flops": 7.0}))["flops"] == 7.0


def test_cost_analysis_none_and_empty():
    assert compat.cost_analysis(_FakeCompiled(None)) == {}
    assert compat.cost_analysis(_FakeCompiled([])) == {}


def test_cost_analysis_merges_multi_program():
    c = compat.cost_analysis(
        _FakeCompiled([{"flops": 5.0}, {"flops": 3.0, "bytes": 1.0}]))
    assert c["flops"] == 8.0 and c["bytes"] == 1.0


def test_cost_analysis_on_real_compiled():
    f = jax.jit(lambda x: x @ x)
    c = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    d = compat.cost_analysis(c)
    assert isinstance(d, dict) and d.get("flops", 0) > 0


# ---------------------------------------------------------------------------
# XLA_FLAGS helper
# ---------------------------------------------------------------------------

def test_force_host_devices_appends(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")
    compat.force_host_devices(8)
    import os
    assert os.environ["XLA_FLAGS"] == (
        "--xla_cpu_multi_thread_eigen=false "
        "--xla_force_host_platform_device_count=8")


def test_force_host_devices_respects_existing_count(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=4")
    compat.force_host_devices(8)
    import os
    assert os.environ["XLA_FLAGS"] == \
        "--xla_force_host_platform_device_count=4"


def test_force_host_devices_sets_when_unset(monkeypatch):
    # setenv first so monkeypatch records the pre-test state (delenv on an
    # absent var records nothing and the write below would leak)
    monkeypatch.setenv("XLA_FLAGS", "sentinel")
    monkeypatch.delenv("XLA_FLAGS")
    compat.force_host_devices(8)
    import os
    assert os.environ["XLA_FLAGS"] == \
        "--xla_force_host_platform_device_count=8"
