"""Measured-collectives plumbing (ISSUE 2 tentpole): record -> traffic
conversion and the simulator's measured-vs-analytic C2C flag.

Fast lane: no lowering here (the real capture is exercised by the slow
HLO tests and `benchmarks/run.py distributed`); these tests pin the
contract between capture records, MeasuredTraffic, and the simulator."""
import pytest

from repro.configs import get_smoke_config
from repro.core import MeasuredTraffic, PicnicSimulator
from repro.launch import collective_capture as cc


def _rec(mode, wire_total, batch, coll=None):
    return {"arch": "x", "mode": mode, "seq_len": 512, "batch": batch,
            "mesh": {"data": 1, "model": 8}, "nchips": 8,
            "variant": "picnic", "smoke": True, "compile_s": 0.0,
            "collectives": coll or {}, "wire_bytes_per_chip": wire_total / 8,
            "wire_bytes_total": wire_total, "flops_per_chip": 0.0,
            "xla_flops": 0.0}


def test_parse_mesh():
    assert cc.parse_mesh("2x4") == ((2, 4), ("data", "model"))
    assert cc.parse_mesh("2x2x2") == ((2, 2, 2), ("pod", "data", "model"))
    with pytest.raises(ValueError):
        cc.parse_mesh("8")


def test_subprocess_device_count_follows_mesh(monkeypatch):
    seen = {}

    def fake_run(cmd, **kw):
        seen["flags"] = kw["env"]["XLA_FLAGS"]

        class R:
            returncode = 0
            stdout = "[]"
            stderr = ""
        return R()

    monkeypatch.setattr(cc.subprocess, "run", fake_run)
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    cc.capture_in_subprocess("x", mesh="2x8")
    assert seen["flags"] == "--xla_force_host_platform_device_count=16"

    # other inherited flags survive; a stale device count is replaced
    monkeypatch.setenv("XLA_FLAGS", "--xla_dump_to=/tmp/d "
                       "--xla_force_host_platform_device_count=4")
    cc.capture_in_subprocess("x", mesh="1x8")
    assert seen["flags"] == ("--xla_dump_to=/tmp/d "
                             "--xla_force_host_platform_device_count=8")


def test_importing_capture_module_leaves_device_state_alone():
    # repo convention (launch/mesh.py): imports never touch XLA_FLAGS;
    # the fast lane must keep the real single-device CPU view
    import os
    assert "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", "")


def test_to_measured_traffic_normalizes_per_request():
    coll = {"all-reduce": {"count": 4.0, "bytes": 10.0, "wire_bytes": 8.0}}
    mt = cc.to_measured_traffic(_rec("prefill", 4000.0, batch=4),
                                _rec("decode", 800.0, batch=4, coll=coll))
    assert mt.prefill_bytes == 1000.0
    assert mt.decode_bytes_per_token == 200.0
    assert mt.per_collective["all-reduce"]["wire_bytes"] == 8.0
    assert mt.n_devices == 8 and mt.source.startswith("hlo")


def test_to_measured_traffic_without_prefill():
    mt = cc.to_measured_traffic(None, _rec("decode", 80.0, batch=1))
    assert mt.prefill_bytes == 0.0
    assert mt.decode_bytes_per_token == 80.0


def test_simulator_measured_c2c_flag():
    cfg = get_smoke_config("llama3.2-1b")
    sim = PicnicSimulator()
    base = sim.run(cfg, 128, 128)
    mt = MeasuredTraffic(prefill_bytes=1e6, decode_bytes_per_token=100.0,
                         source="hlo:test")
    meas = sim.run(cfg, 128, 128, measured_c2c=mt)
    # the flag swaps ONLY the traffic term: timing identical, bytes
    # replaced by prefill + per-token * ctx_out, source recorded
    assert meas.throughput_tps == base.throughput_tps
    assert meas.c2c_bytes_total == int(1e6) + 100 * 128
    assert meas.c2c_source == "hlo:test"
    assert meas.c2c_avg_power_W >= base.c2c_avg_power_W


def test_simulator_default_path_untouched():
    cfg = get_smoke_config("llama3.2-1b")
    sim = PicnicSimulator()
    a, b = sim.run(cfg, 128, 128), sim.run(cfg, 128, 128)
    assert a == b
    assert a.c2c_source == "analytic"
