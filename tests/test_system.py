"""End-to-end system behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_training_improves_loss(tmp_path):
    """A short real training run on the synthetic pipeline must reduce
    loss (end-to-end: data -> model -> optimizer -> checkpoints)."""
    from repro.launch.train import main
    losses = main(["--arch", "smollm-360m", "--smoke", "--steps", "20",
                   "--batch", "4", "--seq-len", "128",
                   "--ckpt-dir", str(tmp_path), "--save-every", "10",
                   "--log-every", "100"])
    assert losses[-1] < losses[0] - 0.1


def test_training_resumes_from_checkpoint(tmp_path):
    from repro.launch.train import main
    main(["--arch", "smollm-360m", "--smoke", "--steps", "10",
          "--batch", "2", "--seq-len", "64", "--ckpt-dir", str(tmp_path),
          "--save-every", "5", "--log-every", "100"])
    # second invocation starts from step 10's checkpoint and continues
    losses = main(["--arch", "smollm-360m", "--smoke", "--steps", "14",
                   "--batch", "2", "--seq-len", "64",
                   "--ckpt-dir", str(tmp_path), "--save-every", "5",
                   "--log-every", "100"])
    assert len(losses) == 4          # only steps 11..14 executed


def test_serving_continuous_batching():
    from repro.launch.serve import Server
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("smollm-360m")
    srv = Server(cfg, max_batch=3, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(3):
        assert srv.admit(rid, rng.integers(2, cfg.vocab_size, size=4))
    assert not srv.admit(99, rng.integers(2, cfg.vocab_size, size=4))
    for _ in range(6):
        srv.decode_round()
    assert all(len(s.generated) == 6 for s in srv.slots)


def test_benchmark_harness_runs():
    """Every paper-table benchmark executes and emits its derived value."""
    import benchmarks.run as br
    rows = br.bench_table_ii()
    assert len(rows) == 9
    t3 = br.bench_table_iii()
    assert t3[0]["platform"].startswith("PICNIC")
    t4 = br.bench_table_iv()
    assert "_tile" in t4
    f8 = br.bench_fig8_ccpg()
    assert len(f8) == 3


def test_dryrun_artifacts_complete():
    """The committed dry-run sweep covers all 40 cells x 2 meshes for both
    variants with zero errors."""
    import json
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    recs = [json.loads(f.read_text()) for f in art.glob("*.json")]
    assert not [r for r in recs if r["status"] == "error"]
    base1 = [r for r in recs if r["mesh"] == "pod1"
             and r.get("variant") == "baseline"]
    assert len(base1) == 40
    ok = sum(r["status"] == "ok" for r in base1)
    sk = sum(r["status"] == "skipped" for r in base1)
    assert (ok, sk) == (33, 7)
    # every ok cell has the three roofline terms + dominant
    for r in recs:
        if r["status"] == "ok":
            assert set(r["roofline"]) == {"compute_s", "memory_s",
                                          "collective_s"}
            assert r["dominant"] in ("compute_s", "memory_s",
                                     "collective_s")
