"""Multi-device numerical-equivalence tests.

These spawn subprocesses with ``--xla_force_host_platform_device_count=8``
(the main test process must keep the real single-device CPU view).  Each
subprocess asserts that the sharded/shard_map execution paths produce the
SAME numerics as the single-device reference:

  * picnic decode (sequence-sharded KV + partial-softmax psum) == baseline
  * sp_attention (shard_map ring-lite) == single-device flash
  * sharded train_step loss == unsharded loss
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {src!r})
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8
    """).format(src=SRC) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_picnic_decode_matches_baseline():
    run_sub("""
    import dataclasses
    from jax.sharding import PartitionSpec as P
    from repro import models
    from repro.configs import get_smoke_config
    from repro.sharding import ShardingCtx, use_sharding
    from repro.sharding import specs as sp

    cfg = dataclasses.replace(get_smoke_config("yi-34b"), dtype="float32")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    _, _, cache = models.forward(cfg, params, toks[:, :-1],
                                 collect_cache=True, kv_max=S)
    ref_logits, _ = models.decode_step(cfg, params, toks[:, -1:], cache,
                                       jnp.int32(S))

    rules = sp.activation_rules(cfg, mesh, "decode")
    ctx = ShardingCtx(mesh, rules, {
        "picnic_decode": True, "seq_axes": ("model",), "dp_axes": ("data",)})
    def step(params, cache, tok, n):
        with use_sharding(ctx):
            return models.decode_step(cfg, params, tok, cache, n)
    out, _ = jax.jit(step)(params, cache, toks[:, -1:], jnp.int32(S))
    err = float(jnp.max(jnp.abs(out - ref_logits)))
    rel = err / float(jnp.max(jnp.abs(ref_logits)))
    assert rel < 1e-4, rel
    print("picnic decode rel err", rel)
    """)


@pytest.mark.slow
def test_sp_attention_matches_flash():
    run_sub("""
    from repro.models.attention import flash_attention, sp_flash_attention
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    ref = flash_attention(q, k, v, causal=True)
    out = jax.jit(lambda q, k, v: sp_flash_attention(
        q, k, v, mesh=mesh, dp_axes=("data",), seq_axes=("model",),
        causal=True, q_chunk=8, kv_chunk=16))(q, k, v)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, err
    print("sp attention err", err)

    # sliding window variant
    refw = flash_attention(q, k, v, causal=True, window=24)
    outw = jax.jit(lambda q, k, v: sp_flash_attention(
        q, k, v, mesh=mesh, dp_axes=("data",), seq_axes=("model",),
        causal=True, window=24, q_chunk=8, kv_chunk=16))(q, k, v)
    assert float(jnp.max(jnp.abs(outw - refw))) < 1e-4
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_sub("""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.launch.steps import init_train_state, make_train_step
    from repro.sharding import ShardingCtx, use_sharding
    from repro.sharding import specs as sp

    cfg = dataclasses.replace(get_smoke_config("smollm-360m"),
                              dtype="float32")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    step = make_train_step(cfg)
    _, _, m_ref = jax.jit(step)(params, opt, batch)

    rules = sp.activation_rules(cfg, mesh, "train")
    ctx = ShardingCtx(mesh, rules, {
        "sp_attention": True, "seq_axes": ("model",), "dp_axes": ("data",)})
    params2, opt2 = init_train_state(cfg, jax.random.PRNGKey(0))
    pspecs = sp.param_specs(cfg, jax.eval_shape(lambda: params2), mesh,
                            "train")
    def wrapped(p, o, b):
        with use_sharding(ctx):
            return step(p, o, b)
    fn = jax.jit(wrapped, in_shardings=(sp.to_named(pspecs, mesh),
                                        None, None))
    _, _, m_sh = fn(params2, opt2, batch)
    d = abs(float(m_sh["loss"]) - float(m_ref["loss"]))
    assert d < 2e-3, (float(m_sh["loss"]), float(m_ref["loss"]))
    print("sharded loss delta", d)
    """)


@pytest.mark.slow
def test_compressed_psum_matches_exact():
    run_sub("""
    from repro.runtime import compressed_allreduce
    mesh = jax.make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 1e-3

    out, _ = jax.jit(lambda g, e: compressed_allreduce(
        {"g": g}, {"g": e}, mesh, "data"))(g, jnp.zeros_like(g))
    exact = jnp.sum(g, axis=0, keepdims=True)
    rel = float(jnp.linalg.norm(out["g"][:1] - exact)
                / jnp.linalg.norm(exact))
    assert rel < 0.02, rel
    print("compressed psum rel err", rel)
    """)


@pytest.mark.slow
def test_pipeline_parallel_matches_reference():
    """GPipe-over-pod-axis: pipelined loss == single-device loss, and a
    few PP train steps reduce it (bwd pipeline via shard_map autodiff)."""
    run_sub("""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_loss_fn
    from repro.launch.pipeline import pp_forward, make_pp_train_step
    from repro import models
    from repro.optim import make_optimizer

    cfg = dataclasses.replace(get_smoke_config("smollm-360m"),
                              dtype="float32", n_layers=4, remat=False)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                              cfg.vocab_size)
    ref_loss, _ = make_loss_fn(cfg)(
        params, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)})
    pl, _ = jax.jit(lambda p, t: pp_forward(
        cfg, p, t, mesh=mesh, stage_axis="pod", n_micro=4,
        dp_axes=("data",)))(params, toks)
    assert abs(float(ref_loss) - float(pl)) < 1e-4

    opt_init, _ = make_optimizer(cfg.optimizer)
    step = jax.jit(make_pp_train_step(cfg, mesh, stage_axis="pod",
                                      n_micro=4, base_lr=2e-3, warmup=0,
                                      total_steps=100))
    opt = opt_init(params)
    losses = []
    for _ in range(3):
        params, opt, m = step(params, opt, toks)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    print("pp losses", losses)
    """)
