"""Sharding-spec validity: for every assigned arch x mode, every inferred
PartitionSpec must evenly divide its tensor on the production mesh (a spec
that doesn't divide would fail or silently pad at scale)."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.launch import input_specs as ispec
from repro.optim import make_optimizer
from repro.sharding import specs as sp


class FakeMesh:
    """Shape-only stand-in for the 16x16 / 2x16x16 production meshes (the
    spec engine only reads mesh.shape)."""
    def __init__(self, shape: dict):
        self.shape = shape


POD1 = FakeMesh({"data": 16, "model": 16})
POD2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divides(tree_shapes, tree_specs, mesh, what):
    flat_sh = jax.tree_util.tree_leaves(tree_shapes)
    flat_sp = jax.tree_util.tree_leaves(
        tree_specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    for leaf, spec in zip(flat_sh, flat_sp):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            div = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % div == 0, \
                f"{what}: dim {dim} not divisible by {axes}={div} " \
                f"(leaf {leaf.shape}, spec {spec})"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [POD1, POD2], ids=["pod1", "pod2"])
@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_param_specs_divide(arch, mesh, mode):
    cfg = get_config(arch)
    pshapes = ispec.params_shapes(cfg)
    pspecs = sp.param_specs(cfg, pshapes, mesh, mode)
    _check_divides(pshapes, pspecs, mesh, f"{arch}/{mode}/params")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_opt_state_specs_divide(arch):
    cfg = get_config(arch)
    pshapes = ispec.params_shapes(cfg)
    opt_init, _ = make_optimizer(cfg.optimizer)
    oshapes = jax.eval_shape(opt_init, pshapes)
    ospecs = sp.opt_state_specs(cfg, oshapes, None, POD1)
    _check_divides(oshapes, ospecs, POD1, f"{arch}/opt")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divide(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        pytest.skip("full-attention arch skips long_500k")
    _, cshapes, _ = ispec.decode_arg_specs(cfg, shape)
    cspecs = sp.cache_specs(cfg, cshapes, POD1,
                            long_context=shape_name == "long_500k")
    _check_divides(cshapes, cspecs, POD1, f"{arch}/{shape_name}/cache")


@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_activation_rules_have_core_roles(mode):
    cfg = get_config("mixtral-8x7b")
    rules = sp.activation_rules(cfg, POD1, mode)
    for role in ("act_btd", "act_ffn", "logits", "moe_buffer"):
        assert role in rules


def test_fsdp16_override_used_by_smollm():
    """smollm d_model=960 is not divisible by 256 — its config must pin
    fsdp_axes=("model",) and the resulting specs stay valid."""
    cfg = get_config("smollm-360m")
    assert cfg.fsdp_axes == ("model",)
