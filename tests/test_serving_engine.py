"""Continuous-batching serving engine: batched cost path, admission,
preemption-free decode, CCPG wake accounting under batch."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import CCPGModel, CycleModel, PicnicSimulator
from repro.core.scheduling import allocate_chiplets
from repro.launch.scheduler import CostModel
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingConfig, EventKind,
                                         poisson_trace, replay_trace,
                                         serve_trace)


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


@pytest.fixture(scope="module")
def alloc(cfg):
    return allocate_chiplets(cfg)


# ---------------------------------------------------------------------------
# Batched cost path (CycleModel)
# ---------------------------------------------------------------------------

def test_batch_of_one_matches_single_stream(cfg, alloc):
    """b=1 must reproduce the calibrated Table II decode path exactly."""
    cm = CycleModel()
    for ctx in (64, 512, 2048):
        single = cm.token_decode_cycles(cfg, alloc, ctx)
        batched = cm.batched_token_decode_cycles(cfg, alloc, [ctx])
        assert batched == single


def test_batched_decode_is_sublinear(cfg, alloc):
    """Weight-stationary amortization: one batch-8 iteration costs less
    than 8 single-stream iterations, but more than one."""
    cm = CycleModel()
    one, _ = cm.token_decode_cycles(cfg, alloc, 512)
    eight, _ = cm.batched_token_decode_cycles(cfg, alloc, [512] * 8)
    assert one < eight < 8 * one


def test_batched_c2c_and_kv_traffic_per_request(cfg, alloc):
    """C2C activation bytes do NOT amortize: every co-batched request
    ships its own activation vector across each chiplet boundary."""
    cm = CycleModel()
    _, c2c_1 = cm.token_decode_cycles(cfg, alloc, 512)
    _, c2c_8 = cm.batched_token_decode_cycles(cfg, alloc, [512] * 8)
    assert c2c_8 == 8 * c2c_1
    # KV reads are per-request too: mixed contexts charge sum(contexts)
    a, _ = cm.batched_token_decode_cycles(cfg, alloc, [100, 900])
    b, _ = cm.batched_token_decode_cycles(cfg, alloc, [500, 500])
    assert a == b


def test_empty_batch_is_free(cfg, alloc):
    assert CycleModel().batched_token_decode_cycles(cfg, alloc, []) == (0, 0)


# ---------------------------------------------------------------------------
# CCPG accounting under batch
# ---------------------------------------------------------------------------

def test_ccpg_wake_charged_once_per_iteration(cfg, alloc):
    """Cluster residency: the wake residue for a batch-8 iteration equals
    the single-stream one (shared cluster walk), so the per-TOKEN CCPG
    overhead shrinks with batch size."""
    m = CCPGModel()
    assert m.wake_overhead_cycles_batched(alloc, 8) \
        == m.wake_overhead_cycles_batched(alloc, 1) \
        == m.wake_overhead_cycles(alloc)
    assert m.wake_overhead_cycles_batched(alloc, 0) == 0
    sim = PicnicSimulator()
    for b in (1, 8):
        plain, _ = sim.decode_iteration_seconds(cfg, alloc, [512] * b)
        gated, _ = sim.decode_iteration_seconds(cfg, alloc, [512] * b,
                                                ccpg=True)
        overhead_s = m.wake_overhead_cycles(alloc) / sim.tile.frequency_hz
        assert gated - plain == pytest.approx(overhead_s, rel=1e-9)


def test_ccpg_idle_power_is_retention_only(alloc):
    m = CCPGModel()
    n = alloc.n_chiplets
    assert m.idle_power(n, ccpg=True) == pytest.approx(
        n * m.tile.tile_power_sleep)
    assert m.idle_power(n, ccpg=False) == pytest.approx(
        m.system_power(n, ccpg=False))
    assert m.idle_power(n, ccpg=True) < m.idle_power(n, ccpg=False)


def test_ccpg_improves_tokens_per_joule_under_load(cfg):
    """Same trace: CCPG must raise tokens/J substantially while keeping
    throughput 'similar' (paper §IV-B: small wake residue)."""
    kw = dict(rate_rps=40, seed=0, prompt_len=512, max_new=32)
    r0 = serve_trace(cfg, poisson_trace(32, **kw), max_batch=8, ccpg=False)
    r1 = serve_trace(cfg, poisson_trace(32, **kw), max_batch=8, ccpg=True)
    assert r1.tokens_per_J > 1.5 * r0.tokens_per_J
    assert r1.tokens_per_s > 0.95 * r0.tokens_per_s


# ---------------------------------------------------------------------------
# Engine: admission, scheduling, reporting
# ---------------------------------------------------------------------------

def test_all_requests_finish_and_tokens_conserved(cfg):
    trace = poisson_trace(24, rate_rps=100, seed=1, prompt_len=128,
                          max_new=16)
    rep = serve_trace(cfg, trace, max_batch=4)
    assert rep.finished == 24 and rep.rejected == 0
    assert rep.tokens_generated == sum(r.max_new for r in trace)
    assert rep.tokens_prefilled == sum(r.prompt_len for r in trace)
    assert rep.p50_latency_s <= rep.p99_latency_s
    assert rep.p50_ttft_s <= rep.p99_ttft_s
    assert 1.0 <= rep.mean_batch_occupancy <= 4.0


def test_admission_respects_queue_limit(cfg):
    """A tiny queue + burst arrivals must shed load, and every request is
    accounted for as finished or rejected."""
    trace = replay_trace([(0.0, 64, 256) for _ in range(20)])
    rep = serve_trace(cfg, trace, max_batch=2, queue_limit=4)
    assert rep.rejected > 0
    assert rep.finished + rep.rejected == 20


def test_no_admission_before_arrival(cfg):
    """The engine may not prefill a request before it arrives."""
    trace = replay_trace([(0.5 * i, 64, 4) for i in range(6)])
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(max_batch=4))
    eng.run(trace)
    prefills = {rid: t for t, k, rid in eng.events
                if k == EventKind.PREFILL}
    for r in trace:
        assert prefills[r.request_id] >= r.arrival


def test_decode_is_preemption_free(cfg):
    """Once admitted, a request decodes to completion: exactly one
    PREFILL and one FINISH per request, monotone context growth, and
    generated == max_new at finish."""
    trace = poisson_trace(16, rate_rps=200, seed=2, prompt_len=64,
                          max_new=12)
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(max_batch=4))
    eng.run(trace)
    for r in trace:
        kinds = [k for _, k, rid in eng.events if rid == r.request_id]
        assert kinds.count(EventKind.PREFILL) == 1
        assert kinds.count(EventKind.FINISH) == 1
        assert r.generated == r.max_new
        assert r.context == r.prompt_len + r.max_new
        assert r.finished_at >= r.first_token_at >= r.arrival


def test_batch8_beats_one_at_a_time(cfg):
    """The acceptance headline: batched decode throughput at batch 8
    exceeds 1-at-a-time serving on the same trace."""
    kw = dict(rate_rps=40, seed=0, prompt_len=512, max_new=32)
    seq = serve_trace(cfg, poisson_trace(32, **kw), max_batch=1)
    bat = serve_trace(cfg, poisson_trace(32, **kw), max_batch=8)
    assert bat.tokens_per_s > 1.2 * seq.tokens_per_s
    assert bat.p99_latency_s < seq.p99_latency_s


def test_replay_trace_tuple_and_dict_deadlines():
    """Tuple rows accept an optional 4th `deadline_ttft` element — both
    row forms must carry deadlines identically (tuple rows used to drop
    them silently)."""
    tuples = replay_trace([(0.0, 64, 8),
                           (0.1, 32, 4, 0.05),
                           (0.2, 16, 2, None)])
    dicts = replay_trace([
        {"arrival_s": 0.0, "prompt_len": 64, "max_new": 8},
        {"arrival_s": 0.1, "prompt_len": 32, "max_new": 4,
         "deadline_ttft": 0.05},
        {"arrival_s": 0.2, "prompt_len": 16, "max_new": 2,
         "deadline_ttft": None}])
    for t, d in zip(tuples, dicts):
        assert (t.arrival, t.prompt_len, t.max_new, t.deadline_ttft) \
            == (d.arrival, d.prompt_len, d.max_new, d.deadline_ttft)
    assert tuples[0].deadline_ttft is None
    assert tuples[1].deadline_ttft == pytest.approx(0.05)
    assert tuples[2].deadline_ttft is None


def test_ttft_deadline_forces_early_prefill_tuple_form(cfg):
    """The deadline override fires identically from a 4-tuple row."""
    trace = replay_trace([(0.0, 256, 512), (0.01, 64, 4, 0.02)])
    eng = ContinuousBatchingEngine(
        cfg, engine=ServingConfig(max_batch=4, decode_quantum=10 ** 6))
    eng.run(trace)
    sim = PicnicSimulator()
    alloc = allocate_chiplets(cfg, sim.tile)
    round_s, _ = sim.decode_iteration_seconds(cfg, alloc, [512])
    assert trace[1].ttft is not None
    assert trace[1].ttft <= 0.02 + 2 * round_s


def test_ttft_deadline_forces_early_prefill(cfg):
    """A tight TTFT deadline overrides the decode quantum (same policy as
    launch/scheduler.py, priced by the cycle model)."""
    rows = [{"arrival_s": 0.0, "prompt_len": 256, "max_new": 512},
            {"arrival_s": 0.01, "prompt_len": 64, "max_new": 4,
             "deadline_ttft": 0.02}]
    trace = replay_trace(rows)
    eng = ContinuousBatchingEngine(
        cfg, engine=ServingConfig(max_batch=4, decode_quantum=10 ** 6))
    eng.run(trace)
    # the at-risk check fires between iterations, so the deadline can slip
    # by at most one decode round; without the override the quantum would
    # hold the prefill back for request 0's full 512-token decode (~0.6 s)
    sim = PicnicSimulator()
    alloc = allocate_chiplets(cfg, sim.tile)
    round_s, _ = sim.decode_iteration_seconds(cfg, alloc, [512])
    assert trace[1].ttft is not None
    assert trace[1].ttft <= 0.02 + 2 * round_s


def test_idle_gaps_charged_at_idle_power(cfg):
    """Sparse arrivals leave idle time; with CCPG the idle energy is
    scratchpad-retention only, so sparse-traffic tokens/J stays high."""
    trace_kw = dict(rows=[(0.5 * i, 32, 4) for i in range(4)])
    r0 = serve_trace(cfg, replay_trace(**trace_kw), max_batch=2, ccpg=False)
    r1 = serve_trace(cfg, replay_trace(**trace_kw), max_batch=2, ccpg=True)
    assert r0.idle_s > 1.0 and r1.idle_s > 1.0
    assert r1.energy_J < 0.5 * r0.energy_J


def test_cost_model_calibrates_from_simulator(cfg):
    """launch/scheduler's abstract CostModel can be derived from the
    mapped cycle model — the two serving layers agree on time."""
    sim = PicnicSimulator()
    alloc = allocate_chiplets(cfg, sim.tile)
    f = sim.tile.frequency_hz
    cm = CostModel.from_simulator(sim, cfg, prompt_len=512)
    dec_cyc, _ = sim.cycle_model.token_decode_cycles(cfg, alloc, 512)
    assert cm.decode_round_s == pytest.approx(dec_cyc / f)
    # the prefill secant is a linearization of a quadratic: held-out
    # prompt lengths must land in the right ballpark but the calibration
    # point must move with prompt_len (i.e. the fit is not a constant)
    p2048, _ = sim.cycle_model.prefill_cycles(cfg, alloc, 2048)
    est = cm.prefill_fixed_s + 2047 * cm.prefill_s_per_token
    assert est == pytest.approx(p2048 / f, rel=0.30)
    assert est < p2048 / f   # secant underestimates past the fit point
    cm_long = CostModel.from_simulator(sim, cfg, prompt_len=2048)
    est_long = cm_long.prefill_fixed_s + 2047 * cm_long.prefill_s_per_token
    assert est_long == pytest.approx(p2048 / f, rel=1e-6)
    assert cm_long.prefill_s_per_token > cm.prefill_s_per_token


def test_no_finishes_reports_nan_percentiles(cfg):
    """An all-rejected run must not masquerade as zero-latency."""
    rep = serve_trace(cfg, replay_trace([(0.0, 16, 4)]), max_batch=1,
                      queue_limit=0)
    assert rep.finished == 0 and rep.rejected == 1
    assert np.isnan(rep.p50_latency_s) and np.isnan(rep.p99_latency_s)
    assert np.isnan(rep.p50_ttft_s) and np.isnan(rep.p99_ttft_s)


def test_all_rejected_row_is_json_safe(cfg):
    """finished == 0 keeps NaN percentiles in the report (locked above),
    but row() must map them to None: `json.dumps` would otherwise emit
    bare `NaN` tokens that strict parsers (and the bench-regression
    gate) reject."""
    import json
    rep = serve_trace(cfg, replay_trace([(0.0, 16, 4)]), max_batch=1,
                      queue_limit=0)
    assert rep.finished == 0
    row = rep.row()
    for k in ("p50_latency_s", "p99_latency_s", "p50_ttft_s",
              "p99_ttft_s"):
        assert row[k] is None
    # a finished run keeps real numbers in the same keys
    ok = serve_trace(cfg, replay_trace([(0.0, 16, 4)]), max_batch=1)
    assert all(ok.row()[k] is not None for k in ok.row())
    json.dumps(row, allow_nan=False)       # must not raise


def test_prefill_only_request_generates_nothing(cfg):
    """max_new == 0 (scoring / prefill-only) must not emit a token."""
    rep = serve_trace(cfg, replay_trace([(0.0, 16, 0), (0.0, 16, 4)]),
                      max_batch=2)
    assert rep.finished == 2
    assert rep.tokens_generated == 4
    assert rep.tokens_prefilled == 32


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 24), batch=st.integers(1, 8),
       quantum=st.integers(1, 8), seed=st.integers(0, 99))
def test_engine_drains_any_load(n, batch, quantum, seed):
    """Starvation-freedom under the cycle-model costs: every admitted
    request finishes for any load/slots/quantum mix."""
    cfg = get_config("llama3.2-1b")
    rng = np.random.default_rng(seed)
    rows = [(float(rng.uniform(0, 0.2)), int(rng.integers(1, 256)),
             int(rng.integers(1, 16))) for _ in range(n)]
    rep = serve_trace(cfg, replay_trace(rows), max_batch=batch,
                      decode_quantum=quantum, queue_limit=1000)
    assert rep.finished == n and rep.rejected == 0
