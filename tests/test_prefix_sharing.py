"""Serving-level prefix sharing / copy-on-write (ISSUE 6): the engine
wiring on top of the refcounted allocator — token-carrying traces are
inert with sharing OFF (byte-identical reports and event streams),
sharing ON recovers batch occupancy on prefix-heavy workloads without
changing any request's results, COW copies land on the TimelineIR as
``kv_cow`` C2C transfers (no new event kinds), preemption/resume
re-adopts cleanly, and the whole shared path is pinned by a committed
golden (tests/golden/prefix_golden.json)."""
import dataclasses
import json
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core.timeline import C2CTransfer
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingConfig, poisson_trace)
from repro.runtime.kv_cache import KVCacheConfig, kv_bytes_per_token

GOLDEN_PATH = Path(__file__).parent / "golden" / "prefix_golden.json"


def _hexdict(obj) -> dict:
    d = dataclasses.asdict(obj)
    d.pop("queue_depth", None)
    # per-node attribution (ISSUE 9 fleet) stays None outside a fleet and
    # is absent from the committed golden — drop it exactly when unset
    for k in ("node_id", "pool"):
        if k in d and d[k] is None:
            d.pop(k)
    return {k: (v.hex() if isinstance(v, float) else v) for k, v in d.items()}


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


def _kvc(cfg, share: bool, n_blocks=120, dram=120):
    return KVCacheConfig(n_blocks=n_blocks, block_tokens=16,
                         dram_blocks=dram,
                         bytes_per_token=kv_bytes_per_token(cfg),
                         prefix_sharing=share)


def _prefix_trace(prefix_len=256, n=12, prompt_len=320, max_new=24,
                  seed=3, groups=2):
    return poisson_trace(n, rate_rps=80, seed=seed, prompt_len=prompt_len,
                         max_new=max_new, prefix_len=prefix_len,
                         prefix_frac=0.85, prefix_groups=groups)


def _run(cfg, share: bool, trace, **kv_kw):
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
        max_batch=4, ccpg=True, kv_cache=_kvc(cfg, share, **kv_kw),
        chunked_prefill_tokens=64))
    rep = eng.run(trace)
    return eng, rep


# ---------------------------------------------------------------------------
# Back-compat: sharing OFF ignores tokens byte-for-byte
# ---------------------------------------------------------------------------

def test_tokens_inert_when_sharing_off(cfg):
    """With prefix_sharing=False a token-carrying trace must reproduce
    the tokenless run byte-for-byte: same report floats, same timeline
    event stream, same kv accounting — prompt_tokens is dead weight."""
    with_tokens = _prefix_trace()
    stripped = [dataclasses.replace(r, prompt_tokens=None)
                for r in with_tokens]
    e1, r1 = _run(cfg, share=False, trace=with_tokens)
    e2, r2 = _run(cfg, share=False, trace=stripped)
    assert _hexdict(r1) == _hexdict(r2)
    assert e1.timeline.events == e2.timeline.events
    assert e1.kv_stats.row() == e2.kv_stats.row()
    st = e1.kv_stats
    assert not st.prefix_sharing
    assert st.prefix_hits == st.cow_forks == st.shared_blocks_peak == 0


# ---------------------------------------------------------------------------
# Sharing ON: same results, better occupancy, coherent accounting
# ---------------------------------------------------------------------------

def test_sharing_preserves_results_and_improves_occupancy(cfg):
    trace = _prefix_trace()
    e_off, r_off = _run(cfg, share=False, trace=list(trace))
    off_final = {r.request_id: (r.generated, r.context) for r in trace}
    e_on, r_on = _run(cfg, share=True, trace=list(trace))
    assert r_on.finished == r_off.finished == len(trace)
    # every request produces the same tokens/context either way
    for r in trace:
        assert (r.generated, r.context) == off_final[r.request_id]
        assert r.generated == r.max_new
        assert r.context == r.prompt_len + r.max_new
    st = e_on.kv_stats
    assert st.prefix_sharing and st.prefix_hits > 0
    assert 0.0 < st.prefix_hit_rate <= 1.0
    assert st.prefix_hit_tokens > 0
    assert st.shared_blocks_peak > 0
    # dedup can only help the capacity path
    assert r_on.mean_batch_occupancy >= r_off.mean_batch_occupancy
    assert e_on.kv.peak_used <= e_off.kv.peak_used
    # shared prompts skip prefill compute for their adopted tokens
    assert r_on.tokens_prefilled < r_off.tokens_prefilled
    # cache fully drained: refcounts all resolved
    assert e_on.kv.free_total() == e_on.kv.cfg.total_blocks
    assert e_on.kv.n_shared_blocks == 0


def test_cow_copies_land_on_timeline_as_kv_cow(cfg):
    """A prefix length that is NOT a block multiple forces mid-block
    divergence: the fork's copied head must appear on the timeline as
    ``kv_cow`` C2C transfers whose bytes total cow_copied_bytes — and as
    a phase of the existing C2CTransfer kind, not a new event type."""
    trace = _prefix_trace(prefix_len=250)
    eng, rep = _run(cfg, share=True, trace=trace)
    st = eng.kv_stats
    assert st.cow_forks > 0 and st.cow_copied_bytes > 0
    cow = [e for e in eng.timeline.events
           if isinstance(e, C2CTransfer) and e.phase == "kv_cow"]
    assert len(cow) == st.cow_forks
    assert sum(e.nbytes for e in cow) == st.cow_copied_bytes
    kinds = {type(e).__name__ for e in eng.timeline.events}
    assert kinds <= {"ComputeSpan", "C2CTransfer", "ClusterWake",
                     "ClusterSleep", "EnergySample", "TokenEmit"}


def test_preempted_sharer_readopts_and_finishes(cfg):
    """A cache tight enough to preempt sharers mid-decode: recompute-on-
    resume re-adopts whatever is still indexed, every request finishes
    with exact context, and the allocator drains to empty."""
    trace = _prefix_trace(n=8, prompt_len=256, max_new=48, prefix_len=192)
    eng, rep = _run(cfg, share=True, trace=trace, n_blocks=40, dram=0)
    st = eng.kv_stats
    assert rep.finished == len(trace) and rep.rejected == 0
    assert st.preemptions > 0
    for r in trace:
        assert r.generated == r.max_new
        assert r.context == r.prompt_len + r.max_new
    assert eng.kv.free_total() == eng.kv.cfg.total_blocks


def test_admission_credits_shared_blocks(cfg):
    """can_admit with a fully indexed prefix admits a prompt that the
    raw free-block count would refuse."""
    kvc = _kvc(cfg, share=True, n_blocks=24, dram=0)
    from repro.runtime.kv_cache import BlockAllocator
    a = BlockAllocator(kvc)
    toks = list(range(1, 24 * 16 - 31))      # fills 22 blocks
    a.ensure(1, len(toks))
    a.register_prefix(1, toks)
    free = a.free_total()
    assert not a.can_admit(len(toks) + 1)    # raw demand > free blocks
    shared = a.probe_prefix(toks + [99])
    assert shared > 0
    assert a.can_admit(len(toks) + 1, shared_blocks=shared)
    assert a.cfg.blocks_for(len(toks) + 1) - shared <= free


# ---------------------------------------------------------------------------
# Prefix-heavy serving golden: the SHARED path is pinned too
# ---------------------------------------------------------------------------

def _golden_payload(cfg) -> dict:
    trace = _prefix_trace(prefix_len=250, n=10, prompt_len=320,
                          max_new=16, seed=7)
    eng, rep = _run(cfg, share=True, trace=trace)
    st = eng.kv_stats
    return {
        "report": _hexdict(rep),
        "kv": st.row(),
        "n_events": eng.timeline.n_events,
        "clock": eng.timeline.now.hex(),
        "energy_J": eng.timeline.energy_J.hex(),
    }


def test_prefix_serving_golden_byte_identical(cfg):
    golden = json.loads(GOLDEN_PATH.read_text())
    assert _golden_payload(cfg) == golden


if __name__ == "__main__":          # regenerate the golden after an
    # INTENTIONAL behavior change:  PYTHONPATH=src python tests/test_prefix_sharing.py
    payload = _golden_payload(get_config("llama3.2-1b"))
    GOLDEN_PATH.write_text(json.dumps(payload, indent=1, sort_keys=True))
    print(f"wrote {GOLDEN_PATH}")
