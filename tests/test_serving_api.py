"""Public serving API (ISSUE 9 redesign): the versioned keyword-only
config schema, the deprecation shim over the old ``EngineConfig``
constructor, the unified :class:`Trace` surface and the
``repro.launch`` facade."""
import copy
import dataclasses

import pytest

from repro.configs import get_config
from repro.core import PicnicSimulator
from repro.core.interconnect import MeasuredTraffic
from repro.launch import (FleetConfig, ServingConfig, Trace, fleet,
                          poisson_trace, replay_trace, serve, sweep)
from repro.runtime.kv_cache import KVCacheConfig


# ---------------------------------------------------------------------------
# ServingConfig / FleetConfig schema contract
# ---------------------------------------------------------------------------

def test_serving_config_round_trip():
    c = ServingConfig(max_batch=4, ccpg=True, overlap=0.5,
                      chunked_prefill_tokens=128)
    d = c.to_dict()
    assert d["schema"] == ServingConfig.SCHEMA_VERSION
    assert ServingConfig.from_dict(d) == c


def test_serving_config_round_trip_nested_kv_cache():
    kvc = KVCacheConfig(n_blocks=32, block_tokens=16, dram_blocks=8,
                        bytes_per_token=2048, prefix_sharing=True)
    c = ServingConfig(max_batch=8, kv_cache=kvc)
    d = c.to_dict()
    assert isinstance(d["kv_cache"], dict)      # JSON-serializable
    c2 = ServingConfig.from_dict(d)
    assert c2 == c and c2.kv_cache == kvc


def test_fleet_config_round_trip_nested():
    fc = FleetConfig(n_prefill=3, n_decode=1, autoscale=True,
                     engine=ServingConfig(max_batch=4, ccpg=True),
                     measured_handoff=MeasuredTraffic(
                         prefill_bytes=1e6, decode_bytes_per_token=128.0),
                     handoff_bytes_per_token=4096)
    d = fc.to_dict()
    assert d["schema"] == FleetConfig.SCHEMA_VERSION
    assert isinstance(d["engine"], dict)
    assert isinstance(d["measured_handoff"], dict)
    fc2 = FleetConfig.from_dict(d)
    assert fc2 == fc
    assert fc2.n_nodes == 4


def test_from_dict_rejects_unknown_keys():
    d = ServingConfig().to_dict()
    d["max_batchh"] = 4                          # the typo'd knob
    with pytest.raises(ValueError, match="max_batchh"):
        ServingConfig.from_dict(d)
    fd = FleetConfig().to_dict()
    fd["n_prefll"] = 2
    with pytest.raises(ValueError, match="n_prefll"):
        FleetConfig.from_dict(fd)
    kd = ServingConfig(kv_cache=KVCacheConfig(n_blocks=4)).to_dict()
    kd["kv_cache"]["n_blockss"] = 4
    with pytest.raises(ValueError, match="n_blockss"):
        ServingConfig.from_dict(kd)


def test_from_dict_rejects_newer_schema():
    d = ServingConfig().to_dict()
    d["schema"] = ServingConfig.SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema"):
        ServingConfig.from_dict(d)
    fd = FleetConfig().to_dict()
    fd["schema"] = FleetConfig.SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema"):
        FleetConfig.from_dict(fd)


def test_configs_are_keyword_only():
    with pytest.raises(TypeError):
        ServingConfig(4)                         # noqa: positional
    with pytest.raises(TypeError):
        FleetConfig(2, 2)                        # noqa: positional


# ---------------------------------------------------------------------------
# EngineConfig deprecation shim
# ---------------------------------------------------------------------------

def test_engine_config_warns_and_maps_keywords():
    from repro.launch.serving_engine import EngineConfig
    with pytest.warns(DeprecationWarning, match="ServingConfig"):
        c = EngineConfig(max_batch=4, ccpg=True)
    assert isinstance(c, ServingConfig)
    assert c.max_batch == 4 and c.ccpg is True
    # dataclass __eq__ is class-strict; the field values are what the
    # shim must preserve
    assert dataclasses.asdict(c) \
        == dataclasses.asdict(ServingConfig(max_batch=4, ccpg=True))


def test_engine_config_accepts_legacy_positional_form():
    from repro.launch.serving_engine import EngineConfig
    # the old dataclass field order: max_batch, queue_limit,
    # decode_quantum, ccpg, ...
    with pytest.warns(DeprecationWarning):
        c = EngineConfig(4, 128, 2, True)
    assert (c.max_batch, c.queue_limit, c.decode_quantum, c.ccpg) \
        == (4, 128, 2, True)
    with pytest.warns(DeprecationWarning), \
            pytest.raises(TypeError):
        EngineConfig(*range(20))                 # too many positionals


# ---------------------------------------------------------------------------
# Trace surface
# ---------------------------------------------------------------------------

def test_trace_poisson_matches_legacy_function():
    a = Trace.poisson(16, rate_rps=40, seed=3, prompt_len=256, max_new=8)
    b = poisson_trace(16, rate_rps=40, seed=3, prompt_len=256, max_new=8)
    assert isinstance(a, Trace) and isinstance(b, Trace)
    assert len(a) == len(b) == 16
    for x, y in zip(a, b):
        assert dataclasses.asdict(x) == dataclasses.asdict(y)


def test_trace_replay_matches_legacy_function():
    rows = [(0.1, 64, 4), {"arrival_s": 0.05, "prompt_len": 32,
                           "max_new": 2, "deadline_ttft": 0.5}]
    a = Trace.replay(rows)
    b = replay_trace(rows)
    assert [dataclasses.asdict(r) for r in a] \
        == [dataclasses.asdict(r) for r in b]
    assert a[0].arrival == 0.05                  # sorted by arrival


# ---------------------------------------------------------------------------
# Facade entry points
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


def test_serve_facade_matches_engine_run(cfg):
    from repro.launch.serving_engine import ContinuousBatchingEngine
    trace = Trace.poisson(8, rate_rps=40, seed=0, prompt_len=256,
                          max_new=8)
    sc = ServingConfig(max_batch=4, ccpg=True)
    r1 = serve(cfg, [copy.copy(r) for r in trace], config=sc,
               sim=PicnicSimulator())
    eng = ContinuousBatchingEngine(cfg, sim=PicnicSimulator(), engine=sc)
    r2 = eng.run([copy.copy(r) for r in trace])
    assert r1.row() == r2.row()


def test_fleet_facade_matches_engine_run(cfg):
    from repro.launch.fleet_engine import FleetEngine
    trace = Trace.poisson(8, rate_rps=40, seed=0, prompt_len=256,
                          max_new=8)
    fc = FleetConfig(engine=ServingConfig(max_batch=4))
    r1 = fleet(cfg, [copy.copy(r) for r in trace], config=fc,
               sim=PicnicSimulator())
    r2 = FleetEngine(cfg, fc, sim=PicnicSimulator()).run(
        [copy.copy(r) for r in trace])
    assert r1.row() == r2.row()


def test_sweep_facade_matches_sweep_serve(cfg):
    from repro.launch.sweep_engine import SweepCell, sweep_serve
    def cells():
        return [SweepCell(f"b{b}", cfg,
                          Trace.poisson(6, rate_rps=40, seed=0,
                                        prompt_len=256, max_new=8),
                          ServingConfig(max_batch=b))
                for b in (1, 4)]
    r1 = sweep(cells())
    r2 = sweep_serve(cells())
    assert [r.report.row() for r in r1] == [r.report.row() for r in r2]


def test_serving_report_row_attribution_fields(cfg):
    """node_id/pool stay OUT of row() on single-node runs (artifact
    byte-identity) and appear once a fleet sets them."""
    trace = Trace.poisson(4, rate_rps=40, seed=0, prompt_len=128,
                          max_new=4)
    rep = serve(cfg, list(trace), config=ServingConfig(max_batch=4))
    row = rep.row()
    assert "node_id" not in row and "pool" not in row
    rep.node_id, rep.pool = 2, "decode"
    row = rep.row()
    assert row["node_id"] == 2 and row["pool"] == "decode"
