"""Paged KV-cache subsystem: block-allocator invariants (refcounted
prefix sharing / copy-on-write included, differentially tested against a
content-addressed naive model), capacity-aware serving (admission by
blocks, watermark preemption with recompute-on-resume, DRAM-hub spill
traffic on the timeline, chunked prefill), and the paged-attention
Pallas kernel vs its dense oracle (interpret mode)."""
from collections import Counter

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import PicnicSimulator
from repro.core.scheduling import CycleModel, allocate_chiplets
from repro.core.timeline import C2CTransfer, TokenEmit
from repro.launch.serving_engine import (ContinuousBatchingEngine,
                                         ServingConfig, EventKind,
                                         replay_trace, serve_trace)
from repro.runtime.kv_cache import (BlockAllocator, KVCacheConfig,
                                    OutOfBlocks, kv_bytes_per_token,
                                    kv_cache_from_model)


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


def _check_invariants(a: BlockAllocator):
    """Every physical id free XOR owned (refcnt == number of tables
    holding it, never twice in one table); counts add up over DISTINCT
    ids; tables never over-allocate by more than one partial block; the
    incremental DRAM counts / scan hints match a recount; the prefix
    index is coherent (only live blocks, inverse maps agree); and the
    heap spill-victim index selects exactly what the reference scan
    would."""
    c = a.cfg
    owned = [b for t in a.tables.values() for b in t.blocks]
    counts = Counter(owned)
    # refcounts always match live mappings, sharing on or off
    assert dict(counts) == a.refcnt, "refcnt drifted from live tables"
    for b, readers in a._refs.items():
        assert readers == {t.request_id for t in a.tables.values()
                           if b in t.blocks}, "reader set drifted"
    if not c.prefix_sharing:
        assert all(n == 1 for n in counts.values()), "block double-owned"
    assert a.n_shared_blocks == sum(1 for n in counts.values() if n >= 2)
    distinct = set(owned)
    free = a._free_scratch + a._free_dram
    assert len(free) == len(set(free)), "block double-freed"
    assert not (distinct & set(free)), "block both free and owned"
    assert len(distinct) + len(free) == c.total_blocks
    for t in a.tables.values():
        assert len(t.blocks) == len(set(t.blocks)), "block twice in table"
        assert len(t.blocks) == c.blocks_for(t.tokens)
        assert len(t.blocks) * c.block_tokens >= t.tokens
        assert t.n_dram == sum(1 for b in t.blocks if a.is_dram(b))
        # everything before the oldest-scratch scan hint is DRAM
        assert all(a.is_dram(b) for b in t.blocks[:t.scan])
    # prefix-index coherence: indexed blocks are live, maps are inverse
    for h, b in a._index.items():
        assert b in a.refcnt, "index points at a freed block"
        assert a._hash_of.get(b) == h
        assert len(a._tok_of[b]) == c.block_tokens
    for b in a._hash_of:
        assert a._index.get(a._hash_of[b]) == b
    for parent, b in a._next.items():
        assert b in a.refcnt and a._parent_of.get(b) == parent
    # victim-order equivalence: O(log n) heap index == reference scan
    # (_spill_victim only prunes stale heap snapshots — state-safe)
    fast, ref = a._spill_victim(), a._spill_victim_reference()
    if ref is None:
        assert fast is None
    else:
        assert fast is not None
        assert fast[0] is ref[0] and fast[1] == ref[1]


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_blocks_for_rounding():
    c = KVCacheConfig(n_blocks=8, block_tokens=16)
    assert c.blocks_for(0) == 0
    assert c.blocks_for(1) == 1
    assert c.blocks_for(16) == 1
    assert c.blocks_for(17) == 2
    assert c.block_bytes == 16 * c.bytes_per_token


def test_alloc_free_conservation():
    a = BlockAllocator(KVCacheConfig(n_blocks=10, block_tokens=4))
    a.ensure(1, 9)            # 3 blocks
    a.ensure(2, 4)            # 1 block
    _check_invariants(a)
    assert a.used_blocks() == 4 and a.free_total() == 6
    a.ensure(1, 10)           # same 3rd block covers token 10
    assert a.used_blocks() == 4
    a.ensure(1, 13)           # crosses into a 4th block
    assert a.used_blocks() == 5
    _check_invariants(a)
    assert a.free(1) == 4
    assert a.free_total() == 9 and a.peak_used == 5
    _check_invariants(a)
    with pytest.raises(KeyError):
        a.free(1)             # double free


def test_out_of_blocks_keeps_partial_growth():
    a = BlockAllocator(KVCacheConfig(n_blocks=4, block_tokens=4))
    with pytest.raises(OutOfBlocks):
        a.ensure(7, 100)
    _check_invariants(a)
    assert a.free_total() == 0          # partial growth retained
    a.free(7)
    assert a.free_total() == 4


def test_spill_moves_coldest_block_and_charges_bytes():
    spills = []
    a = BlockAllocator(KVCacheConfig(n_blocks=4, block_tokens=4,
                                     dram_blocks=4, bytes_per_token=8),
                       on_spill=spills.append)
    a.ensure(1, 16)                      # all 4 scratch blocks
    a.ensure(2, 4)                       # forces one spill
    _check_invariants(a)
    assert a.spilled_blocks == 1
    assert spills == [a.cfg.block_bytes]
    assert a.spilled_bytes == a.cfg.block_bytes
    # request 1 (most scratch blocks) lost its OLDEST block to DRAM
    assert a.dram_tokens(1) == 4 and a.scratch_tokens(1) == 12
    t1 = a.tables[1]
    assert a.is_dram(t1.blocks[0]) and not any(
        a.is_dram(b) for b in t1.blocks[1:])
    # request 2's new (hot) block stayed in scratchpad
    assert a.dram_tokens(2) == 0


def test_exhausting_both_tiers_raises():
    a = BlockAllocator(KVCacheConfig(n_blocks=2, block_tokens=4,
                                     dram_blocks=2))
    a.ensure(1, 16)                      # 2 scratch + 2 dram
    _check_invariants(a)
    with pytest.raises(OutOfBlocks):
        a.ensure(2, 1)
    assert a.feasible(16) and not a.feasible(17)
    assert not a.can_admit(1)
    a.free(1)
    assert a.can_admit(16) and not a.can_admit(16, reserve=1)


def test_kv_sizing_from_model(cfg):
    bpt = kv_bytes_per_token(cfg)
    # K + V rows of kv_dim for each attention layer at 8-bit
    assert bpt == 2 * cfg.kv_dim * cfg.n_layers
    kvc = kv_cache_from_model(cfg, kv_frac=0.5)
    assert kvc.bytes_per_token == bpt and kvc.n_blocks >= 1
    # half the allocated scratchpad capacity, nothing more
    alloc = allocate_chiplets(cfg)
    budget = alloc.n_chiplets * 1024 * 32 * 1024 * 0.5
    assert kvc.n_blocks * kvc.block_bytes <= budget


@settings(max_examples=20, deadline=None)
@given(n_blocks=st.integers(1, 12), dram=st.integers(0, 8),
       block_tokens=st.integers(1, 8), seed=st.integers(0, 999))
def test_allocator_invariants_random_walk(n_blocks, dram, block_tokens,
                                          seed):
    """Random ensure/append/free sequences keep every invariant."""
    rng = np.random.default_rng(seed)
    a = BlockAllocator(KVCacheConfig(
        n_blocks=n_blocks, block_tokens=block_tokens, dram_blocks=dram))
    live = {}
    for op in rng.integers(0, 3, size=40):
        if op == 0 or not live:                      # new request
            rid = int(rng.integers(0, 100)) + 1000 * len(live)
            want = int(rng.integers(1, 4 * block_tokens))
            try:
                a.ensure(rid, want)
                live[rid] = max(live.get(rid, 0), want)
            except OutOfBlocks:
                live[rid] = max(live.get(rid, 0),
                                a.tables[rid].tokens)
        elif op == 1:                                # grow one
            rid = int(rng.choice(list(live)))
            want = live[rid] + int(rng.integers(1, block_tokens + 1))
            try:
                a.ensure(rid, want)
                live[rid] = want
            except OutOfBlocks:
                live[rid] = a.tables[rid].tokens
        else:                                        # free one
            rid = int(rng.choice(list(live)))
            a.free(rid)
            del live[rid]
        _check_invariants(a)
        for rid, tokens in live.items():
            assert a.tables[rid].tokens >= tokens * 0  # table exists
    assert a.peak_used <= a.cfg.total_blocks


# ---------------------------------------------------------------------------
# Prefix sharing / copy-on-write
# ---------------------------------------------------------------------------

def _pcfg(n_blocks=8, block_tokens=4, dram_blocks=0, **kw):
    return KVCacheConfig(n_blocks=n_blocks, block_tokens=block_tokens,
                         dram_blocks=dram_blocks, bytes_per_token=8,
                         prefix_sharing=True, **kw)


def test_prefix_probe_adopt_register_roundtrip():
    a = BlockAllocator(_pcfg())
    toks = list(range(1, 13))                 # 12 tokens = 3 full blocks
    assert a.probe_prefix(toks) == 0          # nothing indexed yet
    a.ensure(1, 12)
    a.register_prefix(1, toks)
    _check_invariants(a)
    # cap: at least one token must remain to prefill -> 2 of 3 blocks
    assert a.probe_prefix(toks) == 2
    assert a.probe_prefix(toks + [99]) == 3   # 13 tokens: all 3 adoptable
    shared = a.adopt_prefix(2, toks)
    _check_invariants(a)
    # 2 whole blocks + COW head of the divergence block (tokens 9..11,
    # capped to leave token 12 for prefill)
    assert shared == 2 * 4 + 3
    assert a.cow_forks == 1 and a.cow_copied_bytes == 3 * 8
    assert a.prefix_hits == 2 and a.shared_tokens_saved == 11
    t1, t2 = a.tables[1], a.tables[2]
    assert t2.blocks[:2] == t1.blocks[:2]     # physically aliased
    assert t2.blocks[2] != t1.blocks[2]       # forked private block
    assert a.refcnt[t1.blocks[0]] == 2 and a.refcnt[t1.blocks[2]] == 1
    a.ensure(2, 12)
    _check_invariants(a)
    a.free(1)
    _check_invariants(a)
    # survivor keeps the (formerly shared) blocks; they stay indexed
    assert a.refcnt[t2.blocks[0]] == 1
    assert a.probe_prefix(toks) == 2
    a.free(2)
    _check_invariants(a)
    assert a.free_total() == a.cfg.total_blocks
    assert a.probe_prefix(toks) == 0          # index fully drained


def test_adopt_identical_prompt_shares_all_but_last_token():
    a = BlockAllocator(_pcfg())
    toks = list(range(100, 116))              # 16 tokens = 4 blocks
    a.ensure(1, 16)
    a.register_prefix(1, toks)
    shared = a.adopt_prefix(2, toks)          # same prompt entirely
    # 3 whole blocks + 3-token COW head of block 4 = 15 of 16 tokens
    assert shared == 15
    assert a.tables[2].tokens == 15
    a.ensure(2, 16)
    _check_invariants(a)
    assert a.used_blocks() == 4 + 1           # one private fork block


def test_cow_fork_at_block_boundary_copies_nothing():
    """Divergence exactly at a block boundary: whole-block adoption, no
    COW copy (the fork block's head match is empty)."""
    a = BlockAllocator(_pcfg())
    base = list(range(1, 9))                  # 2 shared blocks
    a.ensure(1, 8)
    a.register_prefix(1, base)
    shared = a.adopt_prefix(2, base[:8] + [777, 778])
    assert shared == 8                        # 2 blocks, zero COW bytes
    assert a.cow_forks == 0 and a.cow_copied_bytes == 0
    _check_invariants(a)


def test_adopt_skips_fork_when_out_of_blocks():
    """The COW fork must never raise: with zero free blocks the fork is
    skipped and only whole-block sharing happens."""
    a = BlockAllocator(_pcfg(n_blocks=3, dram_blocks=0))
    toks = list(range(1, 13))
    a.ensure(1, 12)                           # all 3 blocks
    a.register_prefix(1, toks)
    shared = a.adopt_prefix(2, toks)
    assert shared == 8                        # 2 whole blocks, no fork
    assert a.cow_forks == 0
    _check_invariants(a)


def test_sharing_off_prefix_api_is_inert():
    a = BlockAllocator(KVCacheConfig(n_blocks=8, block_tokens=4))
    toks = list(range(1, 13))
    a.ensure(1, 12)
    assert a.register_prefix(1, toks) == 0
    assert a.probe_prefix(toks) == 0
    assert a.adopt_prefix(2, toks) == 0
    assert 2 not in a.tables
    assert a.prefix_hits == a.cow_forks == a.shared_tokens_saved == 0
    _check_invariants(a)


def test_free_one_reader_of_spilled_shared_block_keeps_survivor():
    """ISSUE 6 satellite: a block that is both SHARED and SPILLED must
    survive one reader's free with the other reader's DRAM accounting
    intact — re-tiering rewrites every reader's table, and freeing only
    drops one refcount."""
    spills = []
    a = BlockAllocator(_pcfg(n_blocks=4, dram_blocks=4),
                       on_spill=spills.append)
    toks = list(range(1, 17))                 # 16 tokens = 4 blocks
    a.ensure(1, 16)                           # all 4 scratch blocks
    a.register_prefix(1, toks)
    shared = a.adopt_prefix(2, toks)          # 3 shared + COW fork
    # the fork had no free scratch: it spilled the coldest block — which
    # is SHARED (r1's oldest == r2's first) — to DRAM for BOTH readers
    assert shared == 15 and a.cow_forks == 1
    assert spills and a.spilled_blocks == 1
    _check_invariants(a)
    assert a.dram_tokens(1) == 4 and a.dram_tokens(2) == 4
    t1_blocks = list(a.tables[1].blocks)
    assert a.tables[2].blocks[0] == t1_blocks[0]  # same re-tiered id
    a.free(2)
    _check_invariants(a)
    # the survivor still sees its spilled block as DRAM-resident, the
    # DRAM free list did NOT absorb a block another table still reads
    assert a.dram_tokens(1) == 4
    assert a.tables[1].blocks == t1_blocks
    assert t1_blocks[0] not in a._free_dram
    a.free(1)
    _check_invariants(a)
    assert a.free_total() == a.cfg.total_blocks


def test_retier_updates_index_metadata():
    """Spilling an INDEXED block keeps it adoptable: the prefix index
    follows the content to its new physical id."""
    a = BlockAllocator(_pcfg(n_blocks=2, dram_blocks=4))
    toks = list(range(1, 9))                  # 2 blocks
    a.ensure(1, 8)
    a.register_prefix(1, toks)
    a.ensure(2, 4)                            # forces a spill of r1[0]
    _check_invariants(a)
    assert a.dram_tokens(1) == 4
    longer = toks + [55, 56, 57, 58]
    n = a.probe_prefix(longer)
    assert n == 2                             # both blocks still indexed
    shared = a.adopt_prefix(3, longer)
    assert shared == 8
    assert a.tables[3].blocks[:2] == a.tables[1].blocks[:2]
    _check_invariants(a)


# -- differential: allocator vs a content-addressed naive model ------------

class _NaiveSharingModel:
    """Independent reference model of the sharing allocator's OBSERVABLE
    state.  Blocks are identified by *content*: a shared prefix block by
    its whole token-prefix tuple, a private block by (rid, position) —
    no physical ids, free-list stacks, tiers or heaps.  Mirrors the
    adopt/register/free contract with plain dicts."""

    def __init__(self, cfg: KVCacheConfig):
        self.bt = cfg.block_tokens
        self.total = cfg.total_blocks
        self.keys = {}       # rid -> list of content keys
        self.readers = {}    # key -> set of rids
        self.index = {}      # prefix tuple -> key
        self.key_prefix = {}  # key -> the prefix tuple it is indexed as
        self.child = {}      # parent prefix -> (divergence chunk, key)
        self.hits = 0
        self.saved = 0
        self.forks = 0

    def used(self) -> int:
        return len(self.readers)

    def _add(self, rid, key):
        self.keys.setdefault(rid, []).append(key)
        self.readers.setdefault(key, set()).add(rid)

    def admit(self, rid, toks, can_fork: bool) -> int:
        """Adopt the longest indexed prefix + optional COW fork; returns
        the predicted shared token count."""
        bt = self.bt
        cap = max(0, (len(toks) - 1) // bt)
        n = 0
        while n < cap and tuple(toks[:(n + 1) * bt]) in self.index:
            n += 1
        if n == 0:
            self.grow(rid, len(toks))
            return 0
        for i in range(n):
            self._add(rid, self.index[tuple(toks[:(i + 1) * bt])])
        self.hits += n
        shared = n * bt
        cand = self.child.get(tuple(toks[:shared]))
        if cand is not None:
            chunk = cand[0]
            want = toks[shared:shared + bt]
            m = 0
            while m < len(chunk) and m < len(want) and chunk[m] == want[m]:
                m += 1
            m = min(m, len(toks) - 1 - shared)
            if m > 0 and can_fork:
                self._add(rid, ("fork", rid, n))
                self.forks += 1
                shared += m
        self.saved += shared
        self.grow(rid, len(toks))
        return shared

    def grow(self, rid, n_tokens) -> None:
        have = self.keys.setdefault(rid, [])
        while len(have) * self.bt < n_tokens:
            self._add(rid, ("priv", rid, len(have)))

    def register(self, rid, toks) -> None:
        keys = self.keys[rid]
        prev = ()
        for i in range(min(len(toks) // self.bt, len(keys))):
            pre = tuple(toks[:(i + 1) * self.bt])
            if pre not in self.index and keys[i] not in self.key_prefix:
                self.index[pre] = keys[i]
                self.key_prefix[keys[i]] = pre
                self.child.setdefault(
                    prev, (tuple(toks[i * self.bt:(i + 1) * self.bt]),
                           keys[i]))
            prev = pre

    def free(self, rid) -> None:
        for key in self.keys.pop(rid):
            r = self.readers[key]
            r.discard(rid)
            if not r:
                del self.readers[key]
                pre = self.key_prefix.pop(key, None)
                if pre is not None:
                    del self.index[pre]
                    parent = pre[:-self.bt]
                    if self.child.get(parent, (None, None))[1] == key:
                        del self.child[parent]


def _assert_matches_naive(a: BlockAllocator, naive: _NaiveSharingModel):
    """The allocator's sharing structure must be ISOMORPHIC to the naive
    model: same distinct-block usage, same stats, and a consistent
    physical-id <-> content-key bijection across every table."""
    assert a.used_blocks() == naive.used()
    assert a.prefix_hits == naive.hits
    assert a.shared_tokens_saved == naive.saved
    assert a.cow_forks == naive.forks
    assert set(a.tables) == set(naive.keys)
    phys_of, key_of = {}, {}
    for rid, keys in naive.keys.items():
        blocks = a.tables[rid].blocks
        assert len(blocks) == len(keys), (rid, blocks, keys)
        for b, k in zip(blocks, keys):
            assert phys_of.setdefault(k, b) == b, "key maps to two ids"
            assert key_of.setdefault(b, k) == k, "id maps to two keys"
    for b, k in key_of.items():
        assert a.refcnt[b] == len(naive.readers[k])


@settings(max_examples=25, deadline=None)
@given(n_blocks=st.integers(3, 14), dram=st.integers(0, 10),
       block_tokens=st.integers(2, 6), seed=st.integers(0, 9999))
def test_sharing_cow_random_walk_vs_naive_reference(n_blocks, dram,
                                                    block_tokens, seed):
    """Random admit(adopt+grow+register)/extend/free walks over a small
    family of overlapping prompts: after EVERY operation the allocator
    passes the full invariant check, indexed block contents never change
    while referenced, and its observable state equals the naive
    content-addressed model replayed on the same walk."""
    rng = np.random.default_rng(seed)
    cfg_ = KVCacheConfig(n_blocks=n_blocks, block_tokens=block_tokens,
                         dram_blocks=dram, bytes_per_token=8,
                         prefix_sharing=True)
    a = BlockAllocator(cfg_)
    naive = _NaiveSharingModel(cfg_)
    live = {}                       # rid -> token list
    frozen_chunks = {}              # chain hash -> first-seen chunk
    next_rid = 1
    for op in rng.integers(0, 4, size=60):
        if op <= 1 or not live:                       # admit a request
            rid, next_rid = next_rid, next_rid + 1
            g = int(rng.integers(0, 2))               # shared family
            cut = int(rng.integers(0, 4 * block_tokens))
            p = cut + int(rng.integers(1, 2 * block_tokens))
            toks = [g * 1000 + j for j in range(cut)] \
                + [-(rid * 1000 + j) for j in range(p - cut)]
            free0 = a.free_total()
            hashes = a.chunk_hashes(toks)
            shared = a.adopt_prefix(rid, toks, hashes)
            try:
                a.ensure(rid, len(toks))
                ok = True
            except OutOfBlocks:
                ok = False
            if ok:
                a.register_prefix(rid, toks, hashes)
                want = naive.admit(rid, toks, can_fork=free0 > 0)
                assert shared == want, (shared, want)
                naive.register(rid, toks)
                live[rid] = toks
            else:
                a.free(rid)       # walk policy: drop on failed admit
                naive.admit(rid, toks, can_fork=free0 > 0)
                naive.free(rid)
        elif op == 2:                                 # decode growth
            rid = int(rng.choice(list(live)))
            want = a.tables[rid].tokens \
                + int(rng.integers(1, block_tokens + 1))
            try:
                a.ensure(rid, want)
            except OutOfBlocks:
                pass              # partial growth kept (covered below)
            naive.grow(rid, a.tables[rid].tokens)
        else:                                         # free a request
            rid = int(rng.choice(list(live)))
            a.free(rid)
            naive.free(rid)
            del live[rid]
        _check_invariants(a)
        _assert_matches_naive(a, naive)
        # shared blocks are immutable: an indexed chunk's contents must
        # never change for as long as any chain entry references it
        for h, b in a._index.items():
            assert frozen_chunks.setdefault(h, a._tok_of[b]) \
                == a._tok_of[b], "indexed block mutated in place"
    assert a.peak_used <= cfg_.total_blocks
    assert a.peak_shared_blocks >= a.n_shared_blocks


# ---------------------------------------------------------------------------
# Capacity-aware serving
# ---------------------------------------------------------------------------

def _kvc(cfg, n_blocks, dram_blocks=0, block_tokens=16):
    return KVCacheConfig(n_blocks=n_blocks, block_tokens=block_tokens,
                         dram_blocks=dram_blocks,
                         bytes_per_token=kv_bytes_per_token(cfg))


def test_roomy_cache_matches_infinite(cfg):
    """A cache big enough for the whole trace must reproduce the
    infinite-capacity schedule (same report numbers, no preemptions)."""
    rows = [(0.01 * i, 64 + 8 * i, 12) for i in range(8)]
    r_inf = serve_trace(cfg, replay_trace(rows), max_batch=4)
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
        max_batch=4, kv_cache=_kvc(cfg, n_blocks=10_000)))
    r_kv = eng.run(replay_trace(rows))
    assert r_kv.row() == r_inf.row()
    st_ = eng.kv_stats
    assert st_.preemptions == 0 and st_.spilled_blocks == 0
    assert st_.peak_blocks_used > 0
    assert eng.kv.free_total() == eng.kv.cfg.total_blocks  # all returned


def test_preemption_restores_exact_context_lengths(cfg):
    """Watermark/OOM preemption + recompute-on-resume: every request
    still finishes with context == prompt_len + max_new and generated ==
    max_new, and at least one preemption actually happened."""
    trace = replay_trace([(0.0, 100, 60) for _ in range(6)])
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
        max_batch=4, kv_cache=_kvc(cfg, n_blocks=40)))
    rep = eng.run(trace)
    st_ = eng.kv_stats
    assert rep.finished == 6 and rep.rejected == 0
    assert st_.preemptions > 0
    assert st_.recomputed_tokens > 0
    kinds = [k for _, k, _ in eng.events]
    assert EventKind.PREEMPT in kinds
    for r in trace:
        assert r.generated == r.max_new
        assert r.context == r.prompt_len + r.max_new
        assert r.finished_at >= r.first_token_at >= r.arrival
    # cache fully drained at the end
    assert eng.kv.free_total() == eng.kv.cfg.total_blocks


def test_spill_charges_c2c_and_dram_energy(cfg):
    """With a DRAM tier, overflow spills instead of preempting: kv_spill
    and kv_fetch C2CTransfer events appear on the timeline, and the
    remote reads make the run slower and hungrier than an unconstrained
    one."""
    rows = [(0.0, 200, 40) for _ in range(4)]
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
        max_batch=4, kv_cache=_kvc(cfg, n_blocks=40, dram_blocks=80)))
    rep = eng.run(replay_trace(rows))
    st_ = eng.kv_stats
    assert rep.finished == 4
    assert st_.spilled_blocks > 0 and st_.dram_read_bytes > 0
    phases = {e.phase for e in eng.timeline.events
              if isinstance(e, C2CTransfer)}
    assert {"kv_spill", "kv_fetch"} <= phases
    spill_bytes = sum(e.nbytes for e in eng.timeline.events
                      if isinstance(e, C2CTransfer)
                      and e.phase == "kv_spill")
    assert spill_bytes == st_.spilled_bytes
    r_inf = serve_trace(cfg, replay_trace(rows), max_batch=4)
    assert rep.wall_s > r_inf.wall_s          # exposed remote-read stalls
    assert rep.energy_J > r_inf.energy_J      # link + DRAM access energy
    assert rep.tokens_per_J < r_inf.tokens_per_J


def test_admission_waits_for_blocks_not_just_slots(cfg):
    """Free slots but no free blocks: admission must hold the request in
    the queue (not reject it) until residents finish and free blocks."""
    kvc = _kvc(cfg, n_blocks=20)            # 320 tokens of KV
    trace = replay_trace([(0.0, 150, 30), (0.0, 150, 30), (0.0, 150, 8)])
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
        max_batch=8, kv_cache=kvc))         # slots are NOT the binding cap
    rep = eng.run(trace)
    assert rep.finished == 3 and rep.rejected == 0
    # with 8 slots free throughout, occupancy was block-bound: the third
    # request could not be co-resident from the start
    assert rep.mean_batch_occupancy < 3.0


def test_infeasible_request_rejected_upfront(cfg):
    """A request that cannot fit even an EMPTY cache is rejected at
    admission, not deadlocked."""
    kvc = KVCacheConfig(n_blocks=4, block_tokens=16,
                        bytes_per_token=kv_bytes_per_token(cfg))
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
        max_batch=2, kv_cache=kvc))
    rep = eng.run(replay_trace([(0.0, 1000, 4), (0.0, 20, 4)]))
    assert rep.rejected == 1 and rep.finished == 1
    assert eng.kv_stats.infeasible_rejects == 1


def test_chunked_prefill_bounds_decode_stall(cfg):
    """A long prompt must not monopolize an iteration: with chunking the
    resident stream's max inter-token gap collapses (the whole point),
    while the total work only grows by the re-paid pipeline fills."""
    rows = [(0.0, 64, 400), (0.001, 8192, 4)]

    def run(chunk):
        eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
            max_batch=4, chunked_prefill_tokens=chunk))
        rep = eng.run(replay_trace(rows))
        ts = [e.t0 for e in eng.timeline.events
              if isinstance(e, TokenEmit) and e.request_id == 0]
        return rep, max(b - a for a, b in zip(ts, ts[1:]))

    rep_mono, gap_mono = run(0)
    rep_chunk, gap_chunk = run(256)
    assert rep_chunk.finished == rep_mono.finished == 2
    assert gap_chunk < 0.25 * gap_mono
    assert rep_mono.busy_s < rep_chunk.busy_s < 1.1 * rep_mono.busy_s


def test_chunked_prefill_partial_is_preemptible(cfg):
    """An in-flight chunked prefill holds KV blocks outside the slots;
    when a lone resident's growth exhausts the cache it must be able to
    evict the partial (recompute-on-resume) instead of crashing — the
    same trace completes with chunking off, so it must with it on."""
    kvc = KVCacheConfig(n_blocks=84, block_tokens=16,
                        bytes_per_token=kv_bytes_per_token(cfg))
    rows = [(0.0, 20, 600), (0.001, 1200, 8)]
    eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
        max_batch=4, kv_cache=kvc, chunked_prefill_tokens=16,
        decode_quantum=4))
    trace = replay_trace(rows)
    rep = eng.run(trace)          # used to raise RuntimeError
    assert rep.finished == 2 and rep.rejected == 0
    assert eng.kv_stats.preemptions > 0
    for r in trace:
        assert r.generated == r.max_new
        assert r.context == r.prompt_len + r.max_new
    assert eng.kv.free_total() == eng.kv.cfg.total_blocks


def test_chunked_prefill_cycles_compose(cfg):
    """One whole-prompt chunk is EXACTLY the classic prefill (golden
    identity); summed chunks cost slightly more (pipeline re-fill)."""
    cm = CycleModel()
    alloc = allocate_chiplets(cfg)
    whole, whole_c2c = cm.prefill_cycles(cfg, alloc, 1024)
    one, one_c2c = cm.prefill_chunk_cycles(cfg, alloc, 1024, 0)
    assert (one, one_c2c) == (whole, whole_c2c)
    tot = tot_c2c = 0
    for off in range(0, 1024, 256):
        c, b = cm.prefill_chunk_cycles(cfg, alloc, 256, off)
        tot += c
        tot_c2c += b
    assert whole < tot < 1.1 * whole
    assert tot_c2c == whole_c2c              # same activation traffic


def test_rerunning_a_trace_is_idempotent(cfg):
    """run() resets the mutable per-request state: the resume/recompute
    paths branch on it, so a second run over the same TrackedRequest
    objects must reproduce the first run's report exactly (with and
    without paging)."""
    for kvc in (None, _kvc(cfg, n_blocks=40)):
        eng = ContinuousBatchingEngine(cfg, engine=ServingConfig(
            max_batch=4, kv_cache=kvc))
        trace = replay_trace([(0.0, 100, 8), (0.01, 64, 8)])
        r1 = eng.run(trace)
        r2 = eng.run(trace)
        assert r1.row() == r2.row()
        assert r1.tokens_generated == r2.tokens_generated == 16


def test_default_engine_has_no_kv_state(cfg):
    eng = ContinuousBatchingEngine(cfg)
    assert eng.kv is None and eng.kv_stats is None


# ---------------------------------------------------------------------------
# Paged-attention kernel vs oracle (interpret mode, fast lane)
# ---------------------------------------------------------------------------

def _random_paged_case(seed, B=3, H=4, Hkv=2, D=64, bt=16, n_blocks=32,
                       ctxs=(37, 16, 1)):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(n_blocks, bt, Hkv, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(n_blocks, bt, Hkv, D)), jnp.float32)
    ctx = np.asarray(ctxs, np.int32)
    max_blocks = max(-(-int(c) // bt) for c in ctxs)
    tables = np.zeros((B, max_blocks), np.int32)
    perm = rng.permutation(n_blocks)       # scattered physical blocks
    off = 0
    for b in range(B):
        n = -(-int(ctx[b]) // bt)
        tables[b, :n] = perm[off:off + n]
        off += n
    return q, kc, vc, tables, ctx


def test_paged_attention_matches_oracle():
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    q, kc, vc, tables, ctx = _random_paged_case(0)
    o = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                            jnp.asarray(ctx))
    r = ref.ref_paged_attention(q, kc, vc, tables, ctx)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5


def test_paged_attention_gqa_and_ragged_contexts():
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    # context lengths straddle block boundaries; H == 8 over H_kv == 2
    q, kc, vc, tables, ctx = _random_paged_case(
        1, B=4, H=8, Hkv=2, D=32, bt=8, n_blocks=24, ctxs=(8, 9, 23, 1))
    o = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                            jnp.asarray(ctx))
    r = ref.ref_paged_attention(q, kc, vc, tables, ctx)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5


def test_paged_attention_ignores_stale_table_entries():
    """Entries past ceil(ctx/bt) must never be read: poisoning them with
    out-of-range garbage-free ids pointing at NaN blocks must not change
    the output."""
    import jax.numpy as jnp
    from repro.kernels import ops
    q, kc, vc, tables, ctx = _random_paged_case(2)
    used = {int(tables[b, i]) for b in range(tables.shape[0])
            for i in range(-(-int(ctx[b]) // 16))}
    poison = next(i for i in range(kc.shape[0]) if i not in used)
    kc = kc.at[poison].set(jnp.nan)
    vc = vc.at[poison].set(jnp.nan)
    o1 = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                             jnp.asarray(ctx))
    poisoned = tables.copy()
    for b in range(tables.shape[0]):
        n = -(-int(ctx[b]) // 16)
        poisoned[b, n:] = poison           # stale slots -> poison block
    o2 = ops.paged_attention(q, kc, vc, jnp.asarray(poisoned),
                             jnp.asarray(ctx))
    assert bool(jnp.all(o1 == o2))
    assert not bool(jnp.any(jnp.isnan(o1)))


def test_paged_attention_pwl_close_to_scu_softmax():
    """PWL mode: the online rescaling composes PWL segments across
    blocks, so it approximates (not bit-matches) the dense one-pass SCU
    softmax — bounded deviation, exact path unaffected."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    q, kc, vc, tables, ctx = _random_paged_case(3)
    o = ops.paged_attention(q, kc, vc, jnp.asarray(tables),
                            jnp.asarray(ctx), use_pwl=True)
    r = ref.ref_paged_attention(q, kc, vc, tables, ctx, use_pwl=True)
    assert float(jnp.max(jnp.abs(o - r))) < 0.05


def test_paged_attention_matches_contiguous_flash_decode():
    """Identity block table + contiguous cache == plain causal decode
    attention over the same K/V (cross-check against the dense oracle of
    the existing flash kernel)."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(4)
    B, H, D, bt, L = 2, 4, 32, 8, 40
    n_blocks = L // bt * B + B
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, H, D)), jnp.float32)
    # pack each sequence's K/V into consecutive blocks
    nb = L // bt
    kc = jnp.concatenate([k[b].reshape(nb, bt, H, D) for b in range(B)])
    vc = jnp.concatenate([v[b].reshape(nb, bt, H, D) for b in range(B)])
    tables = np.asarray([[b * nb + i for i in range(nb)]
                         for b in range(B)], np.int32)
    ctx = np.full((B,), L, np.int32)
    o = ops.paged_attention(q[:, 0], kc, vc, jnp.asarray(tables),
                            jnp.asarray(ctx))
    # dense oracle: single query attending over the full context
    r = ref.ref_flash_attention(q, k, v, causal=False)[:, 0]
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5
