"""Deterministic fault injection + graceful degradation (ISSUE 10).

Locks the chaos contract of launch/fleet_engine.py:

  * an INERT FaultConfig is invisible — hex-identical reports, event
    logs and timelines vs ``fault=None`` (the zero-fault code paths are
    byte-identical, not merely close);
  * the same FaultConfig replayed (or round-tripped through
    to_dict/from_dict) yields hex-identical results — faults are data,
    not wall-clock accidents;
  * killing a node mid-run never loses work silently: every request
    either finishes (with visible kv_recompute / retransmit pricing on
    the survivors' timelines) or is counted rejected with a cause;
  * NodeFail / NodeRecover land on the dead node's own timeline,
    downtime accrues at zero power, and availability / MTTR come out of
    the DES clock;
  * CCPG wake failures retry with backoff then fall back to the awake
    pool — never a hang, never a silent drop;
  * a golden pins one full chaos run (report floats + event counts) so
    refactors can't drift the fault arithmetic unnoticed.

Regenerate the golden after an INTENDED change:

    PYTHONPATH=src:tests python tests/test_chaos.py
"""
import copy
import dataclasses
import json
from pathlib import Path

import pytest

import _hyp_compat

_hyp_compat.install()   # also needed on the __main__ regen path
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.core import PicnicSimulator
from repro.core.timeline import (C2CTransfer, ClusterSleep, EnergySample,
                                 NodeFail, NodeRecover)
from repro.launch import FleetConfig, ServingConfig, Trace
from repro.launch.config import (FaultConfig, LinkFault, NodeFault,
                                 WakeFault)
from repro.launch.fleet_engine import FleetEngine

GOLDEN_PATH = Path(__file__).parent / "golden" / "chaos_golden.json"


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama3.2-1b")


def _trace(n=24, rate=40, prompt=256, max_new=32, seed=0, **kw):
    return Trace.poisson(n, rate_rps=rate, seed=seed, prompt_len=prompt,
                         max_new=max_new, **kw)


def _hexdict(obj) -> dict:
    d = dataclasses.asdict(obj)
    d.pop("queue_depth", None)
    d.pop("node_reports", None)
    return {k: (v.hex() if isinstance(v, float) else v)
            for k, v in d.items()}


def _hexevents(timeline):
    out = []
    for e in timeline.events:
        out.append(tuple(v.hex() if isinstance(v, float) else v
                         for v in dataclasses.astuple(e)))
    return out


def _run(cfg, fleet, trace):
    fe = FleetEngine(cfg, fleet, sim=PicnicSimulator())
    rep = fe.run([copy.copy(r) for r in trace])
    return fe, rep


# ---------------------------------------------------------------------------
# Zero-fault byte-identity
# ---------------------------------------------------------------------------

def test_inert_fault_config_is_invisible(cfg):
    """fault=None and an inert FaultConfig() take the SAME code paths:
    hex-identical fleet report, node reports, event logs and timelines,
    and the report row gains no fault columns."""
    ecfg = ServingConfig(max_batch=4, ccpg=True)
    trace = _trace()
    base = FleetConfig(n_prefill=2, n_decode=2, engine=ecfg)
    inert = dataclasses.replace(base, fault=FaultConfig())
    assert not FaultConfig().active()

    fe0, rep0 = _run(cfg, base, trace)
    fe1, rep1 = _run(cfg, inert, trace)

    assert _hexdict(rep1) == _hexdict(rep0)
    assert rep1.availability is None and rep1.mttr_s is None
    assert "availability" not in rep1.row()
    for n0, n1 in zip(fe0.nodes, fe1.nodes):
        assert n1.eng.events == n0.eng.events
        assert _hexevents(n1.eng.timeline) == _hexevents(n0.eng.timeline)
    for r0, r1 in zip(rep0.node_reports, rep1.node_reports):
        assert _hexdict(r1) == _hexdict(r0)


# ---------------------------------------------------------------------------
# Determinism of an ACTIVE schedule
# ---------------------------------------------------------------------------

def _chaos_fleet(fault):
    return FleetConfig(n_prefill=2, n_decode=2,
                       engine=ServingConfig(max_batch=4, ccpg=True),
                       fault=fault)


def _chaos_fault():
    """Fixed mixed scenario: a link-degradation window spanning the busy
    phase + the first decode node dying and rejoining."""
    return FaultConfig(
        links=(LinkFault(t_start=0.02, t_end=0.45, retransmit_frac=0.2),),
        nodes=(NodeFault(node=2, t_fail=0.15, t_recover=0.37),))


def test_same_fault_config_hex_identical(cfg):
    trace = _trace()
    _, rep0 = _run(cfg, _chaos_fleet(_chaos_fault()), trace)
    _, rep1 = _run(cfg, _chaos_fleet(_chaos_fault()), trace)
    assert _hexdict(rep1) == _hexdict(rep0)
    for a, b in zip(rep0.node_reports, rep1.node_reports):
        assert _hexdict(a) == _hexdict(b)
    # ... and through the config wire format
    fc2 = FaultConfig.from_dict(_chaos_fault().to_dict())
    assert fc2 == _chaos_fault()
    _, rep2 = _run(cfg, _chaos_fleet(fc2), trace)
    assert _hexdict(rep2) == _hexdict(rep0)


def test_seeded_schedule_reproducible():
    a = FaultConfig.seeded(seed=7, n_nodes=4, horizon_s=1.0,
                           link_windows=2, node_crashes=2, wake_faults=1)
    b = FaultConfig.seeded(seed=7, n_nodes=4, horizon_s=1.0,
                           link_windows=2, node_crashes=2, wake_faults=1)
    c = FaultConfig.seeded(seed=8, n_nodes=4, horizon_s=1.0,
                           link_windows=2, node_crashes=2, wake_faults=1)
    assert a == b and a != c and a.active()
    for w in a.links:
        assert 0.0 < w.t_start < w.t_end
    for nf in a.nodes:
        assert 0 <= nf.node < 4 and nf.t_fail < nf.t_recover


# ---------------------------------------------------------------------------
# Crash / recover semantics
# ---------------------------------------------------------------------------

def test_killed_decode_node_survivors_all_finish(cfg):
    """Kill decode node 2 while it holds in-flight KV: nothing silently
    lost — every request finishes or is counted rejected; the recovery
    work is VISIBLE (kv_recompute prefills, retransmit transfers,
    NodeFail/NodeRecover on the dead node's timeline)."""
    trace = _trace()
    fe, rep = _run(cfg, _chaos_fleet(_chaos_fault()), trace)

    assert rep.finished + rep.rejected == len(trace)
    assert rep.node_failures == 1 and rep.node_recoveries == 1
    assert rep.availability is not None and 0.0 < rep.availability < 1.0
    assert rep.mttr_s == pytest.approx(rep.downtime_s)
    assert rep.downtime_s > 0.0
    # reject attribution: every rejection carries a cause
    assert rep.rejected == (rep.slo_rejected + rep.router_rejected
                            + rep.fault_shed)

    phases = {e.phase for n in fe.nodes for e in n.eng.timeline.events
              if isinstance(e, C2CTransfer)}
    assert "retransmit" in phases          # link window priced the FEC
    assert rep.retransmit_bytes > 0
    # the dead node held partially-decoded KV: it was rebuilt from the
    # prompt and is VISIBLE as a kv_recompute handoff, never silent
    assert rep.recomputes > 0 and rep.recompute_tokens > 0
    assert "kv_recompute" in phases

    dead = fe.nodes[2]
    evs = dead.eng.timeline.events
    fails = [e for e in evs if isinstance(e, NodeFail)]
    recs = [e for e in evs if isinstance(e, NodeRecover)]
    assert len(fails) == 1 and len(recs) == 1
    assert fails[0].node == 2 and recs[0].node == 2
    assert recs[0].downtime_s == pytest.approx(0.37 - 0.15)
    # the dead gap is padded at ZERO power — a dead node burns nothing
    pads = [e for e in evs if isinstance(e, ClusterSleep) and e.power_W == 0.0]
    assert pads and sum(p.dur_s for p in pads) > 0.0
    # the fleet row exposes the chaos block
    row = rep.row()
    assert {"availability", "goodput_tokens_per_s", "mttr_s",
            "downtime_s"} <= row.keys()
    assert "fault model" in rep.summary()
    assert "availability" in rep.summary()


def test_crash_without_recovery_never_silent(cfg):
    """A combined-pool node that dies and never comes back: the fleet
    drains its work to the survivor or sheds it WITH a cause; downtime
    accrues to the end of the run."""
    fc = FaultConfig(nodes=(NodeFault(node=1, t_fail=0.05),))
    fleet = FleetConfig(n_prefill=2, n_decode=0, handoff=False,
                        engine=ServingConfig(max_batch=4, ccpg=True),
                        fault=fc)
    trace = _trace()
    fe, rep = _run(cfg, fleet, trace)
    assert rep.finished + rep.rejected == len(trace)
    assert rep.node_failures == 1 and rep.node_recoveries == 0
    assert rep.mttr_s is None or rep.mttr_s != rep.mttr_s  # NaN -> None
    # unrecovered downtime runs to the wall
    assert rep.downtime_s == pytest.approx(rep.wall_s - 0.05)
    assert 0.0 < rep.availability < 1.0
    assert rep.rejected == (rep.slo_rejected + rep.router_rejected
                            + rep.fault_shed)
    # the dead node stays frozen: after its NodeFail instant nothing
    # runs — only the end-of-run zero-power pad follows
    dead = fe.nodes[1]
    evs = dead.eng.timeline.events
    i_fail = next(i for i, e in enumerate(evs) if isinstance(e, NodeFail))
    tail = evs[i_fail + 1:]
    assert tail and all(isinstance(e, (ClusterSleep, EnergySample))
                        for e in tail)
    assert any(isinstance(e, ClusterSleep) and e.power_W == 0.0
               for e in tail)


def test_transient_blip_resumes_in_place(cfg):
    """A crash shorter than heartbeat_dead_s is never DETECTED: the
    router keeps routing, the node resumes its own queue on recovery,
    and nothing is drained or shed."""
    fc = FaultConfig(nodes=(NodeFault(node=0, t_fail=0.05,
                                      t_recover=0.055),),
                     heartbeat_dead_s=0.050)
    fleet = FleetConfig(n_prefill=2, n_decode=0, handoff=False,
                        engine=ServingConfig(max_batch=4, ccpg=True),
                        fault=fc)
    _, rep = _run(cfg, fleet, trace := _trace())
    assert rep.finished == len(trace)
    assert rep.fault_shed == 0 and rep.recomputes == 0
    assert rep.node_failures == 1 and rep.node_recoveries == 1
    assert rep.mttr_s == pytest.approx(0.005)


# ---------------------------------------------------------------------------
# CCPG wake faults
# ---------------------------------------------------------------------------

def test_wake_faults_retry_with_backoff_then_succeed(cfg):
    """Autoscale wants the asleep decode node; its first wake attempts
    time out.  The router retries (bounded, backoff-priced) and the
    fleet still finishes everything."""
    fc = FaultConfig(wakes=(WakeFault(node=3, failures=2),))
    fleet = FleetConfig(n_prefill=2, n_decode=2,
                        engine=ServingConfig(max_batch=4, ccpg=True),
                        autoscale=True, min_awake=1, scale_up_queue=2,
                        fault=fc)
    trace = _trace()
    fe, rep = _run(cfg, fleet, trace)
    assert rep.finished == len(trace)
    assert rep.wake_retries >= 2
    assert rep.wakes > 0
    # the retries priced real time: the woken node's first event starts
    # strictly later than it would have zero-fault
    fe0, rep0 = _run(cfg, dataclasses.replace(fleet, fault=None), trace)
    assert rep.wall_s >= rep0.wall_s


def test_wake_fault_budget_exhaustion_falls_back(cfg):
    """More failures than the retry budget: the router gives up on the
    faulty node (wake_fallbacks) and lands the work on the awake pool —
    requests still finish or shed with a cause, never hang."""
    fc = FaultConfig(wakes=(WakeFault(node=3, failures=50),),
                     wake_retries=3)
    fleet = FleetConfig(n_prefill=2, n_decode=2,
                        engine=ServingConfig(max_batch=4, ccpg=True),
                        autoscale=True, min_awake=1, scale_up_queue=2,
                        fault=fc)
    trace = _trace()
    fe, rep = _run(cfg, fleet, trace)
    assert rep.finished + rep.rejected == len(trace)
    assert rep.wake_fallbacks > 0
    # the faulty node never woke for the autoscaler's sake
    assert fe.nodes[3].wakes == 0 or rep.wake_retries >= 50


# ---------------------------------------------------------------------------
# Validation + config wire format
# ---------------------------------------------------------------------------

def test_bad_node_ids_rejected(cfg):
    fleet = FleetConfig(n_prefill=1, n_decode=1,
                        fault=FaultConfig(nodes=(NodeFault(node=7,
                                                           t_fail=0.1),)))
    with pytest.raises(ValueError, match="node"):
        FleetEngine(cfg, fleet, sim=PicnicSimulator())
    fleet = FleetConfig(n_prefill=1, n_decode=1,
                        fault=FaultConfig(wakes=(WakeFault(node=-1),)))
    with pytest.raises(ValueError, match="node"):
        FleetEngine(cfg, fleet, sim=PicnicSimulator())


def test_fault_config_wire_format():
    fc = FaultConfig.seeded(seed=3, n_nodes=4, horizon_s=0.5,
                            link_windows=1, node_crashes=1, wake_faults=1)
    d = fc.to_dict()
    assert d["schema"] == FaultConfig.SCHEMA_VERSION
    assert FaultConfig.from_dict(json.loads(json.dumps(d))) == fc
    with pytest.raises((KeyError, TypeError, ValueError)):
        FaultConfig.from_dict({"schema": 1, "no_such_knob": 1})
    # the fault block rides the FleetConfig wire format too
    fl = FleetConfig(n_prefill=2, n_decode=1, fault=fc)
    fl2 = FleetConfig.from_dict(json.loads(json.dumps(fl.to_dict())))
    assert fl2.fault == fc


# ---------------------------------------------------------------------------
# Golden: one full chaos run, hex-pinned
# ---------------------------------------------------------------------------

def _golden_payload():
    cfg = get_config("llama3.2-1b")
    trace = _trace()
    fe, rep = _run(cfg, _chaos_fleet(_chaos_fault()), trace)
    return {
        "report": _hexdict(rep),
        "node_reports": [_hexdict(r) for r in rep.node_reports],
        "n_events": [len(n.eng.timeline.events) for n in fe.nodes],
        "clocks": [n.eng.timeline.now.hex() for n in fe.nodes],
    }


def test_chaos_golden():
    assert GOLDEN_PATH.exists(), \
        f"regenerate: PYTHONPATH=src:tests python {Path(__file__).name}"
    golden = json.loads(GOLDEN_PATH.read_text())
    assert _golden_payload() == golden


# ---------------------------------------------------------------------------
# Property: any seeded schedule degrades gracefully + deterministically
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       crashes=st.integers(0, 2),
       links=st.integers(0, 2),
       wakes=st.integers(0, 1),
       recover=st.booleans())
def test_seeded_chaos_conserves_requests(seed, crashes, links, wakes,
                                         recover):
    """Differential property over the schedule space: whatever the
    seeded fault draw, (a) no request vanishes — finished + rejected ==
    n, every rejection attributed; (b) availability is a probability;
    (c) the run replays hex-identically."""
    cfg = get_config("llama3.2-1b")
    fc = FaultConfig.seeded(seed=seed, n_nodes=4, horizon_s=0.5,
                            link_windows=links, node_crashes=crashes,
                            wake_faults=wakes, recover=recover)
    fleet = FleetConfig(n_prefill=2, n_decode=2,
                        engine=ServingConfig(max_batch=4, ccpg=True),
                        autoscale=bool(wakes), min_awake=1,
                        scale_up_queue=2, fault=fc)
    trace = _trace(n=12, max_new=16)
    _, rep = _run(cfg, fleet, trace)
    assert rep.finished + rep.rejected == len(trace)
    if fc.active():
        assert 0.0 <= rep.availability <= 1.0
        assert rep.rejected == (rep.slo_rejected + rep.router_rejected
                                + rep.fault_shed)
        assert rep.node_failures == len(fc.nodes)
    else:
        assert rep.availability is None
    _, rep2 = _run(cfg, fleet, trace)
    assert _hexdict(rep2) == _hexdict(rep)


if __name__ == "__main__":
    GOLDEN_PATH.parent.mkdir(exist_ok=True)
    GOLDEN_PATH.write_text(json.dumps(_golden_payload(), indent=1,
                                      sort_keys=True))
    print(f"wrote {GOLDEN_PATH}")
